"""Beyond-paper benchmark: prediction accuracy across the whole assigned
zoo (10 architectures x shapes), vs the compiled-XLA ground truth captured
by the dry-run.  The paper validates one model (LLaVA-1.5); this table
shows the factorization generalizes across dense/MoE/SSM/hybrid/VLM/enc-dec
families — its central design claim.
"""

from __future__ import annotations

import json
import os
import sys

from benchmarks.common import (EXP_DIR, GiB, load_dryrun, mape,
                               predict_record)


def run(mesh: str = "16x16", verbose: bool = True) -> dict:
    records = load_dryrun(mesh)
    if not records:
        print("no dry-run artifacts; run python -m repro.launch.dryrun --all",
              file=sys.stderr)
        return {}
    rows = []
    for rec in records:
        pred = predict_record(rec, backend="cpu")
        actual = rec["memory"]["total_bytes"]
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"],
            "kind": rec["kind"],
            "predicted_bytes": pred.peak_bytes,
            "actual_bytes": actual,
            "ape": 100.0 * abs(pred.peak_bytes - actual) / actual,
        })
    by_kind = {}
    for r in rows:
        by_kind.setdefault(r["kind"], []).append(
            (r["predicted_bytes"], r["actual_bytes"]))
    out = {
        "mesh": mesh,
        "rows": rows,
        "mape_total": mape([(r["predicted_bytes"], r["actual_bytes"])
                            for r in rows]),
        "mape_by_kind": {k: mape(v) for k, v in by_kind.items()},
    }
    if verbose:
        print(f"\n=== arch sweep (mesh {mesh}): predicted vs XLA peak "
              f"(GiB/device) ===")
        print(f"{'arch':<24s}{'shape':<14s}{'pred':>9s}{'actual':>9s}"
              f"{'APE%':>8s}")
        for r in sorted(rows, key=lambda r: (r['arch'], r['shape'])):
            print(f"{r['arch']:<24s}{r['shape']:<14s}"
                  f"{r['predicted_bytes']/GiB:9.2f}"
                  f"{r['actual_bytes']/GiB:9.2f}{r['ape']:8.1f}")
        print(f"MAPE: total {out['mape_total']:.1f}%  by kind: " +
              "  ".join(f"{k}={v:.1f}%" for k, v in
                        out["mape_by_kind"].items()))
    os.makedirs(EXP_DIR, exist_ok=True)
    with open(os.path.join(EXP_DIR, f"arch_sweep_{mesh}.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    run()
