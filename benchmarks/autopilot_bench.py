"""Memory-autopilot benchmark: mitigation-search latency + OOM avoidance.

    PYTHONPATH=src python benchmarks/autopilot_bench.py

Two gates, written to ``BENCH_autopilot.{json,md}``:

* **Mitigation-search latency** — wall time of one full
  :meth:`~repro.autopilot.mitigation.MitigationPlanner.plan` call
  (enumerate every knob move, predict each through the memoized sweep
  engine, rank) on the harness cell and on a pipeline cell, cold and
  warm.  The closed loop runs this inside a training step's admission
  window, so the warm path must stay well under a step time (tens of
  milliseconds).

* **OOM-avoidance rate** — every synthetic drift scenario run guarded
  and unguarded through ResilientTrainer.  The guarded trainer must
  complete ALL scenarios with zero injected OOMs and zero restarts
  while the unguarded baseline aborts on each; any guarded abort or
  OOM is a nonzero exit (the property CI pins).
"""

from __future__ import annotations

import argparse
import statistics
import sys
import time

sys.path.insert(0, __file__.rsplit("/", 1)[0])

from common import GiB, write_bench  # noqa: E402

from repro.autopilot import (SCENARIOS, MitigationPlanner, base_cell,
                             run_scenario)  # noqa: E402
from repro.core import sweep as SW  # noqa: E402
from repro.core.spec import FULL_TRAIN  # noqa: E402

#: pipeline-parallel planning cell: more knob moves in scope
#: (microbatch doubling joins accum/offload/remat/reshard)
PP_CELL = SW.SweepCell(
    arch="llama3.2-3b", chip="v5e",
    mesh=(("data", 2), ("model", 2), ("pipe", 2)),
    optimizer=None, remat="none", grad_accum=1, global_batch=64,
    seq_len=2048, kind="train", backend="tpu",
    microbatches=4, schedule="1f1b")


def time_plan(planner: MitigationPlanner, cell, repeats: int = 5) -> dict:
    """Cold (first, empty memo) + warm (median of repeats) plan latency."""
    t0 = time.perf_counter()
    plan = planner.plan(cell, ewma_ratio=1.2)
    cold_ms = (time.perf_counter() - t0) * 1e3
    warm = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        planner.plan(cell, ewma_ratio=1.2)
        warm.append((time.perf_counter() - t0) * 1e3)
    return {"candidates": len(plan.candidates),
            "reaches_safety": plan.reaches_safety,
            "cold_ms": round(cold_ms, 2),
            "warm_ms": round(statistics.median(warm), 3)}


def run(out_dir: str = None, verbose: bool = True) -> dict:
    engine = SW.SweepEngine()
    planner = MitigationPlanner(engine=engine, policy=FULL_TRAIN)
    latency = {"harness-cell": time_plan(planner, base_cell()),
               "pp-cell": time_plan(planner, PP_CELL)}

    rows, guarded_failures = [], 0
    for scn in SCENARIOS:
        for guarded in (True, False):
            r = run_scenario(scn, guarded, engine=engine)
            rows.append(r)
            if guarded and (r.aborted or r.oom_steps):
                guarded_failures += 1
            if verbose:
                print(f"  {r}")
    guarded_rows = [r for r in rows if r.guarded]
    unguarded_rows = [r for r in rows if not r.guarded]
    avoidance = {
        "scenarios": len(SCENARIOS),
        "guarded_completed": sum(r.completed for r in guarded_rows),
        "guarded_oom_steps": sum(len(r.oom_steps) for r in guarded_rows),
        "guarded_restarts": sum(r.restarts for r in guarded_rows),
        "unguarded_aborted": sum(r.aborted for r in unguarded_rows),
        "oom_avoidance_rate": round(
            sum(r.oom_free and r.completed for r in guarded_rows)
            / max(len(guarded_rows), 1), 3),
        "runs": [{
            "scenario": r.scenario, "guarded": r.guarded,
            "completed": r.completed, "aborted": r.aborted,
            "steps_done": r.steps_done, "n_steps": r.n_steps,
            "oom_steps": list(r.oom_steps),
            "mitigations": list(r.mitigations), "restarts": r.restarts,
            "budget_gib": round(r.budget_bytes / GiB, 2),
            "predicted_gib": [round(r.base_predicted_bytes / GiB, 2),
                              round(r.final_predicted_bytes / GiB, 2)],
        } for r in rows],
    }

    payload = {"benchmark": "autopilot", "plan_latency": latency,
               "oom_avoidance": avoidance,
               "guarded_failures": guarded_failures}

    md = ["# Memory-autopilot benchmark", "",
          "## Mitigation-search latency", "",
          "| cell | candidates | cold (ms) | warm (ms) |",
          "|---|---|---|---|"]
    for name, row in latency.items():
        md.append(f"| {name} | {row['candidates']} | {row['cold_ms']} "
                  f"| {row['warm_ms']} |")
    md += ["", "## OOM avoidance (guarded vs unguarded)", "",
           "| scenario | mode | outcome | steps | ooms | mitigations |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        md.append(f"| {r.scenario} | "
                  f"{'guarded' if r.guarded else 'unguarded'} | "
                  f"{'completed' if r.completed else 'ABORTED'} | "
                  f"{r.steps_done}/{r.n_steps} | {len(r.oom_steps)} | "
                  f"{','.join(r.mitigations) or '-'} |")
    md.append("")
    md.append(f"OOM-avoidance rate: "
              f"**{avoidance['oom_avoidance_rate']:.0%}** over "
              f"{len(SCENARIOS)} scenarios; unguarded aborts: "
              f"{avoidance['unguarded_aborted']}/{len(SCENARIOS)}.")

    paths = write_bench("autopilot", payload, "\n".join(md),
                        out_dir=out_dir)
    if verbose:
        print(f"wrote {paths[0]}")
        print(f"plan latency: {latency}")
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=None, help="output dir for BENCH_*")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    payload = run(out_dir=args.out, verbose=not args.quiet)
    bad = payload["guarded_failures"]
    if bad:
        print(f"FAIL: {bad} guarded run(s) aborted or OOMed",
              file=sys.stderr)
        return 1
    if payload["oom_avoidance"]["unguarded_aborted"] != len(SCENARIOS):
        print("FAIL: an unguarded baseline survived — scenarios no "
              "longer cross the budget", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
