"""Calibration accuracy benchmark: fit + evaluate on the bundled fixture
set, write BENCH_calibration.json (+ markdown MAPE report).

    PYTHONPATH=src python benchmarks/calibration_mape.py [--out DIR]
        [--regen-fixture]

The fixture (benchmarks/fixtures/calibration_measurements.json) is the
deterministic synthetic measurement set — the same generator CI uses, so
the bench trajectory tracks prediction ACCURACY (per-arch-family MAPE,
calibrated vs raw), not just throughput.  Exit code is non-zero unless
calibrated predictions achieve strictly lower MAPE than uncalibrated ones
for EVERY arch family in the fixture (the ISSUE-2 acceptance gate).
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.dirname(__file__))

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "calibration_measurements.json")


def regen_fixture(path: str = FIXTURE) -> None:
    from repro.calibrate import generate
    store = generate()
    store.save(path)
    print(f"wrote {path} ({len(store)} measurements)")


def run(verbose: bool = True, out_dir: str = None) -> dict:
    import time

    from common import write_bench

    from repro.calibrate import MeasurementStore, evaluate, fit_profile
    from repro.core import sweep as SW

    engine = SW.SweepEngine()
    store = MeasurementStore.load(FIXTURE)

    t0 = time.perf_counter()
    profile = fit_profile(store, engine=engine,
                          source={"fixture": os.path.basename(FIXTURE)})
    fit_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    by_family = evaluate(store, profile, by="family", engine=engine)
    by_arch = evaluate(store, profile, by="arch", engine=engine)
    eval_s = time.perf_counter() - t0

    payload = {
        "benchmark": "calibration_mape",
        "fixture": os.path.basename(FIXTURE),
        "n_measurements": len(store),
        "profile": profile.to_dict(),
        "profile_hash": profile.profile_hash,
        "fit_seconds": round(fit_s, 4),
        "eval_seconds": round(eval_s, 4),
        "by_family": by_family.to_json_dict(),
        "by_arch": by_arch.to_json_dict(),
        "all_families_improved": by_family.all_groups_improved,
    }
    md = (by_family.to_markdown(
              title="calibration accuracy by family (bundled synthetic "
                    "fixtures)") + "\n\n"
          + by_arch.to_markdown(title="calibration accuracy by arch")
          + "\n\n" + f"profile: `{profile.summary()}`\n")
    json_path, md_path = write_bench("calibration", payload, md,
                                     out_dir=out_dir)

    if verbose:
        print(f"calibration_mape,n_measurements,{len(store)}")
        print(f"calibration_mape,fit_s,{fit_s:.3f}")
        print(f"calibration_mape,mape_raw_pct,{by_family.mape_raw:.2f}")
        print(f"calibration_mape,mape_calibrated_pct,"
              f"{by_family.mape_calibrated:.2f}")
        for row in by_family.rows:
            print(f"calibration_mape,{row.group}_raw_pct,"
                  f"{row.mape_raw:.2f}")
            print(f"calibration_mape,{row.group}_calibrated_pct,"
                  f"{row.mape_calibrated:.2f}")
        print(f"calibration_mape,all_families_improved,"
              f"{by_family.all_groups_improved}")
        print(f"wrote {json_path}")
        print(f"wrote {md_path}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="output dir for BENCH_calibration.{json,md} "
                         "(default: repo root)")
    ap.add_argument("--regen-fixture", action="store_true",
                    help="regenerate the bundled fixture set and exit")
    args = ap.parse_args()
    if args.regen_fixture:
        regen_fixture()
        sys.exit(0)
    result = run(out_dir=args.out)
    sys.exit(0 if result["all_families_improved"] else 1)
