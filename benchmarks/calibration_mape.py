"""Calibration accuracy benchmark: fit + evaluate on the bundled fixture
set, write BENCH_calibration.json (+ markdown MAPE report).

    PYTHONPATH=src python benchmarks/calibration_mape.py [--out DIR]
        [--regen-fixture]

The fixture (benchmarks/fixtures/calibration_measurements.json) is the
deterministic synthetic measurement set — the same generator CI uses, so
the bench trajectory tracks prediction ACCURACY (per-arch-family MAPE,
calibrated vs raw), not just throughput.  Both assembly modes are
benchmarked: the legacy sum-of-maxima peak and the liveness
interval-overlap peak, each fit + evaluated end-to-end.  On top of the
affine profile the learned per-family residual model
(repro.calibrate.learned) is fitted and scored two ways: in-sample
(full-store fit) and leave-one-family-out (one fold per arch family;
the held-out family sees only the model's global fallback — the
transfer setting a NEW architecture family lands in).

Exit code is non-zero unless (a) calibrated predictions achieve
strictly lower MAPE than uncalibrated ones for EVERY arch family under
BOTH assemblies (the ISSUE-2 acceptance gate), (b) the raw liveness
MAPE is strictly below the raw legacy MAPE (the ISSUE-9 acceptance
gate: the overlap peak must cut the ~12.2% legacy baseline toward the
paper's 8.7%), and (c) the leave-one-family-out holdout MAPE with the
learned residual is strictly below the affine-only holdout MAPE (the
ISSUE-10 acceptance gate: the learned correction must generalize, not
memorize).
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.dirname(__file__))

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "calibration_measurements.json")


def regen_fixture(path: str = FIXTURE) -> None:
    from repro.calibrate import generate
    store = generate()
    store.save(path)
    print(f"wrote {path} ({len(store)} measurements)")


def run(verbose: bool = True, out_dir: str = None) -> dict:
    import time

    from common import write_bench

    from repro.calibrate import (MeasurementStore, evaluate, fit_profile,
                                 fit_residual, leave_one_family_out)
    from repro.core import sweep as SW

    engine = SW.SweepEngine()
    store = MeasurementStore.load(FIXTURE)

    payload = {
        "benchmark": "calibration_mape",
        "fixture": os.path.basename(FIXTURE),
        "n_measurements": len(store),
        "assemblies": {},
    }
    md_parts = []
    raw_by_assembly = {}
    all_improved = True
    for assembly in ("legacy", "liveness"):
        t0 = time.perf_counter()
        profile = fit_profile(store, engine=engine, assembly=assembly,
                              source={"fixture": os.path.basename(FIXTURE),
                                      "assembly": assembly})
        fit_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        residual = fit_residual(store, profile=profile, engine=engine,
                                assembly=assembly)
        residual_fit_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        by_family = evaluate(store, profile, by="family", engine=engine,
                             assembly=assembly, residual=residual)
        by_arch = evaluate(store, profile, by="arch", engine=engine,
                           assembly=assembly, residual=residual)
        eval_s = time.perf_counter() - t0

        payload["assemblies"][assembly] = {
            "profile": profile.to_dict(),
            "profile_hash": profile.profile_hash,
            "residual_hash": residual.model_hash,
            "residual_fit": residual.fit_info,
            "fit_seconds": round(fit_s, 4),
            "residual_fit_seconds": round(residual_fit_s, 4),
            "eval_seconds": round(eval_s, 4),
            "by_family": by_family.to_json_dict(),
            "by_arch": by_arch.to_json_dict(),
            "all_families_improved": by_family.all_groups_improved,
        }
        raw_by_assembly[assembly] = by_family.mape_raw
        all_improved = all_improved and by_family.all_groups_improved
        md_parts.append(
            by_family.to_markdown(
                title=f"calibration accuracy by family "
                      f"({assembly} assembly)") + "\n\n"
            + by_arch.to_markdown(
                title=f"calibration accuracy by arch ({assembly} assembly)")
            + "\n\n" + f"{assembly} profile: `{profile.summary()}`\n")

        if verbose:
            tag = f"calibration_mape[{assembly}]"
            print(f"{tag},n_measurements,{len(store)}")
            print(f"{tag},fit_s,{fit_s:.3f}")
            print(f"{tag},mape_raw_pct,{by_family.mape_raw:.2f}")
            print(f"{tag},mape_calibrated_pct,"
                  f"{by_family.mape_calibrated:.2f}")
            print(f"{tag},mape_learned_pct,"
                  f"{by_family.mape_learned:.2f}")
            for row in by_family.rows:
                print(f"{tag},{row.group}_raw_pct,{row.mape_raw:.2f}")
                print(f"{tag},{row.group}_calibrated_pct,"
                      f"{row.mape_calibrated:.2f}")
            print(f"{tag},all_families_improved,"
                  f"{by_family.all_groups_improved}")

    # leave-one-family-out holdout: per fold, fit profile + residual on
    # the OTHER five families and score the held-out one — the held-out
    # family only ever sees the residual model's global fallback, so
    # this leg measures transfer, not memorization.
    t0 = time.perf_counter()
    folds = {}
    aff_sum = lrn_sum = n_sum = 0.0
    for fam, train, test in leave_one_family_out(store):
        fold_profile = fit_profile(train, engine=engine)
        fold_residual = fit_residual(train, profile=fold_profile,
                                     engine=engine)
        rep = evaluate(test, fold_profile, engine=engine,
                       residual=fold_residual)
        folds[fam] = {
            "n": rep.n,
            "mape_affine_pct": round(rep.mape_calibrated, 4),
            "mape_learned_pct": round(rep.mape_learned, 4),
        }
        aff_sum += rep.mape_calibrated * rep.n
        lrn_sum += rep.mape_learned * rep.n
        n_sum += rep.n
    holdout_affine = aff_sum / max(n_sum, 1)
    holdout_learned = lrn_sum / max(n_sum, 1)
    holdout_ok = holdout_learned < holdout_affine
    payload["holdout"] = {
        "folds": folds,
        "mape_affine_pct": round(holdout_affine, 4),
        "mape_learned_pct": round(holdout_learned, 4),
        "seconds": round(time.perf_counter() - t0, 4),
    }

    liveness_cuts_raw = (raw_by_assembly["liveness"]
                         < raw_by_assembly["legacy"])
    payload["all_families_improved"] = all_improved
    payload["liveness_raw_below_legacy_raw"] = liveness_cuts_raw
    payload["holdout_learned_below_affine"] = holdout_ok
    fold_rows = [(fam, f["n"], f"{f['mape_affine_pct']:.2f}",
                  f"{f['mape_learned_pct']:.2f}")
                 for fam, f in sorted(folds.items())]
    fold_rows.append(("ALL", int(n_sum), f"{holdout_affine:.2f}",
                      f"{holdout_learned:.2f}"))
    from repro.core.report import markdown_table
    md_parts.append(markdown_table(
        ("held-out family", "cells", "affine MAPE %", "learned MAPE %"),
        fold_rows,
        title="leave-one-family-out holdout (learned residual "
              "transfer)"))
    md_parts.append(
        f"raw MAPE: legacy {raw_by_assembly['legacy']:.2f}% -> "
        f"liveness {raw_by_assembly['liveness']:.2f}% "
        f"({'improved' if liveness_cuts_raw else 'NOT improved'})\n\n"
        f"holdout MAPE: affine {holdout_affine:.2f}% -> learned "
        f"{holdout_learned:.2f}% "
        f"({'improved' if holdout_ok else 'NOT improved'})\n")
    json_path, md_path = write_bench("calibration", payload,
                                     "\n\n".join(md_parts),
                                     out_dir=out_dir)
    if verbose:
        print(f"calibration_mape,liveness_raw_below_legacy_raw,"
              f"{liveness_cuts_raw}")
        print(f"calibration_mape,holdout_affine_pct,"
              f"{holdout_affine:.2f}")
        print(f"calibration_mape,holdout_learned_pct,"
              f"{holdout_learned:.2f}")
        print(f"calibration_mape,holdout_learned_below_affine,"
              f"{holdout_ok}")
        print(f"wrote {json_path}")
        print(f"wrote {md_path}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="output dir for BENCH_calibration.{json,md} "
                         "(default: repo root)")
    ap.add_argument("--regen-fixture", action="store_true",
                    help="regenerate the bundled fixture set and exit")
    args = ap.parse_args()
    if args.regen_fixture:
        regen_fixture()
        sys.exit(0)
    result = run(out_dir=args.out)
    ok = (result["all_families_improved"]
          and result["liveness_raw_below_legacy_raw"]
          and result["holdout_learned_below_affine"])
    sys.exit(0 if ok else 1)
