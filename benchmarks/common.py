"""Shared helpers for the benchmark suite."""

from __future__ import annotations

import json
import glob
import os

GiB = 1024 ** 3


def _exp_dir() -> str:
    # shared repo-root resolution (same dir dryrun writes + calibrate reads)
    try:
        from repro.calibrate.paths import experiments_dir
        return str(experiments_dir())
    except ImportError:      # benchmarks invoked without PYTHONPATH=src
        return os.path.join(os.path.dirname(__file__), "..", "experiments")


EXP_DIR = _exp_dir()
DRYRUN_DIR = os.path.join(EXP_DIR, "dryrun")


def bench_out_dir() -> str:
    """Default output dir for BENCH_* artifacts: the repo root (where CI
    uploads them from)."""
    try:
        from repro.calibrate.paths import repo_root
        return str(repo_root())
    except ImportError:
        return os.path.join(os.path.dirname(__file__), "..")


def write_bench(name: str, payload: dict, md_text: str = None,
                out_dir: str = None) -> tuple:
    """Write BENCH_<name>.json (+ optional .md) so the perf/accuracy
    trajectory is tracked across PRs; returns the written paths."""
    out_dir = out_dir or bench_out_dir()
    os.makedirs(out_dir, exist_ok=True)
    json_path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    md_path = None
    if md_text is not None:
        md_path = os.path.join(out_dir, f"BENCH_{name}.md")
        with open(md_path, "w") as f:
            f.write(md_text if md_text.endswith("\n") else md_text + "\n")
    return json_path, md_path


def load_dryrun(mesh: str = "16x16") -> list[dict]:
    out = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(fn) as f:
            out.append(json.load(f))
    return out


def mesh_shape_of(record: dict) -> dict:
    return ({"pod": 2, "data": 16, "model": 16}
            if record["mesh"] == "2x16x16" else {"data": 16, "model": 16})


def predict_record(record: dict, backend: str = "cpu"):
    """Re-run the paper framework's prediction for a dry-run artifact
    (pure arithmetic — no mesh, no compile)."""
    from repro.configs import SHAPES, get_config
    from repro.core import factors as FA
    from repro.core import predictor as PR
    from repro.core.spec import FULL_TRAIN
    from repro.launch import mesh as M
    from repro.models import build_model
    from repro.train.optimizer import OptimizerConfig

    cfg = get_config(record["arch"])
    shape = SHAPES[record["shape"]]
    model = build_model(cfg)
    opt = OptimizerConfig(name=cfg.optimizer)
    ctx = FA.PredictContext(
        mesh_shape=mesh_shape_of(record),
        rules=M.arch_rules(cfg, shape.kind),
        optimizer=opt.name, fsdp=cfg.fsdp,
        master_fp32=opt.name != "adafactor",
        remat=cfg.remat, backend=backend,
        global_batch=shape.global_batch, seq_len=shape.seq_len,
        enc_seq=int(shape.seq_len * cfg.encdec.enc_seq_ratio)
        if cfg.encdec else 0,
        kind=shape.kind, max_len=shape.seq_len)
    return PR.predict(model, FULL_TRAIN, ctx)


def mape(pairs) -> float:
    """mean(|pred - actual| / actual) over (pred, actual) pairs, %."""
    errs = [abs(p - a) / a for p, a in pairs if a > 0]
    return 100.0 * sum(errs) / max(len(errs), 1)


def fmt_gib(x: int) -> str:
    return f"{x / GiB:8.2f}"
