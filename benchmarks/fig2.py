"""Paper reproduction: Fig. 2a / 2b — LLaVA-1.5 (7B) peak-memory prediction
accuracy across data-parallel degrees 1..8, two hyper-parameter settings:

  fig2a: SeqLen 1024, micro-batch 16/GPU   (paper: avg MAPE 13%)
  fig2b: SeqLen 2048, micro-batch  8/GPU   (paper: avg MAPE 8.7%)

Protocol mirrors the paper §4: LLaVA-1.5-7B (frozen CLIP ViT-L/14 tower +
projector + Vicuna-7B, stage-2 behaviour), ZeRO-2 (grads reduce-scattered,
Adam states sharded over DP; params replicated), DP swept 1..8.  Ground
truth is the compiled-XLA per-device peak (the quantity whose overflow is
the OoM the paper prevents); each DP degree compiles in a subprocess with
that many devices.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import EXP_DIR, GiB, mape

_CELL_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={dp}"
import json
import jax, jax.numpy as jnp
from repro.configs import ShapeConfig, get_config
from repro.core import factors as FA, predictor as PR, xla_metrics as XM
from repro.core.spec import LLAVA_STAGE2
from repro.launch import mesh as M
from repro.mesh_ctx import mesh_context
from repro.models import build_model, param as PM
from repro.train import OptimizerConfig, TrainState, make_train_step
from repro.train.optimizer import opt_state_specs

dp, seq, mbs = {dp}, {seq}, {mbs}
cfg = get_config("llava15-7b")
model = build_model(cfg)
shape = ShapeConfig("paper", seq, mbs * dp, "train")
mesh = jax.make_mesh((dp, 1), ("data", "model"))
opt_cfg = OptimizerConfig(name="adamw")

with mesh_context(mesh, M.arch_rules(cfg)):
    params = model.param_specs()
    mask = PM.trainable_mask(model.spec, LLAVA_STAGE2)
    tr, _ = PM.partition_params(params, mask)
    opt = opt_state_specs(tr, opt_cfg)
    state = TrainState(params=params, opt=opt,
                       step=jax.ShapeDtypeStruct((), jnp.int32))
    axes_tree = model.param_axes()
    t_axes = jax.tree.map(lambda m, ax: ax if m else None, mask, axes_tree)
    t_specs, _ = PM.partition_params(params, mask)
    zsh = M.zero_grad_shardings(mesh, t_specs, t_axes)       # ZeRO-2
    osh = M.opt_shardings(model, mesh, t_specs, opt_cfg, t_axes)
    psh = M.param_shardings(model, mesh)
    batch = model.batch_spec(shape)
    bsh = M.batch_shardings(mesh, batch)
    step = make_train_step(model, LLAVA_STAGE2, opt_cfg, zero_shardings=zsh)
    state_sh = TrainState(params=psh, opt=osh,
                          step=jax.sharding.NamedSharding(
                              mesh, jax.sharding.PartitionSpec()))
    lowered = jax.jit(step, in_shardings=(state_sh, bsh),
                      donate_argnums=(0,)).lower(state, batch)
    compiled = lowered.compile()

mem = XM.memory_stats(compiled)
ctx = FA.PredictContext(mesh_shape={{"data": dp}}, rules=M.arch_rules(cfg),
                        optimizer="adamw", zero=True, backend="cpu",
                        global_batch=mbs * dp, seq_len=seq, kind="train",
                        remat=cfg.remat)
pred = PR.predict(model, LLAVA_STAGE2, ctx)
print("RESULT " + json.dumps({{
    "dp": dp, "seq": seq, "mbs": mbs,
    "actual_bytes": mem.total_bytes,
    "predicted_bytes": pred.peak_bytes,
    "pred_parts": {{"param": pred.param_bytes, "grad": pred.grad_bytes,
                   "opt": pred.opt_bytes, "act_saved": pred.act_saved_bytes,
                   "act_trans": pred.act_transient_bytes,
                   "loss": pred.loss_bytes, "inputs": pred.input_bytes}},
    "mem_parts": {{"args": mem.argument_bytes, "out": mem.output_bytes,
                  "temp": mem.temp_bytes, "alias": mem.alias_bytes}},
}}))
"""


def run_cell(dp: int, seq: int, mbs: int) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    code = _CELL_CODE.format(dp=dp, seq=seq, mbs=mbs)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=1800)
    for line in r.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"fig2 cell dp={dp} seq={seq} failed:\n"
                       f"{r.stdout[-2000:]}\n{r.stderr[-3000:]}")


def run_setting(name: str, seq: int, mbs: int, dps=(1, 2, 4, 8),
                verbose: bool = True) -> dict:
    rows = [run_cell(dp, seq, mbs) for dp in dps]
    result = {
        "setting": name, "seq": seq, "mbs": mbs, "rows": rows,
        "mape": mape([(r["predicted_bytes"], r["actual_bytes"])
                      for r in rows]),
    }
    if verbose:
        print(f"\n=== {name}: LLaVA-1.5-7B, SeqLen {seq}, MBS {mbs}, "
              f"ZeRO-2 (paper protocol) ===")
        print(f"{'DP':>4s}{'pred GiB':>10s}{'actual GiB':>12s}{'APE%':>8s}")
        for r in rows:
            ape = 100 * abs(r["predicted_bytes"] - r["actual_bytes"]) \
                / r["actual_bytes"]
            print(f"{r['dp']:>4d}{r['predicted_bytes']/GiB:>10.2f}"
                  f"{r['actual_bytes']/GiB:>12.2f}{ape:>8.1f}")
        print(f"MAPE {name}: {result['mape']:.1f}%  "
              f"(paper: {'13%' if name == 'fig2a' else '8.7%'})")
    os.makedirs(EXP_DIR, exist_ok=True)
    with open(os.path.join(EXP_DIR, f"{name}.json"), "w") as f:
        json.dump(result, f, indent=1)
    return result


def run(verbose: bool = True) -> dict:
    a = run_setting("fig2a", seq=1024, mbs=16, verbose=verbose)
    b = run_setting("fig2b", seq=2048, mbs=8, verbose=verbose)
    return {"fig2a": a, "fig2b": b}


if __name__ == "__main__":
    run()
