"""OoM-guard fit table: TPU-native predicted peak vs per-chip HBM for
every (arch x shape) on the production 16x16 mesh, with the planner's
rescue (grad accumulation) where the baseline would OoM.  This is the
paper's framework doing its actual job — preventing OoM before launch.

All cells share one memoized SweepEngine (core/sweep.py), so the table is
a few hundred cache-assembled evaluations rather than fresh builds."""

from __future__ import annotations

from benchmarks.common import GiB
from repro.configs import cells
from repro.core import planner, sweep as SW


def run(verbose: bool = True, chip: str = "v5e"):
    mesh_shape = {"data": 16, "model": 16}
    budget = int(planner.chip_hbm(chip) * planner.HEADROOM)
    engine = SW.SweepEngine()
    rows = []
    for arch, shape in cells():
        base = engine.report(arch, shape, mesh_shape, backend="tpu",
                             budget_bytes=budget)
        planned = base if base.fits else planner.plan(
            arch, shape, mesh_shape, backend="tpu", chip=chip,
            engine=engine)
        rows.append((base, planned))
    if verbose:
        hbm_gib = planner.chip_hbm(chip) / GiB
        print(f"\n=== OoM guard (TPU-native prediction vs {hbm_gib:.0f} "
              f"GiB {chip}, 16x16 mesh) ===")
        print(f"{'arch':<24s}{'shape':<13s}{'peak GiB':>9s}{'fits':>6s}"
              f"{'planned':>22s}")
        for base, planned in rows:
            fix = ""
            if not base.fits:
                fix = (f"accum x{planned.grad_accum} -> "
                       f"{planned.peak_bytes / GiB:.1f} GiB"
                       if planned.fits else "NO FIT")
            print(f"{base.arch:<24s}{base.shape:<13s}"
                  f"{base.peak_bytes / GiB:>9.2f}"
                  f"{'yes' if base.fits else 'NO':>6s}{fix:>22s}")
        adam = planner.adam_state_bytes("arctic-480b")
        print(f"\narctic-480b Adam fp32 states would be "
              f"{adam / GiB ** 1:.0f} GiB total "
              f"({adam / (256 * 16 * GiB) * 100:.0f}% of a pod's entire "
              f"HBM) -> shipped config uses Adafactor + 2-axis FSDP")
        print(f"planner,cells_fit_baseline,"
              f"{sum(1 for b, _ in rows if b.fits)}/{len(rows)}")
        print(f"planner,cells_fit_planned,"
              f"{sum(1 for _, p in rows if p.fits)}/{len(rows)}")
    return rows


if __name__ == "__main__":
    run()
