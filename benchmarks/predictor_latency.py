"""Framework-overhead benchmark: microseconds per full-model prediction.

The paper's pitch against profiling-based estimators is that a
formulation-based predictor needs NO training iterations.  This measures
the end-to-end cost of one prediction (parse -> factorize -> Eq.1) per
architecture — microseconds-to-milliseconds, vs minutes for a profiling
run (and vs ~seconds for an XLA compile).
"""

from __future__ import annotations

import time

from repro.configs import ARCH_NAMES, get_config
from repro.core import factors as FA
from repro.core import predictor as PR
from repro.core.spec import FULL_TRAIN
from repro.launch import mesh as M
from repro.models import build_model


def run(verbose: bool = True) -> list[tuple[str, float]]:
    out = []
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        model = build_model(cfg)
        ctx = FA.PredictContext(
            mesh_shape={"data": 16, "model": 16},
            rules=M.arch_rules(cfg), optimizer=cfg.optimizer,
            fsdp=cfg.fsdp, remat=cfg.remat,
            global_batch=256, seq_len=4096, kind="train")
        PR.predict(model, FULL_TRAIN, ctx)          # warm (imports, caches)
        n = 20
        t0 = time.perf_counter()
        for _ in range(n):
            PR.predict(model, FULL_TRAIN, ctx)
        us = (time.perf_counter() - t0) / n * 1e6
        out.append((arch, us))
        if verbose:
            print(f"predict_memory,{arch},{us:.0f}us_per_call")
    return out


if __name__ == "__main__":
    run()
