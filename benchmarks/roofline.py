"""Roofline analysis (deliverable g): per (arch x shape), the three terms

    compute    = HLO_FLOPs_per_device / peak_FLOPs        (197 TF/s bf16)
    memory     = HLO_bytes_per_device / HBM_bw            (819 GB/s)
    collective = wire_bytes_per_device / ICI_bw           (~50 GB/s/link)

derived from the compiled dry-run artifacts (single-pod 16x16 mesh, per the
brief), plus MODEL_FLOPS = 6*N(active)*D (train) or 2*N(active)*tokens
(serving) and the useful-compute ratio MODEL_FLOPS / HLO_FLOPs that
catches remat/redundancy waste.  The dominant term is the hillclimb target
(EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import csv
import json
import os
import sys

from benchmarks.common import EXP_DIR, load_dryrun

PEAK_FLOPS = 197e12          # TPU v5e bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
N_DEV = 256


def model_flops_per_device(arch: str, shape_name: str) -> float:
    from repro.configs import SHAPES, get_config
    from repro.core.parser import active_params, parse_model
    from repro.core.spec import FULL_TRAIN
    from repro.models import build_model

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rows = parse_model(build_model(cfg).spec, FULL_TRAIN)
    n_active = active_params(rows)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / N_DEV
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / N_DEV
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch / N_DEV


def bottleneck_hint(dom: str, rec: dict) -> str:
    if dom == "compute":
        return ("compute-bound: raise useful-ratio (less remat/recompute) "
                "or accept — this is the roofline target")
    if dom == "memory":
        return ("HBM-bound: fuse/shrink transients (flash tiling, bf16 "
                "stacks), raise arithmetic intensity per pass")
    wb = (rec.get("loop_aware", {}).get("collective_wire_bytes")
          or rec["collectives"]["wire_bytes_per_device"])
    top = max(wb.items(), key=lambda kv: kv[1])[0] if wb else "?"
    return (f"ICI-bound (mostly {top}): reshard to cut gathers, overlap "
            f"collectives with compute, or compress payloads")


def run(mesh: str = "16x16", verbose: bool = True) -> list[dict]:
    records = load_dryrun(mesh)
    rows = []
    for rec in records:
        la = rec.get("loop_aware")
        if la:        # trip-count-aware accounting (see xla_metrics)
            fl = la["flops_per_device"]
            by = la["bytes_accessed_per_device"]
            wire = la["total_wire_bytes_per_device"]
        else:
            fl = rec["cost"]["flops_per_device"]
            by = rec["cost"]["bytes_accessed_per_device"]
            wire = rec["collectives"]["total_wire_bytes_per_device"]
        t_c = fl / PEAK_FLOPS
        t_m = by / HBM_BW
        t_x = wire / ICI_BW
        dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
                  key=lambda kv: kv[1])[0]
        mf = model_flops_per_device(rec["arch"], rec["shape"])
        bound = max(t_c, t_m, t_x)
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": mesh,
            "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "dominant": dom,
            "model_flops_per_dev": mf,
            "useful_ratio": mf / fl if fl else 0.0,
            # fraction of roofline: useful work over the time the dominant
            # term pins the step to (1.0 == perfectly compute-bound with
            # zero waste)
            "roofline_frac": (mf / PEAK_FLOPS) / bound if bound else 0.0,
            "hint": bottleneck_hint(dom, rec),
        })
    if verbose:
        print(f"\n=== roofline terms per cell (mesh {mesh}; seconds/step; "
              f"v5e: 197TF bf16, 819GB/s HBM, 50GB/s ICI) ===")
        print(f"{'arch':<24s}{'shape':<13s}{'compute':>9s}{'memory':>9s}"
              f"{'collect':>9s}{'dominant':>11s}{'useful':>8s}{'RLfrac':>8s}")
        for r in sorted(rows, key=lambda r: (r['arch'], r['shape'])):
            print(f"{r['arch']:<24s}{r['shape']:<13s}{r['compute_s']:>9.4f}"
                  f"{r['memory_s']:>9.4f}{r['collective_s']:>9.4f}"
                  f"{r['dominant']:>11s}{r['useful_ratio']:>8.2f}"
                  f"{r['roofline_frac']:>8.2f}")
    os.makedirs(EXP_DIR, exist_ok=True)
    path = os.path.join(EXP_DIR, f"roofline_{mesh}.csv")
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    if verbose:
        print(f"wrote {path}")
    return rows


if __name__ == "__main__":
    run()
