"""Benchmark orchestrator — one entry per paper table/figure + the
beyond-paper tables.  Prints ``benchmark,metric,value`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run              # offline set
    PYTHONPATH=src python -m benchmarks.run --paper      # + fig2a/b compiles
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true",
                    help="also run the fig2a/fig2b compile sweeps (slow)")
    args = ap.parse_args()

    from benchmarks import arch_sweep, predictor_latency, roofline
    from benchmarks.common import EXP_DIR

    print("benchmark,metric,value")

    # paper repro: fig 2a/2b (compile sweeps; reuse artifacts if present)
    for name in ("fig2a", "fig2b"):
        path = os.path.join(EXP_DIR, f"{name}.json")
        if args.paper or not os.path.exists(path):
            from benchmarks import fig2
            fig2.run(verbose=True)
            break
    for name in ("fig2a", "fig2b"):
        path = os.path.join(EXP_DIR, f"{name}.json")
        if os.path.exists(path):
            with open(path) as f:
                r = json.load(f)
            paper = 13.0 if name == "fig2a" else 8.7
            print(f"{name},mape_percent,{r['mape']:.1f}")
            print(f"{name},paper_mape_percent,{paper}")

    # beyond paper: whole-zoo sweep vs XLA ground truth
    sweep = arch_sweep.run(verbose=True)
    if sweep:
        print(f"arch_sweep,mape_percent,{sweep['mape_total']:.1f}")
        for k, v in sweep["mape_by_kind"].items():
            print(f"arch_sweep,mape_{k}_percent,{v:.1f}")

    # roofline terms per cell
    rows = roofline.run(verbose=True)
    if rows:
        by_dom = {}
        for r in rows:
            by_dom[r["dominant"]] = by_dom.get(r["dominant"], 0) + 1
        for k, v in by_dom.items():
            print(f"roofline,cells_{k}_bound,{v}")
        best = max(rows, key=lambda r: r["roofline_frac"])
        print(f"roofline,best_fraction,{best['roofline_frac']:.2f}")

    # predictor overhead (us per call — the anti-profiling pitch)
    for arch, us in predictor_latency.run(verbose=False):
        print(f"predictor_latency,{arch}_us_per_call,{us:.0f}")

    # OoM guard: the planner's fit table for the production mesh
    from benchmarks import planner_table
    planner_table.run(verbose=True)


if __name__ == "__main__":
    main()
