"""Sweep-engine throughput: a 1,000+-cell capacity grid must clear in
well under a second on CPU (ISSUE 1 acceptance), and every fit/OOM verdict
must match a cell-by-cell ``planner.check`` exactly.

    PYTHONPATH=src python benchmarks/sweep_throughput.py [--verify]

The grid is the paper's model (llava15-7b) over every 2-axis mesh
factorization of a 256-chip pod x grad-accum x remat x global batch.
``--verify`` additionally re-evaluates every cell through the slow
un-memoized path (minutes, not timed) to prove byte-identical verdicts;
the nightly tier-1 suite runs the same comparison on a smaller grid
(tests/test_sweep.py).
"""

from __future__ import annotations

import sys
import time

from repro.configs import ShapeConfig
from repro.core import planner, sweep as SW


def build_grid() -> SW.SweepGrid:
    return SW.SweepGrid(
        arch="llava15-7b",
        chips=256,                              # 9 (data, model) splits
        remats=("none", "block", "dots"),
        grad_accums=(1, 2, 4, 8),
        global_batches=(8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096),
        seq_lens=(2048,),
        chip="v5e",
        backend="tpu")


def run(verbose: bool = True, verify: bool = False):
    grid = build_grid()
    res = SW.sweep(grid)
    n = len(res)
    assert n >= 1000, f"grid only produced {n} cells"
    if verbose:
        print(f"sweep_throughput,cells,{n}")
        print(f"sweep_throughput,elapsed_s,{res.elapsed_s:.3f}")
        print(f"sweep_throughput,cells_per_sec,{res.cells_per_sec:.0f}")
        print(f"sweep_throughput,under_1s,{res.elapsed_s < 1.0}")
        print(f"sweep_throughput,cells_fit,{len(res.fitting())}")
        for chips, batch in res.frontier():
            print(f"sweep_throughput,frontier,{chips},{batch}")
    if verify:
        t0 = time.perf_counter()
        mismatches = 0
        for r in res:
            shape = ShapeConfig("cell", r.seq_len, r.global_batch, r.kind)
            ref = planner.check(r.arch, shape, r.mesh_shape,
                                backend=r.backend, grad_accum=r.grad_accum,
                                remat=r.remat, chip=r.chip)
            if ref.peak_bytes != r.peak_bytes or ref.fits != r.fits:
                mismatches += 1
                if verbose:
                    print(f"MISMATCH: {r} vs {ref}")
        if verbose:
            print(f"sweep_throughput,verify_cells,{n}")
            print(f"sweep_throughput,verify_mismatches,{mismatches}")
            print(f"sweep_throughput,verify_s,"
                  f"{time.perf_counter() - t0:.1f}")
        assert mismatches == 0, f"{mismatches} cells diverged from check()"
    return res


if __name__ == "__main__":
    res = run(verify="--verify" in sys.argv)
    sys.exit(0 if res.elapsed_s < 1.0 else 1)
