"""Sweep-engine throughput + columnar-vs-cell parity benchmark.

    PYTHONPATH=src python benchmarks/sweep_throughput.py
        [--scale large|smoke|pr1] [--verify] [--jobs N] [--out DIR]
        [--engine numpy|jax] [--assembly legacy|liveness] [--search]
        [--min-cells-per-sec N] [--min-speedup X]
        [--min-search-reduction X]

Times the SAME grid through both sweep modes:

* ``columnar`` — the structure-of-arrays batch path (core/batch.py),
* ``cell``    — the per-cell reference path (PR 1's memoized engine),

``--engine jax`` adds a third leg: the jitted columnar engine
(core/batch_jax.py), byte-compared against the numpy columnar arrays
and timed cold (first call pays jit compilation + table folding) and
warm (the steady-state rate the autopilot re-pricing loop sees); the
perf floors then gate the jax warm rate so the numpy gate stays
attributable.  ``--search`` runs the Pareto-query leg: pruned
``plan_min_chips`` / ``plan_frontier`` / ``plan_max_concurrency``
(core/search.py) against their exhaustive twins, asserting IDENTICAL
answers and recording cells-evaluated for both; results land in
``BENCH_search.{json,md}`` and ``--min-search-reduction`` gates the
aggregate exhaustive/pruned cell ratio (CI pins >= 20x).

``--assembly liveness`` adds the interval-overlap assembly leg on the
SAME grid: the liveness columnar sweep timed cold (fresh engine folds +
event-program assembly) and warm (memoized steady state), with EVERY
result column of both runs compared element-wise against the scalar
event-program replay (cell mode) and the tightened peak asserted <= the
legacy peak per cell; the perf floors then gate the liveness warm rate
(CI pins >= 1M cells/s at 0 mismatches on the 124,416-cell grid).

asserts their verdicts and per-device peak bytes are byte-identical on
every cell, and writes ``BENCH_sweep.json``/``.md`` (cells/sec, wall
time, grid size, speedup per mode) via ``benchmarks/common.write_bench``
so the perf trajectory is tracked across PRs.  Scales:

* ``large`` (default): 124,416 cells — the ISSUE-3 acceptance grid
  (>= 100k cells, >= 50x columnar speedup);
* ``smoke``: ~47k cells — the CI perf gate on the MoE arch, spanning
  expert-parallel ep in {1, 2, 4} x context-parallel cp in {1, 2, 4} x
  pipeline degrees pp in {1, 2, 4} x microbatches in {1, 4, 8} x both
  schedules on a 5-axis (data, model, expert, context, pipe) mesh
  enumeration (use with ``--min-cells-per-sec`` / ``--min-speedup``
  floors);
* ``serve``: ~39k decode cells — the CI serving-fleet gate crossing
  paged-KV block sizes x pool utilizations x prefix-cache hit rates x
  request mixes x a speculative draft model;
* ``pr1``: the original 1,080-cell PR-1 grid (under_1s trajectory).

``--verify`` additionally replays the 9,544-cell parity set — every
arch x kind x backend x policy, with and without a calibration profile,
pp in {1, 2, 4} x microbatches in {1, 4, 8} x {1f1b, gpipe} pipeline
grids over the whole zoo, the ISSUE-5 acceptance grids crossing
ep {1, 2, 4} x cp {1, 2, 4} with that pipeline set (full cross on the
MoE arches, the legal slices elsewhere: dense arches pin expert=1,
decode pins context=1), plus the ISSUE-6 serving-fleet grids (paged
block sizes x utilization x hit rates x mixes on decode AND prefill for
all 12 arches, speculative drafts, calibrated paged cells — each grid's
all-neutral combo asserts prior-main cells stay bit-identical), plus
the ISSUE-7 optimizer-offload grids (offload off/on crossed with
optimizer x grad-accum on every arch and with the pipeline schedules
on a calibrated leg; each off cell asserts prior-main stays
bit-identical) — through un-memoized ``planner.check`` cell by cell,
comparing peak, verdict AND the pool/draft/hit-savings/offload
components, failing on any byte difference (seconds, not timed).
"""

from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.dirname(__file__))

from common import write_bench  # noqa: E402

from repro.configs import ShapeConfig, registered_archs  # noqa: E402
from repro.core import planner, sweep as SW  # noqa: E402
from repro.serve.fleet import RequestMix  # noqa: E402

PARITY_CELLS = 9544

# continuous-batching request mix for the serve parity/smoke grids
SERVE_MIX = RequestMix.make(0.25, ((512, 1), (2048, 3)))

PP_MESHES = [{"data": 2, "model": 2, "pipe": 1},
             {"data": 2, "model": 1, "pipe": 2},
             {"data": 1, "model": 2, "pipe": 4}]

# ep {1,2,4} x cp {1,2,4} crossed with the pp {1,2,4} set (ISSUE-5
# acceptance grid); dense arches keep the expert=1 slice (an expert
# axis > 1 on a dense arch is rejected by planner.check_parallel), and
# decode keeps the context=1 slice (cp is train/prefill-only).
EPCP_MESHES = [{"data": 2, "model": 1, "expert": e, "context": c,
                "pipe": p}
               for e in (1, 2, 4) for c in (1, 2, 4) for p in (1, 2, 4)]
CP_MESHES = [m for m in EPCP_MESHES if m["expert"] == 1]
EP_MESHES = [m for m in EPCP_MESHES if m["context"] == 1]


def _bench_profile():
    """Deterministic non-identity profile for the calibrated parity legs."""
    from repro.calibrate.profile import CalibrationProfile
    return CalibrationProfile(
        coefficients={"static": 1.0173, "act_saved": 0.9641,
                      "act_transient": 1.2089, "overhead": 0.8732},
        chip_constant_bytes={"v5e": 201326592, "*": 67108864})


def build_grid(scale: str = "large") -> SW.SweepGrid:
    """The timed grid: the paper's model (llava15-7b) over every 2-axis
    mesh factorization of 64/128/256-chip pods x optimizer x remat x
    grad-accum x global batch x seq len x chip type."""
    if scale == "pr1":                      # PR 1's original 1,080 cells
        return SW.SweepGrid(
            arch="llava15-7b", chips=256,
            remats=("none", "block", "dots"),
            grad_accums=(1, 2, 4, 8),
            global_batches=(8, 16, 32, 64, 128, 256, 512, 1024, 2048,
                            4096),
            seq_lens=(2048,), chip="v5e", backend="tpu")
    if scale == "serve":                    # ~39k cells: CI serve gate —
        return SW.SweepGrid(                # paged-KV pool x prefix
            arch="llama3.2-3b",             # cache x mix x draft knobs
            chips=(64, 128), chip="v5e", kind="decode",
            global_batches=(4, 8, 16, 32, 64, 128),
            seq_lens=(512, 1024, 2048, 4096),
            block_sizes=(0, 16, 32), utilizations=(1.0, 0.9),
            prefix_hit_rates=(0.0, 0.3, 0.6), prefix_len=256,
            mixes=(None, SERVE_MIX,
                   RequestMix.make(0.5, ((1024, 1),))),
            draft_archs=("", "smollm-360m"), backend="tpu")
    if scale == "smoke":                    # ~47k cells: CI perf gate,
        return SW.SweepGrid(                # ep x cp x pp x mb x sched on
            arch="deepseek-v2-lite-16b",    # the MoE arch (5-axis meshes)
            chips=64, chip="v5e",
            mesh_axes=("data", "model", "expert", "context", "pipe"),
            max_axis={"expert": 4, "context": 4, "pipe": 4},
            optimizers=(None, "adafactor"),
            remats=("none", "block", "dots"),
            schedules=("1f1b", "gpipe"),
            microbatches=(1, 4, 8),
            grad_accums=(1, 4),
            global_batches=(8, 32, 128),
            seq_lens=(1024, 4096), backend="tpu")
    return SW.SweepGrid(                    # large: 124,416 cells
        arch="llava15-7b", chips=(64, 128, 256),
        chip=("v5e", "v6e", "h100"),
        optimizers=(None, "adafactor", "adamw8bit"),
        remats=("none", "block", "dots"),
        grad_accums=(1, 2, 4, 8),
        global_batches=(8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096,
                        8192, 16384),
        seq_lens=(512, 1024, 2048, 4096), backend="tpu")


def parity_set() -> list:
    """The 9,544-cell parity set: PR 1's 1,080-cell throughput grid plus
    per-arch train/serve grids on both oracle backends, the LLaVA frozen
    policies, pipeline/ep-cp/serving-fleet/offload grids, and calibrated
    variants — every cell re-checkable against un-memoized
    ``planner.check``."""
    profile = _bench_profile()
    grids = [build_grid("pr1")]                               # 1,080
    for arch in registered_archs():                           # 12 x 272
        for backend in ("tpu", "cpu"):
            for prof in ((None, profile) if backend == "tpu"
                         else (None,)):
                grids.append(SW.SweepGrid(                    # 64 train
                    arch=arch, chips=8, remats=(None, "none"),
                    grad_accums=(1, 2), global_batches=(8, 32),
                    seq_lens=(512, 1024), backend=backend,
                    profile=prof))
        for kind in ("prefill", "decode"):
            for backend in ("tpu", "cpu"):
                grids.append(SW.SweepGrid(                    # 16 serve
                    arch=arch, chips=8, kind=kind,
                    global_batches=(4, 8), seq_lens=(1024, 2048),
                    backend=backend))
        grids.append(SW.SweepGrid(                            # 16 decode
            arch=arch, chips=8, kind="decode",                # calibrated
            global_batches=(4, 8), seq_lens=(1024, 2048),
            backend="tpu", profile=profile))
    from repro.core.sweep import LLAVA_STAGE1, LLAVA_STAGE2   # 2 x 36
    for pol in (LLAVA_STAGE1, LLAVA_STAGE2):
        grids.append(SW.SweepGrid(
            arch="llava15-7b", chips=8, policy=pol,
            grad_accums=(1, 3), global_batches=(8, 12),
            seq_lens=(512, 1024, 2048), backend="cpu"))
    for arch in registered_archs():           # pipeline grids: 12 x 54
        for kind in ("train", "prefill", "decode"):
            grids.append(SW.SweepGrid(
                arch=arch, mesh_shapes=PP_MESHES, kind=kind,
                schedules=("1f1b", "gpipe"), microbatches=(1, 4, 8),
                global_batches=(8,), seq_lens=(1024,), backend="tpu"))
    for arch in registered_archs():           # calibrated pp: 12 x 12
        grids.append(SW.SweepGrid(
            arch=arch, mesh_shapes=PP_MESHES,
            schedules=("1f1b", "gpipe"), microbatches=(1, 8),
            global_batches=(8,), seq_lens=(1024,), backend="cpu",
            profile=profile))
    from repro.configs import get_config    # ep x cp x pp acceptance set
    for arch in registered_archs():         # moe: 2 x 378, dense: 10 x 108
        moe = get_config(arch).moe is not None
        meshes = EPCP_MESHES if moe else CP_MESHES
        for kind in ("train", "prefill"):
            grids.append(SW.SweepGrid(
                arch=arch, mesh_shapes=meshes, kind=kind,
                schedules=("1f1b", "gpipe"), microbatches=(1, 4, 8),
                global_batches=(8,), seq_lens=(1024,), backend="tpu"))
        if moe:
            grids.append(SW.SweepGrid(    # decode rides the ep x pp slice
                arch=arch, mesh_shapes=EP_MESHES, kind="decode",
                schedules=("1f1b", "gpipe"), microbatches=(1, 4, 8),
                global_batches=(8,), seq_lens=(1024,), backend="tpu"))
    grids.append(SW.SweepGrid(              # calibrated ep x cp x pp: 108
        arch="deepseek-v2-lite-16b", mesh_shapes=EPCP_MESHES,
        schedules=("1f1b", "gpipe"), microbatches=(1, 8),
        global_batches=(8,), seq_lens=(1024,), backend="cpu",
        profile=profile))
    # ISSUE-6 serving-fleet grids: paged-KV block sizes x utilization x
    # prefix-cache hit rates x request mixes (the all-neutral combo in
    # each grid doubles as the "prior-main cells stay bit-identical at
    # neutral serve knobs" acceptance leg).
    for arch in registered_archs():         # paged decode: 12 x 128
        grids.append(SW.SweepGrid(
            arch=arch, chips=8, kind="decode",
            global_batches=(4, 8), seq_lens=(1024,),
            block_sizes=(0, 16), utilizations=(1.0, 0.9),
            prefix_hit_rates=(0.0, 0.5), prefix_len=256,
            mixes=(None, SERVE_MIX), backend="tpu"))
    for arch in registered_archs():         # paged prefill: 12 x 32
        grids.append(SW.SweepGrid(
            arch=arch, chips=8, kind="prefill",
            global_batches=(4,), seq_lens=(1024, 2048),
            block_sizes=(0, 16), utilizations=(0.9,),
            prefix_hit_rates=(0.0, 0.5), prefix_len=256,
            backend="tpu"))
    for arch in ("llama3.2-3b", "deepseek-v2-lite-16b"):
        grids.append(SW.SweepGrid(          # speculative draft: 2 x 16
            arch=arch, kind="decode",
            mesh_shapes=({"data": 2}, {"data": 1, "model": 2}),
            global_batches=(4, 8), seq_lens=(1024,),
            block_sizes=(0, 16), draft_archs=("", "smollm-360m"),
            backend="tpu"))
        grids.append(SW.SweepGrid(          # calibrated paged: 2 x 16
            arch=arch, chips=8, kind="decode",
            global_batches=(4, 8), seq_lens=(1024,),
            block_sizes=(16,), utilizations=(0.9,),
            prefix_hit_rates=(0.0, 0.5), prefix_len=256,
            backend="tpu", profile=profile))
    # ISSUE-7 optimizer-offload grids: offload off/on x optimizer x
    # grad-accum on every arch (the off half doubles as the "prior-main
    # cells stay bit-identical with offload off" acceptance leg).
    for arch in registered_archs():         # offload train: 12 x 32
        grids.append(SW.SweepGrid(
            arch=arch, chips=8, offload_optimizer=(False, True),
            optimizers=(None, "adafactor"), grad_accums=(1, 2),
            global_batches=(8,), seq_lens=(1024,), backend="tpu"))
    grids.append(SW.SweepGrid(              # calibrated offload x pp: 24
        arch="llama3.2-3b", mesh_shapes=PP_MESHES,
        offload_optimizer=(False, True), schedules=("1f1b", "gpipe"),
        microbatches=(1, 8), global_batches=(8,), seq_lens=(1024,),
        backend="cpu", profile=profile))
    return grids


def _columns(res) -> list:
    """(peak, fits, resolved knobs) per cell, for exact comparison."""
    return [(r.peak_bytes, r.fits, r.arch, r.chip, r.optimizer, r.remat,
             r.schedule, r.microbatches,
             r.grad_accum, r.global_batch, r.seq_len,
             tuple(sorted(r.mesh_shape.items())),
             r.serve, r.pool_bytes, r.hit_saved_bytes, r.draft_bytes,
             r.offload, r.offload_bytes)
            for r in res.results]


def _verify_parity(verbose: bool) -> dict:
    """Replay the parity set: columnar == cell == planner.check."""
    t0 = time.perf_counter()
    total = mismatches = 0
    for grid in parity_set():
        col = SW.SweepEngine().sweep(grid, mode="columnar")
        cell = SW.SweepEngine().sweep(grid, mode="cell")
        assert len(col) == len(cell)
        if _columns(col) != _columns(cell):
            mismatches += 1
            if verbose:
                print(f"MISMATCH columnar vs cell: {grid.arch} "
                      f"{grid.kind} {grid.backend}")
        for r in col.results:
            shape = ShapeConfig("cell", r.seq_len, r.global_batch, r.kind)
            ref = planner.check(
                r.arch, shape, r.mesh_shape, policy=grid.policy,
                backend=r.backend, grad_accum=r.grad_accum, remat=r.remat,
                optimizer=r.optimizer, chip=r.chip,
                headroom=grid.headroom, profile=grid.profile,
                microbatches=r.microbatches, schedule=r.schedule,
                serve=r.serve, offload_opt=r.offload)
            if (ref.peak_bytes != r.peak_bytes or ref.fits != r.fits
                    or ref.prediction.pool_bytes != r.pool_bytes
                    or ref.prediction.draft_bytes != r.draft_bytes
                    or ref.prediction.hit_saved_bytes
                    != r.hit_saved_bytes
                    or ref.prediction.offload_bytes != r.offload_bytes):
                mismatches += 1
                if verbose and mismatches < 5:
                    print(f"MISMATCH vs check(): {r} vs {ref}")
        total += len(col)
    assert total == PARITY_CELLS, \
        f"parity set drifted: {total} cells != {PARITY_CELLS}"
    return {"cells": total, "mismatches": mismatches,
            "seconds": round(time.perf_counter() - t0, 1)}


#: columns the liveness leg compares element-wise between the columnar
#: sweep and the scalar (cell-mode) replay
LIVENESS_PARITY_COLUMNS = ("peak_bytes", "fits", "budget_bytes",
                           "pool_bytes", "draft_bytes", "hit_saved_bytes",
                           "offload_bytes", "overlap_slack_bytes")


def _liveness_leg(grid, legacy_cols, jobs: int) -> tuple:
    """Time the liveness assembly on the SAME grid (cold = fresh engine
    folds + assembles; warm = memoized steady state) and compare every
    result column of BOTH runs against the scalar event-program replay
    (cell mode), plus the liveness <= legacy bound against the legacy
    columnar arrays.  Returns (modes_dict, per_column_mismatches)."""
    import dataclasses

    import numpy as np

    lgrid = dataclasses.replace(grid, assembly="liveness")
    leng = SW.SweepEngine()
    cold = leng.sweep(lgrid, mode="columnar", jobs=jobs)
    warm = min((leng.sweep(lgrid, mode="columnar", jobs=jobs)
                for _ in range(3)), key=lambda r: r.elapsed_s)
    cell = SW.SweepEngine().sweep(lgrid, mode="cell")
    assert len(cold) == len(warm) == len(cell) == grid.size()
    ref = {c: np.array([getattr(r, c) for r in cell.results])
           for c in LIVENESS_PARITY_COLUMNS}
    per_column = {}
    for c in LIVENESS_PARITY_COLUMNS:
        per_column[c] = int(sum(
            (np.asarray(getattr(r.columns, c)) != ref[c]).sum()
            for r in (cold, warm)))
    # the tightened peak must stay bounded by the legacy peak per cell
    per_column["liveness_gt_legacy"] = int(sum(
        (np.asarray(r.columns.peak_bytes)
         > np.asarray(legacy_cols.peak_bytes)).sum()
        for r in (cold, warm)))
    modes = {"columnar_liveness": {
        "elapsed_s": round(warm.elapsed_s, 4),
        "cells_per_sec": round(warm.cells_per_sec),
        "cold_elapsed_s": round(cold.elapsed_s, 4),
        "cold_cells_per_sec": round(cold.cells_per_sec),
    }}
    return modes, per_column


def run(verbose: bool = True, verify: bool = False, scale: str = "large",
        jobs: int = 1, out_dir: str = None, engine: str = "numpy",
        assembly: str = "legacy") -> dict:
    grid = build_grid(scale)
    n = grid.size()

    col = SW.SweepEngine().sweep(grid, mode="columnar", jobs=jobs)
    assert col.columns is not None, "columnar mode did not engage"
    cell = SW.SweepEngine().sweep(grid, mode="cell")
    assert len(col) == len(cell) == n

    jax_modes = {}
    jax_mismatches = 0
    if engine == "jax":
        import numpy as _np
        jeng = SW.SweepEngine()
        cold = jeng.sweep(grid, engine="jax")        # jit compile + fold
        # steady-state rate: best of 3 warm replays — at this wall
        # clock (tens of ms on the large grid) a single run is
        # scheduler-jitter-dominated
        warm = min((jeng.sweep(grid, engine="jax") for _ in range(3)),
                   key=lambda r: r.elapsed_s)
        for r in (cold, warm):
            jax_mismatches += int(
                (r.columns.peak_bytes != col.columns.peak_bytes).sum()
                + (r.columns.fits != col.columns.fits).sum()
                + (r.columns.budget_bytes
                   != col.columns.budget_bytes).sum()
                + (r.columns.pool_bytes != col.columns.pool_bytes).sum()
                + (r.columns.draft_bytes
                   != col.columns.draft_bytes).sum()
                + (r.columns.hit_saved_bytes
                   != col.columns.hit_saved_bytes).sum()
                + (r.columns.offload_bytes
                   != col.columns.offload_bytes).sum())
        jax_modes["columnar_jax"] = {
            "elapsed_s": round(warm.elapsed_s, 4),
            "cells_per_sec": round(warm.cells_per_sec),
            "cold_elapsed_s": round(cold.elapsed_s, 4),
            "cold_cells_per_sec": round(cold.cells_per_sec),
        }

    live_modes = {}
    live_per_column = {}
    live_mismatches = 0
    if assembly == "liveness":
        live_modes, live_per_column = _liveness_leg(grid, col.columns,
                                                    jobs)
        live_mismatches = sum(live_per_column.values())

    # full-grid parity (arrays first, then every materialized field)
    import numpy as np
    peaks = np.array([r.peak_bytes for r in cell.results])
    fits = np.array([r.fits for r in cell.results])
    grid_mismatches = int((peaks != col.columns.peak_bytes).sum()
                          + (fits != col.columns.fits).sum())
    if _columns(col) != _columns(cell):
        grid_mismatches = max(grid_mismatches, 1)
    grid_mismatches += jax_mismatches + live_mismatches
    speedup = col.cells_per_sec / max(cell.cells_per_sec, 1e-9)

    payload = {
        "benchmark": "sweep_throughput",
        "scale": scale,
        "grid_cells": n,
        "jobs": jobs,
        "modes": {
            "columnar": {"elapsed_s": round(col.elapsed_s, 4),
                         "cells_per_sec": round(col.cells_per_sec)},
            "cell": {"elapsed_s": round(cell.elapsed_s, 4),
                     "cells_per_sec": round(cell.cells_per_sec)},
            **jax_modes,
            **live_modes,
        },
        "speedup": round(speedup, 1),
        "grid_parity_mismatches": grid_mismatches,
        "cells_fit": col.fit_count,
        "frontier": col.frontier(),
    }
    if live_modes:
        payload["liveness_parity_per_column"] = live_per_column
        payload["liveness_mismatches"] = live_mismatches
    if jax_modes:
        payload["jax_speedup"] = round(
            jax_modes["columnar_jax"]["cells_per_sec"]
            / max(cell.cells_per_sec, 1e-9), 1)
    if verify:
        payload["verify"] = _verify_parity(verbose)

    md = [f"# sweep throughput ({scale} grid: {n:,} cells)", "",
          "| mode | wall time (s) | cells/sec |",
          "|------|---------------|-----------|",
          f"| columnar | {col.elapsed_s:.3f} "
          f"| {col.cells_per_sec:,.0f} |",
          f"| cell | {cell.elapsed_s:.3f} "
          f"| {cell.cells_per_sec:,.0f} |"]
    if jax_modes:
        j = jax_modes["columnar_jax"]
        md.append(f"| columnar (jax, warm) | {j['elapsed_s']:.3f} "
                  f"| {j['cells_per_sec']:,.0f} |")
        md.append(f"| columnar (jax, cold) | {j['cold_elapsed_s']:.3f} "
                  f"| {j['cold_cells_per_sec']:,.0f} |")
    if live_modes:
        lm = live_modes["columnar_liveness"]
        md.append(f"| columnar (liveness, warm) | {lm['elapsed_s']:.3f} "
                  f"| {lm['cells_per_sec']:,.0f} |")
        md.append(f"| columnar (liveness, cold) "
                  f"| {lm['cold_elapsed_s']:.3f} "
                  f"| {lm['cold_cells_per_sec']:,.0f} |")
    md += ["",
           f"speedup: **{speedup:.1f}x** — parity mismatches: "
           f"{grid_mismatches}"]
    if live_modes:
        md.append(f"\nliveness leg: {live_mismatches} per-column "
                  f"mismatches vs scalar replay (cold + warm) over "
                  f"{', '.join(LIVENESS_PARITY_COLUMNS)}")
    if verify:
        v = payload["verify"]
        md.append(f"\nverify: {v['cells']:,} parity-set cells vs "
                  f"planner.check, {v['mismatches']} mismatches "
                  f"({v['seconds']}s)")
    json_path, md_path = write_bench("sweep", payload, "\n".join(md),
                                     out_dir=out_dir)

    if verbose:
        if jax_modes:
            j = jax_modes["columnar_jax"]
            print(f"sweep_throughput,jax_warm_cells_per_sec,"
                  f"{j['cells_per_sec']}")
            print(f"sweep_throughput,jax_cold_elapsed_s,"
                  f"{j['cold_elapsed_s']}")
            print(f"sweep_throughput,jax_mismatches,{jax_mismatches}")
        if live_modes:
            lm = live_modes["columnar_liveness"]
            print(f"sweep_throughput,liveness_warm_cells_per_sec,"
                  f"{lm['cells_per_sec']}")
            print(f"sweep_throughput,liveness_cold_cells_per_sec,"
                  f"{lm['cold_cells_per_sec']}")
            print(f"sweep_throughput,liveness_mismatches,"
                  f"{live_mismatches}")
        print(f"sweep_throughput,scale,{scale}")
        print(f"sweep_throughput,cells,{n}")
        print(f"sweep_throughput,columnar_elapsed_s,{col.elapsed_s:.3f}")
        print(f"sweep_throughput,columnar_cells_per_sec,"
              f"{col.cells_per_sec:.0f}")
        print(f"sweep_throughput,cell_elapsed_s,{cell.elapsed_s:.3f}")
        print(f"sweep_throughput,cell_cells_per_sec,"
              f"{cell.cells_per_sec:.0f}")
        print(f"sweep_throughput,speedup,{speedup:.1f}")
        print(f"sweep_throughput,grid_parity_mismatches,{grid_mismatches}")
        print(f"sweep_throughput,cells_fit,{col.fit_count}")
        for chips, batch in col.frontier():
            print(f"sweep_throughput,frontier,{chips},{batch}")
        if verify:
            v = payload["verify"]
            print(f"sweep_throughput,verify_cells,{v['cells']}")
            print(f"sweep_throughput,verify_mismatches,{v['mismatches']}")
            print(f"sweep_throughput,verify_s,{v['seconds']}")
        print(f"wrote {json_path}")
        print(f"wrote {md_path}")
    return payload


def run_search(verbose: bool = True, out_dir: str = None,
               engine: str = "numpy") -> dict:
    """The Pareto-query leg: pruned searches (core/search.py) vs their
    exhaustive twins — identical answers asserted, cells-evaluated and
    wall-clock recorded per query, BENCH_search.{json,md} written."""
    from repro.core import search as SR

    eng = SW.SweepEngine()
    queries = []

    def leg(name, pruned, exhaustive, same):
        st = SR.SearchStats()
        t0 = time.perf_counter()
        a = pruned(st)
        t_pruned = time.perf_counter() - t0
        t0 = time.perf_counter()
        b = exhaustive(st)
        t_exh = time.perf_counter() - t0
        identical = same(a, b)
        pruned_cells = st.cells_evaluated + st.probes
        exhaustive_cells = st.total_cells
        queries.append({
            "query": name,
            "identical": identical,
            "pruned_cells": pruned_cells,
            "exhaustive_cells": exhaustive_cells,
            "reduction": round(exhaustive_cells / max(pruned_cells, 1), 1),
            "pruned_s": round(t_pruned, 4),
            "exhaustive_s": round(t_exh, 4),
        })

    def same_cell(a, b):
        try:
            from repro.core.search import _assert_same_cell
            _assert_same_cell(a, b, "bench")
            return True
        except AssertionError:
            return False

    # -- min_chips: train fit search over an 8..1024-chip plan space -----
    shape = ShapeConfig("bench", 4096, 16, "train")
    chips = (8, 16, 32, 64, 128, 256, 512, 1024)
    queries_mc = [("llama3.1-8b", {}),
                  ("deepseek-v2-lite-16b", {"allow_ep": True, "max_ep": 4})]
    for arch, kw in queries_mc:
        leg(f"min_chips[{arch}]",
            lambda st, a=arch, k=kw: planner.plan_min_chips(
                a, shape, chips=chips, engine=eng, stats=st,
                compute_engine=engine, **k),
            lambda st, a=arch, k=kw: planner.plan_min_chips(
                a, shape, chips=chips, engine=eng, search="exhaustive",
                compute_engine=engine, **k),
            same_cell)

    # -- frontier: chips x global-batch Pareto curve ----------------------
    fshape = ShapeConfig("bench", 2048, 512, "train")
    leg("frontier[llava15-7b]",
        lambda st: planner.plan_frontier(
            "llava15-7b", fshape, chips=(16, 32, 64, 128),
            engine=eng, stats=st, compute_engine=engine),
        lambda st: planner.plan_frontier(
            "llava15-7b", fshape, chips=(16, 32, 64, 128),
            engine=eng, search="exhaustive", compute_engine=engine),
        lambda a, b: a == b)

    # -- max_concurrency: aligned-ladder vs linear scan -------------------
    def brute_concurrency(arch, seq, mesh, cap, st):
        budget = int(planner.chip_hbm("v5e") * planner.HEADROOM)
        best = 0
        for gb in range(1, cap + 1):
            st.cells_pruned += 1          # exhaustive domain accounting
            rep = eng.report(arch, ShapeConfig("c", seq, gb, "decode"),
                             dict(mesh), budget_bytes=budget, chip="v5e")
            if rep.peak_bytes <= budget:
                best = gb
        return best

    for arch, seq, mesh, cap in (
            ("llama3.2-3b", 2048, {"data": 2, "model": 2}, 16384),
            ("smollm-360m", 1024, {"data": 4, "model": 1}, 16384)):
        leg(f"max_concurrency[{arch}]",
            lambda st, a=arch, s=seq, m=mesh, c=cap:
                planner.plan_max_concurrency(
                    a, s, mesh_shape=m, cap=c, engine=eng,
                    stats=st).max_concurrency,
            lambda st, a=arch, s=seq, m=mesh, c=cap:
                brute_concurrency(a, s, m, c, st),
            lambda a, b: a == b)

    total_pruned = sum(q["pruned_cells"] for q in queries)
    total_exh = sum(q["exhaustive_cells"] for q in queries)
    payload = {
        "benchmark": "search",
        "engine": engine,
        "queries": queries,
        "answers_identical": all(q["identical"] for q in queries),
        "pruned_cells": total_pruned,
        "exhaustive_cells": total_exh,
        "reduction": round(total_exh / max(total_pruned, 1), 1),
    }
    md = ["# Pareto-search pruning (branch-and-bound vs exhaustive)", "",
          "| query | identical | pruned cells | exhaustive cells | "
          "reduction | pruned s | exhaustive s |",
          "|-------|-----------|--------------|------------------|"
          "-----------|----------|--------------|"]
    for q in queries:
        md.append(f"| {q['query']} | {q['identical']} "
                  f"| {q['pruned_cells']:,} | {q['exhaustive_cells']:,} "
                  f"| {q['reduction']:.1f}x | {q['pruned_s']:.3f} "
                  f"| {q['exhaustive_s']:.3f} |")
    md.append("")
    md.append(f"aggregate: **{payload['reduction']:.1f}x** fewer cells "
              f"({total_pruned:,} vs {total_exh:,}), answers identical: "
              f"**{payload['answers_identical']}**")
    json_path, md_path = write_bench("search", payload, "\n".join(md),
                                     out_dir=out_dir)
    if verbose:
        for q in queries:
            print(f"search,{q['query']},identical,{q['identical']},"
                  f"reduction,{q['reduction']}")
        print(f"search,aggregate_reduction,{payload['reduction']}")
        print(f"wrote {json_path}")
        print(f"wrote {md_path}")
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=("large", "smoke", "serve", "pr1"),
                    default="large")
    ap.add_argument("--verify", action="store_true",
                    help=f"replay the {PARITY_CELLS:,}-cell parity set "
                         "through un-memoized planner.check (slow)")
    ap.add_argument("--jobs", type=int, default=1)
    ap.add_argument("--out", default=None,
                    help="output dir for BENCH_sweep.{json,md} "
                         "(default: repo root)")
    ap.add_argument("--engine", choices=("numpy", "jax"), default="numpy",
                    help="add the jitted columnar engine leg (cold + "
                         "warm timing, byte-parity vs numpy); the perf "
                         "floors then gate the jax warm rate")
    ap.add_argument("--assembly", choices=("legacy", "liveness"),
                    default="legacy",
                    help="'liveness' adds the interval-overlap assembly "
                         "leg on the same grid (cold + warm timing, "
                         "per-column parity vs the scalar event-program "
                         "replay); the perf floors then gate the "
                         "liveness warm rate")
    ap.add_argument("--search", action="store_true",
                    help="run the Pareto-query leg (pruned vs exhaustive "
                         "plan_min_chips/frontier/max_concurrency) and "
                         "write BENCH_search.{json,md}")
    ap.add_argument("--min-cells-per-sec", type=float, default=0,
                    help="fail unless columnar throughput >= this floor "
                         "(the jax warm rate with --engine jax)")
    ap.add_argument("--min-speedup", type=float, default=0,
                    help="fail unless columnar/cell speedup >= this floor")
    ap.add_argument("--min-search-reduction", type=float, default=0,
                    help="with --search: fail unless the aggregate "
                         "exhaustive/pruned cell ratio >= this floor")
    args = ap.parse_args(argv)
    payload = run(verify=args.verify, scale=args.scale, jobs=args.jobs,
                  out_dir=args.out, engine=args.engine,
                  assembly=args.assembly)
    ok = payload["grid_parity_mismatches"] == 0
    if args.verify:
        ok = ok and payload["verify"]["mismatches"] == 0
    gate_mode = ("columnar_liveness" if args.assembly == "liveness"
                 else "columnar_jax" if args.engine == "jax"
                 else "columnar")
    col_cps = payload["modes"][gate_mode]["cells_per_sec"]
    gate_speedup = payload.get("jax_speedup", payload["speedup"]) \
        if args.engine == "jax" else payload["speedup"]
    if args.min_cells_per_sec and col_cps < args.min_cells_per_sec:
        print(f"FAIL: {gate_mode} {col_cps:,.0f} cells/s below floor "
              f"{args.min_cells_per_sec:,.0f}")
        ok = False
    if args.min_speedup and gate_speedup < args.min_speedup:
        print(f"FAIL: speedup {gate_speedup:.1f}x below floor "
              f"{args.min_speedup:.1f}x")
        ok = False
    if args.search:
        sp = run_search(out_dir=args.out, engine=args.engine)
        if not sp["answers_identical"]:
            print("FAIL: pruned search answers differ from exhaustive")
            ok = False
        if args.min_search_reduction \
                and sp["reduction"] < args.min_search_reduction:
            print(f"FAIL: search reduction {sp['reduction']:.1f}x below "
                  f"floor {args.min_search_reduction:.1f}x")
            ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
