"""Capacity planning: sweep the full knob space before asking for quota.

    PYTHONPATH=src python examples/capacity_plan.py

Three questions a training-platform scheduler asks the paper's estimator,
each answered by one memoized sweep (thousands of Eq.1 cells, no compile):

1. What is the max global batch that fits llava15-7b stage-2 training on
   a 64-chip v5e slice, over every mesh factorization?
2. How many chips do we minimally need for batch 256?
3. Does a leaner optimizer (adafactor) or a bigger chip (v5p) change the
   answer?
"""

from repro.core import sweep as SW
from repro.core.spec import LLAVA_STAGE2

GiB = 1024 ** 3

engine = SW.SweepEngine()     # shared caches across all three sweeps

# ---------------------------------------------------------------------------
# 1. max fitting batch on 64 chips, every (data, model) factorization
# ---------------------------------------------------------------------------
grid = SW.SweepGrid(
    arch="llava15-7b", chips=64, chip="v5e",
    remats=(None, "none", "dots"),
    grad_accums=(1, 2, 4, 8),
    global_batches=(64, 128, 256, 512, 1024),
    seq_lens=(2048,),
    policy=LLAVA_STAGE2, backend="tpu")
res = engine.sweep(grid)
print(f"sweep 1: {len(res)} cells in {res.elapsed_s * 1e3:.0f} ms "
      f"({res.cells_per_sec:,.0f} cells/s)")
best = res.max_global_batch()
print(f"  max batch on 64 v5e: {best}\n" if best
      else "  nothing fits 64 v5e\n")

# ---------------------------------------------------------------------------
# 2. min chips for global batch 256 (sweep chip counts in one grid)
# ---------------------------------------------------------------------------
grid2 = SW.SweepGrid(
    arch="llava15-7b", chips=(16, 32, 64, 128, 256), chip="v5e",
    grad_accums=(1, 2, 4, 8), global_batches=(256,), seq_lens=(2048,),
    policy=LLAVA_STAGE2, backend="tpu")
res2 = engine.sweep(grid2)
least = res2.min_chips(global_batch=256)
print(f"sweep 2: {len(res2)} cells in {res2.elapsed_s * 1e3:.0f} ms")
print(f"  min chips for batch 256: {least}")
print("  Pareto frontier (chips -> max batch):", res2.frontier(), "\n")

# ---------------------------------------------------------------------------
# 3. cross-product with optimizer and chip type
# ---------------------------------------------------------------------------
grid3 = SW.SweepGrid(
    arch="llava15-7b", chips=32, chip=("v5e", "v5p", "h100"),
    optimizers=(None, "adafactor"),
    grad_accums=(1, 2, 4), global_batches=(128, 256), seq_lens=(2048,),
    policy=LLAVA_STAGE2, backend="tpu")
res3 = engine.sweep(grid3)
print(f"sweep 3: {len(res3)} cells in {res3.elapsed_s * 1e3:.0f} ms")
for chip in ("v5e", "v5p", "h100"):
    b = res3.max_global_batch(chip=chip)
    print(f"  32x {chip:<5s}: " + (str(b) if b else "no fit"))
print()
print(res3.to_markdown(limit=10))
