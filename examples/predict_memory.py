"""The paper's workflow as a CLI: parse -> factorize -> predict -> verdict.

    PYTHONPATH=src python examples/predict_memory.py --arch qwen3-32b \\
        --shape train_4k --data 16 --model 16 [--validate]

``--validate`` additionally compiles the same cell with XLA (CPU oracle)
and reports the prediction error — the paper's evaluation, one cell at a
time.
"""

import argparse

GiB = 1024 ** 3


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--data", type=int, default=16)
    ap.add_argument("--model", type=int, default=16)
    ap.add_argument("--policy", default="full",
                    choices=["full", "llava_stage1", "llava_stage2"])
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--backend", default="tpu", choices=["tpu", "cpu"])
    ap.add_argument("--hbm-gib", type=float, default=16.0)
    ap.add_argument("--validate", action="store_true")
    args = ap.parse_args()

    from repro.configs import SHAPES, get_config
    from repro.core import factors as FA
    from repro.core import predictor as PR
    from repro.core.parser import parse_model, modules_of, total_params
    from repro.core.spec import (FULL_TRAIN, LLAVA_STAGE1, LLAVA_STAGE2)
    from repro.launch import mesh as M
    from repro.models import build_model

    policy = {"full": FULL_TRAIN, "llava_stage1": LLAVA_STAGE1,
              "llava_stage2": LLAVA_STAGE2}[args.policy]
    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    model = build_model(cfg)

    # workflow step 1-4: parse into modules and fine-grained layers
    rows = parse_model(model.spec, policy)
    mods = modules_of(rows)
    print(f"parsed {args.arch}: {len(mods)} modules, {len(rows)} layer "
          f"kinds, {total_params(rows) / 1e9:.2f}B params")

    # step 5-6: factorize + per-factor equations; step 7: aggregate (Eq.1)
    mesh_shape = {"data": args.data, "model": args.model}
    ctx = FA.PredictContext(
        mesh_shape=mesh_shape, rules=M.arch_rules(cfg, shape.kind),
        optimizer=cfg.optimizer, fsdp=cfg.fsdp, remat=cfg.remat,
        master_fp32=cfg.optimizer != "adafactor",
        global_batch=shape.global_batch, seq_len=shape.seq_len,
        enc_seq=int(shape.seq_len * cfg.encdec.enc_seq_ratio)
        if cfg.encdec else 0,
        kind=shape.kind, max_len=shape.seq_len,
        grad_accum=args.grad_accum, backend=args.backend)
    pred = PR.predict(model, policy, ctx)

    print(f"\nper-device prediction ({args.backend} oracle, mesh "
          f"data={args.data} x model={args.model}):")
    print(pred.summary())
    budget = args.hbm_gib * GiB * 0.92
    print(f"\nverdict: {'FITS' if pred.peak_bytes <= budget else 'OOM'} "
          f"on a {args.hbm_gib:.0f} GiB chip "
          f"({pred.peak_bytes / GiB:.2f} vs budget {budget / GiB:.2f} GiB)")

    if args.validate:
        import os
        import subprocess
        import sys
        n_dev = args.data * args.model
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                         "src")
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
        code = f"""
import jax
from repro.launch.dryrun import lower_cell
record, compiled = lower_cell({args.arch!r}, {args.shape!r})
print("XLA_TOTAL", record["memory"]["total_bytes"])
"""
        r = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True, env=env)
        for line in r.stdout.splitlines():
            if line.startswith("XLA_TOTAL"):
                actual = int(line.split()[1])
                cpu_ctx = FA.PredictContext(**{
                    **ctx.__dict__, "backend": "cpu"})
                cpu_pred = PR.predict(model, policy, cpu_ctx)
                err = abs(cpu_pred.peak_bytes - actual) / actual * 100
                print(f"\nvalidation vs compiled XLA (cpu oracle): "
                      f"predicted {cpu_pred.peak_bytes / GiB:.2f} GiB, "
                      f"actual {actual / GiB:.2f} GiB, APE {err:.1f}%")
                return
        print("validation failed:", r.stderr[-500:])


if __name__ == "__main__":
    main()
