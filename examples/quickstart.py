"""Quickstart: predict a training job's peak memory BEFORE launching it.

    PYTHONPATH=src python examples/quickstart.py

The 30-second version of the paper: pick a model + hyperparameters, get a
per-device peak-memory prediction and an OoM verdict for the target mesh —
no profiling run, no compile, microseconds of arithmetic.
"""

import jax

from repro.configs import SHAPES, get_config
from repro.core import factors as FA
from repro.core import planner
from repro.core import predictor as PR
from repro.core.spec import FULL_TRAIN, LLAVA_STAGE1, LLAVA_STAGE2
from repro.launch import mesh as M
from repro.models import build_model

GiB = 1024 ** 3

# ---------------------------------------------------------------------------
# 1. Predict peak memory for llama3.2-3b training on the production mesh
# ---------------------------------------------------------------------------
cfg = get_config("llama3.2-3b")
model = build_model(cfg)
shape = SHAPES["train_4k"]

ctx = FA.PredictContext(
    mesh_shape={"data": 16, "model": 16},
    rules=M.arch_rules(cfg, "train"),
    optimizer=cfg.optimizer, remat=cfg.remat, backend="tpu",
    global_batch=shape.global_batch, seq_len=shape.seq_len, kind="train")
pred = PR.predict(model, FULL_TRAIN, ctx)
print(f"== {cfg.name} x {shape.name} on (data=16, model=16), per device ==")
print(pred.summary())

# ---------------------------------------------------------------------------
# 2. The multimodal factorization (the paper's core): training behaviour
#    changes memory — LLaVA stage-1 vs stage-2 vs full
# ---------------------------------------------------------------------------
vlm = build_model(get_config("llava15-7b"))
vctx = FA.PredictContext(mesh_shape={"data": 8}, optimizer="adamw",
                         global_batch=16, seq_len=1024, kind="train",
                         backend="tpu")
print("\n== LLaVA-1.5-7B, DP=8: memory depends on the TRAINING BEHAVIOUR ==")
for policy in (LLAVA_STAGE1, LLAVA_STAGE2, FULL_TRAIN):
    p = PR.predict(vlm, policy, vctx)
    print(f"  {policy.name:<14s} peak {p.peak_bytes / GiB:7.2f} GiB "
          f"(opt {p.opt_bytes / GiB:6.2f}, grads {p.grad_bytes / GiB:6.2f},"
          f" acts {p.act_saved_bytes / GiB:6.2f})")

# ---------------------------------------------------------------------------
# 3. The OoM guard + planner
# ---------------------------------------------------------------------------
print("\n== OoM guard: arctic-480b train_4k on a 16 GiB v5e ==")
report = planner.plan("arctic-480b", "train_4k",
                      {"data": 16, "model": 16}, backend="tpu")
print(report)
adam = planner.adam_state_bytes("arctic-480b")
print(f"(fyi: plain Adam would need {adam / GiB:.0f} GiB of optimizer "
      f"state — more than the whole pod's HBM)")
