"""Serve a small model with batched requests: prefill + batched greedy
decode with KV-cache — including the paper-§5 'future work' we built:
predicting SERVING memory (weights + KV cache + decode transients) before
admitting a batch.

    PYTHONPATH=src python examples/serve_batched.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import factors as FA
from repro.core import predictor as PR
from repro.core.spec import FULL_TRAIN
from repro.models import build_model
from repro.serve import generate

GiB = 1024 ** 3
MiB = 1024 ** 2


def admission_check(model, batch_size: int, max_len: int,
                    hbm_bytes: int = 16 * GiB) -> bool:
    """Predict serving memory for a candidate batch (paper Eq.1, serve
    mode) and admit only if it fits."""
    ctx = FA.PredictContext(mesh_shape={}, kind="decode",
                            global_batch=batch_size, seq_len=max_len,
                            max_len=max_len, backend="tpu")
    pred = PR.predict(model, FULL_TRAIN, ctx)
    print(f"  admission: B={batch_size:<4d} max_len={max_len:<6d} -> "
          f"weights {pred.param_bytes / MiB:8.1f} MiB + "
          f"kv {pred.cache_bytes / MiB:8.1f} MiB + "
          f"transients {(pred.act_transient_bytes + pred.loss_bytes) / MiB:7.1f} MiB "
          f"= {pred.peak_bytes / MiB:8.1f} MiB "
          f"{'ADMIT' if pred.peak_bytes < hbm_bytes else 'REJECT'}")
    return pred.peak_bytes < hbm_bytes


def main():
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    print("== serving-memory admission control (paper §5, built) ==")
    for b, ml in ((8, 2048), (64, 8192), (512, 131072)):
        admission_check(model, b, ml)

    print("\n== batched greedy generation ==")
    B, S = 4, 24
    prompts = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0, cfg.vocab)
    out = generate(model, params, {"tokens": prompts}, max_new_tokens=16)
    for i in range(B):
        print(f"  request {i}: prompt {prompts[i, :6].tolist()}... -> "
              f"generated {out[i].tolist()}")

    # throughput-ish numbers (CPU, reduced model — machinery demo)
    import time
    t0 = time.perf_counter()
    n = 3
    for _ in range(n):
        generate(model, params, {"tokens": prompts}, max_new_tokens=16)
    dt = (time.perf_counter() - t0) / n
    print(f"\n{B} requests x 16 tokens in {dt:.2f}s "
          f"({B * 16 / dt:.1f} tok/s on CPU, reduced config)")


if __name__ == "__main__":
    main()
