"""End-to-end driver: train a ~100M-param LLaVA-style multimodal model for
a few hundred steps on CPU with the FULL production stack — memory
prediction first (the paper's workflow), then fault-tolerant training with
async checkpoints, deterministic data, straggler detection and restart.

    PYTHONPATH=src python examples/train_llava_e2e.py [--steps 200]
"""

import argparse
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import ShapeConfig, get_config, VLMConfig
from repro.core import factors as FA
from repro.core import predictor as PR
from repro.core.spec import LLAVA_STAGE2
from repro.data.pipeline import SyntheticPipeline
from repro.models import build_model
from repro.models import param as PM
from repro.runtime import FaultConfig, ResilientTrainer
from repro.train import OptimizerConfig, TrainState, make_train_step
from repro.train.optimizer import init_opt_state

GiB = 1024 ** 3


def llava_100m():
    """~100M-param LLaVA-style config (real ViT tower + projector + LM)."""
    base = get_config("llava15-7b")
    return dataclasses.replace(
        base, name="llava-100m",
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
        vocab=32000, head_dim=64,
        vlm=VLMConfig(d_vision=256, n_image_tokens=64, projector_layers=2,
                      vision_tower=True, vit_layers=4, vit_heads=4,
                      vit_d_ff=1024, vit_patch=14, vit_image_size=112))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    # total sequence = 64 image tokens + text; keep text non-degenerate
    ap.add_argument("--seq", type=int, default=192)
    args = ap.parse_args()

    cfg = llava_100m()
    model = build_model(cfg)
    shape = ShapeConfig("e2e", args.seq, args.batch, "train")
    policy = LLAVA_STAGE2                       # vision tower frozen

    # 1. paper workflow: predict memory BEFORE training
    ctx = FA.PredictContext(mesh_shape={}, optimizer="adamw",
                            global_batch=args.batch, seq_len=args.seq,
                            kind="train", backend="cpu")
    pred = PR.predict(model, policy, ctx)
    print(f"predicted peak memory: {pred.peak_bytes / GiB:.2f} GiB "
          f"(params {pred.param_bytes / GiB:.2f}, "
          f"opt {pred.opt_bytes / GiB:.2f})")
    for mod, parts in pred.per_module.items():
        if parts["param"]:
            tag = "trainable" if parts["trainable"] else "FROZEN"
            print(f"  {mod:<42s} {tag:>9s} "
                  f"param {parts['param'] / GiB:6.3f} GiB "
                  f"opt {parts['opt'] / GiB:6.3f} GiB")

    # 2. build the training state
    params = model.init(jax.random.PRNGKey(0))
    n = PM.count_params(params)
    print(f"\nmodel: {cfg.name}, {n / 1e6:.1f}M params")
    mask = PM.trainable_mask(model.spec, policy)
    trainable, _ = PM.partition_params(params, mask)
    opt_cfg = OptimizerConfig(name="adamw", lr=3e-4)
    state = TrainState(params=params,
                       opt=init_opt_state(trainable, opt_cfg),
                       step=jnp.int32(0))

    # 3. fault-tolerant training loop (async ckpt, restart, stragglers)
    pipe = SyntheticPipeline(cfg, shape, n_shards=2)
    step_fn = jax.jit(make_train_step(model, policy, opt_cfg))
    ckpt_dir = os.path.join(tempfile.gettempdir(), "repro_llava_e2e")
    trainer = ResilientTrainer(
        train_step=step_fn, pipeline=pipe,
        checkpointer=Checkpointer(ckpt_dir, keep=2),
        fault_cfg=FaultConfig(ckpt_every=50),
        make_batch=lambda s: {k: jnp.asarray(v)
                              for k, v in pipe.global_batch(s).items()})
    state, history = trainer.run(state, start_step=0, n_steps=args.steps,
                                 log_every=20)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    print(f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
