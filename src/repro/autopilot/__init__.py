"""Memory autopilot: closed-loop OOM avoidance.

Telemetry ingest (:mod:`.watch`) classifies live allocator stats
against the calibrated Eq.1 prediction; the mitigation planner
(:mod:`.mitigation`) ranks knob moves by predicted headroom vs
throughput cost; the guard (:mod:`.guard`) validates and applies them
and hooks into the fault-tolerant trainer; the harness
(:mod:`.harness`) replays synthetic OOM trajectories to prove the loop
closes.  ``python -m repro.autopilot`` drives it all from the CLI.
"""

from .guard import Autopilot, MitigationError
from .harness import (DriftScenario, SCENARIOS, ScenarioResult, base_cell,
                      run_all, run_scenario, scenario)
from .mitigation import (COST_PRIOR, Mitigation, MitigationPlan,
                         MitigationPlanner, REMAT_LADDER)
from .watch import (MemoryWatch, WatchSample, WatchState, load_dryrun,
                    observed_bytes, scan_dryrun_dir)

__all__ = [
    "Autopilot", "MitigationError",
    "DriftScenario", "SCENARIOS", "ScenarioResult", "base_cell",
    "run_all", "run_scenario", "scenario",
    "COST_PRIOR", "Mitigation", "MitigationPlan", "MitigationPlanner",
    "REMAT_LADDER",
    "MemoryWatch", "WatchSample", "WatchState", "load_dryrun",
    "observed_bytes", "scan_dryrun_dir",
]
