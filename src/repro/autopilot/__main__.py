"""Memory-autopilot CLI.

    python -m repro.autopilot                       # all scenarios, both modes
    python -m repro.autopilot --scenario slow-leak  # one scenario
    python -m repro.autopilot --unguarded-only      # the failing baseline
    python -m repro.autopilot --list                # scenario catalogue
    python -m repro.autopilot --ingest experiments/dryrun  # artifact triage

Exit status is nonzero when any GUARDED run aborts or suffers an
injected OOM — the property CI pins.
"""

from __future__ import annotations

import argparse
import sys

from repro.core.sweep import SweepEngine

from .harness import SCENARIOS, run_scenario, scenario
from .watch import scan_dryrun_dir

GiB = 1024 ** 3


def _print_result(r) -> None:
    print(f"  {r}")
    if r.guarded and r.mitigations:
        print(f"    predicted {r.base_predicted_bytes / GiB:.2f} -> "
              f"{r.final_predicted_bytes / GiB:.2f} GiB "
              f"(budget {r.budget_bytes / GiB:.2f})")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.autopilot",
        description="closed-loop OOM avoidance: scenarios + telemetry "
                    "triage")
    ap.add_argument("--scenario", help="run one named scenario")
    ap.add_argument("--list", action="store_true",
                    help="list scenarios and exit")
    ap.add_argument("--guarded-only", action="store_true")
    ap.add_argument("--unguarded-only", action="store_true")
    ap.add_argument("--chip", default="v5e")
    ap.add_argument("--ingest", metavar="DIR",
                    help="triage dryrun artifacts in DIR (telemetry "
                         "ingest only; no scenarios)")
    args = ap.parse_args(argv)

    if args.list:
        for s in SCENARIOS:
            print(f"{s.name:<14} {s.n_steps:>3} steps  peak ratio "
                  f"{max(s.ratios):.2f}  {s.description}")
        return 0

    if args.ingest:
        rows = scan_dryrun_dir(args.ingest)
        if not rows:
            print(f"no artifacts under {args.ingest}")
            return 1
        bad = 0
        for name, obs in rows:
            if obs is None:
                bad += 1
                print(f"  {name:<60} telemetry unavailable")
            else:
                print(f"  {name:<60} {obs / GiB:8.2f} GiB")
        print(f"{len(rows)} artifacts, {bad} unusable")
        return 0

    try:
        todo = [scenario(args.scenario)] if args.scenario \
            else list(SCENARIOS)
    except KeyError as e:
        ap.error(str(e))
    modes = [True, False]
    if args.guarded_only:
        modes = [True]
    if args.unguarded_only:
        modes = [False]

    engine = SweepEngine()
    failures = 0
    for s in todo:
        print(f"scenario {s.name}: {s.description}")
        for guarded in modes:
            r = run_scenario(s, guarded, engine=engine, chip=args.chip)
            _print_result(r)
            if guarded and (r.aborted or r.oom_steps):
                failures += 1
    if failures:
        print(f"{failures} guarded run(s) aborted or OOMed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
