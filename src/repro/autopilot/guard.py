"""The closed loop: watch -> plan -> validate -> apply.

:class:`Autopilot` owns the current :class:`~repro.core.sweep.SweepCell`
(the knobs the job is actually running), a :class:`MemoryWatch` over its
calibrated prediction, and a :class:`MitigationPlanner`.  Per step it
ingests one telemetry sample; on a DRIFT or CRITICAL verdict it ranks
mitigations and applies the best one — but only after re-validating the
mutated cell through the un-memoized :func:`repro.core.planner.check`
gate: the applied plan's predicted peak must equal the reference
evaluation byte-for-byte, else :class:`MitigationError` aborts the
apply (a planner/evaluator disagreement means the memory model cannot
be trusted to steer the job).

``on_restart`` is the fault-tolerance hook: every elastic-resize or
preemption restart re-validates the (possibly new) mesh through
:func:`repro.core.planner.check_parallel` and, if the watch's drift
projection no longer clears the budget, applies the top-ranked plan
before the trainer resumes.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

from repro.configs import ShapeConfig
from repro.core import planner as PL
from repro.core import sweep as SW
from repro.core.spec import FULL_TRAIN

from .mitigation import Mitigation, MitigationPlan, MitigationPlanner
from .watch import MemoryWatch, WatchSample, WatchState


class MitigationError(RuntimeError):
    """An applied plan failed re-validation against planner.check."""


@dataclass
class Autopilot:
    """Closed-loop OOM avoidance around one training job's cell."""

    cell: SW.SweepCell
    policy: object = FULL_TRAIN
    headroom: float = PL.HEADROOM
    profile: object = None
    engine: SW.SweepEngine = field(default_factory=SW.SweepEngine)
    drift_tolerance: float = 1.05
    guard_frac: float = 0.95
    max_mitigations: int = 8
    allow_reshard: bool = True

    watch: MemoryWatch = field(init=False)
    planner: MitigationPlanner = field(init=False)
    applied: list = field(default_factory=list)    # Mitigation log
    events: list = field(default_factory=list)     # (step, kind, detail)

    def __post_init__(self):
        self.planner = MitigationPlanner(
            engine=self.engine, policy=self.policy,
            headroom=self.headroom, profile=self.profile)
        self.watch = MemoryWatch(
            predicted_bytes=self._predict(self.cell),
            budget_bytes=self.budget_bytes,
            drift_tolerance=self.drift_tolerance,
            guard_frac=self.guard_frac)

    # -- predictions ---------------------------------------------------------
    @property
    def budget_bytes(self) -> int:
        return int(PL.chip_hbm(self.cell.chip) * self.headroom)

    @property
    def predicted_bytes(self) -> int:
        return self.watch.predicted_bytes

    def _predict(self, cell: SW.SweepCell) -> int:
        return self.engine.evaluate(cell, policy=self.policy,
                                    headroom=self.headroom,
                                    profile=self.profile).peak_bytes

    # -- the loop ------------------------------------------------------------
    def observe(self, step: int, observed) -> WatchSample:
        """Ingest one telemetry sample; mitigate when the budget is
        threatened.  ``observed`` is bytes, a dryrun record dict, or
        None.  An ewma-only DRIFT (ratio past tolerance but projection
        still clear of the guard band) is logged, not acted on — a
        consistently-hot-but-fitting job should keep its knobs; knobs
        move once the projection enters the guard band or crosses the
        budget (CRITICAL)."""
        sample = self.watch.observe(step, observed)
        if sample.state in (WatchState.DRIFT, WatchState.CRITICAL):
            self.events.append((int(step), sample.state.value,
                                sample.projected_bytes))
            threatened = (sample.state is WatchState.CRITICAL
                          or sample.projected_bytes
                          > self.guard_frac * self.budget_bytes)
            if threatened:
                self.mitigate(step, sample.ewma_ratio)
        return sample

    def mitigate(self, step: int,
                 ewma_ratio: Optional[float] = None) -> Optional[Mitigation]:
        """Rank mitigations for the current cell and apply the best one
        (validated).  No-op once ``max_mitigations`` moves were spent —
        the autopilot never thrashes knobs forever."""
        if len(self.applied) >= self.max_mitigations:
            self.events.append((int(step), "exhausted",
                                len(self.applied)))
            return None
        ratio = self.watch.ewma_ratio if ewma_ratio is None else ewma_ratio
        plan = self.planner.plan(self.cell, ewma_ratio=ratio,
                                 allow_reshard=self.allow_reshard)
        best = plan.best
        if best is None:
            self.events.append((int(step), "no-candidates", 0))
            return None
        self._apply(step, best)
        return best

    def _apply(self, step: int, m: Mitigation) -> None:
        """Re-validate ``m`` against the un-memoized planner gate, then
        make its cell the current one and re-point the watch."""
        c = m.cell
        shape = ShapeConfig("autopilot", c.seq_len, c.global_batch,
                            c.kind)
        ref = PL.check(c.arch, shape, c.mesh_shape, policy=self.policy,
                       backend=c.backend, grad_accum=c.grad_accum,
                       remat=c.remat, optimizer=c.optimizer, chip=c.chip,
                       headroom=self.headroom, profile=self.profile,
                       microbatches=c.microbatches, schedule=c.schedule,
                       serve=c.serve, offload_opt=c.offload)
        if ref.peak_bytes != m.predicted_bytes:
            raise MitigationError(
                f"mitigation {m.action!r} failed validation: planner."
                f"check predicts {ref.peak_bytes} bytes for the mutated "
                f"cell but the plan claimed {m.predicted_bytes}")
        self.cell = c
        self.applied.append(m)
        self.events.append((int(step), f"apply:{m.action}",
                            m.predicted_bytes))
        # keep the EWMA: the drift multiplier (fragmentation, model
        # error) is a property of the JOB, not of the knobs — observed
        # usage scales with the new prediction, so the ratio carries over
        self.watch.repredict(m.predicted_bytes, reset_ewma=False)

    # -- fault-tolerance hook ------------------------------------------------
    def on_restart(self, step: int = -1,
                   mesh_shape: Optional[dict] = None) -> SW.SweepCell:
        """Restart/elastic-resize hook: re-validate the mesh through
        planner.check_parallel (a resize onto an illegal mesh must fail
        loudly here, not as a silent misprediction), adopt it, and if
        the drift projection no longer clears the budget apply the
        top-ranked plan before the trainer resumes."""
        cfg, _, _ = self.engine._arch_state(self.cell.arch, self.policy)
        mesh = dict(mesh_shape) if mesh_shape is not None \
            else self.cell.mesh_shape
        PL.check_parallel(cfg, mesh, self.cell.kind, self.cell.seq_len)
        if mesh_shape is not None and mesh != self.cell.mesh_shape:
            self.cell = replace(self.cell,
                                mesh=tuple(sorted(mesh.items())))
            self.watch.repredict(self._predict(self.cell),
                                 reset_ewma=False)
            self.events.append((int(step), "resize",
                                self.watch.predicted_bytes))
        projected = int(self.watch.ewma_ratio * self.watch.predicted_bytes)
        if projected > self.guard_frac * self.budget_bytes:
            self.mitigate(step)
        return self.cell
