"""The closed loop: watch -> plan -> validate -> apply.

:class:`Autopilot` owns the current :class:`~repro.core.sweep.SweepCell`
(the knobs the job is actually running), a :class:`MemoryWatch` over its
calibrated prediction, and a :class:`MitigationPlanner`.  Per step it
ingests one telemetry sample; on a DRIFT or CRITICAL verdict it ranks
mitigations and applies the best one — but only after re-validating the
mutated cell through the un-memoized :func:`repro.core.planner.check`
gate: the applied plan's predicted peak must equal the reference
evaluation byte-for-byte, else :class:`MitigationError` aborts the
apply (a planner/evaluator disagreement means the memory model cannot
be trusted to steer the job).

``on_restart`` is the fault-tolerance hook: every elastic-resize or
preemption restart re-validates the (possibly new) mesh through
:func:`repro.core.planner.check_parallel` and, if the watch's drift
projection no longer clears the budget, applies the top-ranked plan
before the trainer resumes.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

from repro.configs import ShapeConfig
from repro.core import planner as PL
from repro.core import sweep as SW
from repro.core.spec import FULL_TRAIN

from .mitigation import Mitigation, MitigationPlan, MitigationPlanner
from .watch import MemoryWatch, WatchSample, WatchState


class MitigationError(RuntimeError):
    """An applied plan failed re-validation against planner.check."""


@dataclass
class Autopilot:
    """Closed-loop OOM avoidance around one training job's cell."""

    cell: SW.SweepCell
    policy: object = FULL_TRAIN
    headroom: float = PL.HEADROOM
    profile: object = None
    # learned ResidualModel applied on top of the profile (and replaced
    # in place by a continual refit)
    residual: object = None
    engine: SW.SweepEngine = field(default_factory=SW.SweepEngine)
    drift_tolerance: float = 1.05
    guard_frac: float = 0.95
    max_mitigations: int = 8
    allow_reshard: bool = True
    # continual refit (repro.calibrate.learned): when enabled, every
    # usable observation accumulates into ``store`` and a persistent
    # DRIFT verdict spends a residual-model refit BEFORE a mitigation —
    # prediction bias (fragmentation, model error) is absorbed into the
    # model instead of burning a knob move on it.  A refit only fires
    # once ``refit_min_samples`` new samples arrived since the last one,
    # and at most ``max_refits`` times per run.
    refit: bool = False
    refit_min_samples: int = 8
    max_refits: int = 2
    store: object = None           # MeasurementStore (created if refit)

    watch: MemoryWatch = field(init=False)
    planner: MitigationPlanner = field(init=False)
    applied: list = field(default_factory=list)    # Mitigation log
    events: list = field(default_factory=list)     # (step, kind, detail)
    refits: int = field(default=0, init=False)
    _fitted_n: int = field(default=0, init=False)

    def __post_init__(self):
        self.planner = MitigationPlanner(
            engine=self.engine, policy=self.policy,
            headroom=self.headroom, profile=self.profile,
            residual=self.residual)
        self.watch = MemoryWatch(
            predicted_bytes=self._predict(self.cell),
            budget_bytes=self.budget_bytes,
            drift_tolerance=self.drift_tolerance,
            guard_frac=self.guard_frac)
        if self.refit:
            if getattr(self.cell, "serve", None) is not None:
                raise ValueError(
                    "continual refit supports train cells only (a serve "
                    "spec is not representable as a calibrate "
                    "Measurement)")
            if self.store is None:
                from repro.calibrate.measurements import MeasurementStore
                self.store = MeasurementStore()
            self.watch.store = self.store
            self.watch.measurement_of = self._measurement_of

    # -- predictions ---------------------------------------------------------
    @property
    def budget_bytes(self) -> int:
        return int(PL.chip_hbm(self.cell.chip) * self.headroom)

    @property
    def predicted_bytes(self) -> int:
        return self.watch.predicted_bytes

    def _predict(self, cell: SW.SweepCell) -> int:
        return self.engine.evaluate(cell, policy=self.policy,
                                    headroom=self.headroom,
                                    profile=self.profile,
                                    residual=self.residual).peak_bytes

    def _measurement_of(self, step: int, observed: int):
        """One watch observation as a calibrate Measurement of the
        CURRENT cell — the continual-refit sample the store accumulates.
        """
        from repro.calibrate.measurements import Measurement
        c = self.cell
        pname = next((k for k, v in SW.POLICIES.items()
                      if v == self.policy), "full")
        return Measurement(
            arch=c.arch, kind=c.kind, seq_len=c.seq_len,
            global_batch=c.global_batch, mesh_shape=c.mesh_shape,
            measured_bytes=int(observed), backend=c.backend, chip=c.chip,
            optimizer=c.optimizer, remat=c.remat,
            grad_accum=c.grad_accum, policy=pname,
            microbatches=c.microbatches, schedule=c.schedule,
            offload_optimizer=c.offload,
            source=f"autopilot:step{int(step)}")

    # -- the loop ------------------------------------------------------------
    def observe(self, step: int, observed) -> WatchSample:
        """Ingest one telemetry sample; refit, then mitigate, when the
        budget is threatened.  ``observed`` is bytes, a dryrun record
        dict, or None.

        Any DRIFT verdict (ewma-only or guard-band) first tries a
        residual-model refit when the continual-refit gate passes —
        persistent drift is prediction bias first, and a refit that
        absorbs it both fixes the forecast and often clears the guard
        band without spending a knob move.  The threat is re-projected
        under the refreshed prediction; a mitigation fires only if the
        projection STILL violates the guard band.  CRITICAL skips
        straight to mitigation — there is no time to refit when the
        next allocation spike is an OOM abort."""
        sample = self.watch.observe(step, observed)
        if sample.state in (WatchState.DRIFT, WatchState.CRITICAL):
            self.events.append((int(step), sample.state.value,
                                sample.projected_bytes))
            threatened = (sample.state is WatchState.CRITICAL
                          or sample.projected_bytes
                          > self.guard_frac * self.budget_bytes)
            if sample.state is WatchState.DRIFT \
                    and self._maybe_refit(step):
                projected = int(self.watch.ewma_ratio
                                * self.watch.predicted_bytes)
                threatened = (projected
                              > self.guard_frac * self.budget_bytes)
            if threatened:
                self.mitigate(step, self.watch.ewma_ratio)
        return sample

    def _maybe_refit(self, step: int) -> bool:
        """Refit the residual model from the accumulated store when the
        gate passes (refit enabled, refit budget left, enough NEW
        samples since the last fit); True when a refit was applied."""
        if not self.refit or self.store is None:
            return False
        if self.refits >= self.max_refits:
            return False
        if len(self.store) - self._fitted_n < self.refit_min_samples:
            return False
        from repro.calibrate.learned import fit_residual
        try:
            model = fit_residual(self.store, profile=self.profile,
                                 engine=self.engine)
        except ValueError:
            return False
        self._fitted_n = len(self.store)
        self.refits += 1
        self.residual = model
        self.planner.residual = model
        # the EWMA resets: the old ratio measured the bias the refit
        # just absorbed into the model
        self.watch.repredict(self._predict(self.cell), reset_ewma=True)
        self.events.append((int(step), "refit",
                            self.watch.predicted_bytes))
        return True

    def mitigate(self, step: int,
                 ewma_ratio: Optional[float] = None) -> Optional[Mitigation]:
        """Rank mitigations for the current cell and apply the best one
        (validated).  No-op once ``max_mitigations`` moves were spent —
        the autopilot never thrashes knobs forever."""
        if len(self.applied) >= self.max_mitigations:
            self.events.append((int(step), "exhausted",
                                len(self.applied)))
            return None
        ratio = self.watch.ewma_ratio if ewma_ratio is None else ewma_ratio
        plan = self.planner.plan(self.cell, ewma_ratio=ratio,
                                 allow_reshard=self.allow_reshard)
        best = plan.best
        if best is None:
            self.events.append((int(step), "no-candidates", 0))
            return None
        self._apply(step, best)
        return best

    def _apply(self, step: int, m: Mitigation) -> None:
        """Re-validate ``m`` against the un-memoized planner gate, then
        make its cell the current one and re-point the watch."""
        c = m.cell
        shape = ShapeConfig("autopilot", c.seq_len, c.global_batch,
                            c.kind)
        ref = PL.check(c.arch, shape, c.mesh_shape, policy=self.policy,
                       backend=c.backend, grad_accum=c.grad_accum,
                       remat=c.remat, optimizer=c.optimizer, chip=c.chip,
                       headroom=self.headroom, profile=self.profile,
                       microbatches=c.microbatches, schedule=c.schedule,
                       serve=c.serve, offload_opt=c.offload,
                       residual=self.residual)
        if ref.peak_bytes != m.predicted_bytes:
            raise MitigationError(
                f"mitigation {m.action!r} failed validation: planner."
                f"check predicts {ref.peak_bytes} bytes for the mutated "
                f"cell but the plan claimed {m.predicted_bytes}")
        self.cell = c
        self.applied.append(m)
        self.events.append((int(step), f"apply:{m.action}",
                            m.predicted_bytes))
        # keep the EWMA: the drift multiplier (fragmentation, model
        # error) is a property of the JOB, not of the knobs — observed
        # usage scales with the new prediction, so the ratio carries over
        self.watch.repredict(m.predicted_bytes, reset_ewma=False)

    # -- fault-tolerance hook ------------------------------------------------
    def on_restart(self, step: int = -1,
                   mesh_shape: Optional[dict] = None) -> SW.SweepCell:
        """Restart/elastic-resize hook: re-validate the mesh through
        planner.check_parallel (a resize onto an illegal mesh must fail
        loudly here, not as a silent misprediction), adopt it, and if
        the drift projection no longer clears the budget apply the
        top-ranked plan before the trainer resumes."""
        cfg, _, _ = self.engine._arch_state(self.cell.arch, self.policy)
        mesh = dict(mesh_shape) if mesh_shape is not None \
            else self.cell.mesh_shape
        PL.check_parallel(cfg, mesh, self.cell.kind, self.cell.seq_len)
        if mesh_shape is not None and mesh != self.cell.mesh_shape:
            self.cell = replace(self.cell,
                                mesh=tuple(sorted(mesh.items())))
            self.watch.repredict(self._predict(self.cell),
                                 reset_ewma=False)
            self.events.append((int(step), "resize",
                                self.watch.predicted_bytes))
        projected = int(self.watch.ewma_ratio * self.watch.predicted_bytes)
        if projected > self.guard_frac * self.budget_bytes:
            self.mitigate(step)
        return self.cell
