"""Synthetic OOM-trajectory harness: guarded vs unguarded trainer runs.

Each :class:`DriftScenario` is a per-step *drift ratio* trajectory —
the factor by which true device usage exceeds the Eq.1 prediction of
the cell the job is currently running (allocator fragmentation, model
error, an unmodelled resident buffer...).  True usage therefore tracks
the cell: a mitigation that shrinks the predicted peak shrinks real
usage by the same factor, exactly the physical contract the autopilot
steers by.

The harness normalizes the chip budget so the base cell starts at
``BASE_FRAC`` of it (arch-independent trajectories), then drives
:class:`~repro.runtime.fault_tolerance.ResilientTrainer` with

* a failure injector that raises an injected OOM whenever true usage
  exceeds the budget, and
* (guarded only) an :class:`~repro.autopilot.guard.Autopilot` observing
  the same usage BEFORE each step — admission control, so a mitigation
  lands before the allocation that would have died.

Unguarded runs keep the base cell: once the trajectory crosses the
budget every retry fails at the same step, the consecutive-failure
budget exhausts, and the run aborts.  Guarded runs must complete every
scenario with zero injected OOMs.
"""

from __future__ import annotations

import tempfile
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core import planner as PL
from repro.core import sweep as SW
from repro.core.spec import FULL_TRAIN

from .guard import Autopilot

#: the base cell starts at this fraction of the (normalized) budget, so
#: a drift ratio of 1 / BASE_FRAC = 1.25 is the OOM line
BASE_FRAC = 0.8

#: canonical harness cell: activation-heavy so every mitigation class
#: (grad-accum, offload, remat tightening) has real bytes to win back
HARNESS_ARCH = "smollm-360m"
HARNESS_MESH = (("data", 2), ("model", 2))
HARNESS_BATCH = 256
HARNESS_SEQ = 2048


def _ramp(start: float, stop: float, n: int) -> tuple:
    return tuple(round(start + (stop - start) * i / max(n - 1, 1), 4)
                 for i in range(n))


@dataclass(frozen=True)
class DriftScenario:
    """One synthetic trajectory of observed/predicted drift ratios."""

    name: str
    ratios: tuple                  # per-step drift ratio, len == n_steps
    description: str = ""

    @property
    def n_steps(self) -> int:
        return len(self.ratios)

    def crosses_budget(self) -> bool:
        return any(r > 1.0 / BASE_FRAC for r in self.ratios)


#: the scenario set every PR's OOM-avoidance rate is measured on; each
#: crosses the budget line (ratio 1.25) so the unguarded baseline aborts
SCENARIOS = (
    DriftScenario(
        "slow-leak",
        _ramp(0.90, 1.40, 20),
        "fragmentation-style creep: +2.6%/step across the budget line"),
    DriftScenario(
        "spike",
        (1.02, 1.04, 1.06, 1.06, 1.06, 1.06) + (1.30,) * 8,
        "steady mild drift, then a resident-buffer spike past budget"),
    DriftScenario(
        "underestimate",
        (1.30,) * 10,
        "the model underestimates from step 0 (unmodelled allocation)"),
)


def scenario(name: str) -> DriftScenario:
    for s in SCENARIOS:
        if s.name == name:
            return s
    raise KeyError(f"unknown scenario {name!r}; known: "
                   f"{[s.name for s in SCENARIOS]}")


@dataclass
class ScenarioResult:
    """Outcome of one trainer run under one scenario."""

    scenario: str
    guarded: bool
    completed: bool
    aborted: bool
    steps_done: int
    n_steps: int
    oom_steps: list
    mitigations: list              # applied action names, in order
    restarts: int
    budget_bytes: int
    base_predicted_bytes: int
    final_predicted_bytes: int

    @property
    def oom_free(self) -> bool:
        return not self.oom_steps

    def __str__(self) -> str:
        mode = "guarded" if self.guarded else "unguarded"
        out = ("completed" if self.completed else
               "ABORTED" if self.aborted else "stopped")
        mit = ",".join(self.mitigations) or "-"
        return (f"{self.scenario:<14} {mode:<9} {out:<9} "
                f"steps={self.steps_done}/{self.n_steps} "
                f"ooms={len(self.oom_steps)} mitigations=[{mit}] "
                f"restarts={self.restarts}")


def base_cell(chip: str = "v5e") -> SW.SweepCell:
    """The harness's starting knobs: loosest remat, no accumulation, no
    offload — every mitigation class still has room to act."""
    return SW.SweepCell(
        arch=HARNESS_ARCH, chip=chip, mesh=HARNESS_MESH,
        optimizer=None, remat="none", grad_accum=1,
        global_batch=HARNESS_BATCH, seq_len=HARNESS_SEQ,
        kind="train", backend="tpu")


def run_scenario(scn: DriftScenario, guarded: bool,
                 engine: Optional[SW.SweepEngine] = None,
                 chip: str = "v5e",
                 max_restarts: int = 3) -> ScenarioResult:
    """Drive ResilientTrainer through one scenario; returns the outcome.

    The budget is normalized so the base cell's raw prediction sits at
    ``BASE_FRAC`` of it (via the autopilot/planner ``headroom`` knob),
    making the drift trajectories arch-independent.
    """
    from repro.checkpoint import Checkpointer
    from repro.runtime.fault_tolerance import FaultConfig, ResilientTrainer

    engine = engine or SW.SweepEngine()
    cell = base_cell(chip)
    base_pred = engine.evaluate(cell, policy=FULL_TRAIN).peak_bytes
    budget = int(base_pred / BASE_FRAC)
    headroom = budget / PL.chip_hbm(chip)

    pilot = None
    if guarded:
        pilot = Autopilot(cell=cell, policy=FULL_TRAIN,
                          headroom=headroom, engine=engine)

    def predicted_now() -> int:
        return pilot.watch.predicted_bytes if pilot is not None \
            else base_pred

    def usage(step: int) -> int:
        # true usage tracks the CURRENT cell's prediction
        return int(scn.ratios[min(step, scn.n_steps - 1)]
                   * predicted_now())

    oom_steps: list = []

    def injector(step: int) -> bool:
        if usage(step) > budget:
            oom_steps.append(step)
            return True
        return False

    done = {"n": 0}

    def train_step(state, batch):
        done["n"] += 1
        return state + 1, {"loss": 0.0}

    trainer = ResilientTrainer(
        train_step=train_step,
        pipeline=None,
        checkpointer=Checkpointer(directory=tempfile.mkdtemp(
            prefix="autopilot_harness_")),
        fault_cfg=FaultConfig(ckpt_every=10 ** 6,
                              max_restarts=max_restarts),
        make_batch=lambda step: np.zeros(1),
        failure_injector=injector,
        autopilot=pilot, memory_source=usage)

    completed, aborted = False, False
    try:
        trainer.run(0, 0, scn.n_steps)
        completed = True
    except RuntimeError:
        aborted = True
    return ScenarioResult(
        scenario=scn.name, guarded=guarded, completed=completed,
        aborted=aborted, steps_done=done["n"], n_steps=scn.n_steps,
        oom_steps=oom_steps,
        mitigations=[m.action for m in pilot.applied] if pilot else [],
        restarts=trainer.restarts, budget_bytes=budget,
        base_predicted_bytes=base_pred,
        final_predicted_bytes=predicted_now())


def run_all(engine: Optional[SW.SweepEngine] = None,
            chip: str = "v5e") -> list:
    """Every scenario, guarded AND unguarded; shared engine caches."""
    engine = engine or SW.SweepEngine()
    return [run_scenario(s, guarded, engine=engine, chip=chip)
            for s in SCENARIOS for guarded in (True, False)]
