"""Ranked mitigation planning: knob moves that buy back memory headroom.

On a DRIFT/CRITICAL verdict the planner enumerates candidate cell
mutations, predicts each through the memoized
:class:`~repro.core.sweep.SweepEngine` (component groups shared with
every other prediction this process made), and ranks them by

    (reaches safety, estimated throughput cost, -headroom gained)

so the cheapest knob that actually clears the projected peak wins.
Candidates, cheapest first by prior:

* ``microbatches``  — double the microbatch count (pp > 1 only: shrinks
  the 1F1B stash); near-free, it only re-slices the schedule.
* ``grad_accum``    — double gradient accumulation: halves the
  micro-batch activations at some step-efficiency cost.
* ``offload_opt``   — host-offload the optimizer states, keeping only
  the Eq.1 double-buffered staging window on device; costs PCIe/ICI
  streaming bandwidth each update.
* ``remat``         — tighten the rematerialization policy one notch
  (none -> dots -> block); costs recompute FLOPs in the backward.
* ``reshard``       — :func:`repro.core.planner.plan_min_chips` over
  larger chip counts: the last resort, it needs new hardware.

Predicted savings are Eq.1 arithmetic, so every candidate's
``predicted_bytes`` is exactly what ``planner.check`` would report for
the mutated cell — the guard re-validates that equality before applying
a plan.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

from repro.configs import ShapeConfig
from repro.core import planner as PL
from repro.core import sweep as SW
from repro.core.spec import FULL_TRAIN

#: remat ladder, loosest to tightest (factors.eff_act_saved semantics:
#: "none" saves everything, "dots" drops matmul partials, "block" keeps
#: only the scan carry)
REMAT_LADDER = ("none", "dots", "block")

#: static throughput-cost priors (fraction of step time sacrificed);
#: ranking inputs, not measurements — they order candidates, nothing else
COST_PRIOR = {
    "microbatches": 0.02,
    "grad_accum": 0.10,
    "offload_opt": 0.15,
    "remat": 0.30,
    "reshard": 1.00,
}


@dataclass(frozen=True)
class Mitigation:
    """One candidate knob move and its predicted effect."""

    action: str                    # COST_PRIOR key
    cell: SW.SweepCell             # the mutated cell
    predicted_bytes: int           # Eq.1 peak of the mutated cell
    projected_bytes: int           # drift-scaled peak (ewma * predicted)
    budget_bytes: int
    throughput_cost: float
    note: str = ""

    @property
    def safe(self) -> bool:
        return self.projected_bytes <= self.budget_bytes

    @property
    def headroom_gained(self) -> int:
        return self.budget_bytes - self.projected_bytes

    def __str__(self) -> str:
        gib = 1024 ** 3
        verdict = "safe" if self.safe else "STILL OVER"
        return (f"{self.action:<12} -> {self.predicted_bytes / gib:.2f} "
                f"GiB predicted ({self.projected_bytes / gib:.2f} "
                f"projected vs {self.budget_bytes / gib:.2f} budget, "
                f"{verdict}; cost~{self.throughput_cost:.2f}) {self.note}")


@dataclass(frozen=True)
class MitigationPlan:
    """Ranked candidates for one drifting cell."""

    cell: SW.SweepCell
    projected_bytes: int
    budget_bytes: int
    ewma_ratio: float
    candidates: tuple              # of Mitigation, ranked best-first

    @property
    def best(self) -> Optional[Mitigation]:
        return self.candidates[0] if self.candidates else None

    @property
    def reaches_safety(self) -> bool:
        return bool(self.candidates) and self.candidates[0].safe


@dataclass
class MitigationPlanner:
    """Enumerate + rank mitigations through a shared SweepEngine."""

    engine: SW.SweepEngine = field(default_factory=SW.SweepEngine)
    policy: object = FULL_TRAIN
    headroom: float = PL.HEADROOM
    profile: object = None
    # learned ResidualModel applied on top of the profile; the guard
    # updates this in place after a continual refit so candidate ranking
    # and the _apply byte-equality validation see the same corrections
    residual: object = None
    reshard_chips: tuple = (8, 16, 32, 64)
    # re-pricing path knobs: the reshard search prunes through
    # core.search by default ("exhaustive" restores brute-force
    # enumeration — answers are identical either way), and
    # compute_engine="jax" runs the surviving sweep slices on the
    # jitted columnar engine (worth it once reshard_chips spans large
    # counts; numpy avoids jit warm-up on the small default span)
    search: str = "pruned"
    compute_engine: str = "numpy"

    def _predict(self, cell: SW.SweepCell) -> int:
        res = self.engine.evaluate(cell, policy=self.policy,
                                   headroom=self.headroom,
                                   profile=self.profile,
                                   residual=self.residual)
        return res.peak_bytes

    # -- candidate enumeration ----------------------------------------------
    def _mutations(self, cell: SW.SweepCell):
        """(action, mutated_cell, note) tuples; mutations that don't
        apply to this cell (already at the knob's limit, wrong kind)
        are skipped rather than emitted as no-ops."""
        cfg, _, _ = self.engine._arch_state(cell.arch, self.policy)
        out = []
        pp = dict(cell.mesh).get("pipe", 1)
        if pp > 1 and cell.kind == "train":
            m = max(cell.microbatches, 1) * 2
            gb_micro = max(cell.global_batch // max(cell.grad_accum, 1), 1)
            if m <= gb_micro and gb_micro % m == 0:
                out.append(("microbatches",
                            replace(cell, microbatches=m),
                            f"microbatches {cell.microbatches} -> {m}"))
        if cell.kind == "train":
            a = max(cell.grad_accum, 1) * 2
            if a <= cell.global_batch and cell.global_batch % a == 0:
                out.append(("grad_accum", replace(cell, grad_accum=a),
                            f"grad_accum {cell.grad_accum} -> {a}"))
            if not cell.offload:
                out.append(("offload_opt", replace(cell, offload=True),
                            "optimizer states -> host tier"))
            cur = cell.remat or cfg.remat
            if cur in REMAT_LADDER:
                for nxt in REMAT_LADDER[REMAT_LADDER.index(cur) + 1:]:
                    out.append(("remat", replace(cell, remat=nxt),
                                f"remat {cur} -> {nxt}"))
        return out

    def _reshard(self, cell: SW.SweepCell,
                 ewma_ratio: float) -> Optional[Mitigation]:
        """plan_min_chips over chip counts above the current mesh; the
        enumerated factorizations check_parallel would reject are
        filtered inside the search."""
        n_now = cell.n_chips
        chips = tuple(c for c in self.reshard_chips if c > n_now)
        if not chips or cell.kind != "train":
            return None
        shape = ShapeConfig("autopilot", cell.seq_len, cell.global_batch,
                            cell.kind)
        res = PL.plan_min_chips(
            cell.arch, shape, chips=chips, chip=cell.chip,
            policy=self.policy, backend=cell.backend,
            headroom=self.headroom, profile=self.profile,
            engine=self.engine, search=self.search,
            compute_engine=self.compute_engine)
        if res is None:
            return None
        new = SW.SweepCell(
            arch=cell.arch, chip=cell.chip,
            mesh=tuple(sorted(res.mesh_shape.items())),
            optimizer=cell.optimizer, remat=res.remat,
            grad_accum=res.grad_accum, global_batch=cell.global_batch,
            seq_len=cell.seq_len, kind=cell.kind, backend=cell.backend,
            schedule=res.schedule, microbatches=res.microbatches,
            offload=cell.offload)
        pred = self._predict(new)
        budget = int(PL.chip_hbm(cell.chip) * self.headroom)
        cost = COST_PRIOR["reshard"] * res.n_chips / max(n_now, 1)
        return Mitigation(
            action="reshard", cell=new, predicted_bytes=pred,
            projected_bytes=int(ewma_ratio * pred), budget_bytes=budget,
            throughput_cost=cost,
            note=f"{n_now} -> {res.n_chips} chips ({res.mesh_str})")

    # -- ranking -------------------------------------------------------------
    def plan(self, cell: SW.SweepCell, ewma_ratio: float = 1.0,
             allow_reshard: bool = True) -> MitigationPlan:
        """Rank every applicable mitigation for ``cell`` under the
        watch's drift ratio.  A candidate is "safe" when its
        drift-scaled projection clears the chip budget."""
        ratio = max(float(ewma_ratio), 1.0)
        budget = int(PL.chip_hbm(cell.chip) * self.headroom)
        base_pred = self._predict(cell)
        cands = []
        for action, mutated, note in self._mutations(cell):
            pred = self._predict(mutated)
            if pred >= base_pred:
                continue               # no savings: not a mitigation
            cands.append(Mitigation(
                action=action, cell=mutated, predicted_bytes=pred,
                projected_bytes=int(ratio * pred), budget_bytes=budget,
                throughput_cost=COST_PRIOR[action], note=note))
        if allow_reshard and not any(c.safe for c in cands):
            rs = self._reshard(cell, ratio)
            if rs is not None:
                cands.append(rs)
        cands.sort(key=lambda c: (not c.safe, c.throughput_cost,
                                  -c.headroom_gained))
        return MitigationPlan(cell=cell,
                              projected_bytes=int(ratio * base_pred),
                              budget_bytes=budget, ewma_ratio=ratio,
                              candidates=tuple(cands))
