"""Telemetry ingest + drift classification for the memory autopilot.

The watch consumes live allocator numbers — on a real job the per-device
peak from ``compiled.memory_analysis()`` that ``repro.launch.dryrun``
serializes into ``experiments/dryrun/*.json`` artifacts, in tests any
injectable step -> bytes source — and maintains an EWMA of the
observed / predicted ratio against the calibrated
:class:`~repro.core.predictor.PredictedMemory` peak of the current cell.
Each observation is classified:

* ``UNAVAILABLE`` — no usable telemetry this step (missing artifact,
  truncated metric dump, zero/negative counters).  Deliberately NOT
  ``SAFE``: a blind autopilot must not report health it cannot see.
* ``SAFE``       — projected peak comfortably inside the budget.
* ``DRIFT``      — observed usage runs persistently above the
  prediction (EWMA ratio past ``drift_tolerance``) or the projection
  has entered the guard band below the budget.
* ``CRITICAL``   — the projected peak meets or exceeds the budget: the
  next allocation spike is an OOM abort.

``projected_bytes = max(observed, ewma * predicted)`` is the quantity
classified — the EWMA arm catches slow leaks the newest sample alone
would understate, the raw arm catches spikes faster than the EWMA can
follow.
"""

from __future__ import annotations

import enum
import json
import os
from dataclasses import dataclass, field
from typing import Optional


class WatchState(enum.Enum):
    UNAVAILABLE = "unavailable"
    SAFE = "safe"
    DRIFT = "drift"
    CRITICAL = "critical"

    def __str__(self) -> str:  # pragma: no cover - repr sugar
        return self.value


# -- allocator-stat ingest ---------------------------------------------------

_COUNTERS = ("argument_bytes", "output_bytes", "temp_bytes", "alias_bytes")


def observed_bytes(record) -> Optional[int]:
    """Per-device peak bytes out of one dryrun artifact record, or None
    when the telemetry is unusable (the "telemetry unavailable" state —
    never a crash, never a bogus zero that would read as SAFE).

    Accepts the ``record["memory"]`` dict written by
    ``repro.launch.dryrun`` (or the full record).  A serialized
    ``total_bytes`` wins; otherwise the total is rebuilt from the four
    allocator counters exactly like
    :meth:`repro.core.xla_metrics.MemoryStats.total_bytes`.  Missing
    counters, non-numeric values and non-positive totals all yield None.
    """
    if not isinstance(record, dict):
        return None
    mem = record.get("memory", record)
    if not isinstance(mem, dict):
        return None
    total = mem.get("total_bytes")
    if total is None:
        try:
            total = (int(mem["argument_bytes"]) + int(mem["temp_bytes"])
                     + int(mem["output_bytes"]) - int(mem["alias_bytes"]))
        except (KeyError, TypeError, ValueError):
            return None
    try:
        total = int(total)
    except (TypeError, ValueError):
        return None
    return total if total > 0 else None


def telemetry_defect(record) -> Optional[str]:
    """Human-readable reason ``observed_bytes(record)`` returned None —
    the defect matrix, named.  None when the record is usable.  Ingest
    paths (calibrate.measurements.from_dryrun_record) use this to raise
    errors that say WHICH defect poisoned the sample."""
    if not isinstance(record, dict):
        return f"record is {type(record).__name__}, not a dict"
    mem = record.get("memory", record)
    if not isinstance(mem, dict):
        return "memory block is not a dict"
    total = mem.get("total_bytes")
    if total is None:
        missing = [c for c in _COUNTERS if c not in mem]
        if missing:
            return (f"no total_bytes and allocator counters "
                    f"{missing} missing")
        try:
            total = (int(mem["argument_bytes"]) + int(mem["temp_bytes"])
                     + int(mem["output_bytes"]) - int(mem["alias_bytes"]))
        except (TypeError, ValueError):
            return "no total_bytes and non-numeric allocator counters"
    try:
        total = int(total)
    except (TypeError, ValueError):
        return f"non-numeric total_bytes {total!r}"
    if total <= 0:
        return f"non-positive total ({total} bytes)"
    return None


def load_dryrun(path: str) -> Optional[int]:
    """Observed bytes from a dryrun artifact file; None on any defect
    (missing file, truncated JSON, missing counters, zero peak)."""
    try:
        with open(path) as f:
            record = json.load(f)
    except (OSError, ValueError):
        return None
    return observed_bytes(record)


def scan_dryrun_dir(dirname: str) -> list:
    """(filename, observed_bytes_or_None) for every artifact in a dryrun
    directory, sorted by name; tolerates a missing directory."""
    try:
        names = sorted(n for n in os.listdir(dirname)
                       if n.endswith(".json"))
    except OSError:
        return []
    return [(n, load_dryrun(os.path.join(dirname, n))) for n in names]


# -- the watch ---------------------------------------------------------------


@dataclass(frozen=True)
class WatchSample:
    """One classified observation."""

    step: int
    state: WatchState
    observed_bytes: Optional[int]
    predicted_bytes: int
    projected_bytes: int
    budget_bytes: int
    ewma_ratio: float

    @property
    def headroom_bytes(self) -> int:
        return max(0, self.budget_bytes - self.projected_bytes)


@dataclass
class MemoryWatch:
    """EWMA drift detector over observed vs predicted peak memory."""

    predicted_bytes: int
    budget_bytes: int
    drift_tolerance: float = 1.05   # EWMA ratio past this => DRIFT
    guard_frac: float = 0.95        # projection past this * budget => DRIFT
    ewma_alpha: float = 0.25
    # continual-refit hook (repro.calibrate.learned): every USABLE
    # observation is also appended to ``store`` (a
    # calibrate.measurements.MeasurementStore) as the Measurement built
    # by ``measurement_of(step, observed_bytes)`` — the guard's refit
    # trigger fits the learned residual model from exactly these
    # samples.  Both default to None (no accumulation).
    store: Optional[object] = None
    measurement_of: Optional[object] = None

    ewma_ratio: float = 1.0
    samples: list = field(default_factory=list)

    def __post_init__(self):
        if self.predicted_bytes <= 0:
            raise ValueError("predicted_bytes must be positive")
        if self.budget_bytes <= 0:
            raise ValueError("budget_bytes must be positive")

    def repredict(self, predicted_bytes: int,
                  reset_ewma: bool = True) -> None:
        """Point the watch at a new cell's prediction (after a
        mitigation changed the knobs).  The EWMA resets by default: the
        old ratio measured the OLD cell's model error."""
        if predicted_bytes <= 0:
            raise ValueError("predicted_bytes must be positive")
        self.predicted_bytes = int(predicted_bytes)
        if reset_ewma:
            self.ewma_ratio = 1.0

    def classify(self, observed: Optional[int]) -> WatchState:
        """Stateless classification of a single observation against the
        CURRENT ewma (used by observe after the EWMA update)."""
        if observed is None or observed <= 0:
            return WatchState.UNAVAILABLE
        projected = self.project(observed)
        if projected >= self.budget_bytes:
            return WatchState.CRITICAL
        if (self.ewma_ratio > self.drift_tolerance
                or projected > self.guard_frac * self.budget_bytes):
            return WatchState.DRIFT
        return WatchState.SAFE

    def project(self, observed: int) -> int:
        return max(int(observed),
                   int(self.ewma_ratio * self.predicted_bytes))

    def observe(self, step: int, observed: Optional[int]) -> WatchSample:
        """Fold one telemetry sample in and classify it.  Unusable
        telemetry leaves the EWMA untouched (no observation, no
        update) and comes back UNAVAILABLE."""
        obs = observed_bytes(observed) if isinstance(observed, dict) \
            else observed
        if obs is not None and obs > 0:
            ratio = obs / self.predicted_bytes
            a = self.ewma_alpha
            self.ewma_ratio = (1 - a) * self.ewma_ratio + a * ratio
            projected = self.project(obs)
            if self.store is not None and self.measurement_of is not None:
                self.store.add(self.measurement_of(int(step), int(obs)))
        else:
            obs = None
            projected = int(self.ewma_ratio * self.predicted_bytes)
        sample = WatchSample(step=int(step), state=self.classify(obs),
                             observed_bytes=obs,
                             predicted_bytes=self.predicted_bytes,
                             projected_bytes=projected,
                             budget_bytes=self.budget_bytes,
                             ewma_ratio=self.ewma_ratio)
        self.samples.append(sample)
        return sample
