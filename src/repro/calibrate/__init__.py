"""Measurement-driven calibration of the analytic memory predictor.

Closes the loop the paper's evaluation opens: dry-run/real measurements
flow into a :class:`MeasurementStore`, the prediction-vs-measured residual
is decomposed per Eq.1 component group, a :class:`CalibrationProfile`
(per-term multiplicative coefficients + per-chip constant overhead) is
fitted by non-negative least squares, and the profile threads through
``predictor.assemble`` / ``planner.check`` / the sweep engine so every
verdict can be measurement-corrected.

    python -m repro.calibrate fit --synthetic --out profile.json
    python -m repro.calibrate report --profile profile.json --synthetic
    python -m repro.calibrate apply --profile profile.json \
        --arch llava15-7b --mesh data=8,model=2 --chip v5e

See docs/calibration.md for the walkthrough and the JSON schemas.

Exports resolve lazily (PEP 562) so light consumers — launch/dryrun.py
and benchmarks/common.py import only ``repro.calibrate.paths`` for the
shared artifact-directory resolution — never pay for (or depend on) the
fit/report stack's imports.
"""

from __future__ import annotations

import importlib

_EXPORTS = {
    "fit_profile": "repro.calibrate.fit",
    "fit_rows": "repro.calibrate.fit",
    "nnls": "repro.calibrate.fit",
    "ridge": "repro.calibrate.fit",
    "FEATURE_NAMES": "repro.calibrate.learned",
    "ResidualModel": "repro.calibrate.learned",
    "apply_residual": "repro.calibrate.learned",
    "features_from": "repro.calibrate.learned",
    "fit_residual": "repro.calibrate.learned",
    "leave_one_family_out": "repro.calibrate.learned",
    "residual_hash_of": "repro.calibrate.learned",
    "parse_mesh_string": "repro.calibrate.measurements",
    "Measurement": "repro.calibrate.measurements",
    "MeasurementStore": "repro.calibrate.measurements",
    "dryrun_dir": "repro.calibrate.paths",
    "profiles_dir": "repro.calibrate.paths",
    "repo_root": "repro.calibrate.paths",
    "TERMS": "repro.calibrate.profile",
    "CalibrationProfile": "repro.calibrate.profile",
    "AccuracyReport": "repro.calibrate.report",
    "evaluate": "repro.calibrate.report",
    "TermRow": "repro.calibrate.residual",
    "decompose": "repro.calibrate.residual",
    "predict_measurement": "repro.calibrate.residual",
    "SYNTHETIC_ARCHS": "repro.calibrate.synthetic",
    "TRUE_PROFILE": "repro.calibrate.synthetic",
    "generate": "repro.calibrate.synthetic",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(
            f"module 'repro.calibrate' has no attribute {name!r}")
    value = getattr(importlib.import_module(mod), name)
    globals()[name] = value        # cache for subsequent lookups
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
