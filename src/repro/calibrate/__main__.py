"""Calibration CLI: fit | fit-residual | apply | report.

    # fit a profile from measurements (dry-run artifacts, a saved store,
    # or the deterministic synthetic set) and save it
    python -m repro.calibrate fit --synthetic --out profile.json
    python -m repro.calibrate fit --dryrun-dir experiments/dryrun \
        --out profile.json
    python -m repro.calibrate fit --measurements store.json --out p.json

    # fit the learned per-family residual model on top of that profile
    python -m repro.calibrate fit-residual --synthetic \
        --profile profile.json --out residual.json

    # calibrated vs raw prediction for one cell
    python -m repro.calibrate apply --profile profile.json \
        --arch llava15-7b --shape train_4k --mesh data=8,model=2 --chip v5e

    # the paper-style accuracy table (per-arch/family MAPE, cal vs raw)
    python -m repro.calibrate report --profile profile.json --synthetic \
        --by family --md report.md --json report.json
"""

from __future__ import annotations

import argparse
import datetime
import sys
from typing import Optional, Sequence

GiB = 1024 ** 3


def _load_store(args) -> "object":
    from repro.calibrate.measurements import MeasurementStore
    if args.synthetic:
        from repro.calibrate.synthetic import generate
        return generate(noise=args.noise)
    if args.measurements:
        return MeasurementStore.load(args.measurements)
    store = MeasurementStore.ingest_dryrun_dir(args.dryrun_dir)
    if not len(store):
        raise SystemExit(
            f"no measurements: dry-run dir "
            f"{args.dryrun_dir or 'experiments/dryrun'} is empty — run "
            f"python -m repro.launch.dryrun, pass --measurements, or use "
            f"--synthetic")
    return store


def _add_source_args(p) -> None:
    p.add_argument("--measurements", metavar="PATH",
                   help="saved MeasurementStore JSON")
    p.add_argument("--dryrun-dir", metavar="DIR", default=None,
                   help="dry-run artifact dir (default: experiments/dryrun)")
    p.add_argument("--synthetic", action="store_true",
                   help="use the deterministic synthetic measurement set")
    p.add_argument("--noise", type=float, default=0.01,
                   help="synthetic relative noise amplitude")


def cmd_fit(args) -> int:
    from repro.calibrate.fit import fit_profile
    store = _load_store(args)
    created = datetime.datetime.now(datetime.timezone.utc) \
        .strftime("%Y-%m-%dT%H:%M:%SZ")
    profile = fit_profile(
        store, created=created,
        source={"cli": "fit",
                "input": ("synthetic" if args.synthetic
                          else args.measurements or "dryrun")})
    path = profile.save(args.out)
    print(profile.summary())
    print(f"fitted from {len(store)} measurements "
          f"({', '.join(store.archs())})")
    print(f"wrote {path}")
    return 0


def cmd_fit_residual(args) -> int:
    from repro.calibrate.learned import fit_residual
    from repro.calibrate.profile import CalibrationProfile
    profile = CalibrationProfile.load(args.profile) if args.profile \
        else None
    store = _load_store(args)
    created = datetime.datetime.now(datetime.timezone.utc) \
        .strftime("%Y-%m-%dT%H:%M:%SZ")
    model = fit_residual(
        store, profile=profile, lam=args.lam, created=created,
        source={"cli": "fit-residual",
                "input": ("synthetic" if args.synthetic
                          else args.measurements or "dryrun")})
    path = model.save(args.out)
    print(model.summary())
    info = model.fit_info
    print(f"in-sample MAPE: affine {info['mape_affine_pct']:.2f}% -> "
          f"learned {info['mape_learned_pct']:.2f}% "
          f"({len(store)} measurements)")
    print(f"wrote {path}")
    return 0


def _load_residual(args, profile):
    """--residual-model loader shared by apply/report; validates the
    base-profile binding before any prediction runs."""
    if not getattr(args, "residual_model", None):
        return None
    from repro.calibrate.learned import ResidualModel
    model = ResidualModel.load(args.residual_model)
    phash = profile.profile_hash if profile is not None else None
    if model.base_profile_hash != phash:
        raise SystemExit(
            f"--residual-model was fitted over profile "
            f"{model.base_profile_hash or 'raw'}, not "
            f"{phash or 'raw'}; pass the matching --profile")
    return model


def cmd_apply(args) -> int:
    from repro.calibrate.profile import CalibrationProfile
    from repro.core import planner
    from repro.core.sweep import _parse_mesh, normalize_arch
    profile = CalibrationProfile.load(args.profile)
    residual = _load_residual(args, profile)
    arch = normalize_arch(args.arch)
    mesh = _parse_mesh(args.mesh)
    raw = planner.check(arch, args.shape, mesh, backend=args.backend,
                        chip=args.chip)
    cal = planner.check(arch, args.shape, mesh, backend=args.backend,
                        chip=args.chip, profile=profile,
                        residual=residual)
    print(profile.summary())
    if residual is not None:
        print(residual.summary())
    print(f"raw : {raw}")
    print(f"cal : {cal}")
    delta = cal.peak_bytes - raw.peak_bytes
    print(f"delta: {delta / GiB:+.3f} GiB "
          f"({100.0 * delta / max(raw.peak_bytes, 1):+.2f}%)")
    return 0


def cmd_report(args) -> int:
    from repro.calibrate.profile import CalibrationProfile
    from repro.calibrate.report import evaluate
    profile = CalibrationProfile.load(args.profile)
    residual = _load_residual(args, profile)
    store = _load_store(args)
    rep = evaluate(store, profile, by=args.by, residual=residual)
    md = rep.to_markdown()
    print(md)
    if args.md:
        with open(args.md, "w") as f:
            f.write(md + "\n")
        print(f"wrote {args.md}")
    if args.json:
        rep.save_json(args.json)
        print(f"wrote {args.json}")
    return 0 if rep.mape_calibrated <= rep.mape_raw else 1


def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.calibrate",
        description="Fit/apply/evaluate measurement-driven calibration "
                    "profiles for the memory predictor.")
    sub = p.add_subparsers(dest="cmd", required=True)

    f = sub.add_parser("fit", help="fit a CalibrationProfile (NNLS)")
    _add_source_args(f)
    f.add_argument("--out", required=True, metavar="PATH",
                   help="profile JSON output path")
    f.set_defaults(fn=cmd_fit)

    fr = sub.add_parser(
        "fit-residual",
        help="fit a learned per-family ResidualModel (ridge) on top of "
             "a profile")
    _add_source_args(fr)
    fr.add_argument("--profile", default=None, metavar="PATH",
                    help="CalibrationProfile the residual is fitted on "
                         "top of (omit to fit the raw-prediction "
                         "residual)")
    fr.add_argument("--lam", type=float, default=1e-3,
                    help="ridge regularization strength")
    fr.add_argument("--out", required=True, metavar="PATH",
                    help="residual model JSON output path")
    fr.set_defaults(fn=cmd_fit_residual)

    a = sub.add_parser("apply",
                       help="calibrated vs raw prediction for one cell")
    a.add_argument("--profile", required=True)
    a.add_argument("--residual-model", default=None, metavar="PATH",
                   help="learned ResidualModel JSON applied on top of "
                        "--profile")
    a.add_argument("--arch", required=True)
    a.add_argument("--shape", default="train_4k")
    a.add_argument("--mesh", default="data=16,model=16",
                   metavar="data=16,model=16")
    a.add_argument("--chip", default="v5e")
    a.add_argument("--backend", default="tpu", choices=("tpu", "cpu"))
    a.set_defaults(fn=cmd_apply)

    r = sub.add_parser("report",
                       help="per-group MAPE table, calibrated vs raw")
    r.add_argument("--profile", required=True)
    r.add_argument("--residual-model", default=None, metavar="PATH",
                   help="learned ResidualModel JSON; adds a third "
                        "(learned) MAPE series")
    _add_source_args(r)
    r.add_argument("--by", default="family", choices=("family", "arch"))
    r.add_argument("--md", metavar="PATH", help="write markdown report")
    r.add_argument("--json", metavar="PATH", help="write JSON report")
    r.set_defaults(fn=cmd_report)

    args = p.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
