"""Fit a CalibrationProfile from measurements via non-negative least
squares.

Model: for measurement i with raw term bytes t_{i,term} on chip c_i,

    measured_i  ~=  sum_term  coef_term * t_{i,term}  +  k_{c_i}

solved for non-negative ``coef_term`` (multiplicative per-term
corrections) and ``k_chip`` (per-chip-type constant overhead, bytes).
Columns are scaled to GiB before solving so term columns (1e9..1e12
bytes) and chip indicator columns condition comparably.

A term whose column is identically zero over the measurement set (e.g.
``overhead`` on a store with no serve cells AND no inputs) is left at the
identity coefficient 1.0 rather than the NNLS zero — a profile must never
silently erase a term it has no evidence about.

scipy's reference NNLS is used when available; otherwise a dependency-free
projected-gradient solve (FISTA-style) matches it to benchmark tolerance.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.calibrate.measurements import MeasurementStore
from repro.calibrate.profile import TERMS, CalibrationProfile
from repro.calibrate.residual import TermRow, decompose

GiB = 1024 ** 3


def nnls(A: np.ndarray, b: np.ndarray) -> tuple[np.ndarray, float]:
    """min ||Ax - b||_2 s.t. x >= 0; (solution, residual norm)."""
    try:
        from scipy.optimize import nnls as _scipy_nnls
        x, rnorm = _scipy_nnls(A, b)
        return x, float(rnorm)
    except ImportError:
        return _pg_nnls(A, b)


def _pg_nnls(A: np.ndarray, b: np.ndarray,
             iters: int = 5000) -> tuple[np.ndarray, float]:
    """Projected-gradient fallback (no scipy): accelerated gradient on
    0.5||Ax-b||^2 with projection onto the non-negative orthant."""
    AtA = A.T @ A
    Atb = A.T @ b
    # Lipschitz constant of the gradient = largest eigenvalue of AtA
    L = float(np.linalg.eigvalsh(AtA)[-1]) or 1.0
    x = np.zeros(A.shape[1])
    y, t = x.copy(), 1.0
    for _ in range(iters):
        x_new = np.maximum(y - (AtA @ y - Atb) / L, 0.0)
        t_new = (1.0 + (1.0 + 4.0 * t * t) ** 0.5) / 2.0
        y = x_new + ((t - 1.0) / t_new) * (x_new - x)
        if np.max(np.abs(x_new - x)) < 1e-12:
            x = x_new
            break
        x, t = x_new, t_new
    return x, float(np.linalg.norm(A @ x - b))


def ridge(A: np.ndarray, b: np.ndarray,
          lam: float = 1e-3) -> np.ndarray:
    """Closed-form ridge regression: argmin ||Ax - b||^2 + lam ||x||^2.

    The solver behind the learned residual model
    (repro.calibrate.learned): unlike the NNLS profile fit the residual
    weights are signed (a learned correction may subtract bytes), and
    the L2 penalty keeps small per-family sample sets from overfitting
    their noise.  ``lam > 0`` also makes the normal equations
    non-singular for constant/collinear feature columns."""
    A = np.asarray(A, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    n = A.shape[1]
    return np.linalg.solve(A.T @ A + float(lam) * np.eye(n), A.T @ b)


def fit_rows(rows: list[TermRow], created: str = "",
             source: Optional[dict] = None) -> CalibrationProfile:
    """NNLS over pre-decomposed rows (see :func:`fit_profile`)."""
    if not rows:
        raise ValueError("cannot fit a profile from zero measurements")
    chips = sorted({r.measurement.chip for r in rows if r.measurement.chip})
    term_cols = np.array([[r.terms[t] / GiB for t in TERMS] for r in rows])
    chip_cols = np.array([[1.0 if r.measurement.chip == c else 0.0
                           for c in chips] for r in rows]) \
        if chips else np.zeros((len(rows), 0))
    b = np.array([r.measured_bytes / GiB for r in rows])

    # terms with no support in this measurement set stay at identity
    active = [j for j, t in enumerate(TERMS)
              if float(np.abs(term_cols[:, j]).sum()) > 0.0]
    A = np.hstack([term_cols[:, active], chip_cols])
    x, rnorm = nnls(A, b)

    coefficients = {t: 1.0 for t in TERMS}
    for k, j in enumerate(active):
        coefficients[TERMS[j]] = float(x[k])
    chip_constant = {c: int(round(float(x[len(active) + k]) * GiB))
                     for k, c in enumerate(chips)}
    return CalibrationProfile(
        coefficients=coefficients,
        chip_constant_bytes=chip_constant,
        created=created,
        source=dict(source or {},
                    n_measurements=len(rows),
                    archs=sorted({r.measurement.arch for r in rows}),
                    backends=sorted({r.measurement.backend for r in rows}),
                    chips=chips),
        fit_info={"method": "nnls", "residual_norm_gib": round(rnorm, 6),
                  "inactive_terms": [TERMS[j] for j in range(len(TERMS))
                                     if j not in active]})


def fit_profile(store: MeasurementStore, engine=None, created: str = "",
                source: Optional[dict] = None,
                assembly: str = "legacy") -> CalibrationProfile:
    """Decompose + fit in one call (the ``calibrate fit`` CLI backend)."""
    return fit_rows(decompose(store, engine, assembly=assembly),
                    created=created, source=source)
