"""Learned per-arch-family residual calibration (ROADMAP item 1.ii).

The affine :class:`~repro.calibrate.profile.CalibrationProfile` is four
multiplicative coefficients + per-chip constants — it cannot express
structure that varies with the KNOBS of a cell (a seq-length-dependent
allocator reservation, a per-family activation bias).  This module fits
a small regularized linear model per architecture family over

* Eq.1 term-byte features — the four profile-term group bytes of the
  (profile-applied) prediction, in GiB, and
* knob features — step kind, remat policy, optimizer class, pipeline
  degree / microbatch count, optimizer offload, and the seq bucket

to predict the residual bytes left AFTER the affine profile applies.
Families with too few samples (or whose fitted weights do not improve
their own in-sample MAPE — the fit is self-guarding) fall back to a
global model fitted over all rows; a family can therefore never be made
WORSE than affine-only by its own refit.

A :class:`ResidualModel` serializes to versioned JSON under the same
staleness rules as a profile (kind / schema_version / feature-set match,
plus a binding to the ``profile_hash`` it was fitted on top of), and its
``model_hash`` participates in the sweep engine's memo keys exactly like
``profile_hash`` — no model active means every prediction stays
bit-identical to the uncorrected path.

Continual refit: :class:`~repro.autopilot.watch.MemoryWatch` samples
accumulate into a :class:`~repro.calibrate.measurements.MeasurementStore`
and :class:`~repro.autopilot.guard.Autopilot` refits mid-run on
persistent DRIFT — see docs/calibration.md ("Learned residual model").
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from dataclasses import dataclass, field
from pathlib import Path
from typing import Optional

from repro.calibrate.measurements import MeasurementStore
from repro.calibrate.profile import profile_hash_of

SCHEMA_VERSION = 1
MODEL_KIND = "residual_model"

GiB = 1024 ** 3

#: feature vector layout, in order.  Loading a model fitted against a
#: different feature set fails (same staleness contract as profile TERMS).
FEATURE_NAMES = (
    "const",
    "static_gib", "act_saved_gib", "act_transient_gib", "overhead_gib",
    "kind_train", "kind_prefill", "kind_decode",
    "remat_none", "remat_dots", "remat_block",
    "opt_master_fp32", "opt_8bit",
    "log2_pp", "log2_microbatches",
    "offload_opt",
    "seq_bucket",
)

#: a family needs at least this many rows for its own weights; below it
#: the rows still train the global fallback
MIN_FAMILY_ROWS = 4


def features_from(pred, ctx) -> list:
    """The model's feature vector for one (prediction, context) pair.

    Used identically at fit time (contexts rebuilt from measurements via
    ``residual._context_for``) and at apply time (the live sweep/planner
    context) — the two paths can never disagree on featurization.
    ``pred`` must be the prediction the residual corrects, i.e. with the
    affine profile already applied."""
    static = (pred.param_bytes + pred.grad_bytes + pred.opt_bytes
              + pred.output_copy_bytes)
    overhead = pred.loss_bytes + pred.input_bytes + pred.cache_bytes
    opt = ctx.optimizer or ""
    return [
        1.0,
        static / GiB, pred.act_saved_bytes / GiB,
        pred.act_transient_bytes / GiB, overhead / GiB,
        1.0 if ctx.kind == "train" else 0.0,
        1.0 if ctx.kind == "prefill" else 0.0,
        1.0 if ctx.kind == "decode" else 0.0,
        1.0 if ctx.remat == "none" else 0.0,
        1.0 if ctx.remat == "dots" else 0.0,
        1.0 if ctx.remat == "block" else 0.0,
        1.0 if ctx.master_fp32 else 0.0,
        1.0 if "8bit" in opt else 0.0,
        math.log2(max(ctx.pp, 1)),
        math.log2(max(ctx.eff_microbatches, 1)),
        1.0 if ctx.offload_opt else 0.0,
        math.log2(max(ctx.seq_len, 1)),
    ]


@dataclass(frozen=True)
class ResidualModel:
    """Immutable per-family linear residual corrector.

    ``families`` maps an arch-family name to its weight vector (one
    float per FEATURE_NAMES entry, GiB scale); ``global_weights`` is the
    all-family fallback used for families without their own entry (e.g.
    a family held out of the fit).  ``base_profile_hash`` binds the
    model to the affine profile it was fitted on top of — applying it
    over any other profile raises (staleness rule: the residual is
    defined relative to ONE calibrated prediction)."""

    families: dict = field(default_factory=dict)
    global_weights: Optional[tuple] = None
    base_profile_hash: Optional[str] = None
    created: str = ""
    source: dict = field(default_factory=dict)
    fit_info: dict = field(default_factory=dict)

    def __post_init__(self):
        for fam, w in self.families.items():
            if len(w) != len(FEATURE_NAMES):
                raise ValueError(
                    f"family {fam!r} has {len(w)} weights; the current "
                    f"feature set has {len(FEATURE_NAMES)}")
        if self.global_weights is not None \
                and len(self.global_weights) != len(FEATURE_NAMES):
            raise ValueError(
                f"global weights have {len(self.global_weights)} "
                f"entries; the current feature set has "
                f"{len(FEATURE_NAMES)}")

    # -- identity ------------------------------------------------------------
    @classmethod
    def identity(cls, base_profile_hash: Optional[str] = None
                 ) -> "ResidualModel":
        """The all-zero-correction model: bit-inert on every prediction."""
        return cls(base_profile_hash=base_profile_hash)

    @property
    def is_identity(self) -> bool:
        return not self.families and self.global_weights is None

    # -- application ---------------------------------------------------------
    def weights_for(self, family: str) -> Optional[tuple]:
        w = self.families.get(family)
        return w if w is not None else self.global_weights

    def residual_bytes(self, family: str, feats) -> int:
        """Predicted leftover bytes (may be negative) for one cell."""
        w = self.weights_for(family)
        if w is None:
            return 0
        gib = sum(float(a) * float(b) for a, b in zip(w, feats))
        return int(round(gib * GiB))

    # -- identity/serialization ---------------------------------------------
    def to_dict(self) -> dict:
        return {
            "schema_version": SCHEMA_VERSION,
            "kind": MODEL_KIND,
            "features": list(FEATURE_NAMES),
            "families": {f: [float(x) for x in w] for f, w in
                         sorted(self.families.items())},
            "global": ([float(x) for x in self.global_weights]
                       if self.global_weights is not None else None),
            "base_profile_hash": self.base_profile_hash,
            "created": self.created,
            "source": self.source,
            "fit": self.fit_info,
        }

    @property
    def model_hash(self) -> str:
        """Digest of the prediction-changing payload ONLY (not
        metadata); participates in the sweep engine's memo keys exactly
        like ``profile_hash``."""
        payload = {
            "schema_version": SCHEMA_VERSION,
            "features": list(FEATURE_NAMES),
            "families": {f: [float(x) for x in w] for f, w in
                         sorted(self.families.items())},
            "global": ([float(x) for x in self.global_weights]
                       if self.global_weights is not None else None),
            "base_profile_hash": self.base_profile_hash,
        }
        blob = json.dumps(payload, sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:16]

    @classmethod
    def from_dict(cls, d: dict) -> "ResidualModel":
        if d.get("kind") != MODEL_KIND:
            raise ValueError(
                f"not a residual model (kind={d.get('kind')!r})")
        if d.get("schema_version") != SCHEMA_VERSION:
            raise ValueError(
                f"residual model schema_version "
                f"{d.get('schema_version')!r} != supported "
                f"{SCHEMA_VERSION}; re-fit with "
                f"`python -m repro.calibrate fit-residual` "
                f"(docs/calibration.md)")
        if tuple(d.get("features", ())) != FEATURE_NAMES:
            raise ValueError(
                f"residual model features {d.get('features')} do not "
                f"match the current feature set {list(FEATURE_NAMES)}; "
                f"the model is stale — re-fit against fresh "
                f"measurements")
        g = d.get("global")
        return cls(families={f: tuple(w) for f, w in
                             d.get("families", {}).items()},
                   global_weights=tuple(g) if g is not None else None,
                   base_profile_hash=d.get("base_profile_hash"),
                   created=d.get("created", ""),
                   source=dict(d.get("source", {})),
                   fit_info=dict(d.get("fit", {})))

    def save(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_dict(), indent=1,
                                   sort_keys=True) + "\n")
        return path

    @classmethod
    def load(cls, path) -> "ResidualModel":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def summary(self) -> str:
        fams = ", ".join(sorted(self.families)) or "none"
        return (f"ResidualModel[{self.model_hash}] families: {fams}; "
                f"global fallback: "
                f"{'yes' if self.global_weights is not None else 'no'}; "
                f"base profile: {self.base_profile_hash or 'raw'}")


def residual_hash_of(model: Optional[ResidualModel]) -> Optional[str]:
    """Memo-key helper: None for the uncorrected path."""
    return None if model is None else model.model_hash


def apply_residual(pred, model: ResidualModel, family: str, ctx,
                   profile=None):
    """Residual-corrected copy of a PredictedMemory.

    Applied AFTER the affine profile and after the pipeline worst-stage
    max — the model corrects the composed per-device peak, the thing a
    measurement observes.  Raises when ``model`` was fitted over a
    different profile than the one active (the correction would be
    defined relative to the wrong baseline)."""
    phash = profile_hash_of(profile)
    if model.base_profile_hash != phash:
        raise ValueError(
            f"residual model {model.model_hash} was fitted over profile "
            f"{model.base_profile_hash or 'raw'} but is being applied "
            f"over {phash or 'raw'}; re-fit the residual against the "
            f"active profile (docs/calibration.md)")
    rb = model.residual_bytes(family, features_from(pred, ctx))
    if rb == 0:
        return pred
    return dataclasses.replace(pred, residual_bytes=rb)


# ---------------------------------------------------------------------------
# fitting
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ResidualRow:
    """One fit-ready sample: features + target residual, both GiB."""

    family: str
    features: tuple
    residual_gib: float            # measured - calibrated peak
    measured_bytes: int
    calibrated_bytes: int

    @property
    def ape_base(self) -> float:
        """Affine-only absolute percentage error of this row."""
        return abs(self.calibrated_bytes - self.measured_bytes) \
            / self.measured_bytes * 100.0

    def ape_with(self, weights) -> float:
        gib = sum(float(a) * float(b) for a, b in
                  zip(weights, self.features))
        corrected = self.calibrated_bytes + int(round(gib * GiB))
        return abs(corrected - self.measured_bytes) \
            / self.measured_bytes * 100.0


def residual_rows(store: MeasurementStore, profile=None, engine=None,
                  assembly: str = "legacy") -> list:
    """Feature/target rows for every usable measurement in ``store``.

    Predictions go through the shared memoized engine WITH the affine
    profile applied — the target is exactly the residual the learned
    model is asked to mop up.  Zero/negative measured peaks are skipped
    (the same defect rule core.report.mape applies)."""
    from repro.calibrate.residual import _context_for
    from repro.core import sweep as SW
    engine = engine or SW.SweepEngine()
    rows = []
    for m in store:
        if m.measured_bytes <= 0:
            continue
        policy = SW.POLICIES[m.policy]
        cfg, _, _ = engine._arch_state(m.arch, policy)
        ctx = _context_for(m, cfg)
        pred = engine.predict_cell(m.arch, policy, ctx, profile=profile,
                                   chip=m.chip, assembly=assembly)
        rows.append(ResidualRow(
            family=cfg.family,
            features=tuple(features_from(pred, ctx)),
            residual_gib=(m.measured_bytes - pred.peak_bytes) / GiB,
            measured_bytes=m.measured_bytes,
            calibrated_bytes=pred.peak_bytes))
    return rows


def _mape_of(rows, weights=None) -> float:
    if not rows:
        return 0.0
    if weights is None:
        return sum(r.ape_base for r in rows) / len(rows)
    return sum(r.ape_with(weights) for r in rows) / len(rows)


def _guarded_fit(rows, lam: float):
    """Ridge weights for ``rows``, or None when the fitted correction
    does not strictly improve the rows' own in-sample MAPE — the
    never-worsen guard: a model that cannot beat affine-only on the
    data it was fitted on must not ship.

    Rows are weighted by 1/measured: the solve minimizes the RELATIVE
    residual, which is the quantity every MAPE gate scores.  An
    unweighted GiB-scale least squares would chase the largest cells'
    absolute residuals and happily worsen small cells by whole
    percentage points."""
    import numpy as np

    from repro.calibrate.fit import ridge
    A = np.array([r.features for r in rows], dtype=np.float64)
    b = np.array([r.residual_gib for r in rows], dtype=np.float64)
    wts = np.array([GiB / r.measured_bytes for r in rows],
                   dtype=np.float64)
    w = tuple(float(x) for x in ridge(A * wts[:, None], b * wts,
                                      lam=lam))
    if _mape_of(rows, w) < _mape_of(rows):
        return w
    return None


def fit_residual(store: MeasurementStore, profile=None, engine=None,
                 assembly: str = "legacy", lam: float = 1e-3,
                 created: str = "",
                 source: Optional[dict] = None) -> ResidualModel:
    """Fit a ResidualModel over a measurement store, on top of
    ``profile`` (None fits the residual of the RAW prediction).

    One guarded ridge solve per family with >= MIN_FAMILY_ROWS samples,
    plus the guarded global fallback over all rows.  Guard semantics
    (see ``_guarded_fit``) mean every emitted weight vector strictly
    improves the in-sample MAPE of the rows it will be applied to."""
    rows = residual_rows(store, profile=profile, engine=engine,
                         assembly=assembly)
    if not rows:
        raise ValueError(
            "cannot fit a residual model from zero usable measurements")
    by_family: dict[str, list] = {}
    for r in rows:
        by_family.setdefault(r.family, []).append(r)
    families = {}
    for fam, frows in sorted(by_family.items()):
        if len(frows) < MIN_FAMILY_ROWS:
            continue
        w = _guarded_fit(frows, lam)
        if w is not None:
            families[fam] = w
    gw = _guarded_fit(rows, lam)
    model = ResidualModel(
        families=families,
        global_weights=gw,
        base_profile_hash=profile_hash_of(profile),
        created=created,
        source=dict(source or {},
                    n_measurements=len(rows),
                    assembly=assembly,
                    families=sorted(by_family)),
        fit_info={"method": "ridge", "lam": lam,
                  "mape_affine_pct": round(_mape_of(rows), 4),
                  "mape_learned_pct": round(
                      _in_sample_mape(rows, families, gw), 4),
                  "skipped_families": sorted(
                      set(by_family) - set(families))})
    return model


def _in_sample_mape(rows, families: dict, gw) -> float:
    if not rows:
        return 0.0
    total = 0.0
    for r in rows:
        w = families.get(r.family, gw)
        total += r.ape_base if w is None else r.ape_with(w)
    return total / len(rows)


def leave_one_family_out(store: MeasurementStore):
    """Holdout folds: for each arch family in the store, (family,
    train_store, test_store) with every measurement of that family held
    out of the training split.  The held-out family exercises the
    model's GLOBAL fallback — exactly the generalization the BENCH
    calibration gate scores."""
    from repro.calibrate.report import _family_of
    folds = []
    fams: dict[str, list] = {}
    for m in store:
        fams.setdefault(_family_of(m.arch), []).append(m)
    for fam in sorted(fams):
        train = MeasurementStore([m for f, ms in fams.items()
                                  if f != fam for m in ms])
        test = MeasurementStore(list(fams[fam]))
        folds.append((fam, train, test))
    return folds
