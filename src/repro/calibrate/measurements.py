"""Measured peak-memory samples and their on-disk store.

A :class:`Measurement` is one observed (configuration -> peak bytes) pair
— from an XLA dry-run artifact (``launch/dryrun.py``), a real device run,
or the deterministic synthetic generator (``repro.calibrate.synthetic``).
It carries exactly the fields :func:`repro.core.planner.make_context`
needs to rebuild the prediction context, so the residual decomposition
can recompute every Eq.1 term for the same cell.

:class:`MeasurementStore` is a list-shaped container with versioned JSON
(de)serialization and a dry-run artifact ingester.
"""

from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass, field, asdict
from pathlib import Path
from typing import Iterator, Optional

from repro.calibrate.paths import dryrun_dir

SCHEMA_VERSION = 1
STORE_KIND = "measurement_store"

# dryrun artifacts name meshes by shape string ("16x16", "2x16x16"); the
# launch-mesh naming convention maps the factors back to named axes:
# make_production_mesh builds (data, model) meshes and prefixes a "pod"
# axis for multi-pod 3-d shapes (repro.launch.mesh).
_MESH_AXES_BY_RANK = {2: ("data", "model"), 3: ("pod", "data", "model")}


def parse_mesh_string(mesh: str) -> dict:
    """``"AxB"``/``"AxBxC"`` -> named mesh-shape dict under the
    launch-mesh axis convention.  Raises ValueError on anything else —
    a mesh the convention cannot name must not be guessed at."""
    parts = str(mesh).split("x")
    axes = _MESH_AXES_BY_RANK.get(len(parts))
    if axes is None:
        raise ValueError(
            f"mesh string {mesh!r} has {len(parts)} factor(s); the "
            f"launch-mesh convention names only AxB (data x model) and "
            f"AxBxC (pod x data x model) shapes — write the artifact "
            f"with an explicit mesh_shape dict instead")
    try:
        sizes = [int(p) for p in parts]
    except ValueError:
        raise ValueError(f"mesh string {mesh!r} has non-integer factors")
    if any(s <= 0 for s in sizes):
        raise ValueError(f"mesh string {mesh!r} has non-positive factors")
    return dict(zip(axes, sizes))


@dataclass
class Measurement:
    """One measured cell.  ``optimizer``/``remat`` of None mean "the
    architecture's default" (same convention as the sweep grid)."""

    arch: str
    kind: str                      # train | prefill | decode
    seq_len: int
    global_batch: int
    mesh_shape: dict
    measured_bytes: int
    backend: str = "cpu"
    chip: Optional[str] = None     # None: no chip constant applies
    optimizer: Optional[str] = None
    remat: Optional[str] = None
    grad_accum: int = 1
    policy: str = "full"           # key into repro.core.sweep.POLICIES
    # pipeline/offload knobs (schema-v1 stores lack them; the defaults
    # reproduce the pre-knob decomposition: one microbatch, 1F1B, no
    # offload).  A pipelined or offloaded cell measured without these
    # fields would decompose against the WRONG cell — see _context_for.
    microbatches: int = 1
    schedule: str = "1f1b"
    offload_optimizer: bool = False
    source: str = ""               # provenance: dryrun path / "synthetic"
    meta: dict = field(default_factory=dict)

    @property
    def key(self) -> tuple:
        """Stable identity of the measured cell (not the measured value).
        Includes every knob make_context reads — two cells differing only
        in microbatches/schedule/offload must never collide."""
        return (self.arch, self.kind, self.seq_len, self.global_batch,
                tuple(sorted(self.mesh_shape.items())), self.backend,
                self.chip, self.optimizer, self.remat, self.grad_accum,
                self.policy, self.microbatches, self.schedule,
                self.offload_optimizer)

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Measurement":
        return cls(**{k: d[k] for k in cls.__dataclass_fields__ if k in d})

    @classmethod
    def from_dryrun_record(cls, record: dict,
                           source: str = "") -> "Measurement":
        """Ingest one launch/dryrun.py artifact.  The XLA compiled-memory
        total is the ground truth whose overflow aborts a job; the
        prediction block in the artifact is ignored (we recompute it).

        The total goes through the same telemetry defect matrix the
        autopilot watch applies (``autopilot.watch.observed_bytes``): a
        missing ``total_bytes`` is rebuilt from the four allocator
        counters, and an unusable record (missing counters, non-numeric
        values, non-positive total) raises a ValueError naming the defect
        — a zero/negative peak must never enter a fit as ground truth."""
        from repro.autopilot.watch import observed_bytes, telemetry_defect
        from repro.configs import SHAPES
        mesh = record.get("mesh_shape")
        if mesh is None:
            mesh = parse_mesh_string(record.get("mesh", ""))
        measured = observed_bytes(record)
        if measured is None:
            raise ValueError(
                f"dryrun record {source or '<record>'} has unusable "
                f"memory telemetry: {telemetry_defect(record)}")
        shape = SHAPES[record["shape"]]
        return cls(
            arch=record["arch"], kind=record.get("kind", shape.kind),
            seq_len=shape.seq_len, global_batch=shape.global_batch,
            mesh_shape=dict(mesh),
            measured_bytes=measured,
            backend="cpu",             # dryrun compiles on the cpu oracle
            microbatches=int(record.get("microbatches", 1)),
            schedule=str(record.get("schedule", "1f1b")),
            offload_optimizer=bool(record.get("offload_optimizer",
                                              False)),
            source=source or "dryrun",
            meta={"shape": record["shape"],
                  "compile_seconds": record.get("compile_seconds")})


@dataclass
class MeasurementStore:
    measurements: list = field(default_factory=list)

    def __len__(self) -> int:
        return len(self.measurements)

    def __iter__(self) -> Iterator[Measurement]:
        return iter(self.measurements)

    def add(self, m: Measurement) -> None:
        self.measurements.append(m)

    def extend(self, ms) -> None:
        self.measurements.extend(ms)

    def archs(self) -> list[str]:
        return sorted({m.arch for m in self.measurements})

    def chips(self) -> list[str]:
        return sorted({m.chip for m in self.measurements if m.chip})

    def by_arch(self) -> dict:
        out: dict[str, list[Measurement]] = {}
        for m in self.measurements:
            out.setdefault(m.arch, []).append(m)
        return out

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> dict:
        return {"schema_version": SCHEMA_VERSION, "kind": STORE_KIND,
                "measurements": [m.to_dict() for m in self.measurements]}

    @classmethod
    def from_dict(cls, d: dict) -> "MeasurementStore":
        if d.get("kind") != STORE_KIND:
            raise ValueError(f"not a measurement store "
                             f"(kind={d.get('kind')!r})")
        if d.get("schema_version") != SCHEMA_VERSION:
            raise ValueError(
                f"measurement store schema_version "
                f"{d.get('schema_version')!r} != {SCHEMA_VERSION}")
        return cls([Measurement.from_dict(m) for m in d["measurements"]])

    def save(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_dict(), indent=1,
                                   sort_keys=True) + "\n")
        return path

    @classmethod
    def load(cls, path) -> "MeasurementStore":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    # -- dryrun ingest -------------------------------------------------------
    @classmethod
    def ingest_dryrun_dir(cls, path=None,
                          strict: bool = False) -> "MeasurementStore":
        """Scan a dry-run artifact directory (default: the shared
        ``experiments/dryrun`` the dryrun CLI writes to) into a store.
        Unreadable / non-artifact JSON files are skipped unless
        ``strict``."""
        path = Path(path) if path is not None else dryrun_dir()
        store = cls()
        for fn in sorted(glob.glob(os.path.join(str(path), "*.json"))):
            try:
                with open(fn) as f:
                    record = json.load(f)
                store.add(Measurement.from_dryrun_record(
                    record, source=os.path.basename(fn)))
            except (KeyError, TypeError, ValueError, json.JSONDecodeError):
                if strict:
                    raise
        return store
