"""Repo-anchored artifact paths shared by the measurement pipeline.

``launch/dryrun.py`` (the artifact writer) and the calibration
``MeasurementStore`` (the artifact reader) must agree on where dry-run
records live; both resolve through here instead of fragile
``os.path.join(.., "..", "..")`` chains.  Import-light on purpose: no
jax, no repro modules.
"""

from __future__ import annotations

from pathlib import Path


def repo_root() -> Path:
    """The repository root (parent of ``src/``), resolved from this file:
    src/repro/calibrate/paths.py -> three levels up."""
    return Path(__file__).resolve().parents[3]


def experiments_dir() -> Path:
    return repo_root() / "experiments"


def dryrun_dir() -> Path:
    """Where ``python -m repro.launch.dryrun`` writes its artifacts and
    where ``MeasurementStore.ingest_dryrun_dir`` reads them by default."""
    return experiments_dir() / "dryrun"


def profiles_dir() -> Path:
    """Default home of fitted CalibrationProfile JSON files."""
    return experiments_dir() / "profiles"
