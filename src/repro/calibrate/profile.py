"""CalibrationProfile: per-term multiplicative corrections + chip offsets.

A profile is the output of ``repro.calibrate.fit`` and the input of every
prediction path (``predictor.assemble``, ``planner.check/plan``, the sweep
engine): four non-negative coefficients, one per Eq.1 component group, plus
a per-chip-type constant overhead in bytes:

    peak_cal = c_static * (M_param + M_grad + M_opt + M_out_copy)
             + c_act_saved * M_act_saved
             + c_act_transient * M_act_transient
             + c_overhead * (M_loss + M_input + M_cache)
             + k_chip

Applied AFTER :func:`repro.core.predictor.assemble` composes the raw
terms, so the cpu-oracle couplings inside ``act_transient`` (embed
all-gathers, the fp32 optimizer-update stacks) are scaled as one group —
exactly the granularity the residual decomposition fits.

Profiles are versioned JSON (see docs/calibration.md for the schema and
the staleness rules); ``profile_hash`` is a stable digest of everything
that changes a prediction, and participates in the sweep engine's memo
keys so cached cells can never leak across profiles.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Optional

SCHEMA_VERSION = 1
PROFILE_KIND = "calibration_profile"

# The term groups a profile corrects — must track PredictedMemory's field
# groups; loading a profile fitted against a different term set fails
# (staleness rule 1 in docs/calibration.md).
TERMS = ("static", "act_saved", "act_transient", "overhead")

GiB = 1024 ** 3


@dataclass(frozen=True)
class CalibrationProfile:
    """Immutable, hashable correction profile (identity by default)."""

    coefficients: dict = field(
        default_factory=lambda: {t: 1.0 for t in TERMS})
    # chip type -> constant overhead bytes; "*" is the any-chip fallback
    chip_constant_bytes: dict = field(default_factory=dict)
    created: str = ""
    source: dict = field(default_factory=dict)
    fit_info: dict = field(default_factory=dict)

    def __post_init__(self):
        missing = [t for t in TERMS if t not in self.coefficients]
        if missing:
            raise ValueError(f"profile missing coefficients for {missing}")
        bad = [t for t, c in self.coefficients.items() if c < 0]
        if bad:
            raise ValueError(f"negative coefficients for {bad}")

    # -- identity ------------------------------------------------------------
    @classmethod
    def identity(cls) -> "CalibrationProfile":
        return cls()

    @property
    def is_identity(self) -> bool:
        return (all(self.coefficients[t] == 1.0 for t in TERMS)
                and not any(self.chip_constant_bytes.values()))

    # -- application ---------------------------------------------------------
    def coef(self, term: str) -> float:
        return float(self.coefficients[term])

    def chip_offset(self, chip: Optional[str]) -> int:
        if chip in self.chip_constant_bytes:
            return int(self.chip_constant_bytes[chip])
        return int(self.chip_constant_bytes.get("*", 0))

    def apply(self, pred, chip: Optional[str] = None):
        """Scaled copy of a PredictedMemory (duck-typed so core.predictor
        needs no import of this module).  ``per_module`` stays RAW — the
        breakdown documents where bytes come from, the calibrated totals
        are the per-term fields."""
        c_s = self.coef("static")
        scale = lambda v, c: int(round(v * c))
        return dataclasses.replace(
            pred,
            param_bytes=scale(pred.param_bytes, c_s),
            grad_bytes=scale(pred.grad_bytes, c_s),
            opt_bytes=scale(pred.opt_bytes, c_s),
            output_copy_bytes=scale(pred.output_copy_bytes, c_s),
            act_saved_bytes=scale(pred.act_saved_bytes,
                                  self.coef("act_saved")),
            act_transient_bytes=scale(pred.act_transient_bytes,
                                      self.coef("act_transient")),
            loss_bytes=scale(pred.loss_bytes, self.coef("overhead")),
            input_bytes=scale(pred.input_bytes, self.coef("overhead")),
            cache_bytes=scale(pred.cache_bytes, self.coef("overhead")),
            # serve terms: the KV pool is allocator overhead like the
            # contiguous cache it replaces; the draft model is extra
            # static residency (params + state), scaled accordingly
            pool_bytes=scale(pred.pool_bytes, self.coef("overhead")),
            hit_saved_bytes=scale(pred.hit_saved_bytes,
                                  self.coef("overhead")),
            draft_bytes=scale(pred.draft_bytes, c_s),
            calibration_bytes=self.chip_offset(chip))

    def scale_batch(self, values, term: str):
        """Vectorized affine twin of the per-field scaling in ``apply``:
        ``int(round(v * coef(term)))`` over an int64 array.  Same float64
        product, same round-half-even — the columnar sweep path
        (repro.core.batch) stays byte-identical to per-cell application.
        """
        import numpy as np
        c = self.coef(term)
        return np.rint(np.asarray(values, np.float64) * c).astype(np.int64)

    # -- identity/serialization ---------------------------------------------
    def to_dict(self) -> dict:
        return {
            "schema_version": SCHEMA_VERSION,
            "kind": PROFILE_KIND,
            "terms": list(TERMS),
            "coefficients": {t: float(self.coefficients[t]) for t in TERMS},
            "chip_constant_bytes": {k: int(v) for k, v in sorted(
                self.chip_constant_bytes.items())},
            "created": self.created,
            "source": self.source,
            "fit": self.fit_info,
        }

    @property
    def profile_hash(self) -> str:
        """Digest of the prediction-changing payload ONLY (not metadata):
        two profiles that predict identically hash identically."""
        payload = {
            "schema_version": SCHEMA_VERSION,
            "coefficients": {t: float(self.coefficients[t]) for t in TERMS},
            "chip_constant_bytes": {k: int(v) for k, v in sorted(
                self.chip_constant_bytes.items())},
        }
        blob = json.dumps(payload, sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:16]

    @classmethod
    def from_dict(cls, d: dict) -> "CalibrationProfile":
        if d.get("kind") != PROFILE_KIND:
            raise ValueError(
                f"not a calibration profile (kind={d.get('kind')!r})")
        if d.get("schema_version") != SCHEMA_VERSION:
            raise ValueError(
                f"profile schema_version {d.get('schema_version')!r} != "
                f"supported {SCHEMA_VERSION}; re-fit with "
                f"`python -m repro.calibrate fit` (docs/calibration.md)")
        if tuple(d.get("terms", ())) != TERMS:
            raise ValueError(
                f"profile terms {d.get('terms')} do not match the current "
                f"predictor term set {list(TERMS)}; the profile is stale — "
                f"re-fit against fresh measurements")
        return cls(coefficients=dict(d["coefficients"]),
                   chip_constant_bytes=dict(
                       d.get("chip_constant_bytes", {})),
                   created=d.get("created", ""),
                   source=dict(d.get("source", {})),
                   fit_info=dict(d.get("fit", {})))

    def save(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_dict(), indent=1,
                                   sort_keys=True) + "\n")
        return path

    @classmethod
    def load(cls, path) -> "CalibrationProfile":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def summary(self) -> str:
        cs = ", ".join(f"{t}={self.coefficients[t]:.4f}" for t in TERMS)
        ks = ", ".join(f"{k}={v / GiB:.3f}GiB" for k, v in sorted(
            self.chip_constant_bytes.items())) or "none"
        return (f"CalibrationProfile[{self.profile_hash}] {cs}; "
                f"chip offsets: {ks}")


def profile_hash_of(profile: Optional[CalibrationProfile]) -> Optional[str]:
    """Memo-key helper: None for the uncalibrated path."""
    return None if profile is None else profile.profile_hash
