"""Paper-style accuracy evaluation: per-group MAPE, calibrated vs raw.

``evaluate(store, profile)`` predicts every measured cell twice — once
uncalibrated, once through the profile — and aggregates absolute
percentage errors against the measured peaks into the paper's evaluation
table, grouped by architecture or by family.  Output goes through the
:mod:`repro.core.report` writers (markdown / CSV / the MAPE arithmetic),
so this table and the paper-repro benchmarks render identically.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Optional

from repro.calibrate.measurements import MeasurementStore
from repro.calibrate.profile import CalibrationProfile
from repro.calibrate.residual import predict_measurement
from repro.core import report as RPT

GiB = 1024 ** 3


@dataclass
class AccuracyRow:
    group: str
    n: int
    mape_raw: float
    mape_calibrated: float

    @property
    def improvement_pp(self) -> float:
        return self.mape_raw - self.mape_calibrated


@dataclass
class AccuracyReport:
    by: str                        # "arch" | "family"
    profile_hash: str
    rows: list = field(default_factory=list)
    mape_raw: float = 0.0
    mape_calibrated: float = 0.0
    n: int = 0

    def to_markdown(self, title: str = "") -> str:
        headers = ("group", "cells", "MAPE raw %", "MAPE calibrated %",
                   "improvement pp")
        body = [(r.group, r.n, f"{r.mape_raw:.2f}",
                 f"{r.mape_calibrated:.2f}", f"{r.improvement_pp:+.2f}")
                for r in self.rows]
        body.append(("ALL", self.n, f"{self.mape_raw:.2f}",
                     f"{self.mape_calibrated:.2f}",
                     f"{self.mape_raw - self.mape_calibrated:+.2f}"))
        return RPT.markdown_table(
            headers, body,
            title=title or f"calibration accuracy by {self.by} "
                           f"(profile {self.profile_hash})")

    def to_csv(self) -> str:
        headers = ("group", "cells", "mape_raw_pct", "mape_calibrated_pct")
        body = [(r.group, r.n, f"{r.mape_raw:.3f}",
                 f"{r.mape_calibrated:.3f}") for r in self.rows]
        body.append(("ALL", self.n, f"{self.mape_raw:.3f}",
                     f"{self.mape_calibrated:.3f}"))
        return RPT.csv_table(headers, body)

    def to_json_dict(self) -> dict:
        return {
            "by": self.by,
            "profile_hash": self.profile_hash,
            "n_measurements": self.n,
            "mape_raw_pct": round(self.mape_raw, 4),
            "mape_calibrated_pct": round(self.mape_calibrated, 4),
            "groups": {r.group: {
                "n": r.n,
                "mape_raw_pct": round(r.mape_raw, 4),
                "mape_calibrated_pct": round(r.mape_calibrated, 4),
            } for r in self.rows},
        }

    def save_json(self, path) -> None:
        from pathlib import Path
        Path(path).write_text(
            json.dumps(self.to_json_dict(), indent=1, sort_keys=True)
            + "\n")

    @property
    def all_groups_improved(self) -> bool:
        return all(r.mape_calibrated < r.mape_raw for r in self.rows)


def _family_of(arch: str) -> str:
    from repro.configs import get_config
    return get_config(arch).family


def evaluate(store: MeasurementStore,
             profile: CalibrationProfile,
             by: str = "family",
             engine=None, assembly: str = "legacy") -> AccuracyReport:
    """Per-group MAPE of raw vs calibrated predictions over a store."""
    if by not in ("arch", "family"):
        raise ValueError(f"by={by!r}; expected 'arch' or 'family'")
    from repro.core import sweep as SW
    engine = engine or SW.SweepEngine()
    raw_groups: dict[str, list] = {}
    cal_groups: dict[str, list] = {}
    raw_all: list = []
    cal_all: list = []
    for m in store:
        group = m.arch if by == "arch" else _family_of(m.arch)
        raw = predict_measurement(m, engine, assembly=assembly)
        cal = predict_measurement(m, engine, profile=profile,
                                  assembly=assembly)
        label = f"{m.arch}|{m.kind}|b{m.global_batch}|s{m.seq_len}"
        r_rec = RPT.PredictionRecord(label, raw.peak_bytes,
                                     m.measured_bytes)
        c_rec = RPT.PredictionRecord(label, cal.peak_bytes,
                                     m.measured_bytes)
        raw_groups.setdefault(group, []).append(r_rec)
        cal_groups.setdefault(group, []).append(c_rec)
        raw_all.append(r_rec)
        cal_all.append(c_rec)
    cal_by_group = dict(
        (g, mp) for g, _, mp in RPT.grouped_mape(cal_groups))
    rows = [AccuracyRow(group=g, n=n, mape_raw=mp,
                        mape_calibrated=cal_by_group[g])
            for g, n, mp in RPT.grouped_mape(raw_groups)]
    return AccuracyReport(by=by, profile_hash=profile.profile_hash,
                          rows=rows, mape_raw=RPT.mape(raw_all),
                          mape_calibrated=RPT.mape(cal_all),
                          n=len(raw_all))
