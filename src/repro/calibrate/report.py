"""Paper-style accuracy evaluation: per-group MAPE, calibrated vs raw.

``evaluate(store, profile)`` predicts every measured cell twice — once
uncalibrated, once through the profile — and aggregates absolute
percentage errors against the measured peaks into the paper's evaluation
table, grouped by architecture or by family.  Passing a learned
``residual`` model adds a third series (profile + residual correction).
Output goes through the :mod:`repro.core.report` writers (markdown /
CSV / the MAPE arithmetic), so this table and the paper-repro benchmarks
render identically.

Records with no usable ground truth (``measured_bytes <= 0``) are
excluded from every aggregate and surfaced as ``n_excluded`` — a
defective zero-measured cell must never read as a perfect prediction.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Optional

from repro.calibrate.measurements import MeasurementStore
from repro.calibrate.profile import CalibrationProfile
from repro.calibrate.residual import predict_measurement
from repro.core import report as RPT

GiB = 1024 ** 3


@dataclass
class AccuracyRow:
    group: str
    n: int
    mape_raw: float
    mape_calibrated: float
    mape_learned: Optional[float] = None   # profile + residual model

    @property
    def improvement_pp(self) -> float:
        return self.mape_raw - self.mape_calibrated


@dataclass
class AccuracyReport:
    by: str                        # "arch" | "family"
    profile_hash: str
    rows: list = field(default_factory=list)
    mape_raw: float = 0.0
    mape_calibrated: float = 0.0
    mape_learned: Optional[float] = None
    residual_hash: Optional[str] = None
    n: int = 0
    n_excluded: int = 0            # defective (zero/negative) measurements

    @property
    def _has_learned(self) -> bool:
        return self.mape_learned is not None

    def to_markdown(self, title: str = "") -> str:
        headers = ["group", "cells", "MAPE raw %", "MAPE calibrated %",
                   "improvement pp"]
        if self._has_learned:
            headers.append("MAPE learned %")
        body = []
        for r in self.rows:
            row = [r.group, r.n, f"{r.mape_raw:.2f}",
                   f"{r.mape_calibrated:.2f}", f"{r.improvement_pp:+.2f}"]
            if self._has_learned:
                row.append("" if r.mape_learned is None
                           else f"{r.mape_learned:.2f}")
            body.append(tuple(row))
        total = ["ALL", self.n, f"{self.mape_raw:.2f}",
                 f"{self.mape_calibrated:.2f}",
                 f"{self.mape_raw - self.mape_calibrated:+.2f}"]
        if self._has_learned:
            total.append(f"{self.mape_learned:.2f}")
        body.append(tuple(total))
        out = RPT.markdown_table(
            headers, body,
            title=title or f"calibration accuracy by {self.by} "
                           f"(profile {self.profile_hash})")
        if self.n_excluded:
            out += (f"\n\n{self.n_excluded} measurement(s) excluded "
                    f"(no usable ground truth)")
        return out

    def to_csv(self) -> str:
        headers = ["group", "cells", "mape_raw_pct", "mape_calibrated_pct"]
        if self._has_learned:
            headers.append("mape_learned_pct")
        body = []
        for r in self.rows:
            row = [r.group, r.n, f"{r.mape_raw:.3f}",
                   f"{r.mape_calibrated:.3f}"]
            if self._has_learned:
                row.append("" if r.mape_learned is None
                           else f"{r.mape_learned:.3f}")
            body.append(tuple(row))
        total = ["ALL", self.n, f"{self.mape_raw:.3f}",
                 f"{self.mape_calibrated:.3f}"]
        if self._has_learned:
            total.append(f"{self.mape_learned:.3f}")
        body.append(tuple(total))
        return RPT.csv_table(headers, body)

    def to_json_dict(self) -> dict:
        out = {
            "by": self.by,
            "profile_hash": self.profile_hash,
            "n_measurements": self.n,
            "n_excluded": self.n_excluded,
            "mape_raw_pct": round(self.mape_raw, 4),
            "mape_calibrated_pct": round(self.mape_calibrated, 4),
            "groups": {r.group: {
                "n": r.n,
                "mape_raw_pct": round(r.mape_raw, 4),
                "mape_calibrated_pct": round(r.mape_calibrated, 4),
                **({"mape_learned_pct": round(r.mape_learned, 4)}
                   if r.mape_learned is not None else {}),
            } for r in self.rows},
        }
        if self._has_learned:
            out["mape_learned_pct"] = round(self.mape_learned, 4)
            out["residual_hash"] = self.residual_hash
        return out

    def save_json(self, path) -> None:
        from pathlib import Path
        Path(path).write_text(
            json.dumps(self.to_json_dict(), indent=1, sort_keys=True)
            + "\n")

    @property
    def all_groups_improved(self) -> bool:
        return all(r.mape_calibrated < r.mape_raw for r in self.rows)


def _family_of(arch: str) -> str:
    from repro.configs import get_config
    return get_config(arch).family


def evaluate(store: MeasurementStore,
             profile: CalibrationProfile,
             by: str = "family",
             engine=None, assembly: str = "legacy",
             residual=None) -> AccuracyReport:
    """Per-group MAPE of raw vs calibrated (vs learned) predictions."""
    if by not in ("arch", "family"):
        raise ValueError(f"by={by!r}; expected 'arch' or 'family'")
    from repro.core import sweep as SW
    engine = engine or SW.SweepEngine()
    raw_groups: dict[str, list] = {}
    cal_groups: dict[str, list] = {}
    lrn_groups: dict[str, list] = {}
    raw_all: list = []
    cal_all: list = []
    lrn_all: list = []
    n_excluded = 0
    for m in store:
        if m.measured_bytes <= 0:
            n_excluded += 1
            continue
        group = m.arch if by == "arch" else _family_of(m.arch)
        raw = predict_measurement(m, engine, assembly=assembly)
        cal = predict_measurement(m, engine, profile=profile,
                                  assembly=assembly)
        label = f"{m.arch}|{m.kind}|b{m.global_batch}|s{m.seq_len}"
        r_rec = RPT.PredictionRecord(label, raw.peak_bytes,
                                     m.measured_bytes)
        c_rec = RPT.PredictionRecord(label, cal.peak_bytes,
                                     m.measured_bytes)
        raw_groups.setdefault(group, []).append(r_rec)
        cal_groups.setdefault(group, []).append(c_rec)
        raw_all.append(r_rec)
        cal_all.append(c_rec)
        if residual is not None:
            lrn = predict_measurement(m, engine, profile=profile,
                                      assembly=assembly,
                                      residual=residual)
            l_rec = RPT.PredictionRecord(label, lrn.peak_bytes,
                                         m.measured_bytes)
            lrn_groups.setdefault(group, []).append(l_rec)
            lrn_all.append(l_rec)
    cal_by_group = dict(
        (g, mp) for g, _, mp in RPT.grouped_mape(cal_groups))
    lrn_by_group = dict(
        (g, mp) for g, _, mp in RPT.grouped_mape(lrn_groups))
    rows = [AccuracyRow(group=g, n=n, mape_raw=mp,
                        mape_calibrated=cal_by_group[g],
                        mape_learned=lrn_by_group.get(g))
            for g, n, mp in RPT.grouped_mape(raw_groups)]
    return AccuracyReport(by=by, profile_hash=profile.profile_hash,
                          rows=rows, mape_raw=RPT.mape(raw_all),
                          mape_calibrated=RPT.mape(cal_all),
                          mape_learned=(RPT.mape(lrn_all)
                                        if residual is not None else None),
                          residual_hash=(residual.model_hash
                                         if residual is not None else None),
                          n=len(raw_all), n_excluded=n_excluded)
