"""Residual decomposition: one design-matrix row per measurement.

For every measurement the UNCALIBRATED Eq.1 components are recomputed
through the exact predictor component functions the sweep engine memoizes
(``compute_static`` / ``compute_acts`` / ``compute_overheads`` composed by
``assemble``), then grouped into the profile's term set:

    static        = M_param + M_grad + M_opt + M_out_copy
    act_saved     = M_act_saved
    act_transient = M_act_transient (incl. embed gathers + opt-update stacks)
    overhead      = M_loss + M_input + M_cache

The residual ``measured - raw_peak`` is what the NNLS fit re-attributes
per term; going through the shared :class:`repro.core.sweep.SweepEngine`
means decomposing N measurements costs one model build per architecture.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.calibrate.measurements import Measurement, MeasurementStore
from repro.calibrate.profile import TERMS


@dataclass(frozen=True)
class TermRow:
    """Raw per-term bytes + measured total for one cell."""

    measurement: Measurement
    terms: dict                    # term name -> raw bytes
    raw_peak_bytes: int

    @property
    def measured_bytes(self) -> int:
        return self.measurement.measured_bytes

    @property
    def residual_bytes(self) -> int:
        return self.measurement.measured_bytes - self.raw_peak_bytes


def _context_for(m: Measurement, cfg):
    """Rebuild the EXACT cell the measurement was taken on.  Every knob
    the Measurement carries must reach make_context — dropping
    microbatches/schedule/offload here would decompose a pipelined or
    offloaded measurement against the wrong cell (m=1, no offload) and
    poison every profile fitted from it."""
    from repro.core import planner as PL
    return PL.make_context(cfg, m.mesh_shape, kind=m.kind,
                           global_batch=m.global_batch, seq_len=m.seq_len,
                           backend=m.backend, grad_accum=m.grad_accum,
                           remat=m.remat, optimizer=m.optimizer,
                           microbatches=m.microbatches,
                           schedule=m.schedule,
                           offload_opt=m.offload_optimizer)


def predict_measurement(m: Measurement, engine=None, profile=None,
                        assembly: str = "legacy", residual=None):
    """The framework's prediction for a measured cell (optionally
    calibrated and residual-corrected), through the shared memoized
    engine."""
    from repro.core import sweep as SW
    engine = engine or SW.SweepEngine()
    policy = SW.POLICIES[m.policy]
    cfg, _, _ = engine._arch_state(m.arch, policy)
    ctx = _context_for(m, cfg)
    return engine.predict_cell(m.arch, policy, ctx, profile=profile,
                               chip=m.chip, assembly=assembly,
                               residual=residual)


def decompose(store: MeasurementStore, engine=None,
              assembly: str = "legacy") -> list[TermRow]:
    """Raw term groups for every measurement (shared engine caches).

    ``assembly="liveness"`` decomposes the interval-overlap peak
    instead: the per-term bytes are the components LIVE at the winning
    event of the alloc/free program (``liveness.Replay.group_at_peak``),
    so the rows still sum to that assembly's raw peak exactly and the
    NNLS fit calibrates the composed liveness peak through the same
    affine transform.
    """
    from repro.core import sweep as SW
    engine = engine or SW.SweepEngine()
    rows = []
    for m in store:
        pred = predict_measurement(m, engine, assembly=assembly)
        if assembly == "liveness":
            terms = dict(pred.liveness_groups)
        else:
            terms = {
                "static": (pred.param_bytes + pred.grad_bytes
                           + pred.opt_bytes + pred.output_copy_bytes),
                "act_saved": pred.act_saved_bytes,
                "act_transient": pred.act_transient_bytes,
                "overhead": (pred.loss_bytes + pred.input_bytes
                             + pred.cache_bytes),
            }
        assert set(terms) == set(TERMS)
        assert sum(terms.values()) == pred.peak_bytes
        rows.append(TermRow(measurement=m, terms=terms,
                            raw_peak_bytes=pred.peak_bytes))
    return rows
