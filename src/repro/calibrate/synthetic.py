"""Deterministic synthetic measurements: the pipeline's CPU-only oracle.

Real measurement sources (TPU runs, the XLA dry-run harness) are not
available on CPU-only CI, so this module manufactures a measurement set
with a KNOWN ground-truth distortion: it decomposes each cell's raw Eq.1
terms and re-composes them under a hidden "true" profile (per-term
multiplicative skews + per-chip constants) plus bounded deterministic
noise.  The fit must then recover the hidden profile from the residuals —
a closed-loop correctness check that needs no hardware.

Determinism is load-bearing: the bundled benchmark fixture
(benchmarks/fixtures/calibration_measurements.json) is regenerated and
compared in tests, so no wall-clock, no ``random`` — noise is derived
from a sha256 of the cell identity.
"""

from __future__ import annotations

import hashlib
from typing import Optional, Sequence

from repro.calibrate.measurements import Measurement, MeasurementStore
from repro.calibrate.profile import TERMS, CalibrationProfile
from repro.calibrate.residual import decompose

GiB = 1024 ** 3

# one arch per family, smallest member where the zoo offers a choice
SYNTHETIC_ARCHS: tuple[str, ...] = (
    "smollm-360m",             # dense
    "deepseek-v2-lite-16b",    # moe (MLA attention)
    "mamba2-1.3b",             # ssm
    "zamba2-2.7b",             # hybrid
    "llava15-7b",              # vlm (frozen vision tower)
    "seamless-m4t-large-v2",   # encdec
)

# The hidden allocator behavior the synthetic oracle applies to the
# liveness-at-peak terms: fragmentation and allocator rounding inflate
# saved activations, the analytic transient and overhead estimates are
# slightly conservative (real allocators reuse freed transient blocks),
# and each chip type carries a constant runtime/XLA reservation the
# analytic model does not see.  Against this oracle the raw legacy
# (sum-of-maxima) prediction lands at ~12.2% MAPE on the bundled
# fixture grid while the raw liveness peak lands at ~8.7% — the
# overlap slack is most of the gap the paper closes.
TRUE_PROFILE = CalibrationProfile(
    coefficients={"static": 0.99, "act_saved": 1.21,
                  "act_transient": 0.84, "overhead": 0.95},
    chip_constant_bytes={"v5e": int(0.14 * GiB), "h100": int(0.77 * GiB)},
    source={"note": "synthetic ground truth (repro.calibrate.synthetic)"})

# Structure the affine profile CANNOT express — the signal the learned
# residual model (repro.calibrate.learned) exists to recover:
#
# * FAMILY_ACT_SKEW — per-family multiplicative skew on the saved-
#   activation term (mean ~1.0 so the global NNLS coefficient stays
#   honest).  A single global ``act_saved`` coefficient averages over
#   these; only a per-family corrector can close them.
# * KNOB_EFFECTS — a family-INDEPENDENT additive reservation that grows
#   with log2(seq_len/1024) GiB (think allocator metadata / collective
#   buffers scaling with sequence).  No affine per-term coefficient or
#   per-chip constant can express a seq-dependent constant, but the
#   residual model's seq feature can — and because it is family-
#   independent it TRANSFERS to a family held out of the fit, which is
#   exactly what the leave-one-family-out benchmark gate scores.
FAMILY_ACT_SKEW: dict = {"dense": 1.06, "moe": 0.95, "ssm": 1.03,
                         "hybrid": 0.97, "vlm": 1.05, "encdec": 0.94}
KNOB_EFFECTS: dict = {"seq_gib_per_log2": 0.25}

DEFAULT_MESHES: tuple[dict, ...] = ({"data": 8, "model": 2},
                                    {"data": 4, "model": 4},
                                    {"data": 2, "model": 8})
DEFAULT_BATCHES: tuple[int, ...] = (16, 32)
# two seq_lens: act_saved scales ~linearly with seq but the transient's
# flash tiles / loss chunk do not — decorrelates the two columns
DEFAULT_SEQ_LENS: tuple[int, ...] = (1024, 2048)
DEFAULT_CHIPS: tuple[str, ...] = ("v5e", "h100")


def _unit_noise(key: str) -> float:
    """Deterministic value in [-1, 1) from the cell identity."""
    h = hashlib.sha256(key.encode()).digest()
    return int.from_bytes(h[:8], "big") / 2.0 ** 63 - 1.0


def generate(archs: Sequence[str] = SYNTHETIC_ARCHS,
             meshes: Sequence[dict] = DEFAULT_MESHES,
             global_batches: Sequence[int] = DEFAULT_BATCHES,
             seq_lens: Sequence[int] = DEFAULT_SEQ_LENS,
             chips: Sequence[str] = DEFAULT_CHIPS,
             backend: str = "tpu",
             noise: float = 0.01,
             true_profile: CalibrationProfile = TRUE_PROFILE,
             engine=None, assembly: str = "liveness",
             family_skew: Optional[dict] = FAMILY_ACT_SKEW,
             knob_effects: Optional[dict] = KNOB_EFFECTS
             ) -> MeasurementStore:
    """Synthesize measured_bytes for the (arch x mesh x batch x seq x chip)
    grid under ``true_profile`` with +-``noise`` relative deterministic
    jitter.

    The oracle composes from the ``assembly="liveness"`` interval-overlap
    decomposition by default: a real allocator frees the loss head before
    the backward transients materialize, so the true footprint follows
    the alloc/free overlap, not the legacy sum-of-maxima.  Against this
    oracle the raw legacy prediction carries a systematic overshoot (the
    overlap slack) on top of the skews — exactly the gap the liveness
    assembly closes.  Pass ``assembly="legacy"`` for the historical
    sum-of-maxima oracle.

    ``family_skew`` / ``knob_effects`` (defaults: the module constants)
    layer non-affine structure on top of the profile — the learned
    residual model's ground truth.  Pass ``None`` for either to get a
    PURE affine oracle (the profile-recovery tests do: an exact NNLS
    inversion is only defined against an exactly-affine truth)."""
    import math

    from repro.core import sweep as SW
    from repro.configs import get_config
    engine = engine or SW.SweepEngine()
    cells = MeasurementStore()
    for arch in archs:
        arch = SW.normalize_arch(arch)
        for chip in chips:
            for mesh in meshes:
                for gb in global_batches:
                    for seq in seq_lens:
                        cells.add(Measurement(
                            arch=arch, kind="train", seq_len=int(seq),
                            global_batch=int(gb), mesh_shape=dict(mesh),
                            measured_bytes=0, backend=backend, chip=chip,
                            source="synthetic"))
    for row in decompose(cells, engine, assembly=assembly):
        m = row.measurement
        skew = (family_skew or {}).get(get_config(m.arch).family, 1.0)
        true_bytes = sum(true_profile.coef(t) * row.terms[t]
                         * (skew if t == "act_saved" else 1.0)
                         for t in TERMS)
        true_bytes += true_profile.chip_offset(m.chip)
        if knob_effects:
            true_bytes += (knob_effects.get("seq_gib_per_log2", 0.0)
                           * math.log2(max(m.seq_len, 1) / 1024) * GiB)
        jitter = 1.0 + noise * _unit_noise("|".join(map(str, m.key)))
        m.measured_bytes = int(round(true_bytes * jitter))
        m.meta = {"noise": noise,
                  "true_profile": true_profile.profile_hash,
                  "family_skew": bool(family_skew),
                  "knob_effects": bool(knob_effects)}
    return cells
