from repro.checkpoint.checkpointing import (Checkpointer, save_checkpoint,  # noqa: F401
                                            load_checkpoint, latest_step)
