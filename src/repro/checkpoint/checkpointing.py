"""Checkpointing with elastic resharding and async writes.

Layout: ``<dir>/step_<N>/{meta.json, leaf_<i>.npy}`` — leaves are stored as
full logical arrays with their treedef path, so a checkpoint written on any
mesh restores onto any other mesh (the loader re-shards via device_put).
Writes go through a background thread (training never blocks on IO) into a
tmp dir that is atomically renamed — a crash mid-write can never corrupt
the latest complete checkpoint.  ``keep`` bounds disk usage.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import numpy as np


def _paths(tree) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: x is None)
    return ["/".join(str(k) for k in path) for path, _ in flat]


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: x is None)
    tmp = os.path.join(directory, f".tmp_step_{step}")
    final = os.path.join(directory, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    meta = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (path, leaf) in enumerate(flat):
        name = f"leaf_{i}.npy"
        if leaf is None:
            meta["leaves"].append({"path": "/".join(map(str, path)),
                                   "none": True})
            continue
        arr = np.asarray(jax.device_get(leaf))
        # np.save cannot represent ml_dtypes (bfloat16 -> void); store the
        # raw bytes and record the true dtype in meta.
        np.save(os.path.join(tmp, name),
                np.frombuffer(np.ascontiguousarray(arr).tobytes(),
                              dtype=np.uint8))
        meta["leaves"].append({"path": "/".join(map(str, path)),
                               "file": name, "dtype": str(arr.dtype),
                               "shape": list(arr.shape)})
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, like: Any,
                    shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; optional pytree of
    NamedShardings re-shards each leaf for the CURRENT mesh (elastic)."""
    d = os.path.join(directory, f"step_{step}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(
        like, is_leaf=lambda x: x is None)
    by_path = {m["path"]: m for m in meta["leaves"]}
    flat_sh = (treedef.flatten_up_to(shardings)
               if shardings is not None else [None] * len(flat_like))
    out = []
    for (path, leaf), sh in zip(flat_like, flat_sh):
        key = "/".join(str(k) for k in path)
        m = by_path.get(key)
        if m is None or m.get("none"):
            out.append(None)
            continue
        import jax.numpy as jnp
        raw = np.load(os.path.join(d, m["file"]))
        dtype = jnp.dtype(m["dtype"])
        arr = np.frombuffer(raw.tobytes(), dtype=dtype).reshape(m["shape"])
        if leaf is not None and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclass
class Checkpointer:
    """Async checkpointer with retention."""

    directory: str
    keep: int = 3
    _thread: Optional[threading.Thread] = field(default=None, repr=False)
    _error: list = field(default_factory=list, repr=False)

    def save_async(self, step: int, tree: Any,
                   extra: Optional[dict] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(
            lambda x: None if x is None else np.asarray(jax.device_get(x)),
            tree, is_leaf=lambda x: x is None)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except Exception as e:       # surfaced on next wait()
                self._error.append(e)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            raise self._error.pop()

    def _gc(self) -> None:
        steps = sorted(int(m.group(1)) for d in os.listdir(self.directory)
                       if (m := re.fullmatch(r"step_(\d+)", d)))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    def restore_latest(self, like: Any, shardings: Any = None):
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, load_checkpoint(self.directory, step, like, shardings)
