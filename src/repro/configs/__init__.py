"""Architecture + shape configuration system.

``get_config(name)`` returns the full published configuration;
``get_config(name).reduced()`` returns a CPU-smoke-testable miniature of the
same family (same code paths, tiny dims).  ``SHAPES`` holds the assigned
input-shape set; ``cells(arch)`` enumerates the (arch x shape) cells that
are applicable (see DESIGN.md for skip rules).
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 0           # 0 => no q compression
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 64
    top_k: int = 2
    n_shared_experts: int = 0
    d_expert: int = 1408           # per-expert FFN hidden dim
    n_dense_layers: int = 0        # leading layers that use a dense FFN instead
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class VLMConfig:
    d_vision: int = 1024           # vision-tower output width
    n_image_tokens: int = 576      # tokens contributed by the image
    projector_layers: int = 2
    vision_tower: bool = False     # True => real ViT params (paper repro);
                                   # False => stubbed frontend (assigned arch)
    vit_layers: int = 24
    vit_heads: int = 16
    vit_d_ff: int = 4096
    vit_patch: int = 14
    vit_image_size: int = 336


@dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int = 24
    d_frontend: int = 1024         # stubbed speech-frontend embedding width
    enc_seq_ratio: float = 1.0     # encoder seq = ratio * shape.seq_len


@dataclass(frozen=True)
class HybridConfig:
    attn_every: int = 6            # shared attention block applied every k layers
    shared_attn_blocks: int = 2    # distinct shared blocks, alternating


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 => d_model // n_heads
    qk_norm: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    dtype: str = "bfloat16"
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    vlm: Optional[VLMConfig] = None
    encdec: Optional[EncDecConfig] = None
    hybrid: Optional[HybridConfig] = None
    # training-system defaults (overridable by TrainConfig)
    optimizer: str = "adamw"       # adamw | adafactor | adamw8bit
    fsdp: bool = False             # shard params over the data axis too (ZeRO-3)
    remat: str = "block"           # none | block | dots
    seq_parallel: bool = True      # shard the residual seq dim over `model`
    subquadratic: bool = False     # may run long_500k
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    def reduced(self) -> "ArchConfig":
        """Miniature config of the same family for CPU smoke tests."""
        r = dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(max(self.n_kv_heads // max(self.n_heads // 4, 1), 1), 4),
            d_ff=128,
            vocab=256,
            head_dim=16,
        )
        if self.mla:
            r = dataclasses.replace(r, mla=MLAConfig(
                q_lora_rank=32 if self.mla.q_lora_rank else 0,
                kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
                v_head_dim=16))
        if self.moe:
            r = dataclasses.replace(r, moe=dataclasses.replace(
                self.moe, n_experts=4, top_k=2, d_expert=32,
                n_shared_experts=min(self.moe.n_shared_experts, 1),
                n_dense_layers=min(self.moe.n_dense_layers, 1)))
        if self.ssm:
            r = dataclasses.replace(r, ssm=SSMConfig(
                d_state=16, d_conv=4, expand=2, head_dim=16,
                n_groups=1, chunk=32))
        if self.vlm:
            r = dataclasses.replace(r, vlm=dataclasses.replace(
                self.vlm, d_vision=32, n_image_tokens=16,
                vit_layers=2, vit_heads=2, vit_d_ff=64,
                vit_image_size=28, vit_patch=14))
        if self.encdec:
            r = dataclasses.replace(r, encdec=dataclasses.replace(
                self.encdec, n_enc_layers=2, d_frontend=32))
        if self.hybrid:
            r = dataclasses.replace(r, hybrid=HybridConfig(
                attn_every=2, shared_attn_blocks=1))
        return r


# ---------------------------------------------------------------------------
# Shapes (assigned): seq_len x global_batch; kind decides which step lowers.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_NAMES = [
    "llama3.2-3b",
    "minicpm3-4b",
    "smollm-360m",
    "qwen3-32b",
    "deepseek-v2-lite-16b",
    "arctic-480b",
    "mamba2-1.3b",
    "llava-next-mistral-7b",
    "zamba2-2.7b",
    "seamless-m4t-large-v2",
]

_MODULES = {
    "llama3.2-3b": "llama3_2_3b",
    "minicpm3-4b": "minicpm3_4b",
    "smollm-360m": "smollm_360m",
    "qwen3-32b": "qwen3_32b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "arctic-480b": "arctic_480b",
    "mamba2-1.3b": "mamba2_1_3b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "zamba2-2.7b": "zamba2_2_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "llava15-7b": "llava15_7b",
    "llama3.1-8b": "llama3_1_8b",
}

# runtime-registered configs (register_config); checked before _MODULES
_RUNTIME: dict[str, ArchConfig] = {}


def register_config(cfg: ArchConfig, name: Optional[str] = None) -> None:
    """Register an architecture at runtime so ``get_config``/the sweep
    engine can plan for it without a module under repro/configs/.  See
    docs/configs.md for the file-based registration path."""
    _RUNTIME[name or cfg.name] = cfg


def registered_archs() -> list[str]:
    """All arch names ``get_config`` accepts (file-based + runtime)."""
    return sorted(set(_MODULES) | set(_RUNTIME))


def get_config(name: str) -> ArchConfig:
    if name in _RUNTIME:
        return _RUNTIME[name]
    if name not in _MODULES:
        raise KeyError(
            f"unknown arch {name!r}; known: {registered_archs()}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def cells(arch: Optional[str] = None) -> list[tuple[str, str]]:
    """All applicable (arch, shape) dry-run cells. long_500k only runs for
    sub-quadratic archs (SSM / hybrid); see DESIGN.md."""
    out = []
    for a in ([arch] if arch else ARCH_NAMES):
        cfg = get_config(a)
        for s, shape in SHAPES.items():
            if shape.name == "long_500k" and not cfg.subquadratic:
                continue
            out.append((a, s))
    return out


def skipped_cells() -> list[tuple[str, str, str]]:
    out = []
    for a in ARCH_NAMES:
        cfg = get_config(a)
        if not cfg.subquadratic:
            out.append((a, "long_500k",
                        "pure full-attention arch; 500k decode requires "
                        "sub-quadratic attention (DESIGN.md)"))
    return out
