"""Print the registered-architecture table (markdown).

    PYTHONPATH=src python -m repro.configs
    PYTHONPATH=src python -m repro.configs --profile profile.json \
        --chip v5e --mesh data=16,model=16 --shape train_4k

docs/configs.md embeds the plain output; re-run after registering a new
arch.  With ``--profile`` (a fitted repro.calibrate CalibrationProfile)
two extra columns show each architecture's predicted peak on the
reference cell, raw and calibrated.
"""

from __future__ import annotations

import argparse
from typing import Optional

from repro.configs import get_config, registered_archs

GiB = 1024 ** 3


def _attention_kind(cfg) -> str:
    if cfg.mla:
        return "MLA"
    if cfg.family == "ssm":
        return "SSM (mamba2)"
    if cfg.family == "hybrid":
        return "SSM + shared attn"
    base = "GQA" if cfg.n_kv_heads < cfg.n_heads else "MHA"
    if cfg.family == "encdec":
        return f"{base} + cross"
    return base


def _modality(cfg) -> str:
    return {"vlm": "vision+text", "encdec": "audio+text"}.get(
        cfg.family, "text")


def _params(cfg) -> str:
    from repro.core.parser import parse_model, total_params
    from repro.core.spec import FULL_TRAIN
    from repro.models import build_model
    n = total_params(parse_model(build_model(cfg).spec, FULL_TRAIN))
    return f"{n / 1e9:.2f}B" if n >= 1e9 else f"{n / 1e6:.0f}M"


def table(profile=None, chip: str = "v5e",
          mesh: Optional[dict] = None, shape: str = "train_4k") -> str:
    """The arch table; with a CalibrationProfile, adds raw + calibrated
    predicted-peak columns for the reference (shape, mesh, chip) cell."""
    from repro.core.report import markdown_table
    headers = ["arch", "family", "params", "modality", "attention",
               "optimizer", "remat", "fsdp"]
    engine = None
    if profile is not None:
        from repro.core import sweep as SW
        engine = SW.SweepEngine()
        mesh = mesh or {"data": 16, "model": 16}
        headers += [f"peak GiB ({shape})", "calibrated GiB"]
    rows = []
    for name in registered_archs():
        cfg = get_config(name)
        row = [name, cfg.family, _params(cfg), _modality(cfg),
               _attention_kind(cfg), cfg.optimizer, cfg.remat,
               "yes" if cfg.fsdp else "no"]
        if profile is not None:
            from repro.core import planner as PL
            budget = int(PL.chip_hbm(chip) * PL.HEADROOM)
            raw = engine.report(name, shape, mesh, budget_bytes=budget,
                                chip=chip)
            cal = engine.report(name, shape, mesh, budget_bytes=budget,
                                chip=chip, profile=profile)
            row += [f"{raw.peak_bytes / GiB:.2f}",
                    f"{cal.peak_bytes / GiB:.2f}"]
        rows.append(tuple(row))
    return markdown_table(headers, rows)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.configs")
    ap.add_argument("--profile", metavar="PATH", default=None,
                    help="CalibrationProfile JSON: adds raw + calibrated "
                         "predicted-peak columns")
    ap.add_argument("--chip", default=None,
                    help="reference chip (with --profile; default v5e)")
    ap.add_argument("--mesh", default=None, metavar="data=16,model=16",
                    help="reference mesh (with --profile)")
    ap.add_argument("--shape", default=None,
                    help="reference shape (with --profile; "
                         "default train_4k)")
    args = ap.parse_args(argv)
    if args.profile is None:
        given = [f for f in ("chip", "mesh", "shape")
                 if getattr(args, f) is not None]
        if given:
            ap.error(f"--{'/--'.join(given)} only apply to the "
                     f"--profile reference cell")
        print(table())
        return 0
    from repro.calibrate.profile import CalibrationProfile
    from repro.configs import SHAPES
    from repro.core import planner as PL
    from repro.core.sweep import _parse_mesh
    chip = args.chip or "v5e"
    shape = args.shape or "train_4k"
    mesh_str = args.mesh or "data=16,model=16"
    try:
        profile = CalibrationProfile.load(args.profile)
        mesh = _parse_mesh(mesh_str)
        PL.chip_hbm(chip)
        if shape not in SHAPES:
            raise ValueError(f"unknown shape {shape!r}; "
                             f"known: {sorted(SHAPES)}")
    except (OSError, KeyError, ValueError) as e:
        ap.error(str(e))
    print(f"_profile {profile.profile_hash}: reference cell "
          f"{shape} on {mesh_str} ({chip})_\n")
    print(table(profile=profile, chip=chip, mesh=mesh, shape=shape))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
