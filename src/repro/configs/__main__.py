"""Print the registered-architecture table (markdown).

    PYTHONPATH=src python -m repro.configs
    PYTHONPATH=src python -m repro.configs --profile profile.json \
        --chip v5e --mesh data=16,model=16 --shape train_4k
    PYTHONPATH=src python -m repro.configs --breakdown --arch llava15_7b \
        --mesh data=4,model=2,pipe=2 --microbatches 4

docs/configs.md embeds the plain output; re-run after registering a new
arch.  With ``--profile`` (a fitted repro.calibrate CalibrationProfile)
two extra columns show each architecture's predicted peak on the
reference cell, raw and calibrated.  With ``--breakdown`` one
architecture's prediction is decomposed into the per-module memory table
(``PredictedMemory.per_module``) and — when the mesh has a ``pipe``
axis — the per-pipeline-stage table (``predictor.predict_stages``);
a mesh with an ``expert`` / ``context`` axis adds per-expert-shard and
per-context-shard columns (``ep_saved`` / ``cp_saved``: what each module
saves versus the same cell with that axis stripped).
"""

from __future__ import annotations

import argparse
from typing import Optional

from repro.configs import get_config, registered_archs

GiB = 1024 ** 3


def _attention_kind(cfg) -> str:
    if cfg.mla:
        return "MLA"
    if cfg.family == "ssm":
        return "SSM (mamba2)"
    if cfg.family == "hybrid":
        return "SSM + shared attn"
    base = "GQA" if cfg.n_kv_heads < cfg.n_heads else "MHA"
    if cfg.family == "encdec":
        return f"{base} + cross"
    return base


def _modality(cfg) -> str:
    return {"vlm": "vision+text", "encdec": "audio+text"}.get(
        cfg.family, "text")


def _params(cfg) -> str:
    from repro.core.parser import parse_model, total_params
    from repro.core.spec import FULL_TRAIN
    from repro.models import build_model
    n = total_params(parse_model(build_model(cfg).spec, FULL_TRAIN))
    return f"{n / 1e9:.2f}B" if n >= 1e9 else f"{n / 1e6:.0f}M"


def table(profile=None, chip: str = "v5e",
          mesh: Optional[dict] = None, shape: str = "train_4k",
          residual=None) -> str:
    """The arch table; with a CalibrationProfile, adds raw + calibrated
    predicted-peak columns for the reference (shape, mesh, chip) cell
    (plus a learned column when a ResidualModel is given)."""
    from repro.core.report import markdown_table
    headers = ["arch", "family", "params", "modality", "attention",
               "optimizer", "remat", "fsdp"]
    engine = None
    if profile is not None:
        from repro.core import sweep as SW
        engine = SW.SweepEngine()
        mesh = mesh or {"data": 16, "model": 16}
        headers += [f"peak GiB ({shape})", "calibrated GiB"]
        if residual is not None:
            headers += ["learned GiB"]
    rows = []
    for name in registered_archs():
        cfg = get_config(name)
        row = [name, cfg.family, _params(cfg), _modality(cfg),
               _attention_kind(cfg), cfg.optimizer, cfg.remat,
               "yes" if cfg.fsdp else "no"]
        if profile is not None:
            from repro.core import planner as PL
            budget = int(PL.chip_hbm(chip) * PL.HEADROOM)
            raw = engine.report(name, shape, mesh, budget_bytes=budget,
                                chip=chip)
            cal = engine.report(name, shape, mesh, budget_bytes=budget,
                                chip=chip, profile=profile)
            row += [f"{raw.peak_bytes / GiB:.2f}",
                    f"{cal.peak_bytes / GiB:.2f}"]
            if residual is not None:
                lrn = engine.report(name, shape, mesh,
                                    budget_bytes=budget, chip=chip,
                                    profile=profile, residual=residual)
                row += [f"{lrn.peak_bytes / GiB:.2f}"]
        rows.append(tuple(row))
    return markdown_table(headers, rows)


def breakdown(arch: str, shape: str = "train_4k",
              mesh: Optional[dict] = None, chip: str = "v5e",
              policy: str = "full", backend: str = "tpu",
              microbatches: int = 1, schedule: str = "1f1b",
              serve=None, assembly: str = "legacy") -> str:
    """Per-module (and, with a ``pipe`` mesh axis, per-stage) memory
    breakdown of one architecture's prediction on a reference cell.
    ``serve`` (a repro.serve.pool.ServeSpec, serve kinds only) adds the
    paged-KV pool / prefix-savings / draft-residency summary line.
    ``assembly="liveness"`` reports the interval-overlap peak and adds
    the reporting-only overlap-slack column (legacy minus liveness)."""
    from repro.configs import get_config
    from repro.core import planner as PL
    from repro.core import predictor as PR
    from repro.core.report import markdown_table
    from repro.core.sweep import POLICIES, normalize_arch
    from repro.models import build_model

    from repro.launch.mesh import cp_degree, ep_degree

    arch = normalize_arch(arch)
    cfg = get_config(arch)
    model = build_model(cfg)
    shp = PL._resolve_shape(shape)
    mesh = mesh or {"data": 16, "model": 16}
    ctx = PL.make_context(cfg, mesh, kind=shp.kind,
                          global_batch=shp.global_batch,
                          seq_len=shp.seq_len, backend=backend,
                          microbatches=microbatches, schedule=schedule,
                          serve=serve)
    preds = PR.predict_stages(model, POLICIES[policy], ctx,
                              assembly=assembly)
    peak_stage = max(range(len(preds)),
                     key=lambda i: preds[i].peak_bytes)
    pred = preds[peak_stage]
    budget = PL.chip_hbm(chip) * PL.HEADROOM
    mesh_str = ",".join(f"{k}={v}" for k, v in sorted(mesh.items()))
    gib = lambda v: f"{v / GiB:.3f}"
    live = assembly == "liveness"
    out = [f"## {arch} {shp.name} on {mesh_str} ({backend} prediction"
           + (", liveness assembly)" if live else ")"),
           "",
           f"peak {pred.peak_bytes / GiB:.2f} GiB vs "
           f"{budget / GiB:.2f} GiB budget ({chip}) -> "
           f"{'FITS' if pred.peak_bytes <= budget else 'OOM'}", ""]
    if live:
        out += [f"overlap slack {gib(pred.overlap_slack_bytes)} GiB "
                f"(legacy sum-of-maxima would report "
                f"{(pred.peak_bytes + pred.overlap_slack_bytes) / GiB:.2f}"
                f" GiB)", ""]

    # serving-fleet summary (decode/prefill cells with active serve
    # knobs): the paged pool replaces the slen-growing cache terms, so
    # its line sits next to the peak it feeds instead of being dropped
    if ctx.serve is not None and (pred.pool_bytes or pred.draft_bytes
                                  or pred.hit_saved_bytes):
        from repro.serve.pool import pool_blocks
        s = ctx.serve
        line = (f"serving: block {s.block_size} "
                f"({pool_blocks(shp.seq_len, s)} blocks/seq), "
                f"util {s.util_bp / 10000:.2f}, "
                f"hit {s.hit_bp / 10000:.2f} -> "
                f"kv_pool {gib(pred.pool_bytes)} GiB "
                f"(prefix hits save {gib(pred.hit_saved_bytes)} GiB)")
        if s.draft_arch:
            line += (f"; draft {s.draft_arch} "
                     f"{gib(pred.draft_bytes)} GiB resident")
        out += [line, ""]

    # per-expert-shard / per-context-shard columns: re-predict the SAME
    # cell with the expert (resp. context) axis stripped; each module's
    # delta is what that axis saves it on the peak stage.  The stage
    # partition depends only on the pipe degree, so stage indices line
    # up between the stripped and full meshes.
    ep, cp = ep_degree(mesh), cp_degree(mesh)

    def _without(axis):
        m = {k: v for k, v in mesh.items() if k != axis}
        c = PL.make_context(cfg, m, kind=shp.kind,
                            global_batch=shp.global_batch,
                            seq_len=shp.seq_len, backend=backend,
                            microbatches=microbatches, schedule=schedule)
        return PR.predict_stages(model, POLICIES[policy], c)[peak_stage]

    mod_total = lambda m: m["param"] + m["grad"] + m["opt"] + m["act"]
    ep_saved = cp_saved = None
    if ep > 1:
        ep_saved = {path: mod_total(m) - mod_total(pred.per_module[path])
                    for path, m in _without("expert").per_module.items()}
    if cp > 1:
        cp_saved = {path: mod_total(m) - mod_total(pred.per_module[path])
                    for path, m in _without("context").per_module.items()}
    if ep > 1 or cp > 1:
        out.append(f"expert-parallel ep={ep} (MoE weights + dispatch "
                   f"buffers / {ep}) x context-parallel cp={cp} (seq "
                   f"activations + ring KV blocks / {cp})")
        out.append("")
    if len(preds) > 1:
        from repro.core import stages as ST
        rows = []
        for i, p in enumerate(preds):
            stash = ST.stash_count(i, ctx.pp, ctx.eff_microbatches,
                                   ctx.schedule)
            row = (i, len(p.per_module), stash,
                   gib(p.param_bytes),
                   gib(p.grad_bytes + p.opt_bytes),
                   gib(p.act_saved_bytes),
                   gib(p.act_transient_bytes),
                   gib(p.loss_bytes + p.input_bytes
                       + p.cache_bytes))
            if live:
                row += (gib(p.overlap_slack_bytes),)
            rows.append(row + (gib(p.peak_bytes),
                               "<- peak" if i == peak_stage else ""))
        stage_headers = ("stage", "modules", "stash", "param", "grad+opt",
                         "act_saved", "act_trans", "overheads")
        if live:
            stage_headers += ("ovl_slack",)
        out.append(markdown_table(
            stage_headers + ("peak_gib", ""),
            rows,
            title=f"pipeline stages (pp={ctx.pp} x {microbatches} "
                  f"microbatches, {schedule})"))
        out.append("")
    mod_rows = []
    for path, m in pred.per_module.items():
        total = m["param"] + m["grad"] + m["opt"] + m["act"]
        row = [path, "yes" if m["trainable"] else "frozen",
               gib(m["param"]), gib(m["grad"]), gib(m["opt"]),
               gib(m["act"]), gib(total)]
        if ep_saved is not None:
            row.append(gib(ep_saved[path]))
        if cp_saved is not None:
            row.append(gib(cp_saved[path]))
        mod_rows.append(tuple(row))
    headers = ["module", "trainable", "param", "grad", "opt", "act_saved",
               "total_gib"]
    if ep_saved is not None:
        headers.append(f"ep_saved (x{ep})")
    if cp_saved is not None:
        headers.append(f"cp_saved (x{cp})")
    title = ("per-module breakdown"
             + (f" (peak stage {peak_stage})" if len(preds) > 1 else ""))
    out.append(markdown_table(tuple(headers), mod_rows, title=title))
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.configs")
    ap.add_argument("--profile", metavar="PATH", default=None,
                    help="CalibrationProfile JSON: adds raw + calibrated "
                         "predicted-peak columns")
    ap.add_argument("--residual-model", metavar="PATH", default=None,
                    help="learned ResidualModel JSON (needs --profile it "
                         "was fitted over): adds a learned predicted-"
                         "peak column")
    ap.add_argument("--breakdown", action="store_true",
                    help="print one arch's per-module / per-stage memory "
                         "table for the reference cell (needs --arch)")
    ap.add_argument("--arch", default=None,
                    help="architecture for --breakdown")
    ap.add_argument("--policy", default="full",
                    help="train policy for --breakdown "
                         "(full/llava_stage1/llava_stage2)")
    ap.add_argument("--backend", default="tpu", choices=("tpu", "cpu"),
                    help="prediction backend for --breakdown")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="pipeline microbatch count for --breakdown "
                         "(with a pipe mesh axis)")
    ap.add_argument("--schedule", default="1f1b",
                    choices=("1f1b", "gpipe"),
                    help="pipeline schedule for --breakdown")
    ap.add_argument("--assembly", default="legacy",
                    choices=("legacy", "liveness"),
                    help="peak assembly for --breakdown: legacy "
                         "sum-of-maxima or liveness interval-overlap "
                         "(adds the overlap-slack column)")
    ap.add_argument("--block-size", type=int, default=0,
                    help="paged-KV block size in tokens for --breakdown "
                         "(serve kinds; 0 = contiguous)")
    ap.add_argument("--utilization", type=float, default=1.0,
                    help="KV pool utilization in (0,1] for --breakdown")
    ap.add_argument("--prefix-hit-rate", type=float, default=0.0,
                    help="prefix-cache hit rate in [0,1] for --breakdown")
    ap.add_argument("--prefix-len", type=int, default=0,
                    help="shared-prefix token count for --breakdown")
    ap.add_argument("--mix", default=None, metavar="P[:LxW,...]",
                    help="request mix for --breakdown (prefill fraction "
                         "+ seq-len histogram, e.g. 0.3:512x1,4096x3)")
    ap.add_argument("--draft-arch", default="",
                    help="speculative-decode draft arch for --breakdown "
                         "(decode kind only)")
    ap.add_argument("--chip", default=None,
                    help="reference chip (default v5e)")
    ap.add_argument("--mesh", default=None, metavar="data=16,model=16",
                    help="reference mesh (may include pipe=N)")
    ap.add_argument("--shape", default=None,
                    help="reference shape (default train_4k)")
    args = ap.parse_args(argv)
    serve_given = bool(args.block_size or args.utilization != 1.0
                       or args.prefix_hit_rate or args.prefix_len
                       or args.mix or args.draft_arch)
    if serve_given and not args.breakdown:
        ap.error("--block-size/--utilization/--prefix-hit-rate/"
                 "--prefix-len/--mix/--draft-arch only apply to "
                 "--breakdown")
    if args.assembly != "legacy" and not args.breakdown:
        ap.error("--assembly only applies to --breakdown")
    if args.breakdown:
        if args.profile:
            ap.error("--breakdown and --profile are mutually exclusive")
        if not args.arch:
            ap.error("--breakdown needs --arch")
        from repro.core import planner as PL
        from repro.core.sweep import POLICIES, _parse_mesh
        try:
            mesh = _parse_mesh(args.mesh) if args.mesh else None
            chip = args.chip or "v5e"
            PL.chip_hbm(chip)
            if args.policy not in POLICIES:
                raise ValueError(f"unknown policy {args.policy!r}; "
                                 f"known: {sorted(POLICIES)}")
            serve = None
            if serve_given:
                from repro.serve.fleet import parse_mix
                from repro.serve.pool import ServeSpec
                serve = ServeSpec.make(
                    block_size=args.block_size,
                    utilization=args.utilization,
                    prefix_hit_rate=args.prefix_hit_rate,
                    prefix_len=args.prefix_len,
                    mix=parse_mix(args.mix) if args.mix else None,
                    draft_arch=args.draft_arch)
            print(breakdown(args.arch, shape=args.shape or "train_4k",
                            mesh=mesh, chip=chip, policy=args.policy,
                            backend=args.backend,
                            microbatches=args.microbatches,
                            schedule=args.schedule, serve=serve,
                            assembly=args.assembly))
        except (KeyError, ValueError) as e:
            ap.error(str(e))
        return 0
    if args.profile is None:
        if args.residual_model:
            ap.error("--residual-model needs the --profile it was "
                     "fitted over")
        given = [f for f in ("chip", "mesh", "shape")
                 if getattr(args, f) is not None]
        if given:
            ap.error(f"--{'/--'.join(given)} only apply to the "
                     f"--profile reference cell or --breakdown")
        print(table())
        return 0
    from repro.calibrate.profile import CalibrationProfile
    from repro.configs import SHAPES
    from repro.core import planner as PL
    from repro.core.sweep import _parse_mesh
    chip = args.chip or "v5e"
    shape = args.shape or "train_4k"
    mesh_str = args.mesh or "data=16,model=16"
    residual = None
    try:
        profile = CalibrationProfile.load(args.profile)
        if args.residual_model:
            from repro.calibrate.learned import ResidualModel
            residual = ResidualModel.load(args.residual_model)
            if residual.base_profile_hash != profile.profile_hash:
                raise ValueError(
                    f"--residual-model was fitted over profile "
                    f"{residual.base_profile_hash or 'raw'}, not "
                    f"{profile.profile_hash}; pass the matching "
                    f"--profile")
        mesh = _parse_mesh(mesh_str)
        PL.chip_hbm(chip)
        if shape not in SHAPES:
            raise ValueError(f"unknown shape {shape!r}; "
                             f"known: {sorted(SHAPES)}")
    except (OSError, KeyError, ValueError) as e:
        ap.error(str(e))
    print(f"_profile {profile.profile_hash}"
          + (f" + residual {residual.model_hash}" if residual else "")
          + f": reference cell {shape} on {mesh_str} ({chip})_\n")
    print(table(profile=profile, chip=chip, mesh=mesh, shape=shape,
                residual=residual))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
