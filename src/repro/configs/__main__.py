"""Print the registered-architecture table (markdown).

    PYTHONPATH=src python -m repro.configs

docs/configs.md embeds this output; re-run after registering a new arch.
"""

from __future__ import annotations

from repro.configs import get_config, registered_archs


def _attention_kind(cfg) -> str:
    if cfg.mla:
        return "MLA"
    if cfg.family == "ssm":
        return "SSM (mamba2)"
    if cfg.family == "hybrid":
        return "SSM + shared attn"
    base = "GQA" if cfg.n_kv_heads < cfg.n_heads else "MHA"
    if cfg.family == "encdec":
        return f"{base} + cross"
    return base


def _modality(cfg) -> str:
    return {"vlm": "vision+text", "encdec": "audio+text"}.get(
        cfg.family, "text")


def _params(cfg) -> str:
    from repro.core.parser import parse_model, total_params
    from repro.core.spec import FULL_TRAIN
    from repro.models import build_model
    n = total_params(parse_model(build_model(cfg).spec, FULL_TRAIN))
    return f"{n / 1e9:.2f}B" if n >= 1e9 else f"{n / 1e6:.0f}M"


def table() -> str:
    from repro.core.report import markdown_table
    headers = ("arch", "family", "params", "modality", "attention",
               "optimizer", "remat", "fsdp")
    rows = []
    for name in registered_archs():
        cfg = get_config(name)
        rows.append((name, cfg.family, _params(cfg), _modality(cfg),
                     _attention_kind(cfg), cfg.optimizer, cfg.remat,
                     "yes" if cfg.fsdp else "no"))
    return markdown_table(headers, rows)


if __name__ == "__main__":
    print(table())
