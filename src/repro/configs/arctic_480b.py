"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf]."""
from repro.configs import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864,  # dense-residual FFN width
    vocab=32000, head_dim=128,
    moe=MoEConfig(n_experts=128, top_k=2, d_expert=4864,
                  dense_residual=True),
    optimizer="adafactor",  # Adam fp32 states (5.8 TB) cannot fit a v5e pod
    fsdp=True,              # params/grads/opt sharded over BOTH mesh axes
    remat="block",
    notes="Dense FFN residual in parallel with 128-expert top-2 MoE. "
          "Memory plan (core/planner.py): Adafactor + 2-axis FSDP required; "
          "see EXPERIMENTS.md.",
)
