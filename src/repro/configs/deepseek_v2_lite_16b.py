"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512 [arXiv:2405.04434; hf]."""
from repro.configs import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944,  # dense first-layer FFN width
    vocab=102400,
    mla=MLAConfig(q_lora_rank=0, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared_experts=2,
                  d_expert=1408, n_dense_layers=1),
    notes="MLA (no q compression in lite); 64 routed experts top-6 + 2 shared; "
          "first layer dense FFN 10944.",
)
