"""llama3.1-8b [dense] — llama3.1 8B [hf:meta-llama/Llama-3.1-8B;
unverified].  Registered as a capacity-planning target (not part of the
assigned dry-run cell set in ARCH_NAMES)."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="llama3.1-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256, head_dim=128,
    rope_theta=500000.0,
    notes="GQA kv=8; SwiGLU; RoPE theta 500k; untied embeddings.",
)
