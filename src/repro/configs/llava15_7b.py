"""llava15-7b — the PAPER's evaluation model (LLaVA-1.5 7B):
CLIP ViT-L/14-336 vision tower (REAL params, frozen) + 2-layer MLP projector
+ Vicuna-7B (llama-arch) language model.  Used by benchmarks/fig2a, fig2b.
"""
from repro.configs import ArchConfig, VLMConfig

CONFIG = ArchConfig(
    name="llava15-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab=32000, head_dim=128,
    vlm=VLMConfig(d_vision=1024, n_image_tokens=576, projector_layers=2,
                  vision_tower=True, vit_layers=24, vit_heads=16,
                  vit_d_ff=4096, vit_patch=14, vit_image_size=336),
    notes="Paper-repro model: frozen CLIP ViT-L/14 + projector + Vicuna-7B.",
)
