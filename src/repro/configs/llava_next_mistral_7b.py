"""llava-next-mistral-7b [vlm] — anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

Backbone-only per assignment: the anyres vision frontend is a STUB —
input_specs() provides precomputed patch embeddings (B, n_image_tokens,
d_vision); the projector and Mistral-7B backbone are real.
"""
from repro.configs import ArchConfig, VLMConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, head_dim=128,
    rope_theta=1000000.0,
    vlm=VLMConfig(d_vision=1024, n_image_tokens=576,
                  projector_layers=2, vision_tower=False),
    notes="Mistral-7B backbone (GQA kv=8, SwiGLU); stub anyres frontend.",
)
