"""qwen3-32b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
    d_ff=25600, vocab=151936, head_dim=128,
    qk_norm=True, rope_theta=1000000.0,
    fsdp=True,  # 32B: params must shard over data too to fit 16GB v5e chips
    notes="qk-norm on per-head q/k; GQA kv=8; FSDP over data axis.",
)
