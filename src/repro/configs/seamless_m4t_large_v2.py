"""seamless-m4t-large-v2 [audio] — enc-dec, multimodal [arXiv:2308.11596; hf].

Backbone-only per assignment: the speech frontend is a STUB — input_specs()
provides precomputed frame embeddings (B, enc_seq, d_frontend). 24L encoder
+ 24L decoder with cross-attention; text vocab 256206.
"""
from repro.configs import ArchConfig, EncDecConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206, head_dim=64,
    encdec=EncDecConfig(n_enc_layers=24, d_frontend=1024, enc_seq_ratio=1.0),
    notes="Encoder-decoder; decode_32k decodes with 32k-decoder KV cache + "
          "cross-attention over 32k encoder memory.",
)
