"""smollm-360m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M; hf]."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
    d_ff=2560, vocab=49152, head_dim=64,
    tie_embeddings=True,
    notes="GQA kv=5; 15 heads (not 16) — TP policy replicates attention "
          "projections over the model axis (960/16 OK for FFN, heads 15%16!=0).",
)
