"""zamba2-2.7b [hybrid] — Mamba2 + shared attn blocks [arXiv:2411.15242; hf]."""
from repro.configs import ArchConfig, HybridConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, head_dim=80,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk=256),
    hybrid=HybridConfig(attn_every=6, shared_attn_blocks=2),
    tie_embeddings=True,
    subquadratic=True,
    notes="54 Mamba-2 blocks; 2 shared (weight-tied) full-attention blocks "
          "applied every 6 layers, alternating. KV cache exists only for the "
          "shared blocks' 9 invocations.",
)
