"""Columnar batch evaluation of the Eq.1 memory model.

The per-cell path (``SweepEngine.evaluate`` -> ``predictor.assemble``)
costs tens of microseconds of Python per cell; a real pre-launch capacity
search covers 10^5-10^6 cells (every mesh factorization x remat x
optimizer x schedule x microbatches x grad-accum x batch x seq-len x chip
type), where interpreter overhead — not arithmetic — is the bound.  This
module lowers the predictor's component groups into structure-of-arrays
NumPy kernels that evaluate ALL cells of a
:class:`repro.core.sweep.SweepGrid` at once:

* per-layer byte terms are factored into (arch-dependent,
  cell-independent) :class:`repro.core.factors.TermSpec` coefficient
  tuples built once per arch x policy x pipeline stage — the SAME specs
  the scalar path evaluates, so the two paths share one source of truth;
* cell-dependent knobs (micro-batch, seq-len, encoder len, loss/flash
  chunks, pipeline microbatches) become int64 column arrays over the
  grid's unique knob tuples, contracted against the specs in
  ``O(stages x layers x cells)`` array ops;
* mesh shard counts come from :func:`batch_shard_factor`, an exact
  broadcast transliteration of ``mesh_ctx.assign_axes`` — divisibility,
  axis-reuse, FSDP/ZeRO greedy assignment and the pipe-axis exclusion
  are computed per cell with boolean masks, in integer arithmetic; the
  expert-parallel (`expert`) and context-parallel (`context`) axes flow
  through the same rule machinery, with the MoE-only (`experts` /
  `expert_buf`) and attention-only (ring KV block, gated per mesh on
  cp > 1) terms columnar-gated exactly like the scalar path;
* pipeline parallelism groups meshes by their ``pipe`` degree: every
  mesh in a group shares one stage partition (``core.stages``), the
  per-stage tables compose exactly like the scalar per-stage
  ``assemble``, the schedule's in-flight stash scales the saved-act
  column, and the cell's peak is the elementwise max over stages;
* :class:`~repro.calibrate.profile.CalibrationProfile` application is a
  vectorized affine transform per stage (one multiply + round per term
  group), maxed over stages like the scalar path.

Everything is exact int64 + floor-division arithmetic (float enters only
where the scalar path itself uses floats: the calibration coefficients
and the optimizer-transient fraction, reproduced operation-for-operation)
so the columnar path is BYTE-IDENTICAL to per-cell ``planner.check`` —
asserted cell-by-cell in tests/test_batch.py + tests/test_stages.py and
on 100k+-cell grids by ``benchmarks/sweep_throughput.py --verify``.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core import factors as F
from repro.core import planner as PL
from repro.core import predictor as PR
from repro.core import sweep as SW
from repro.core.spec import FULL_TRAIN, dtype_bytes
from repro.mesh_ctx import CONTEXT_AXIS, PIPE_AXIS

I64 = np.int64

# Optional accelerated shard-factor twin (jax fori / pallas kernel),
# installed by ``repro.kernels.shard_factor.use_backend`` — None means
# the numpy reference below runs.
_shard_factor_impl = None

# Optional accelerated segmented-cummax twin for the liveness assembly
# (jax / pallas kernel over the event axis), installed by
# ``repro.kernels.segmented_cummax.use_backend`` — None means the numpy
# reference in ``liveness_peak_batch`` runs.
_liveness_peak_impl = None


def liveness_peak_batch(deltas: np.ndarray) -> np.ndarray:
    """Per-cell interval-overlap peak of an event-delta stack.

    ``deltas`` is ``(n_events, n_cells)`` int64 — each row the contraction
    of one event's ±1 component coefficients (``core.liveness``) against
    the component columns.  The peak is the max over running event-axis
    prefix sums (a segmented cummax: cumsum along events, max-reduce),
    exactly ``liveness.replay``'s ``max(prefixes)`` per cell."""
    if _liveness_peak_impl is not None:
        return np.asarray(_liveness_peak_impl(deltas), I64)
    return np.cumsum(deltas, axis=0).max(axis=0)


def _liveness_deltas(kind: str, comps: dict, m: int) -> np.ndarray:
    """Event-delta stack for one pipeline stage: program delta matrix
    (cell-independent) contracted against the stage's component columns
    (missing / None components contribute 0, mirroring replay())."""
    from repro.core import liveness as LV
    prog = LV.compile_program(kind)
    deltas = np.zeros((prog.n_events, m), I64)
    for ei, row in enumerate(prog.delta_matrix()):
        for ci, coef in enumerate(row):
            if coef:
                col = comps.get(LV.COMPONENTS[ci])
                if col is not None:
                    deltas[ei] += coef * np.asarray(col, I64)
    return deltas


# ---------------------------------------------------------------------------
# vectorized shard resolution
# ---------------------------------------------------------------------------


def batch_shard_factor(dims, axes, sizes: dict, rules: dict,
                       extra=()) -> np.ndarray:
    """Exact broadcast twin of :func:`repro.mesh_ctx.shard_factor`.

    ``dims`` entries and ``sizes`` values may be ints or broadcastable
    int64 arrays; the result has the full broadcast shape.  The greedy
    axis assignment of ``mesh_ctx.assign_axes`` (divisibility checks,
    one-use-per-axis, FSDP/ZeRO ``extra`` pass, the ``layers`` stack-dim
    exclusion, the never-shard ``pipe`` axis) is transliterated with
    per-cell boolean masks.

    Mesh axes absent from a given mesh may be supplied as size-1 entries:
    a size-1 axis multiplies every factor by 1 and never changes another
    axis's divisibility, so the result equals the scalar path's
    skip-missing behaviour (property-tested in tests/test_batch.py).
    """
    if _shard_factor_impl is not None:
        return _shard_factor_impl(dims, axes, sizes, rules, extra)
    arrs = [np.asarray(d, I64) for d in dims]
    svals = {a: np.asarray(v, I64) for a, v in sizes.items()}
    shape = np.broadcast_shapes(*(a.shape for a in arrs),
                                *(v.shape for v in svals.values()))
    # a size-1 axis multiplies every factor by 1 and can never block a
    # later dim (marking it "used" only matters to another x1 attempt),
    # so all-ones columns — e.g. the expert/context padding of meshes
    # without those axes — are skipped outright
    live = {a for a, v in svals.items() if np.any(v > 1)}
    one = np.ones((), I64)
    totals = [one] * len(arrs)         # per-dim applied shard product
    denom = one
    used: dict[str, np.ndarray] = {}
    for i, ax in enumerate(axes):
        if not ax:
            continue
        for a in rules.get(ax, ()):
            if a == PIPE_AXIS or a not in live:
                continue
            ok = arrs[i] % (totals[i] * svals[a]) == 0
            prev = used.get(a)
            if prev is not None:
                ok = ok & ~prev
            totals[i] = np.where(ok, totals[i] * svals[a], totals[i])
            denom = np.where(ok, denom * svals[a], denom)
            used[a] = ok if prev is None else (prev | ok)
    for a in extra:
        if a == PIPE_AXIS or a not in live:
            continue
        prev = used.get(a)
        avail = ~prev if prev is not None else np.ones((), bool)
        assigned = np.zeros((), bool)
        for i in range(len(arrs)):
            # never FSDP/ZeRO-shard the scan-stack dim (see mesh_ctx)
            if axes[i] == "layers":
                continue
            ok = avail & ~assigned \
                & (arrs[i] % (totals[i] * svals[a]) == 0)
            totals[i] = np.where(ok, totals[i] * svals[a], totals[i])
            denom = np.where(ok, denom * svals[a], denom)
            assigned = assigned | ok
        used[a] = assigned if prev is None else (prev | assigned)
    return np.broadcast_to(denom, shape)


def eval_term_batch(spec: F.TermSpec, env: dict, sizes: dict,
                    rules: dict) -> np.ndarray:
    """Batch twin of :func:`repro.core.factors.eval_term`: same
    ``mult * prod(dims) * nbytes // max(denom, 1)`` integer arithmetic,
    broadcast over the knob columns in ``env`` and the mesh ``sizes``."""
    dims = tuple(env[d] if isinstance(d, str) else d for d in spec.dims)
    denom = batch_shard_factor(dims, spec.axes, sizes, rules)
    q = np.asarray(spec.mult * spec.nbytes, I64)
    for d in dims:
        q = q * np.asarray(d, I64)
    return q // np.maximum(denom, 1)


# ---------------------------------------------------------------------------
# grid -> column arrays
# ---------------------------------------------------------------------------


@dataclass
class CellColumns:
    """Structure-of-arrays twin of ``SweepGrid.cells()``: the exact same
    cells in the exact same order, as int64 code columns into the small
    per-axis value tables instead of one SweepCell object per cell."""

    n: int
    arches: tuple
    chips: tuple
    meshes: tuple                   # of dict
    opts: tuple                     # raw (may contain None)
    offs: tuple                     # offload-optimizer knob values (bool)
    remats: tuple                   # raw (may contain None)
    scheds: tuple                   # pipeline schedules ("1f1b"/"gpipe")
    mbs: tuple                      # pipeline microbatch counts
    serves: tuple                   # Optional[ServeSpec] per combo
    pairs: tuple                    # (grad_accum, global_batch), enum order
    seqs: tuple
    kind: str
    backend: str
    # per-cell code columns (int64)
    arch_c: np.ndarray
    chip_c: np.ndarray
    mesh_c: np.ndarray
    opt_c: np.ndarray
    off_c: np.ndarray
    remat_c: np.ndarray
    sched_c: np.ndarray
    mb_c: np.ndarray
    srv_c: np.ndarray
    pair_c: np.ndarray
    seq_c: np.ndarray
    # per-cell knob values (int64)
    accum: np.ndarray
    gb: np.ndarray
    seq: np.ndarray
    micro: np.ndarray


def build_columns(grid: "SW.SweepGrid") -> CellColumns:
    """Lower a grid to code columns.  Mirrors ``SweepGrid.cells()``:
    arch -> chip -> mesh -> optimizer -> offload -> remat -> schedule ->
    microbatch -> serve -> accum -> batch -> seq, innermost fastest, with
    non-divisible (batch, accum) pairs dropped."""
    arches = tuple(SW.normalize_arch(a) for a in SW._seq(grid.arch))
    chips = tuple(SW._seq(grid.chip))
    meshes = tuple(grid.meshes())
    opts = tuple(SW._seq(grid.optimizers))
    offs = tuple(grid.offloads())
    remats = tuple(SW._seq(grid.remats))
    scheds = tuple(grid.check_schedules())
    mbs = tuple(int(m) for m in SW._seq(grid.microbatches))
    serves = tuple(grid.serve_specs())
    pairs = tuple((int(a), int(g)) for a in SW._seq(grid.grad_accums)
                  for g in SW._seq(grid.global_batches) if not g % a)
    seqs = tuple(int(s) for s in SW._seq(grid.seq_lens))

    sizes = [len(arches), len(chips), len(meshes), len(opts), len(offs),
             len(remats), len(scheds), len(mbs), len(serves), len(pairs),
             len(seqs)]
    n = math.prod(sizes)
    if n == 0:
        z = np.zeros(0, I64)
        return CellColumns(0, arches, chips, meshes, opts, offs, remats,
                           scheds, mbs, serves, pairs, seqs, grid.kind,
                           grid.backend,
                           z, z, z, z, z, z, z, z, z, z, z, z, z, z, z)
    # code column i cycles 0..s_i-1 with period inner_i (the product of
    # the axes to its right): repeat+tile is a pair of memcpy-shaped ops
    # instead of the old idx%s / idx//=s passes over the full column
    codes = []
    inner = 1
    for s in reversed(sizes):
        if s == 1:
            codes.append(np.zeros(n, I64))
        else:
            codes.append(np.tile(np.repeat(np.arange(s, dtype=I64), inner),
                                 n // (s * inner)))
        inner *= s
    (seq_c, pair_c, srv_c, mb_c, sched_c, remat_c, off_c, opt_c, mesh_c,
     chip_c, arch_c) = codes
    accum = np.array([p[0] for p in pairs], I64)[pair_c]
    gb = np.array([p[1] for p in pairs], I64)[pair_c]
    seq = np.array(seqs, I64)[seq_c]
    micro = np.array(mbs, I64)[mb_c]
    return CellColumns(n, arches, chips, meshes, opts, offs, remats,
                       scheds, mbs, serves, pairs, seqs, grid.kind,
                       grid.backend,
                       arch_c, chip_c, mesh_c, opt_c, off_c, remat_c,
                       sched_c, mb_c, srv_c, pair_c, seq_c, accum, gb,
                       seq, micro)


# ---------------------------------------------------------------------------
# lazy result store
# ---------------------------------------------------------------------------


@dataclass
class ColumnarResults:
    """Array-backed sweep verdicts; ``result(i)`` materializes one
    :class:`~repro.core.sweep.SweepResult` identical to the cell path's."""

    n: int
    kind: str
    backend: str
    arch_names: tuple
    chip_names: tuple
    meshes: tuple                    # of dict
    n_chips_by_mesh: np.ndarray
    opt_names: tuple                 # resolved (never None)
    remat_names: tuple               # resolved
    sched_names: tuple
    arch_c: np.ndarray
    chip_c: np.ndarray
    mesh_c: np.ndarray
    opt_c: np.ndarray                # codes into opt_names
    remat_c: np.ndarray              # codes into remat_names
    sched_c: np.ndarray              # codes into sched_names
    microbatches: np.ndarray
    grad_accum: np.ndarray
    global_batch: np.ndarray
    seq_len: np.ndarray
    peak_bytes: np.ndarray
    budget_bytes: np.ndarray
    fits: np.ndarray                 # bool
    # serving-fleet axis + peak-stage serve provenance (all-zero /
    # single-None on grids without active serve knobs)
    serves: tuple = (None,)
    srv_c: Optional[np.ndarray] = None
    pool_bytes: Optional[np.ndarray] = None
    draft_bytes: Optional[np.ndarray] = None
    hit_saved_bytes: Optional[np.ndarray] = None
    # Eq.1 offload-tier axis + peak-stage host-optimizer provenance
    offs: tuple = (False,)
    off_c: Optional[np.ndarray] = None
    offload_bytes: Optional[np.ndarray] = None
    # liveness assembly: winning stage's legacy - liveness overestimate
    # (None on legacy-assembly runs — zero extra work there)
    overlap_slack_bytes: Optional[np.ndarray] = None

    @property
    def n_chips(self) -> np.ndarray:
        return self.n_chips_by_mesh[self.mesh_c]

    def result(self, i: int) -> "SW.SweepResult":
        return SW.SweepResult(
            arch=self.arch_names[self.arch_c[i]],
            chip=self.chip_names[self.chip_c[i]],
            mesh_shape=dict(self.meshes[self.mesh_c[i]]),
            n_chips=int(self.n_chips_by_mesh[self.mesh_c[i]]),
            optimizer=self.opt_names[self.opt_c[i]],
            remat=self.remat_names[self.remat_c[i]],
            schedule=self.sched_names[self.sched_c[i]],
            microbatches=int(self.microbatches[i]),
            grad_accum=int(self.grad_accum[i]),
            global_batch=int(self.global_batch[i]),
            seq_len=int(self.seq_len[i]),
            kind=self.kind, backend=self.backend,
            serve=None if self.srv_c is None
            else self.serves[self.srv_c[i]],
            pool_bytes=0 if self.pool_bytes is None
            else int(self.pool_bytes[i]),
            draft_bytes=0 if self.draft_bytes is None
            else int(self.draft_bytes[i]),
            hit_saved_bytes=0 if self.hit_saved_bytes is None
            else int(self.hit_saved_bytes[i]),
            offload=False if self.off_c is None
            else bool(self.offs[self.off_c[i]]),
            offload_bytes=0 if self.offload_bytes is None
            else int(self.offload_bytes[i]),
            overlap_slack_bytes=0 if self.overlap_slack_bytes is None
            else int(self.overlap_slack_bytes[i]),
            peak_bytes=int(self.peak_bytes[i]),
            budget_bytes=int(self.budget_bytes[i]),
            fits=bool(self.fits[i]), prediction=None)

# ---------------------------------------------------------------------------
# per-arch / per-stage component tables
# ---------------------------------------------------------------------------


def _act_entries(row) -> list:
    """(name, ActTerm) entries with the exact dict semantics of
    ``factors.layer_act_terms`` (keyed by name, last value wins, first
    insertion order)."""
    d = {}
    for t in row.layer.acts:
        d[t.name] = t
    return list(d.items())


_DIM_TOKENS = {"B": "mb", "S": "seq", "T": "enc"}


def _sym_dims(term) -> tuple:
    """ActTerm shape -> TermSpec-style symbolic dims."""
    return tuple(_DIM_TOKENS[d] if isinstance(d, str) else int(d)
                 for d in term.shape)


def _resolve_dims(dims, env) -> tuple:
    return tuple(env[d] if isinstance(d, str) else d for d in dims)


def _dims_prod(dims) -> np.ndarray:
    q = np.asarray(1, I64)
    for d in dims:
        q = q * np.asarray(d, I64)
    return q


def _knob_env(cfg, cols: CellColumns, pp: int) -> dict:
    """Int64 knob columns over the grid's unique
    (microbatches, accum, batch, seq) tuples for one pipeline degree —
    the batch twin of ``factors.term_env`` (whose ``mb`` is the pipeline
    micro-batch) plus the derived columns the composition needs.

    Microbatches only split the batch when there is a pipeline to fill
    (``PredictContext.eff_microbatches``); pp==1 / serve groups collapse
    the microbatch axis entirely (``_expanded`` False) so their tables
    are not built ``len(microbatches)`` times over identical columns —
    the caller indexes them with the reduced (pair, seq) code.

    On serve kinds with any active serving-fleet spec the T axis expands
    over (serve, pair, seq) instead — mutually exclusive with the train
    microbatch expansion, because ``planner.check_serve`` rejects active
    serve knobs on train kinds up front — and the env grows the paged-KV
    ``pool_tok`` column (plus its hit-rate-0 twin for the hit-savings
    delta), computed per (seq, serve) through the SAME
    ``repro.serve.pool.pool_tokens`` exact-integer ledger the scalar
    ``factors.term_env`` calls."""
    from repro.models.transformer import LOSS_CHUNK
    n_pairs, n_seq = len(cols.pairs), len(cols.seqs)
    accum_1 = np.repeat(np.array([p[0] for p in cols.pairs], I64), n_seq)
    gb_1 = np.repeat(np.array([p[1] for p in cols.pairs], I64), n_seq)
    seq_1 = np.tile(np.array(cols.seqs, I64), n_pairs)
    serves = cols.serves
    serve_on = cols.kind != "train" \
        and any(s is not None for s in serves)
    expanded = pp > 1 and cols.kind == "train"
    if expanded:
        n_m = len(cols.mbs)
        accum_t = np.tile(accum_1, n_m)
        gb_t = np.tile(gb_1, n_m)
        seq_t = np.tile(seq_1, n_m)
        micro_t = np.repeat(np.array(cols.mbs, I64), n_pairs * n_seq)
        eff_m = np.maximum(micro_t, 1)       # PredictContext.eff_microbatches
    elif serve_on:
        n_srv = len(serves)
        accum_t = np.tile(accum_1, n_srv)
        gb_t = np.tile(gb_1, n_srv)
        seq_t = np.tile(seq_1, n_srv)
        srv_t = np.repeat(np.arange(n_srv, dtype=I64), n_pairs * n_seq)
        eff_m = np.ones_like(gb_t)
    else:
        accum_t, gb_t, seq_t = accum_1, gb_1, seq_1
        eff_m = np.ones_like(gb_t)
    mb_t = np.maximum(np.maximum(gb_t // np.maximum(accum_t, 1), 1)
                      // eff_m, 1)           # PredictContext.pp_micro_batch
    gb_in = np.maximum(gb_t // eff_m, 1)     # _input_bytes batch dim
    if cfg.encdec:
        ratio = cfg.encdec.enc_seq_ratio
        # exact Python int(seq * ratio), as make_context computes it
        enc_t = np.array([int(s * ratio) for s in seq_t.tolist()], I64)
    else:
        enc_t = np.zeros(len(seq_t), I64)
    if serve_on:
        import dataclasses
        from repro.serve.pool import pool_tokens
        seq_l, srv_l = seq_t.tolist(), srv_t.tolist()
        pool_tok = np.array([pool_tokens(s, serves[i])
                             for s, i in zip(seq_l, srv_l)], I64)
        nohit = [None if sp is None else dataclasses.replace(sp, hit_bp=0)
                 for sp in serves]
        pool_tok0 = np.array([pool_tokens(s, nohit[i])
                              for s, i in zip(seq_l, srv_l)], I64)
        active_t = np.array([serves[i] is not None for i in srv_l], bool)
    else:
        srv_t = np.zeros(len(seq_t), I64)
        pool_tok = pool_tok0 = seq_t             # neutral: pool_tok == slen
        active_t = np.zeros(len(seq_t), bool)
    env = {"mb": mb_t, "gb": gb_t, "seq": seq_t, "enc": enc_t,
           "slen": seq_t,                      # make_context: max_len=seq
           "chunk": np.minimum(LOSS_CHUNK, seq_t),
           "qc": np.minimum(F.FLASH_CHUNK, seq_t),
           "tok_cross": np.where(enc_t > 0, enc_t, seq_t),
           "cache_mult": 3 if (cols.backend == "cpu"
                               and cols.kind == "decode") else 1,
           "pool_tok": pool_tok,
           # derived (not TermSpec dims)
           "_pool_tok0": pool_tok0, "_srv_t": srv_t, "_active_t": active_t,
           "_eff_m": eff_m, "_gb_in": gb_in, "_expanded": expanded,
           "_serve_expanded": serve_on}
    return env


@dataclass
class _StageTables:
    """Component-group tables for one (arch, pipeline stage) over
    (pp-group meshes x knob tuples)."""

    static_sum: np.ndarray          # (n_mesh, n_opt, n_off, 2) [cls: 2/4]
    opt_trans: np.ndarray           # (n_mesh, n_opt, n_off)
    static_scaled: Optional[np.ndarray]   # profile-scaled static group
    saved: np.ndarray               # (n_remat_eval, n_mesh, T)
    transient: np.ndarray           # (n_mesh, T)
    loss: np.ndarray                # (n_mesh, T)
    inputs: np.ndarray              # (n_mesh, T)
    cache: np.ndarray               # (n_mesh, T)
    boundary: np.ndarray            # (n_mesh, T)
    embed: int
    # out-copy split of the static group for the liveness assembly:
    # static_sum folds param + out_copy + opt + grad together, but the
    # liveness base component excludes the out_copy (it is live only in
    # the optimizer-update window) — stored separately so base can be
    # recovered as static_sum - outcopy byte-exactly
    outcopy: np.ndarray             # (n_mesh,)
    outcopy_scaled: Optional[np.ndarray]  # (n_mesh,) profile-scaled
    # serving-fleet tables (None unless the env is serve-expanded, so
    # non-serve grids pay zero extra gathers in the composition)
    pool: Optional[np.ndarray] = None         # (n_mesh, T) paged-KV pool
    pool_saved: Optional[np.ndarray] = None   # prefix-hit savings info
    draft: Optional[np.ndarray] = None        # first stage only
    # Eq.1 offload tier: host-resident optimizer bytes per offload flag
    # (None on grids without the knob — zero gathers in the composition)
    host_opt: Optional[np.ndarray] = None     # (n_mesh, n_opt, n_off)


def _stage_tables(cfg, model, rows, rules, rep_ctx,
                  cols: CellColumns, env: dict, profile,
                  opt_res: tuple, remat_eval: tuple,
                  mesh_ids, stage: int, pp: int,
                  drafts: Optional[dict] = None) -> _StageTables:
    """Tables for ONE pipeline stage's rows over the meshes in
    ``mesh_ids`` (the whole model when ``pp == 1``) — the columnar twin
    of ``compute_static`` / ``compute_acts`` / ``compute_overheads`` on
    that stage (the stash multiplier is applied by the caller)."""
    kind, backend = cols.kind, cols.backend
    first, last = stage == 0, stage == pp - 1
    meshes = [cols.meshes[i] for i in mesh_ids]
    n_mesh = len(meshes)
    T = len(env["mb"])
    axes_names = sorted({a for m in meshes for a in m})
    sizes1 = {a: np.array([m.get(a, 1) for m in meshes], I64)
              for a in axes_names}
    sizes2 = {a: v[:, None] for a, v in sizes1.items()}
    shape2 = (n_mesh, T)
    full = lambda v: np.broadcast_to(np.asarray(v, I64), shape2)
    # context-parallel gate: the ring-attention send/recv transient
    # exists only on meshes whose `context` axis exceeds 1 (the scalar
    # twin gates on ctx.cp > 1 in factors._ring_bytes)
    cp_gt1 = (sizes1[CONTEXT_AXIS] > 1)[:, None] \
        if CONTEXT_AXIS in sizes1 else np.zeros((n_mesh, 1), bool)

    def ring_term(r):
        rspec = F.ring_kv_spec(r)
        if rspec is None or kind == "decode" or not cp_gt1.any():
            return 0
        ring = np.broadcast_to(
            eval_term_batch(rspec, env, sizes2, rules), shape2)
        return np.where(cp_gt1, ring, 0)

    # -- static group (params / grads / optimizer states / output copy) --
    train = kind == "train"
    param_arr = np.zeros(n_mesh, I64)
    outcopy_arr = np.zeros(n_mesh, I64)
    grad_arr = np.zeros((2, n_mesh), I64)          # cls: eff_grad 2 / 4
    opt_arr = np.zeros((len(opt_res), n_mesh), I64)
    p_extra = ("data",) if cfg.fsdp else ()
    for r in rows:
        row_param = np.zeros(n_mesh, I64)
        for p in r.layer.params.values():
            shape, axes = F._stacked(p, r)
            pden = batch_shard_factor(shape, axes, sizes1, rules, p_extra)
            row_param = row_param + p.nbytes * r.repeat // pden
            if train and r.trainable:
                nsize = p.size * r.repeat
                grad_arr[0] += nsize * 2 // pden
                grad_arr[1] += nsize * 4 // pden
                # ZeRO: opt states always shard over data on top of TP
                oden = pden if cfg.fsdp else batch_shard_factor(
                    shape, axes, sizes1, rules, ("data",))
                rep_o = 1 if r.scanned else r.repeat
                for oi, oname in enumerate(opt_res):
                    ob = F.opt_bytes_for(p, shape, oname,
                                         oname != "adafactor")
                    opt_arr[oi] += ob * rep_o // oden
        param_arr += row_param
        if train and r.trainable:
            outcopy_arr += row_param
    # Eq.1 offload tier: per offload flag the resident optimizer bytes
    # are either the full state (off) or the double-buffered staging
    # window over it (on), with the displaced total recorded as
    # host_opt.  Per-element ints through factors.offload_staged_bytes
    # so staged values match the scalar path byte-for-byte.
    offs = cols.offs
    n_off = len(offs)
    # vectorized offload_staged_bytes: same 2 * ceil(o / OFFLOAD_BUCKETS)
    # exact-int expression, broadcast over (mesh, opt, off)
    opt_dev = opt_arr.T[:, :, None]                   # (n_mesh, n_opt, 1)
    staged = 2 * (-(-opt_dev // F.OFFLOAD_BUCKETS))
    off_mask = np.array(offs, bool)[None, None, :]
    opt_eff = np.where(off_mask, staged,
                       np.broadcast_to(opt_dev,
                                       (n_mesh, len(opt_res), n_off)))
    host_opt = None
    if train and any(offs):
        host_opt = np.zeros((n_mesh, len(opt_res), n_off), I64)
        for fi, off in enumerate(offs):
            if off:
                host_opt[:, :, fi] = opt_arr.T
    static_sum = (param_arr + outcopy_arr)[:, None, None, None] \
        + opt_eff[:, :, :, None] + grad_arr.T[:, None, None, :]
    frac = rep_ctx.opt_transient_frac
    if frac:
        # float64 multiply + truncation toward zero, elementwise — the
        # vector twin of the scalar ``int(frac * int(opt_eff))``
        opt_trans = (frac * opt_eff.astype(np.float64)).astype(I64)
    else:
        opt_trans = np.zeros((n_mesh, len(opt_res), n_off), I64)
    static_scaled = None
    outcopy_scaled = None
    if profile is not None:
        c_s = profile.coef("static")
        # np.rint is round-half-even, matching the scalar path's
        # ``int(round(v * c_s))`` per static term
        sc = lambda v: np.rint(np.asarray(v, np.float64)
                               * c_s).astype(I64)
        outcopy_scaled = sc(outcopy_arr)
        static_scaled = (sc(param_arr) + outcopy_scaled
                         )[:, None, None, None] \
            + sc(opt_eff)[:, :, :, None] \
            + sc(grad_arr.T)[:, None, None, :]

    # -- activation group (saved-for-backward + worst transient) ---------
    zeros2 = np.zeros(shape2, I64)
    saved_stack = np.zeros((len(remat_eval), n_mesh, T), I64)
    if kind == "train":
        worst = zeros2
        blocks: dict = {}
        for r in rows:
            entries = _act_entries(r)
            if not entries:
                continue
            saved_vals, trans_vals, by_name = [], [], {}
            for name, t in entries:
                dims = _resolve_dims(_sym_dims(t), env)
                taxes = t.axes if t.axes else (None,) * len(dims)
                denom = np.maximum(
                    batch_shard_factor(dims, taxes, sizes2, rules), 1)
                q = _dims_prod(dims)
                sv = q * F.eff_act_nbytes(dtype_bytes(t.dtype), rep_ctx,
                                          True) // denom
                tv = q * F.eff_act_nbytes(dtype_bytes(t.dtype), rep_ctx,
                                          False) // denom
                saved_vals.append(sv)
                trans_vals.append(tv)
                by_name[name] = sv
            S_full = sum(saved_vals)
            T_full = sum(trans_vals)
            S_dots = sum((v for t, v in zip(r.layer.acts, saved_vals)
                          if F._is_dot_term(t)), np.asarray(0, I64))
            first_act = r.layer.acts[0]
            S_block = by_name.get(first_act.name) \
                if (first_act.name.endswith(".in")
                    and r.layer.kind in ("rmsnorm", "layernorm")) else None
            inv = r.layer.meta.get("invocation_repeat")
            if r.trainable:
                for ri, rname in enumerate(remat_eval):
                    if inv:
                        saved_stack[ri] += S_full * inv
                    elif (not r.scanned) or rname == "none":
                        saved_stack[ri] += S_full * r.repeat
                    elif rname == "dots":
                        saved_stack[ri] += S_dots * r.repeat
                    elif S_block is not None:
                        saved_stack[ri] += S_block * r.repeat
            tspec = F.flash_tile_spec(r)
            tile = 0 if tspec is None \
                else eval_term_batch(tspec, env, sizes2, rules)
            ring = ring_term(r)
            t_row = 2 * T_full + 2 * tile + ring if r.trainable \
                else T_full + tile + ring
            if r.scanned:
                blocks[r.module_path] = blocks.get(r.module_path, 0) + t_row
            else:
                worst = np.maximum(worst, t_row)
        bmax = zeros2
        for v in blocks.values():
            bmax = np.maximum(bmax, v)
        transient = np.maximum(worst, bmax)
    elif kind == "prefill":
        blocks = {}
        for r in rows:
            if not r.scanned:
                continue
            t_row = np.asarray(0, I64)
            entries = _act_entries(r)
            if entries:
                T_full = np.asarray(0, I64)
                for name, t in entries:
                    dims = _resolve_dims(_sym_dims(t), env)
                    taxes = t.axes if t.axes else (None,) * len(dims)
                    denom = np.maximum(
                        batch_shard_factor(dims, taxes, sizes2, rules), 1)
                    T_full = T_full + _dims_prod(dims) \
                        * F.eff_act_nbytes(dtype_bytes(t.dtype), rep_ctx,
                                           False) // denom
                tspec = F.flash_tile_spec(r)
                tile = 0 if tspec is None \
                    else eval_term_batch(tspec, env, sizes2, rules)
                t_row = T_full + tile + ring_term(r)
            blocks[r.module_path] = blocks.get(r.module_path, 0) + t_row
        transient = zeros2
        for v in blocks.values():
            transient = np.maximum(transient, v)
    else:                                           # decode
        transient = zeros2
        for group in PR.decode_transient_groups(rows):
            t = sum(eval_term_batch(s, env, sizes2, rules) for s in group)
            transient = np.maximum(transient, t)

    # -- overhead group (loss head, inputs, caches, boundary buffers) ----
    if last:
        loss = full(sum(eval_term_batch(s, env, sizes2, rules)
                        for s in PR.loss_specs(cfg, kind)))
    else:
        loss = full(0)
    pool = pool_saved = draft = None
    if kind == "train":
        cache = full(0)
    elif not env["_serve_expanded"]:
        cache = full(sum((eval_term_batch(s, env, sizes2, rules)
                          for s in PR.cache_specs(rows)),
                         np.asarray(0, I64)))
    else:
        # paged-KV split (scalar twin: predictor._cache_bytes /
        # _pool_terms on this stage's rows): the slen-growing cache terms
        # price at pool_tok tokens per sequence; serve-active cells keep
        # only the fixed remainder in cache and move the paged part to
        # the pool table, while serve=None cells (pool_tok == slen there)
        # recompose the contiguous cache exactly as fixed + paged.
        active2 = np.broadcast_to(env["_active_t"][None, :], shape2)
        fixed = full(sum((eval_term_batch(s, env, sizes2, rules)
                          for s in PR.fixed_cache_specs(rows)),
                         np.asarray(0, I64)))
        paged = full(sum((eval_term_batch(s, env, sizes2, rules)
                          for s in PR.pool_specs(rows)),
                         np.asarray(0, I64)))
        cache = np.where(active2, fixed, fixed + paged)
        pool = np.where(active2, paged, 0)
        if any(s is not None and s.hit_bp for s in cols.serves):
            env0 = dict(env)
            env0["pool_tok"] = env["_pool_tok0"]
            paged0 = full(sum((eval_term_batch(s, env0, sizes2, rules)
                               for s in PR.pool_specs(rows)),
                              np.asarray(0, I64)))
            pool_saved = np.where(active2, paged0 - paged, 0)
        else:
            pool_saved = np.zeros(shape2, I64)
        if first and drafts:
            # speculative-decode draft residency (scalar twin:
            # predictor.draft_residency_bytes): the draft's params under
            # ITS OWN rules + fsdp flag, plus its KV pool and fixed
            # caches at the cell's serve knobs — first stage only, per-T
            # masked to the cells whose spec names this draft
            draft = np.zeros(shape2, I64)
            srv_t = env["_srv_t"]
            for dname, (dcfg, drows, drules) in drafts.items():
                dmask = np.array(
                    [sp is not None and sp.draft_arch == dname
                     for sp in cols.serves], bool)[srv_t]
                if not dmask.any():
                    continue
                d_extra = ("data",) if dcfg.fsdp else ()
                dparams = np.zeros(n_mesh, I64)
                for r in drows:
                    for p in r.layer.params.values():
                        dshape, daxes = F._stacked(p, r)
                        dden = batch_shard_factor(dshape, daxes, sizes1,
                                                  drules, d_extra)
                        dparams = dparams + p.nbytes * r.repeat // dden
                dterms = full(sum(
                    (eval_term_batch(s, env, sizes2, drules)
                     for s in (PR.pool_specs(drows)
                               + PR.fixed_cache_specs(drows))),
                    np.asarray(0, I64)))
                draft = np.where(dmask[None, :],
                                 dparams[:, None] + dterms, draft)
    embed = PR.embed_gather_const(rows, backend)
    bmult = PR.boundary_mult(stage, pp, kind)
    if bmult:
        boundary = full(bmult * sum(
            eval_term_batch(s, env, sizes2, rules)
            for s in PR.boundary_specs(cfg, kind)))
    else:
        boundary = full(0)

    if first:
        from repro.configs import ShapeConfig
        gb_in, seq_t = env["_gb_in"], env["seq"]
        gs_index: dict = {}
        gs_order: list = []
        for g, s in zip(gb_in.tolist(), seq_t.tolist()):
            if (g, s) not in gs_index:
                gs_index[(g, s)] = len(gs_order)
                gs_order.append((g, s))
        t_to_gs = np.array([gs_index[(g, s)]
                            for g, s in zip(gb_in.tolist(),
                                            seq_t.tolist())], I64)
        input_gs = np.zeros((n_mesh, len(gs_order)), I64)
        for gi, (g, s) in enumerate(gs_order):
            tot = np.zeros(n_mesh, I64)
            for arr in model.batch_spec(
                    ShapeConfig("tmp", s, g, kind)).values():
                ax = ("batch",) + (None,) * (len(arr.shape) - 1)
                den = batch_shard_factor(arr.shape, ax, sizes1, rules)
                tot += math.prod(arr.shape) * arr.dtype.itemsize \
                    // np.maximum(den, 1)
            input_gs[:, gi] = tot
        inputs = input_gs[:, t_to_gs]
    else:
        inputs = full(0)

    return _StageTables(
        static_sum=static_sum, opt_trans=opt_trans,
        static_scaled=static_scaled,
        saved=np.ascontiguousarray(
            np.broadcast_to(saved_stack, (len(remat_eval),) + shape2)),
        transient=full(transient), loss=loss, inputs=inputs, cache=cache,
        boundary=boundary, embed=embed, outcopy=outcopy_arr,
        outcopy_scaled=outcopy_scaled, pool=pool, pool_saved=pool_saved,
        draft=draft, host_opt=host_opt)


def _stage_tables_jobs(cfg, model, rows, rules, rep_ctx, cols, env,
                       profile, opt_res, remat_eval, mesh_ids,
                       stage: int, pp: int, jobs: int,
                       drafts: Optional[dict] = None) -> _StageTables:
    """``_stage_tables`` with the mesh axis split over worker threads
    (order-identical results)."""
    mesh_ids = list(mesh_ids)
    if jobs <= 1 or len(mesh_ids) <= 1:
        return _stage_tables(cfg, model, rows, rules, rep_ctx, cols, env,
                             profile, opt_res, remat_eval, mesh_ids,
                             stage, pp, drafts)
    from concurrent.futures import ThreadPoolExecutor
    chunks = [c.tolist() for c in
              np.array_split(np.asarray(mesh_ids), jobs) if len(c)]
    with ThreadPoolExecutor(max_workers=len(chunks)) as ex:
        parts = list(ex.map(
            lambda ids: _stage_tables(cfg, model, rows, rules, rep_ctx,
                                      cols, env, profile, opt_res,
                                      remat_eval, ids, stage, pp, drafts),
            chunks))
    first = parts[0]
    cat = lambda pick, axis: np.concatenate(
        [pick(p) for p in parts], axis=axis)
    opt_cat = lambda pick: None if pick(first) is None \
        else cat(pick, 0)
    return _StageTables(
        static_sum=cat(lambda p: p.static_sum, 0),
        opt_trans=cat(lambda p: p.opt_trans, 0),
        static_scaled=opt_cat(lambda p: p.static_scaled),
        saved=cat(lambda p: p.saved, 1),
        transient=cat(lambda p: p.transient, 0),
        loss=cat(lambda p: p.loss, 0),
        inputs=cat(lambda p: p.inputs, 0),
        cache=cat(lambda p: p.cache, 0),
        boundary=cat(lambda p: p.boundary, 0),
        embed=first.embed,
        outcopy=cat(lambda p: p.outcopy, 0),
        outcopy_scaled=opt_cat(lambda p: p.outcopy_scaled),
        pool=opt_cat(lambda p: p.pool),
        pool_saved=opt_cat(lambda p: p.pool_saved),
        draft=opt_cat(lambda p: p.draft),
        host_opt=opt_cat(lambda p: p.host_opt))


# ---------------------------------------------------------------------------
# the columnar sweep driver
# ---------------------------------------------------------------------------


def _intern(table: dict, names: list, name: str) -> int:
    if name not in table:
        table[name] = len(names)
        names.append(name)
    return table[name]


def _draft_states(engine, cols) -> dict:
    """Speculative-decode draft states: one (cfg, rows, rules) per
    distinct draft arch on the serve axis, parsed under FULL_TRAIN
    exactly like the scalar ``predictor._draft_state`` memo."""
    from repro.launch.mesh import arch_rules
    drafts: dict = {}
    for s in cols.serves:
        if s is not None and s.draft_arch and s.draft_arch not in drafts:
            dcfg, _, drows = engine._arch_state(
                SW.normalize_arch(s.draft_arch), FULL_TRAIN)
            drafts[s.draft_arch] = (dcfg, drows,
                                    arch_rules(dcfg, cols.kind))
    return drafts


def _finalize_results(grid, cols: CellColumns, t0: float,
                      peak, pool_arr, draft_arr, hit_arr, off_arr,
                      opt_names, remat_names,
                      res_opt_c, res_remat_c,
                      slack_arr=None) -> "SW.SweepResults":
    """Assemble the SweepResults store from the per-cell peak/provenance
    columns — shared by the numpy and jax engines so both produce
    structurally identical results."""
    from repro.launch.mesh import mesh_chips
    budget = np.array([int(PL.chip_hbm(c) * grid.headroom)
                       for c in cols.chips], I64)[cols.chip_c]
    n_chips_by_mesh = np.array([mesh_chips(m) for m in cols.meshes], I64)
    columns = ColumnarResults(
        n=cols.n, kind=cols.kind, backend=cols.backend,
        arch_names=cols.arches, chip_names=cols.chips, meshes=cols.meshes,
        n_chips_by_mesh=n_chips_by_mesh,
        opt_names=tuple(opt_names), remat_names=tuple(remat_names),
        sched_names=cols.scheds,
        arch_c=cols.arch_c, chip_c=cols.chip_c, mesh_c=cols.mesh_c,
        opt_c=res_opt_c, remat_c=res_remat_c, sched_c=cols.sched_c,
        microbatches=cols.micro,
        grad_accum=cols.accum, global_batch=cols.gb, seq_len=cols.seq,
        peak_bytes=peak, budget_bytes=budget, fits=peak <= budget,
        serves=cols.serves, srv_c=cols.srv_c, pool_bytes=pool_arr,
        draft_bytes=draft_arr, hit_saved_bytes=hit_arr,
        offs=cols.offs, off_c=cols.off_c, offload_bytes=off_arr,
        overlap_slack_bytes=slack_arr)
    return SW.SweepResults(grid=grid, columns=columns,
                           elapsed_s=time.perf_counter() - t0)


def sweep_columnar(engine, grid, jobs: int = 1) -> "SW.SweepResults":
    """Evaluate every cell of ``grid`` columnarly; byte-identical to the
    per-cell path (``SweepEngine.evaluate`` per ``grid.cells()`` cell)."""
    t0 = time.perf_counter()
    # same up-front ep/cp + serve validation the cell path hits via
    # grid.cells() -> make_context -> planner.check_parallel/check_serve
    grid.check_parallel()
    grid.check_serve()
    grid.check_offload()
    grid.check_assembly()
    live_mode = grid.assembly == "liveness"
    cols = build_columns(grid)
    if cols.n == 0:
        return SW.SweepResults(grid=grid, results=[],
                               elapsed_s=time.perf_counter() - t0)
    profile = grid.profile
    n = cols.n
    n_pairs, n_seq = len(cols.pairs), len(cols.seqs)
    peak = np.zeros(n, I64)
    opt_names: list = []
    remat_names: list = []
    opt_tbl: dict = {}
    remat_tbl: dict = {}
    res_opt_c = np.zeros(n, I64)
    res_remat_c = np.zeros(n, I64)
    pp_of = np.array([int(m.get(PIPE_AXIS, 1)) for m in cols.meshes], I64)
    is_gpipe_sched = np.array([s == "gpipe" for s in cols.scheds], bool)
    from repro.launch.mesh import arch_rules
    drafts = _draft_states(engine, cols)
    pool_arr = np.zeros(n, I64)
    draft_arr = np.zeros(n, I64)
    hit_arr = np.zeros(n, I64)
    # offload provenance is train-only (check_offload rejects it on
    # serve kinds), so the serve and offload branches never both apply
    off_grp = cols.kind == "train" and any(cols.offs)
    off_arr = np.zeros(n, I64)
    slack_arr = np.zeros(n, I64) if live_mode else None
    block = n // len(cols.arches)
    for ai, arch in enumerate(cols.arches):
        sl = slice(ai * block, (ai + 1) * block)
        cfg, model, rows = engine._arch_state(arch, grid.policy)
        rules = arch_rules(cfg, cols.kind)
        opt_res = tuple(o or cfg.optimizer for o in cols.opts)
        remat_res = tuple(r or cfg.remat for r in cols.remats)
        remat_eval = tuple(dict.fromkeys(remat_res))
        remat_idx = np.array([remat_eval.index(r) for r in remat_res], I64)
        # backend-derived scalars (bf16 multipliers, opt-transient frac)
        rep_ctx = PL.make_context(
            cfg, dict(cols.meshes[0]), kind=cols.kind,
            global_batch=int(cols.gb[sl][0]), seq_len=int(cols.seq[sl][0]),
            backend=cols.backend)

        m_c = cols.mesh_c[sl]
        o_c = cols.opt_c[sl]
        f_c = cols.off_c[sl]
        t2_full = (cols.mb_c[sl] * n_pairs + cols.pair_c[sl]) * n_seq \
            + cols.seq_c[sl]
        t2_flat = cols.pair_c[sl] * n_seq + cols.seq_c[sl]
        t2_srv = (cols.srv_c[sl] * n_pairs + cols.pair_c[sl]) * n_seq \
            + cols.seq_c[sl]
        r_codes = remat_idx[cols.remat_c[sl]]
        accum_col = cols.accum[sl]
        gpipe_col = is_gpipe_sched[cols.sched_c[sl]]
        chip_off = None
        if profile is not None:
            chip_off = np.array([profile.chip_offset(c)
                                 for c in cols.chips], I64)[cols.chip_c[sl]]

        arch_peak = np.zeros(block, I64)
        arch_pool = np.zeros(block, I64)
        arch_draft = np.zeros(block, I64)
        arch_hit = np.zeros(block, I64)
        arch_off = np.zeros(block, I64)
        arch_slack = np.zeros(block, I64)
        for pp in sorted(set(pp_of.tolist())):
            mesh_ids = np.flatnonzero(pp_of == pp)
            sel = np.isin(m_c, mesh_ids)
            if not sel.any():
                continue
            env = _knob_env(cfg, cols, pp)
            plan = engine._stage_plan(arch, grid.policy, pp)
            lidx = np.full(len(cols.meshes), -1, I64)
            lidx[mesh_ids] = np.arange(len(mesh_ids), dtype=I64)
            lm = lidx[m_c[sel]]
            serve_grp = env["_serve_expanded"]
            t2 = (t2_full if env["_expanded"]
                  else t2_srv if serve_grp else t2_flat)[sel]
            osel = o_c[sel]
            fsel = f_c[sel]
            rsel = r_codes[sel]
            eff_m_cells = env["_eff_m"][t2]
            cls = ((accum_col[sel] > 1) | (eff_m_cells > 1)).astype(I64)
            gp = gpipe_col[sel]
            best = np.zeros(int(sel.sum()), I64)
            if serve_grp:
                b_pool = np.zeros_like(best)
                b_draft = np.zeros_like(best)
                b_hit = np.zeros_like(best)
            if off_grp:
                b_off = np.zeros_like(best)
            if live_mode:
                b_slack = np.zeros_like(best)
            for s, srows in enumerate(plan.stages):
                tabs = _stage_tables_jobs(
                    cfg, model, list(srows), rules, rep_ctx, cols, env,
                    profile, opt_res, remat_eval, mesh_ids, s, pp, jobs,
                    drafts)
                # schedule stash: GPipe stages hold all m microbatch
                # activation sets, 1F1B stage s holds min(pp - s, m)
                stash = np.maximum(
                    np.where(gp, eff_m_cells,
                             np.minimum(pp - s, eff_m_cells)), 1)
                saved = tabs.saved[rsel, lm, t2] * stash
                trans = tabs.transient[lm, t2]
                loss = tabs.loss[lm, t2]
                inp = tabs.inputs[lm, t2]
                cache = tabs.cache[lm, t2]
                bnd = tabs.boundary[lm, t2]
                if profile is None:
                    speak = (tabs.static_sum[lm, osel, fsel, cls]
                             + tabs.opt_trans[lm, osel, fsel]
                             + saved + trans + bnd + tabs.embed
                             + loss + inp + cache)
                else:
                    # assemble() folds embed gathers + boundary buffers +
                    # the optimizer-update transient into act_transient
                    # BEFORE the profile scales it; loss/input/cache
                    # round separately, exactly like apply()
                    speak = (tabs.static_scaled[lm, osel, fsel, cls]
                             + profile.scale_batch(saved, "act_saved")
                             + profile.scale_batch(
                                 trans + bnd + tabs.embed
                                 + tabs.opt_trans[lm, osel, fsel],
                                 "act_transient")
                             + profile.scale_batch(loss, "overhead")
                             + profile.scale_batch(inp, "overhead")
                             + profile.scale_batch(cache, "overhead")
                             + chip_off[sel])
                if serve_grp:
                    # paged pool scales with the cache group, the draft
                    # model's residency with the statics (profile.apply);
                    # the peak-stage provenance is strictly-greater like
                    # predictor.predict, so ties keep the earliest stage
                    pool = tabs.pool[lm, t2]
                    psv = tabs.pool_saved[lm, t2]
                    drf = tabs.draft[lm, t2] if tabs.draft is not None \
                        else np.zeros_like(pool)
                    if profile is not None:
                        pool = profile.scale_batch(pool, "overhead")
                        psv = profile.scale_batch(psv, "overhead")
                        drf = profile.scale_batch(drf, "static")
                    speak = speak + pool + drf
                if live_mode:
                    # liveness assembly: component columns -> event-delta
                    # stack -> segmented cummax (twin of
                    # predictor.liveness_values + liveness.replay)
                    ecol = np.full_like(trans, tabs.embed)
                    ot = tabs.opt_trans[lm, osel, fsel]
                    if profile is None:
                        comps = {
                            "base": (tabs.static_sum[lm, osel, fsel, cls]
                                     - tabs.outcopy[lm]),
                            "inputs": inp, "cache": cache, "loss": loss,
                            "saved": saved, "boundary": bnd,
                            "transient": trans, "embed": ecol,
                            "opt_transient": ot,
                            "out_copy": tabs.outcopy[lm]}
                    else:
                        # telescoped act_transient deltas (cumulative
                        # scaled prefixes in liveness.TRANSIENT_ORDER) so
                        # their sum equals the legacy group byte-exactly
                        sc_t = lambda v: profile.scale_batch(
                            v, "act_transient")
                        p1 = sc_t(ecol)
                        p2 = sc_t(ecol + bnd)
                        p3 = sc_t(ecol + bnd + trans)
                        p4 = sc_t(ecol + bnd + trans + ot)
                        comps = {
                            "base": (tabs.static_scaled[lm, osel, fsel,
                                                        cls]
                                     - tabs.outcopy_scaled[lm]
                                     + chip_off[sel]),
                            "inputs": profile.scale_batch(inp, "overhead"),
                            "cache": profile.scale_batch(cache,
                                                         "overhead"),
                            "loss": profile.scale_batch(loss, "overhead"),
                            "saved": profile.scale_batch(saved,
                                                         "act_saved"),
                            "embed": p1, "boundary": p2 - p1,
                            "transient": p3 - p2,
                            "opt_transient": p4 - p3,
                            "out_copy": tabs.outcopy_scaled[lm]}
                    if serve_grp:
                        comps["pool"] = pool
                        comps["draft"] = drf
                    lpeak = liveness_peak_batch(_liveness_deltas(
                        cols.kind, comps, best.shape[0]))
                    if not (lpeak <= speak).all():
                        raise AssertionError(
                            "liveness peak exceeded legacy peak")
                    cur = lpeak
                else:
                    cur = speak
                if serve_grp or off_grp or live_mode:
                    upd = cur > best
                    best = np.where(upd, cur, best)
                    if live_mode:
                        b_slack = np.where(upd, speak - lpeak, b_slack)
                    if serve_grp:
                        b_pool = np.where(upd, pool, b_pool)
                        b_draft = np.where(upd, drf, b_draft)
                        b_hit = np.where(upd, psv, b_hit)
                    if off_grp:
                        # host-tier provenance follows the same
                        # strictly-greater peak-stage rule: the reported
                        # offload_bytes are the winning stage's
                        # host-resident optimizer total (unscaled — host
                        # DRAM is outside the HBM profile, mirroring
                        # CalibrationProfile.apply)
                        hop = tabs.host_opt[lm, osel, fsel] \
                            if tabs.host_opt is not None \
                            else np.zeros_like(best)
                        b_off = np.where(upd, hop, b_off)
                else:
                    best = np.maximum(best, speak)
            arch_peak[sel] = best
            if serve_grp:
                arch_pool[sel] = b_pool
                arch_draft[sel] = b_draft
                arch_hit[sel] = b_hit
            if off_grp:
                arch_off[sel] = b_off
            if live_mode:
                arch_slack[sel] = b_slack
        peak[sl] = arch_peak
        pool_arr[sl] = arch_pool
        draft_arr[sl] = arch_draft
        hit_arr[sl] = arch_hit
        off_arr[sl] = arch_off
        if live_mode:
            slack_arr[sl] = arch_slack
        per_opt = np.array([_intern(opt_tbl, opt_names, o)
                            for o in opt_res], I64)
        res_opt_c[sl] = per_opt[o_c]
        per_remat = np.array([_intern(remat_tbl, remat_names, r)
                              for r in remat_res], I64)
        res_remat_c[sl] = per_remat[cols.remat_c[sl]]
    return _finalize_results(grid, cols, t0, peak, pool_arr, draft_arr,
                             hit_arr, off_arr, opt_names, remat_names,
                             res_opt_c, res_remat_c, slack_arr)
