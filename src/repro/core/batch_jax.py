"""JAX-lowered columnar engine: the jitted twin of
:func:`repro.core.batch.sweep_columnar`.

The numpy engine stays the byte-exact reference; this module re-expresses
its per-cell composition as a handful of table gathers so the O(cells)
work runs inside one jitted ``lax.scan`` over pipeline stages:

* the per-stage component tables come from the SAME host
  :func:`repro.core.batch._stage_tables` the numpy engine uses (one
  source of truth for every TermSpec / shard-factor evaluation), then
  get **folded** into compound gather tables — the saved-activation
  table absorbs the schedule stash multiplier on its knob axis, the
  static group absorbs the optimizer-update transient, the calibration
  profile's per-term-group ``rint`` scaling is applied in table space.
  Folding is exact: every fold either pre-applies an elementwise op
  that commutes with the gather (``rint(c*x)``, ``x*stash[t2]``) or
  merges tables indexed by the same code tuple (integer addition), so
  each cell's folded value is bit-equal to the numpy engine's
  gather-then-combine value;
* the composition domain drops from ``n_cells`` to
  ``n_meshes x inner`` knob tuples: the chip axis never enters the
  stage max (the calibration chip offset is a per-stage constant, so
  adding it after the max — and outside the strictly-greater peak-stage
  provenance compare — is exact), and the per-chip HBM budget is
  applied by the shared result finalizer;
* one jitted ``lax.scan`` walks the stacked per-stage tables with a
  donated carry of running ``(best, pool, draft, hit, offload)``
  buffers, reproducing the numpy loop's strictly-greater peak-stage
  provenance update; everything is int64 under
  ``jax.experimental.enable_x64`` (jax's default int32 canonicalization
  would overflow byte counts);
* folded tables are cached on the engine keyed by everything that
  determines their values (arch, policy, meshes, knob axes, profile
  hash), so re-pricing sweeps — the autopilot / planner search hot
  path — skip straight to the jitted composition.

Byte-identity to the numpy engine (and therefore to per-cell
``planner.check``) is asserted on mixed train/serve/offload grids in
tests/test_batch_jax.py and on the 9,544-cell parity set + the
124,416-cell large grid by ``benchmarks/sweep_throughput.py --verify
--engine jax``.
"""

from __future__ import annotations

import functools
import time

import numpy as np

from repro.core import batch as B
from repro.core import planner as PL
from repro.core import sweep as SW
from repro.mesh_ctx import PIPE_AXIS

I64 = np.int64


# ---------------------------------------------------------------------------
# jitted stage-scan composition
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _compose_fn():
    """Build the jitted composition once (import jax lazily so the numpy
    engine never pays for it)."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    from repro.core import liveness as LV

    def compose(carry0, tabs, idx, has_profile: bool, serve: bool,
                off: bool, assembly: str, kind: str):
        c_aff, c_b, c_ctr, c_ho, t2 = idx

        def step(carry, xs):
            best, bp, bd, bh, bo, bs = carry
            if assembly == "liveness":
                # gather the liveness component columns (the folded
                # tables already hold profile-scaled telescoped deltas
                # in calibrated mode — see _fold_stage), then unroll the
                # cell-independent event program at trace time: a
                # running sum over its delta rows whose max IS the
                # segmented cummax of core.batch.liveness_peak_batch.
                # The chip offset rides every prefix uniformly (base is
                # persistent from event 0), so the driver adding it
                # after the stage max stays exact.
                ot = jnp.take(xs["ctr"], c_ctr, axis=1) if has_profile \
                    else jnp.take(xs["otr"], c_ho, axis=1)
                comps = {
                    "base": jnp.take(xs["aff"], c_aff, axis=1),
                    "inputs": jnp.take(xs["inp"], t2, axis=1),
                    "cache": jnp.take(xs["cch"], t2, axis=1),
                    "loss": jnp.take(xs["lss"], t2, axis=1),
                    "saved": jnp.take(xs["b"], c_b, axis=1),
                    "boundary": jnp.take(xs["bd"], t2, axis=1),
                    "transient": jnp.take(xs["tr"], t2, axis=1),
                    "embed": xs["emb"],
                    "opt_transient": ot,
                    "out_copy": xs["ocp"][:, None],
                }
                if serve:
                    comps["pool"] = jnp.take(xs["pool"], t2, axis=1)
                    comps["draft"] = jnp.take(xs["drf"], t2, axis=1)
                # legacy peak = plain sum of every component (the event
                # deltas partition it), needed for the slack provenance
                speak = functools.reduce(jnp.add, comps.values())
                run = None
                peakl = None
                for row in LV.compile_program(kind).delta_matrix():
                    for ci, coef in enumerate(row):
                        name = LV.COMPONENTS[ci]
                        if coef and name in comps:
                            term = coef * comps[name]
                            run = term if run is None else run + term
                    peakl = run if peakl is None \
                        else jnp.maximum(peakl, run)
                upd = peakl > best
                best = jnp.where(upd, peakl, best)
                bs = jnp.where(upd, speak - peakl, bs)
                if serve:
                    bp = jnp.where(upd, comps["pool"], bp)
                    bd = jnp.where(upd, comps["draft"], bd)
                    bh = jnp.where(upd,
                                   jnp.take(xs["hit"], t2, axis=1), bh)
                if off:
                    bo = jnp.where(upd,
                                   jnp.take(xs["ho"], c_ho, axis=1), bo)
                return (best, bp, bd, bh, bo, bs), None
            speak = (jnp.take(xs["aff"], c_aff, axis=1)
                     + jnp.take(xs["b"], c_b, axis=1)
                     + jnp.take(xs["base"], t2, axis=1))
            if has_profile:
                speak = speak + jnp.take(xs["ctr"], c_ctr, axis=1)
            if serve:
                p = jnp.take(xs["pool"], t2, axis=1)
                d = jnp.take(xs["drf"], t2, axis=1)
                h = jnp.take(xs["hit"], t2, axis=1)
                speak = speak + p + d
                upd = speak > best
                best = jnp.where(upd, speak, best)
                bp = jnp.where(upd, p, bp)
                bd = jnp.where(upd, d, bd)
                bh = jnp.where(upd, h, bh)
            elif off:
                hop = jnp.take(xs["ho"], c_ho, axis=1)
                upd = speak > best
                best = jnp.where(upd, speak, best)
                bo = jnp.where(upd, hop, bo)
            else:
                best = jnp.maximum(best, speak)
            return (best, bp, bd, bh, bo, bs), None

        return lax.scan(step, carry0, tabs)[0]

    return jax.jit(compose, static_argnames=("has_profile", "serve",
                                             "off", "assembly", "kind"),
                   donate_argnums=(0,))


# ---------------------------------------------------------------------------
# table folding (host, exact int64 / profile-rint arithmetic)
# ---------------------------------------------------------------------------


def _fold_stage(tabs: "B._StageTables", profile, env, pp: int,
                stage: int, liveness: bool = False) -> dict:
    """Fold one stage's component tables into compound gather tables.

    Returns 2-D ``(n_lm, K)`` arrays whose flattened trailing codes the
    composition gathers with:

    * ``aff``  — static group (+ optimizer transient when unscaled),
      code ``(opt*n_off + off)*2 + cls``;
    * ``b``    — saved activations with the schedule stash folded per
      (schedule-class, remat), code ``(gpipe*n_r + remat)*T + t2``;
    * ``base`` — transient+overhead terms indexed by ``t2`` alone;
    * ``ctr``  — profile mode only: the act_transient rint group
      (transient+boundary+embed+opt_trans), code ``(opt*n_off+off)*T+t2``;
    * ``pool/drf/hit`` (serve) and ``ho`` (offload provenance).
    """
    eff_m = env["_eff_m"]
    # schedule stash per knob tuple: 1F1B stage s stashes min(pp-s, m),
    # GPipe stashes all m — folded onto the saved table's T axis
    stash = np.stack([np.maximum(np.minimum(pp - stage, eff_m), 1),
                      np.maximum(eff_m, 1)])              # (2, T)
    n_lm = tabs.transient.shape[0]
    n_r = tabs.saved.shape[0]
    T = tabs.transient.shape[1]
    sv = tabs.saved[None, :, :, :] * stash[:, None, None, :]
    out: dict = {}
    if liveness:
        # liveness assembly: keep the event-program components separate
        # instead of folding them into aff/base sums.  ``aff`` becomes
        # the persistent base (static group MINUS the out-copy, which is
        # live only in the optimizer-update window); in calibrated mode
        # tr/bd/emb/ctr hold the TELESCOPED act_transient deltas
        # (cumulative scaled prefixes in liveness.TRANSIENT_ORDER), so
        # their sum telescopes back to the legacy rint group exactly.
        if profile is None:
            aff = tabs.static_sum - tabs.outcopy[:, None, None, None]
            out["b"] = sv
            out["ocp"] = tabs.outcopy
            out["emb"] = np.asarray(tabs.embed, I64)
            out["tr"], out["bd"] = tabs.transient, tabs.boundary
            out["lss"], out["inp"] = tabs.loss, tabs.inputs
            out["cch"] = tabs.cache
            out["otr"] = np.ascontiguousarray(
                tabs.opt_trans).reshape(n_lm, -1)
        else:
            aff = tabs.static_scaled \
                - tabs.outcopy_scaled[:, None, None, None]
            out["b"] = profile.scale_batch(sv, "act_saved")
            out["ocp"] = tabs.outcopy_scaled
            e = np.asarray(tabs.embed, I64)
            p1 = profile.scale_batch(e, "act_transient")
            p2 = profile.scale_batch(e + tabs.boundary, "act_transient")
            p3 = profile.scale_batch(e + tabs.boundary + tabs.transient,
                                     "act_transient")
            ctr = profile.scale_batch(
                (tabs.transient + tabs.boundary + e)[:, None, None, :]
                + tabs.opt_trans[:, :, :, None], "act_transient")
            out["emb"] = p1
            out["bd"] = p2 - p1
            out["tr"] = p3 - p2
            out["ctr"] = np.ascontiguousarray(
                ctr - p3[:, None, None, :]).reshape(n_lm, -1)
            out["lss"] = profile.scale_batch(tabs.loss, "overhead")
            out["inp"] = profile.scale_batch(tabs.inputs, "overhead")
            out["cch"] = profile.scale_batch(tabs.cache, "overhead")
        out["aff"] = np.ascontiguousarray(aff).reshape(n_lm, -1)
        out["b"] = np.ascontiguousarray(
            out["b"].transpose(2, 0, 1, 3)).reshape(n_lm, 2 * n_r * T)
        if tabs.pool is not None:
            pool, hit = tabs.pool, tabs.pool_saved
            drf = tabs.draft if tabs.draft is not None \
                else np.zeros_like(pool)
            if profile is not None:
                pool = profile.scale_batch(pool, "overhead")
                hit = profile.scale_batch(hit, "overhead")
                drf = profile.scale_batch(drf, "static")
            out["pool"], out["hit"], out["drf"] = pool, hit, drf
        if tabs.host_opt is not None:
            out["ho"] = np.ascontiguousarray(
                tabs.host_opt).reshape(n_lm, -1)
        return out
    if profile is None:
        aff = tabs.static_sum + tabs.opt_trans[:, :, :, None]
        b = sv
        base = (tabs.transient + tabs.loss + tabs.inputs + tabs.cache
                + tabs.boundary + tabs.embed)
    else:
        aff = tabs.static_scaled
        b = profile.scale_batch(sv, "act_saved")
        out["ctr"] = profile.scale_batch(
            (tabs.transient + tabs.boundary + tabs.embed
             )[:, None, None, :]
            + tabs.opt_trans[:, :, :, None],
            "act_transient").reshape(n_lm, -1)
        base = (profile.scale_batch(tabs.loss, "overhead")
                + profile.scale_batch(tabs.inputs, "overhead")
                + profile.scale_batch(tabs.cache, "overhead"))
    out["aff"] = np.ascontiguousarray(aff).reshape(n_lm, -1)
    # (2, n_r, n_lm, T) -> (n_lm, 2*n_r*T) with (gpipe, remat) leading
    out["b"] = np.ascontiguousarray(
        b.transpose(2, 0, 1, 3)).reshape(n_lm, 2 * n_r * T)
    out["base"] = np.ascontiguousarray(base, dtype=I64)
    if tabs.pool is not None:
        pool, hit = tabs.pool, tabs.pool_saved
        drf = tabs.draft if tabs.draft is not None \
            else np.zeros_like(pool)
        if profile is not None:
            pool = profile.scale_batch(pool, "overhead")
            hit = profile.scale_batch(hit, "overhead")
            drf = profile.scale_batch(drf, "static")
        out["pool"], out["hit"], out["drf"] = pool, hit, drf
    if tabs.host_opt is not None:
        out["ho"] = np.ascontiguousarray(tabs.host_opt).reshape(n_lm, -1)
    return out


def _mesh_key(m: dict) -> tuple:
    return tuple(sorted(m.items()))


def _group_tables(engine, grid, cols, cfg, model, rows, rules, rep_ctx,
                  arch, env, profile, opt_res, remat_eval, mesh_ids,
                  pp: int, jobs: int, drafts) -> dict:
    """Folded + stage-stacked tables for one (arch, pipeline-degree)
    group, cached on the engine by everything that determines their
    values so repeated sweeps skip straight to the jitted composition."""
    from repro.calibrate.profile import profile_hash_of
    key = ("jax_tables", arch, grid.policy, cols.kind, cols.backend, pp,
           tuple(_mesh_key(cols.meshes[i]) for i in mesh_ids),
           opt_res, remat_eval, cols.offs, cols.serves, cols.pairs,
           cols.seqs, cols.mbs, profile_hash_of(profile),
           grid.assembly)
    cache = engine.__dict__.setdefault("_jax_table_cache", {})
    hit = cache.get(key)
    if hit is not None:
        return hit
    plan = engine._stage_plan(arch, grid.policy, pp)
    folded = []
    for s, srows in enumerate(plan.stages):
        tabs = B._stage_tables_jobs(
            cfg, model, list(srows), rules, rep_ctx, cols, env, profile,
            opt_res, remat_eval, mesh_ids, s, pp, jobs, drafts)
        folded.append(_fold_stage(tabs, profile, env, pp, s,
                                  liveness=grid.assembly == "liveness"))
    stacked = {k: np.stack([f[k] for f in folded])
               for k in folded[0]}
    cache[key] = stacked
    return stacked


# ---------------------------------------------------------------------------
# the jax sweep driver
# ---------------------------------------------------------------------------


def sweep_columnar_jax(engine, grid, jobs: int = 1) -> "SW.SweepResults":
    """Drop-in twin of :func:`repro.core.batch.sweep_columnar` running
    the per-cell composition under jax; byte-identical results."""
    from jax.experimental import enable_x64

    t0 = time.perf_counter()
    grid.check_parallel()
    grid.check_serve()
    grid.check_offload()
    grid.check_assembly()
    live_mode = grid.assembly == "liveness"
    cols = B.build_columns(grid)
    if cols.n == 0:
        return SW.SweepResults(grid=grid, results=[],
                               elapsed_s=time.perf_counter() - t0)
    profile = grid.profile
    n = cols.n
    n_pairs, n_seq = len(cols.pairs), len(cols.seqs)
    n_chip, n_mesh = len(cols.chips), len(cols.meshes)
    n_arch = len(cols.arches)
    n_off = len(cols.offs)
    block = n // n_arch
    inner = block // (n_chip * n_mesh)
    # inner-axis code columns: the first `inner` cells cycle every axis
    # right of the mesh axis once, and those codes repeat verbatim for
    # every (arch, chip, mesh) prefix — so the composition runs on the
    # (mesh, inner) domain and the result broadcasts over the chip axis
    o_i = cols.opt_c[:inner]
    f_i = cols.off_c[:inner]
    rm_i = cols.remat_c[:inner]
    mb_i = cols.mb_c[:inner]
    sv_i = cols.srv_c[:inner]
    pr_i = cols.pair_c[:inner]
    sq_i = cols.seq_c[:inner]
    accum_i = cols.accum[:inner]
    is_gpipe_sched = np.array([s == "gpipe" for s in cols.scheds], bool)
    gp_i = is_gpipe_sched[cols.sched_c[:inner]].astype(I64)
    t2_full_i = (mb_i * n_pairs + pr_i) * n_seq + sq_i
    t2_flat_i = pr_i * n_seq + sq_i
    t2_srv_i = (sv_i * n_pairs + pr_i) * n_seq + sq_i
    pp_of = np.array([int(m.get(PIPE_AXIS, 1)) for m in cols.meshes], I64)
    drafts = B._draft_states(engine, cols)
    off_grp = cols.kind == "train" and any(cols.offs)

    peak = np.zeros(n, I64)
    pool_arr = np.zeros(n, I64)
    draft_arr = np.zeros(n, I64)
    hit_arr = np.zeros(n, I64)
    off_arr = np.zeros(n, I64)
    slack_arr = np.zeros(n, I64) if live_mode else None
    opt_names: list = []
    remat_names: list = []
    opt_tbl: dict = {}
    remat_tbl: dict = {}
    res_opt_c = np.zeros(n, I64)
    res_remat_c = np.zeros(n, I64)
    compose = _compose_fn()
    from repro.launch.mesh import arch_rules
    for ai, arch in enumerate(cols.arches):
        sl = slice(ai * block, (ai + 1) * block)
        cfg, model, rows = engine._arch_state(arch, grid.policy)
        rules = arch_rules(cfg, cols.kind)
        opt_res = tuple(o or cfg.optimizer for o in cols.opts)
        remat_res = tuple(r or cfg.remat for r in cols.remats)
        remat_eval = tuple(dict.fromkeys(remat_res))
        remat_idx = np.array([remat_eval.index(r) for r in remat_res],
                             I64)
        r_i = remat_idx[rm_i]
        n_r = len(remat_eval)
        rep_ctx = PL.make_context(
            cfg, dict(cols.meshes[0]), kind=cols.kind,
            global_batch=int(cols.gb[sl][0]), seq_len=int(cols.seq[sl][0]),
            backend=cols.backend)
        view = lambda a: a[sl].reshape(n_chip, n_mesh, inner)
        peak_v = view(peak)
        pool_v, draft_v, hit_v, off_v = (view(pool_arr), view(draft_arr),
                                         view(hit_arr), view(off_arr))
        slack_v = view(slack_arr) if live_mode else None
        for pp in sorted(set(pp_of.tolist())):
            mesh_ids = np.flatnonzero(pp_of == pp)
            env = B._knob_env(cfg, cols, pp)
            serve_grp = env["_serve_expanded"]
            t2 = (t2_full_i if env["_expanded"]
                  else t2_srv_i if serve_grp else t2_flat_i)
            T = len(env["mb"])
            cls_i = ((accum_i > 1) | (env["_eff_m"][t2] > 1)).astype(I64)
            tabs = _group_tables(engine, grid, cols, cfg, model, rows,
                                 rules, rep_ctx, arch, env, profile,
                                 opt_res, remat_eval, mesh_ids, pp, jobs,
                                 drafts)
            n_lm = len(mesh_ids)
            c_aff = (o_i * n_off + f_i) * 2 + cls_i
            c_b = (gp_i * n_r + r_i) * T + t2
            c_ctr = (o_i * n_off + f_i) * T + t2 if profile is not None \
                else np.zeros(0, I64)
            c_ho = o_i * n_off + f_i \
                if off_grp or (live_mode and profile is None) \
                else np.zeros(0, I64)
            carry0 = tuple(np.zeros((n_lm, inner), I64)
                           for _ in range(6))
            with enable_x64():
                best, bp, bd, bh, bo, bs = compose(
                    carry0, tabs, (c_aff, c_b, c_ctr, c_ho, t2),
                    has_profile=profile is not None,
                    serve=bool(serve_grp), off=bool(off_grp),
                    assembly=grid.assembly, kind=cols.kind)
                best = np.asarray(best)
                peak_v[:, mesh_ids, :] = best
                if serve_grp:
                    pool_v[:, mesh_ids, :] = np.asarray(bp)
                    draft_v[:, mesh_ids, :] = np.asarray(bd)
                    hit_v[:, mesh_ids, :] = np.asarray(bh)
                if off_grp:
                    off_v[:, mesh_ids, :] = np.asarray(bo)
                if live_mode:
                    slack_v[:, mesh_ids, :] = np.asarray(bs)
        if profile is not None:
            # per-chip calibration offset: stage-constant, so adding it
            # after the stage max (and outside the strictly-greater
            # provenance compare, which it shifts uniformly) is exact
            chip_off = np.array([profile.chip_offset(c)
                                 for c in cols.chips], I64)
            peak_v += chip_off[:, None, None]
        per_opt = np.array([B._intern(opt_tbl, opt_names, o)
                            for o in opt_res], I64)
        res_opt_c[sl] = per_opt[cols.opt_c[sl]]
        per_remat = np.array([B._intern(remat_tbl, remat_names, r)
                              for r in remat_res], I64)
        res_remat_c[sl] = per_remat[cols.remat_c[sl]]
    return B._finalize_results(grid, cols, t0, peak, pool_arr, draft_arr,
                               hit_arr, off_arr, opt_names, remat_names,
                               res_opt_c, res_remat_c, slack_arr)
