"""Per-factor analytical equations (paper workflow steps 5-6).

For every parsed layer, four factors are computed:

* ``M_param`` — parameter bytes, divided by the layer's real shard factor
  (TP over ``model``; optionally FSDP over ``data``).
* ``M_grad``  — gradient bytes (param dtype), zero for frozen layers.  In a
  single compiled XLA train step the full (TP-sharded) gradient pytree is
  live at the end of the backward pass, so grads share the *param* shard
  factor — the ZeRO reduce-scatter changes the persistent accumulator, not
  the transient peak.
* ``M_opt``   — optimizer-state bytes (AdamW: fp32 master + m + v; 8-bit
  Adam: fp32 master + int8 m/v + block scales; Adafactor: factored second
  moment), ZeRO-sharded over ``data`` on top of the param sharding.
* ``M_act``   — activation bytes saved for backward, a function of the
  remat policy and of the training behaviour: frozen modules save nothing
  (the paper's central multimodal observation).

All equations take shard factors from the SAME axis-resolution logic the
runtime uses (``repro.mesh_ctx``), so prediction and execution cannot
disagree about sharding.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from repro.core.parser import ParsedLayer
from repro.core.spec import ActTerm, ParamSpec, dtype_bytes
from repro.mesh_ctx import DEFAULT_RULES, shard_factor

AXIS_LAYERS = "layers"


@dataclass(frozen=True)
class PredictContext:
    """Everything the factor equations need to know about the run."""

    mesh_shape: dict[str, int] = field(default_factory=dict)
    rules: dict = field(default_factory=lambda: dict(DEFAULT_RULES))
    optimizer: str = "adamw"
    zero: bool = True              # ZeRO: opt states sharded over data
    fsdp: bool = False             # params/grads sharded over data too
    remat: str = "block"
    global_batch: int = 1
    seq_len: int = 1
    enc_seq: int = 0
    kind: str = "train"            # train | prefill | decode
    max_len: int = 0               # KV-cache length for decode
    grad_accum: int = 1
    grad_dtype_bytes: int = 2      # bf16 grads
    master_fp32: bool = True       # keep fp32 master copy in optimizer
    # Oracle backend the prediction targets.  "tpu": native bf16 compute
    # (deployment prediction).  "cpu": XLA:CPU float-normalization — every
    # bf16 op is legalized to f32-with-converts and LICM hoists the
    # converts of loop-carried stacks, so saved bf16 buffers effectively
    # exist twice (bf16 + f32) at the fwd->bwd boundary and gradients
    # accumulate in f32.  Used when validating against this container's
    # compiled-memory ground truth (see DESIGN.md §2).
    backend: str = "cpu"

    @property
    def act_saved_bytes_per_bf16(self) -> int:
        return 6 if self.backend == "cpu" else 2      # bf16 + hoisted f32

    @property
    def act_transient_mult(self) -> int:
        return 2 if self.backend == "cpu" else 1      # f32 twins of bf16

    @property
    def eff_grad_bytes(self) -> int:
        if self.grad_accum > 1:
            return 4                     # fp32 cross-microbatch accumulator
        return self.grad_dtype_bytes

    # In-flight fp32 new-state stacks of the (chunked) optimizer update
    # before buffer assignment aliases them — ZeRO-sharded, so the term
    # shrinks with DP.  Coefficient calibrated on the fig2a DP sweep
    # (llava15-7b, SeqLen 1024, MBS 16) and validated on fig2b + the
    # arch sweep; see EXPERIMENTS.md §Calibration.
    OPT_UPDATE_TRANSIENT = 0.6

    @property
    def opt_transient_frac(self) -> float:
        return self.OPT_UPDATE_TRANSIENT if self.backend == "cpu" else 0.0

    @property
    def micro_batch(self) -> int:
        """Activations live per-microbatch under gradient accumulation."""
        return max(self.global_batch // max(self.grad_accum, 1), 1)

    @property
    def dp(self) -> int:
        return (self.mesh_shape.get("data", 1)
                * self.mesh_shape.get("pod", 1))


def _stacked(p: ParamSpec, row: ParsedLayer) -> tuple[tuple, tuple]:
    """Shape/axes including the scan-stack leading dim."""
    if row.scanned:
        return (row.repeat,) + tuple(p.shape), \
            (AXIS_LAYERS,) + (tuple(p.axes) if p.axes
                              else (None,) * len(p.shape))
    return tuple(p.shape), tuple(p.axes) if p.axes else (None,) * len(p.shape)


def _psharding(p: ParamSpec, row: ParsedLayer, ctx: PredictContext) -> int:
    shape, axes = _stacked(p, row)
    extra = ("data",) if ctx.fsdp else ()
    return shard_factor(shape, axes, ctx.mesh_shape, ctx.rules, extra)


# ---------------------------------------------------------------------------
# factor 1: parameters
# ---------------------------------------------------------------------------


def param_factor(row: ParsedLayer, ctx: PredictContext) -> int:
    total = 0
    for p in row.layer.params.values():
        # stacked total bytes divided by the stacked shard factor
        total += p.nbytes * row.repeat // _psharding(p, row, ctx)
    return total


# ---------------------------------------------------------------------------
# factor 2: gradients
# ---------------------------------------------------------------------------


def grad_factor(row: ParsedLayer, ctx: PredictContext) -> int:
    if not row.trainable or ctx.kind != "train":
        return 0
    total = 0
    for p in row.layer.params.values():
        # grads share the param sharding (TP / FSDP); dtype per backend
        n = p.size * row.repeat
        total += n * ctx.eff_grad_bytes // _psharding(p, row, ctx)
    return total


# ---------------------------------------------------------------------------
# factor 3: optimizer states
# ---------------------------------------------------------------------------


def opt_bytes_for(p: ParamSpec, stacked_shape: tuple, optimizer: str,
                  master_fp32: bool = True) -> int:
    """Bytes of optimizer state for one (possibly stacked) param tensor.

    Mirrors train/optimizer.py exactly: any change there must land here.
    """
    size = math.prod(stacked_shape) if stacked_shape else 1
    if optimizer == "adamw":
        return size * (4 + 4 + (4 if master_fp32 else 0))      # m, v, master
    if optimizer == "adamw8bit":
        nblk = -(-size // 256)                                 # padded blocks
        scales = 2 * nblk * 4                                  # per-block fp32
        return 2 * nblk * 256 + size * (4 if master_fp32 else 0) + scales
    if optimizer == "adafactor":
        if len(stacked_shape) >= 2:
            r = math.prod(stacked_shape[:-1])
            c = math.prod(stacked_shape[:-2]) * stacked_shape[-1]
            return 4 * (r + c)                                 # v_row + v_col
        return 4 * size                                        # full v
    raise ValueError(optimizer)


def opt_factor(row: ParsedLayer, ctx: PredictContext) -> int:
    if not row.trainable or ctx.kind != "train":
        return 0
    total = 0
    for p in row.layer.params.values():
        shape, axes = _stacked(p, row)
        rep = 1 if row.scanned else row.repeat
        extra = ("data",) if (ctx.zero or ctx.fsdp) else ()
        denom = shard_factor(shape, axes, ctx.mesh_shape, ctx.rules, extra)
        total += opt_bytes_for(p, shape, ctx.optimizer,
                               ctx.master_fp32) * rep // denom
    return total


# ---------------------------------------------------------------------------
# factor 4: activations
# ---------------------------------------------------------------------------


def _term_bytes(t: ActTerm, ctx: PredictContext, batch: int,
                saved: bool = False) -> int:
    shape = t.concrete_shape(batch, ctx.seq_len, ctx.enc_seq)
    axes = t.axes if t.axes else (None,) * len(shape)
    denom = shard_factor(shape, axes, ctx.mesh_shape, ctx.rules)
    nb = dtype_bytes(t.dtype)
    if nb == 2:                       # bf16 tensors feel the cpu-oracle
        nb = ctx.act_saved_bytes_per_bf16 if saved \
            else nb * ctx.act_transient_mult
    return math.prod(shape) * nb // max(denom, 1)


_DOT_KINDS = {"linear", "attention", "mlp", "moe", "ssm", "embedding"}


def _is_dot_term(t: ActTerm) -> bool:
    return not (t.name.endswith(".lse") or t.dtype == "int32")


def layer_act_terms(row: ParsedLayer, ctx: PredictContext,
                    batch: Optional[int] = None,
                    saved: bool = False) -> dict[str, int]:
    """Bytes of each activation tensor of ONE instance of this layer."""
    b = batch if batch is not None else ctx.micro_batch
    return {t.name: _term_bytes(t, ctx, b, saved) for t in row.layer.acts}


def act_factor_saved(row: ParsedLayer, ctx: PredictContext) -> int:
    """Activation bytes SAVED for backward across all repeats of the layer
    under the remat policy.  Frozen layers save nothing (their backward is
    dead-code-eliminated); the paper's M_act rule for multimodal models.
    """
    if ctx.kind != "train" or not row.trainable or not row.layer.acts:
        return 0
    terms = layer_act_terms(row, ctx, saved=True)
    # weight-tied python-unrolled invocations (zamba2 shared blocks): all
    # invocations' activations are saved — no scan, no remat
    inv = row.layer.meta.get("invocation_repeat")
    if inv:
        return sum(terms.values()) * inv
    if not row.scanned or ctx.remat == "none":
        return sum(terms.values()) * row.repeat
    if ctx.remat == "dots":
        keep = sum(v for t, v in zip(row.layer.acts, terms.values())
                   if _is_dot_term(t))
        return keep * row.repeat
    # remat == "block": only the scan carry is saved per iteration; it is
    # attributed to the block's first layer (its ".in" term == block input).
    first = row.layer.acts[0]
    if first.name.endswith(".in") and row.layer.kind in ("rmsnorm",
                                                         "layernorm"):
        return terms[first.name] * row.repeat
    return 0


FLASH_CHUNK = 1024


def _flash_tile_bytes(row: ParsedLayer, ctx: PredictContext) -> int:
    """fp32 probability tiles of the two-level blocked flash attention:
    (B, q_chunk, H, kv_chunk) — the dominant attention transient."""
    meta = row.layer.meta
    if row.layer.kind != "attention" or ctx.kind == "decode":
        return 0
    h = meta.get("n_heads", 1)
    qc = min(FLASH_CHUNK, ctx.seq_len)
    b = ctx.micro_batch
    denom = shard_factor((b, qc, h, qc), ("batch", "seq", "heads", None),
                         ctx.mesh_shape, ctx.rules)
    return b * qc * h * qc * 4 // max(denom, 1)


def act_factor_transient(row: ParsedLayer, ctx: PredictContext) -> int:
    """Peak transient working set of ONE instance (recomputed block during
    its backward, or plain forward for frozen modules)."""
    if not row.layer.acts:
        return 0
    total = sum(layer_act_terms(row, ctx).values())
    tiles = _flash_tile_bytes(row, ctx)
    if ctx.kind == "train" and row.trainable:
        # recomputed fwd + cotangents (+ p and ds score tiles in the
        # flash backward)
        return 2 * total + 2 * tiles
    return total + tiles
