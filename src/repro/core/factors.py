"""Per-factor analytical equations (paper workflow steps 5-6).

For every parsed layer, four factors are computed:

* ``M_param`` — parameter bytes, divided by the layer's real shard factor
  (TP over ``model``; optionally FSDP over ``data``).
* ``M_grad``  — gradient bytes (param dtype), zero for frozen layers.  In a
  single compiled XLA train step the full (TP-sharded) gradient pytree is
  live at the end of the backward pass, so grads share the *param* shard
  factor — the ZeRO reduce-scatter changes the persistent accumulator, not
  the transient peak.
* ``M_opt``   — optimizer-state bytes (AdamW: fp32 master + m + v; 8-bit
  Adam: fp32 master + int8 m/v + block scales; Adafactor: factored second
  moment), ZeRO-sharded over ``data`` on top of the param sharding.
* ``M_act``   — activation bytes saved for backward, a function of the
  remat policy and of the training behaviour: frozen modules save nothing
  (the paper's central multimodal observation).

All equations take shard factors from the SAME axis-resolution logic the
runtime uses (``repro.mesh_ctx``), so prediction and execution cannot
disagree about sharding.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from repro.core.parser import ParsedLayer
from repro.core.spec import ActTerm, ParamSpec, dtype_bytes
from repro.mesh_ctx import (CONTEXT_AXIS, DEFAULT_RULES, EXPERT_AXIS,
                            shard_factor)

AXIS_LAYERS = "layers"


# ---------------------------------------------------------------------------
# Symbolic term specs: the shared vocabulary between the scalar factor
# equations below and the columnar batch kernels (core.batch).  A TermSpec
# is one Eq.1 byte term in unevaluated form —
#
#     bytes = mult * prod(dims) * nbytes // max(shard_factor(dims), 1)
#
# where every entry of ``dims`` is either a concrete int (arch-dependent,
# cell-independent) or one of the TERM_VARS tokens resolved against an
# environment of cell knobs.  The scalar path evaluates a spec with a
# scalar env (``term_env``); the batch path evaluates the same spec with
# int64 column arrays.  Because both paths share the spec AND the shard
# resolution, they cannot drift apart.
# ---------------------------------------------------------------------------

#: env keys a symbolic dim may name.  ``mb``/``gb`` micro/global batch,
#: ``seq`` sequence length, ``enc`` encoder length, ``slen`` cache length
#: (max_len or seq), ``chunk`` loss chunk (min(LOSS_CHUNK, seq)), ``qc``
#: flash q/kv chunk (min(FLASH_CHUNK, seq)), ``tok_cross`` cross-attention
#: cache length (enc, falling back to slen), ``cache_mult`` the cpu-oracle
#: decode bf16-twin multiplier (a dimension-shaped multiplier: it scales
#: prod(dims) but carries no shardable axis), ``pool_tok`` the effective
#: paged-pool tokens per sequence (slen folded through the serve knobs —
#: block padding, utilization, prefix-cache hits, request mix; equals
#: slen exactly when no serve spec is active).
TERM_VARS = ("mb", "gb", "seq", "enc", "slen", "chunk", "qc", "tok_cross",
             "cache_mult", "pool_tok")


@dataclass(frozen=True)
class TermSpec:
    """One symbolic byte term (see module comment above)."""

    dims: tuple                    # ints and/or TERM_VARS tokens
    axes: tuple                    # logical axis names (or None) per dim
    nbytes: int                    # per-element bytes
    mult: int = 1                  # constant multiplier INSIDE the floor div


def term_env(ctx: "PredictContext") -> dict:
    """Scalar evaluation environment for TermSpec dims.  ``mb`` is the
    *pipeline* micro-batch: under pipeline parallelism only one
    microbatch's activations are in flight per term (the stash multiplier
    in ``core.stages`` accounts for the schedule's in-flight copies).

    The expert-parallel / context-parallel divisors (``ctx.ep`` /
    ``ctx.cp``) deliberately do NOT appear as env tokens: they divide
    through the shard-factor side of every TermSpec instead — the
    `experts`/`expert_buf` and `seq` logical axes map onto the `expert`
    and `context` mesh axes — so every existing spec scales with ep/cp
    automatically and the scalar and columnar paths cannot disagree on
    where the division happens."""
    from repro.models.transformer import LOSS_CHUNK
    from repro.serve.pool import pool_tokens
    slen = ctx.max_len or ctx.seq_len
    return {"mb": ctx.pp_micro_batch, "gb": ctx.global_batch,
            "seq": ctx.seq_len, "enc": ctx.enc_seq, "slen": slen,
            "chunk": min(LOSS_CHUNK, ctx.seq_len),
            "qc": min(FLASH_CHUNK, ctx.seq_len),
            "tok_cross": ctx.enc_seq or slen,
            "cache_mult": 3 if (ctx.backend == "cpu"
                                and ctx.kind == "decode") else 1,
            "pool_tok": pool_tokens(slen, ctx.serve)}


def eval_term(spec: TermSpec, env: dict, mesh_shape: dict,
              rules: dict) -> int:
    """Scalar TermSpec evaluation (the batch twin lives in core.batch)."""
    dims = tuple(env[d] if isinstance(d, str) else d for d in spec.dims)
    denom = shard_factor(dims, spec.axes, mesh_shape, rules)
    return math.prod(dims) * spec.nbytes * spec.mult // max(denom, 1)


# ---------------------------------------------------------------------------
# Optimizer-state host offload: the Eq.1 offload tier.
#
# With ``PredictContext.offload_opt`` the optimizer states live in host
# DRAM and stream through a small double-buffered device staging window
# during the (bucketed) update: the full state is cut into
# ``OFFLOAD_BUCKETS`` equal buckets and while bucket i updates on device
# bucket i+1 prefetches, so exactly TWO bucket-sized staging buffers are
# resident at the peak.  The device-side term therefore shrinks from
# ``opt_total`` to ``offload_staged_bytes(opt_total)`` and the full
# ``opt_total`` moves to the host tier, reported as
# ``PredictedMemory.offload_bytes`` (NOT part of the device peak).
#
# This helper is the SINGLE source of truth for the staging arithmetic:
# the scalar path (predictor.compute_static) and the columnar path
# (core.batch._stage_tables) both call it, in exact integer arithmetic,
# so offload cells stay byte-identical between the two paths and
# offload-off cells are untouched (the transform is only applied when
# the knob is set).
# ---------------------------------------------------------------------------

OFFLOAD_BUCKETS = 16


def offload_staged_bytes(opt_total: int) -> int:
    """Device bytes of the double-buffered streaming window over a host
    optimizer state of ``opt_total`` bytes: 2 ceil-divided buckets.
    Exact ints; monotone in ``opt_total``; 0 stays 0."""
    return 2 * (-(-int(opt_total) // OFFLOAD_BUCKETS))


def eff_act_nbytes(nbytes: int, ctx: "PredictContext", saved: bool) -> int:
    """Backend-adjusted per-element bytes of an activation tensor: bf16
    tensors feel the cpu-oracle float normalization (see PredictContext)."""
    if nbytes == 2:
        return ctx.act_saved_bytes_per_bf16 if saved \
            else nbytes * ctx.act_transient_mult
    return nbytes


@dataclass(frozen=True)
class PredictContext:
    """Everything the factor equations need to know about the run."""

    mesh_shape: dict[str, int] = field(default_factory=dict)
    rules: dict = field(default_factory=lambda: dict(DEFAULT_RULES))
    optimizer: str = "adamw"
    zero: bool = True              # ZeRO: opt states sharded over data
    fsdp: bool = False             # params/grads sharded over data too
    remat: str = "block"
    global_batch: int = 1
    seq_len: int = 1
    enc_seq: int = 0
    kind: str = "train"            # train | prefill | decode
    max_len: int = 0               # KV-cache length for decode
    # Pipeline parallelism: the mesh's `pipe` axis degree, the microbatch
    # count the batch is split into, and the schedule governing how many
    # microbatch activation sets are in flight per stage (core.stages).
    pp: int = 1
    microbatches: int = 1
    schedule: str = "1f1b"         # "1f1b" | "gpipe"
    grad_accum: int = 1
    grad_dtype_bytes: int = 2      # bf16 grads
    master_fp32: bool = True       # keep fp32 master copy in optimizer
    # Oracle backend the prediction targets.  "tpu": native bf16 compute
    # (deployment prediction).  "cpu": XLA:CPU float-normalization — every
    # bf16 op is legalized to f32-with-converts and LICM hoists the
    # converts of loop-carried stacks, so saved bf16 buffers effectively
    # exist twice (bf16 + f32) at the fwd->bwd boundary and gradients
    # accumulate in f32.  Used when validating against this container's
    # compiled-memory ground truth (see DESIGN.md §2).
    backend: str = "cpu"
    # Serving-fleet knobs (repro.serve.pool.ServeSpec) for serve kinds:
    # paged-KV block pool, prefix-cache hits, request mix, draft model.
    # Always None for train kinds and when every knob is neutral —
    # planner.make_context normalizes, so serve=None cells are
    # bit-identical to pre-serve predictions.
    serve: Optional[object] = None
    # Eq.1 offload tier (train-only; planner.make_context rejects it on
    # serve kinds): optimizer states live in host DRAM and only the
    # double-buffered ``offload_staged_bytes`` streaming window stays on
    # device; the host residency is reported as
    # ``PredictedMemory.offload_bytes`` outside the device peak.
    offload_opt: bool = False

    @property
    def act_saved_bytes_per_bf16(self) -> int:
        return 6 if self.backend == "cpu" else 2      # bf16 + hoisted f32

    @property
    def act_transient_mult(self) -> int:
        return 2 if self.backend == "cpu" else 1      # f32 twins of bf16

    @property
    def eff_grad_bytes(self) -> int:
        if self.grad_accum > 1 or self.eff_microbatches > 1:
            return 4                     # fp32 cross-microbatch accumulator
        return self.grad_dtype_bytes

    # In-flight fp32 new-state stacks of the (chunked) optimizer update
    # before buffer assignment aliases them — ZeRO-sharded, so the term
    # shrinks with DP.  Coefficient calibrated on the fig2a DP sweep
    # (llava15-7b, SeqLen 1024, MBS 16) and validated on fig2b + the
    # arch sweep; see EXPERIMENTS.md §Calibration.
    OPT_UPDATE_TRANSIENT = 0.6

    @property
    def opt_transient_frac(self) -> float:
        return self.OPT_UPDATE_TRANSIENT if self.backend == "cpu" else 0.0

    @property
    def micro_batch(self) -> int:
        """Activations live per-microbatch under gradient accumulation."""
        return max(self.global_batch // max(self.grad_accum, 1), 1)

    @property
    def eff_microbatches(self) -> int:
        """Pipeline microbatch count that actually splits the batch.

        Without a pipeline (``pp == 1``) there is nothing to fill — the
        step is the plain fused step and ``microbatches`` is inert
        (gradient accumulation already models batch splitting there);
        serve steps never split either.
        """
        if self.pp > 1 and self.kind == "train":
            return max(self.microbatches, 1)
        return 1

    @property
    def pp_micro_batch(self) -> int:
        """Per-pipeline-microbatch batch size: the batch dimension every
        in-flight activation/loss term sees."""
        return max(self.micro_batch // self.eff_microbatches, 1)

    @property
    def dp(self) -> int:
        return (self.mesh_shape.get("data", 1)
                * self.mesh_shape.get("pod", 1))

    @property
    def ep(self) -> int:
        """Expert-parallel degree: the mesh's `expert` axis.  Divides
        ONLY the MoE `experts` weight stacks and `expert_buf` dispatch
        buffers (through the rule table) — never dense layers."""
        return int(self.mesh_shape.get(EXPERT_AXIS, 1))

    @property
    def cp(self) -> int:
        """Context-parallel (ring-attention) degree: the mesh's `context`
        axis.  Divides the seq dim of train/prefill activations through
        the `seq` rule; every TermSpec with a seq-axis dim scales
        automatically.  Decode caches stay on `cache_seq` (cp is
        rejected for decode by planner.check_parallel)."""
        return int(self.mesh_shape.get(CONTEXT_AXIS, 1))


def _stacked(p: ParamSpec, row: ParsedLayer) -> tuple[tuple, tuple]:
    """Shape/axes including the scan-stack leading dim."""
    if row.scanned:
        return (row.repeat,) + tuple(p.shape), \
            (AXIS_LAYERS,) + (tuple(p.axes) if p.axes
                              else (None,) * len(p.shape))
    return tuple(p.shape), tuple(p.axes) if p.axes else (None,) * len(p.shape)


def _psharding(p: ParamSpec, row: ParsedLayer, ctx: PredictContext) -> int:
    shape, axes = _stacked(p, row)
    extra = ("data",) if ctx.fsdp else ()
    return shard_factor(shape, axes, ctx.mesh_shape, ctx.rules, extra)


# ---------------------------------------------------------------------------
# factor 1: parameters
# ---------------------------------------------------------------------------


def param_factor(row: ParsedLayer, ctx: PredictContext) -> int:
    total = 0
    for p in row.layer.params.values():
        # stacked total bytes divided by the stacked shard factor
        total += p.nbytes * row.repeat // _psharding(p, row, ctx)
    return total


# ---------------------------------------------------------------------------
# factor 2: gradients
# ---------------------------------------------------------------------------


def grad_factor(row: ParsedLayer, ctx: PredictContext) -> int:
    if not row.trainable or ctx.kind != "train":
        return 0
    total = 0
    for p in row.layer.params.values():
        # grads share the param sharding (TP / FSDP); dtype per backend
        n = p.size * row.repeat
        total += n * ctx.eff_grad_bytes // _psharding(p, row, ctx)
    return total


# ---------------------------------------------------------------------------
# factor 3: optimizer states
# ---------------------------------------------------------------------------


def opt_bytes_for(p: ParamSpec, stacked_shape: tuple, optimizer: str,
                  master_fp32: bool = True) -> int:
    """Bytes of optimizer state for one (possibly stacked) param tensor.

    Mirrors train/optimizer.py exactly: any change there must land here.
    """
    size = math.prod(stacked_shape) if stacked_shape else 1
    if optimizer == "adamw":
        return size * (4 + 4 + (4 if master_fp32 else 0))      # m, v, master
    if optimizer == "adamw8bit":
        nblk = -(-size // 256)                                 # padded blocks
        scales = 2 * nblk * 4                                  # per-block fp32
        return 2 * nblk * 256 + size * (4 if master_fp32 else 0) + scales
    if optimizer == "adafactor":
        if len(stacked_shape) >= 2:
            r = math.prod(stacked_shape[:-1])
            c = math.prod(stacked_shape[:-2]) * stacked_shape[-1]
            return 4 * (r + c)                                 # v_row + v_col
        return 4 * size                                        # full v
    raise ValueError(optimizer)


def opt_factor(row: ParsedLayer, ctx: PredictContext) -> int:
    if not row.trainable or ctx.kind != "train":
        return 0
    total = 0
    for p in row.layer.params.values():
        shape, axes = _stacked(p, row)
        rep = 1 if row.scanned else row.repeat
        extra = ("data",) if (ctx.zero or ctx.fsdp) else ()
        denom = shard_factor(shape, axes, ctx.mesh_shape, ctx.rules, extra)
        total += opt_bytes_for(p, shape, ctx.optimizer,
                               ctx.master_fp32) * rep // denom
    return total


# ---------------------------------------------------------------------------
# factor 4: activations
# ---------------------------------------------------------------------------


def _term_bytes(t: ActTerm, ctx: PredictContext, batch: int,
                saved: bool = False) -> int:
    shape = t.concrete_shape(batch, ctx.seq_len, ctx.enc_seq)
    axes = t.axes if t.axes else (None,) * len(shape)
    denom = shard_factor(shape, axes, ctx.mesh_shape, ctx.rules)
    nb = eff_act_nbytes(dtype_bytes(t.dtype), ctx, saved)
    return math.prod(shape) * nb // max(denom, 1)


_DOT_KINDS = {"linear", "attention", "mlp", "moe", "ssm", "embedding"}


def _is_dot_term(t: ActTerm) -> bool:
    return not (t.name.endswith(".lse") or t.dtype == "int32")


def layer_act_terms(row: ParsedLayer, ctx: PredictContext,
                    batch: Optional[int] = None,
                    saved: bool = False) -> dict[str, int]:
    """Bytes of each activation tensor of ONE instance of this layer."""
    b = batch if batch is not None else ctx.pp_micro_batch
    return {t.name: _term_bytes(t, ctx, b, saved) for t in row.layer.acts}


def act_factor_saved(row: ParsedLayer, ctx: PredictContext) -> int:
    """Activation bytes SAVED for backward across all repeats of the layer
    under the remat policy.  Frozen layers save nothing (their backward is
    dead-code-eliminated); the paper's M_act rule for multimodal models.
    """
    if ctx.kind != "train" or not row.trainable or not row.layer.acts:
        return 0
    terms = layer_act_terms(row, ctx, saved=True)
    # weight-tied python-unrolled invocations (zamba2 shared blocks): all
    # invocations' activations are saved — no scan, no remat
    inv = row.layer.meta.get("invocation_repeat")
    if inv:
        return sum(terms.values()) * inv
    if not row.scanned or ctx.remat == "none":
        return sum(terms.values()) * row.repeat
    if ctx.remat == "dots":
        keep = sum(v for t, v in zip(row.layer.acts, terms.values())
                   if _is_dot_term(t))
        return keep * row.repeat
    # remat == "block": only the scan carry is saved per iteration; it is
    # attributed to the block's first layer (its ".in" term == block input).
    first = row.layer.acts[0]
    if first.name.endswith(".in") and row.layer.kind in ("rmsnorm",
                                                         "layernorm"):
        return terms[first.name] * row.repeat
    return 0


FLASH_CHUNK = 1024


def flash_tile_spec(row: ParsedLayer) -> Optional[TermSpec]:
    """Symbolic fp32 probability tiles of the two-level blocked flash
    attention: (B, q_chunk, H, kv_chunk) — the dominant attention
    transient.  None for non-attention rows; callers must additionally
    gate on ``ctx.kind != "decode"``."""
    if row.layer.kind != "attention":
        return None
    h = row.layer.meta.get("n_heads", 1)
    return TermSpec(dims=("mb", "qc", h, "qc"),
                    axes=("batch", "seq", "heads", None), nbytes=4)


def _flash_tile_bytes(row: ParsedLayer, ctx: PredictContext) -> int:
    spec = flash_tile_spec(row)
    if spec is None or ctx.kind == "decode":
        return 0
    return eval_term(spec, term_env(ctx), ctx.mesh_shape, ctx.rules)


def ring_kv_spec(row: ParsedLayer) -> Optional[TermSpec]:
    """Per-hop ring-attention KV block of one attention row under
    context parallelism: each cp shard holds its own KV slice plus one
    in-flight send + recv buffer pair rotating around the ring.  GQA
    rows rotate k+v ``(mb, seq, Hkv, hd)`` bf16 blocks (mult 4 = (k+v)
    x (send+recv)); MLA rows rotate the compressed latent.  The seq dim
    carries the `seq` axis so the block shards by cp (and SP's model
    split) exactly like the activations it travels with.  None for
    non-attention rows; callers gate on ``ctx.cp > 1`` and
    ``ctx.kind != "decode"`` (decode has no ring)."""
    if row.layer.kind != "attention":
        return None
    meta = row.layer.meta
    tok = "enc" if meta.get("cross") else "seq"
    if meta.get("attn_kind") == "mla":
        mla = meta["mla"]
        width = mla.kv_lora_rank + mla.qk_rope_head_dim
        return TermSpec(dims=("mb", tok, width),
                        axes=("batch", "seq", None), nbytes=2, mult=2)
    if "n_kv_heads" in meta:
        return TermSpec(dims=("mb", tok, meta["n_kv_heads"],
                              meta["head_dim"]),
                        axes=("batch", "seq", "kv_heads", None),
                        nbytes=2, mult=4)
    return None


def _ring_bytes(row: ParsedLayer, ctx: PredictContext) -> int:
    """Ring-hop send/recv transient (0 without a context axis > 1)."""
    if ctx.cp <= 1 or ctx.kind == "decode":
        return 0
    spec = ring_kv_spec(row)
    if spec is None:
        return 0
    return eval_term(spec, term_env(ctx), ctx.mesh_shape, ctx.rules)


def act_factor_transient(row: ParsedLayer, ctx: PredictContext) -> int:
    """Peak transient working set of ONE instance (recomputed block during
    its backward, or plain forward for frozen modules).  Under context
    parallelism the ring-attention per-hop KV send/recv buffers ride on
    top (folded into act_transient by the assembler)."""
    if not row.layer.acts:
        return 0
    total = sum(layer_act_terms(row, ctx).values())
    tiles = _flash_tile_bytes(row, ctx)
    ring = _ring_bytes(row, ctx)
    if ctx.kind == "train" and row.trainable:
        # recomputed fwd + cotangents (+ p and ds score tiles in the
        # flash backward)
        return 2 * total + 2 * tiles + ring
    return total + tiles + ring
