"""Liveness assembly: interval-overlap peak from an alloc/free event program.

Eq.1's legacy assembly adds every component's own maximum — saved
activations, the worst transient block, the loss head, the optimizer-update
stacks — as if all of them were resident at once.  On a real step they are
not: the loss head fires after the forward stash is full but before the
backward transient exists, and the optimizer update runs only after the
backward has freed the stash.  The dynamic-analysis line of related work
(arXiv:2504.03887; xMem) reports that exactly this buffer-lifetime overlap,
not per-layer math, dominates estimator error.

This module compiles the step schedule — parse table + ``stages.py``
partition + microbatch stash rules — into a **cell-independent** alloc/free
event program.  Events carry ±1 coefficients over named *components* whose
byte values are the existing Eq.1 factors (every one of them evaluated from
the same TermSpecs the legacy path uses — no new env tokens), so the scalar
replay here and the columnar contraction in ``core.batch`` share one source
of truth.  The peak is the maximum running-sum prefix over the program:

    peak_liveness = max_j  sum_{i<=j} delta_i . values

which the columnar engines compute as a segmented cummax over the event
axis.  Because every event delta is a ±1 combination of non-negative
component values, every prefix is a sub-sum of the legacy total — hence
``peak_liveness <= peak_legacy`` always, which is what keeps the
branch-and-bound statics floor and the aligned batch ladder sound
(docs/search.md).

Microbatch handling: the 1F1B warmup ramp fills the stash one microbatch at
a time, but the running sum is maximal only once the stash is full — so the
ramp collapses to a single ``+saved`` event whose value already carries the
``stash_count`` multiplier (exactly the value the legacy path uses).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

ASSEMBLIES = ("legacy", "liveness")

# Component vocabulary.  Values come from the predictor's component groups
# (StaticTerms / ActTermsAgg / OverheadTerms) — see values_of() callers in
# core.predictor and the column tables in core.batch.
COMPONENTS = (
    "base",           # params + grads + opt states (+ chip constant when
                      # calibrated): persistent for the whole step
    "inputs",         # batch arguments (first stage)
    "cache",          # fixed (non-paged) serve caches
    "pool",           # paged KV pool (serve)
    "draft",          # speculative-draft residency (serve, first stage)
    "embed",          # all-gathered embedding tables (fwd lookup + bwd
                      # scatter at train; lookup only at serve)
    "saved",          # saved-for-backward set x stash_count
    "boundary",       # pipeline stage-boundary send/recv buffers
    "loss",           # loss-head / logits window (last stage)
    "transient",      # one block's recomputed-backward (train) or forward
                      # (serve) working set
    "opt_transient",  # optimizer-update in-flight fp32 stacks
    "out_copy",       # non-aliased updated-param copy of the train step
)

# Profile term group of each component — mirrors CalibrationProfile.apply
# and calibrate.residual.decompose exactly.
COMPONENT_TERM = {
    "base": "static", "out_copy": "static", "draft": "static",
    "saved": "act_saved",
    "embed": "act_transient", "boundary": "act_transient",
    "transient": "act_transient", "opt_transient": "act_transient",
    "inputs": "overhead", "cache": "overhead", "loss": "overhead",
    "pool": "overhead",
}

# Canonical telescoping order of the act_transient group (see
# telescoped_transient): the legacy path scales e+b+t+o as ONE group, so
# the liveness deltas must be differences of cumulative scaled prefixes in
# a fixed order to sum back to the legacy group byte-exactly.
TRANSIENT_ORDER = ("embed", "boundary", "transient", "opt_transient")


@dataclass(frozen=True)
class Event:
    """One schedule point: a set of ±1 component deltas."""

    label: str
    deltas: tuple  # ((component, +1 | -1), ...)


@dataclass(frozen=True)
class EventProgram:
    """Cell-independent alloc/free program for one step kind."""

    kind: str
    events: tuple  # (Event, ...)

    @property
    def n_events(self) -> int:
        return len(self.events)

    def delta_matrix(self) -> list:
        """``n_events x len(COMPONENTS)`` list-of-lists of {-1, 0, +1}
        coefficients in COMPONENTS order — the contraction matrix the
        columnar engines multiply against component columns."""
        idx = {c: i for i, c in enumerate(COMPONENTS)}
        rows = []
        for ev in self.events:
            row = [0] * len(COMPONENTS)
            for comp, sign in ev.deltas:
                row[idx[comp]] += sign
            rows.append(row)
        return rows

    def net_deltas(self) -> dict:
        """Component -> net coefficient over the whole program.  Persistent
        components net +1 (allocated, never freed within the step); every
        within-step buffer nets 0 (each alloc has a matching free)."""
        net = {c: 0 for c in COMPONENTS}
        for ev in self.events:
            for comp, sign in ev.deltas:
                net[comp] += sign
        return net


# Persistent components: allocated by the first event, freed outside the
# step window — the running sum must return to exactly their sum.
_PERSISTENT = ("base", "cache", "pool", "draft")

_TRAIN_EVENTS = (
    Event("persist", (("base", +1), ("cache", +1), ("pool", +1),
                      ("draft", +1))),
    Event("step_in", (("inputs", +1),)),
    # the token-lookup all-gather materializes at the first forward and its
    # gradient scatter-add lives until the last backward -> spans the step
    Event("fwd_embed", (("embed", +1),)),
    # forward fills the stash (warmup ramp collapsed — see module docstring)
    # while the steady-state boundary send/recv buffers are in flight
    Event("fwd_stash", (("saved", +1), ("boundary", +1))),
    # loss head on the last stage: hidden + logits chunk, freed before the
    # body's backward starts recomputing
    Event("loss_head", (("loss", +1),)),
    Event("loss_free", (("loss", -1),)),
    # backward walks the scan: one block's recomputed working set is live
    # against the still-full stash
    Event("bwd_recompute", (("transient", +1),)),
    Event("bwd_free", (("transient", -1), ("saved", -1), ("boundary", -1),
                       ("embed", -1))),
    # optimizer update: in-flight fp32 stacks + the non-aliased updated
    # params, after the backward freed the activation set
    Event("opt_update", (("opt_transient", +1), ("out_copy", +1))),
    Event("step_out", (("opt_transient", -1), ("out_copy", -1),
                       ("inputs", -1))),
)

# Serve kinds (prefill / decode / paged variants): no backward, no
# optimizer — the embed gather, the block transient and the logits head are
# exclusive windows over a persistent cache+carry floor.
_SERVE_EVENTS = (
    Event("persist", (("base", +1), ("cache", +1), ("pool", +1),
                      ("draft", +1))),
    Event("step_in", (("inputs", +1),)),
    Event("fwd_carry", (("saved", +1), ("boundary", +1))),
    Event("embed_gather", (("embed", +1),)),
    Event("embed_free", (("embed", -1),)),
    Event("block_transient", (("transient", +1),)),
    Event("block_free", (("transient", -1),)),
    Event("logits_head", (("loss", +1),)),
    Event("logits_free", (("loss", -1),)),
    Event("step_out", (("saved", -1), ("boundary", -1), ("inputs", -1))),
)


@functools.lru_cache(maxsize=8)
def compile_program(kind: str) -> EventProgram:
    """Event program for a step kind.  Stage/schedule specifics (stash
    multiplier, boundary edge count, loss-on-last / inputs-on-first) enter
    through component VALUES, not program shape — the program itself is
    cell-independent, which is what lets the columnar engines contract one
    delta matrix against whole knob columns."""
    events = _TRAIN_EVENTS if kind == "train" else _SERVE_EVENTS
    program = EventProgram(kind=kind, events=events)
    _validate(program)
    return program


def _validate(program: EventProgram) -> None:
    """Ledger conservation: every within-step alloc has a matching free and
    persistent components are allocated exactly once (net +1)."""
    for comp, net in program.net_deltas().items():
        want = 1 if comp in _PERSISTENT else 0
        if net != want:
            raise AssertionError(
                f"{program.kind}: component {comp!r} nets {net}, "
                f"expected {want}")


@dataclass(frozen=True)
class Replay:
    """Scalar replay result (the columnar engines' parity oracle)."""

    peak: int                 # max running-sum prefix
    event_index: int          # first prefix attaining the peak
    event_label: str
    prefixes: tuple           # running sum after every event
    final: int                # running sum after the last event
    group_at_peak: dict       # profile term -> live bytes at the peak


def replay(program: EventProgram, values: dict) -> Replay:
    """Replay the program against component byte values (missing components
    default to 0; all values must be >= 0).  Ties keep the earliest event,
    mirroring the strictly-greater stage rule in ``predictor.predict``."""
    for comp, v in values.items():
        if comp not in COMPONENT_TERM:
            raise ValueError(f"unknown component {comp!r}")
        if v < 0:
            raise ValueError(f"negative component {comp}={v}")
    run = 0
    live = {c: 0 for c in COMPONENTS}
    prefixes = []
    peak, peak_i, peak_live = 0, 0, dict(live)
    for i, ev in enumerate(program.events):
        for comp, sign in ev.deltas:
            run += sign * values.get(comp, 0)
            live[comp] += sign
        prefixes.append(run)
        if run > peak or i == 0:
            peak, peak_i, peak_live = run, i, dict(live)
    groups = {t: 0 for t in ("static", "act_saved", "act_transient",
                             "overhead")}
    for comp, n in peak_live.items():
        if n:
            groups[COMPONENT_TERM[comp]] += n * values.get(comp, 0)
    return Replay(peak=peak, event_index=peak_i,
                  event_label=program.events[peak_i].label,
                  prefixes=tuple(prefixes), final=run,
                  group_at_peak=groups)


def telescoped_transient(values: dict, scale) -> dict:
    """Calibrated deltas of the act_transient group.

    The legacy path scales ``embed + boundary + transient + opt_transient``
    as ONE group: ``scale(e + b + t + o)``.  The liveness program needs the
    four members separately, so each scaled delta is the difference of
    cumulative scaled prefixes in TRANSIENT_ORDER:

        d_embed     = scale(e)
        d_boundary  = scale(e + b)         - scale(e)
        d_transient = scale(e + b + t)     - scale(e + b)
        d_opt       = scale(e + b + t + o) - scale(e + b + t)

    ``scale`` must be monotone with scale(0) == 0 (both the scalar
    ``int(round(v * c))`` and the vectorized ``np.rint`` twin are, for
    c >= 0), so every delta is >= 0 and their sum telescopes back to the
    legacy group scale EXACTLY — which is what guarantees calibrated
    liveness <= calibrated legacy in integer arithmetic.
    """
    out = {}
    run = 0
    prev = scale(0)
    for name in TRANSIENT_ORDER:
        run += values.get(name, 0)
        cur = scale(run)
        out[name] = cur - prev
        prev = cur
    return out
