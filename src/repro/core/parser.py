"""Model parser (paper workflow steps 1-4).

Decomposes a model into modality-level modules and fine-grained layers,
annotating each layer with its training behaviour (trainable / frozen) and
its scan-stack repeat count.  Because every model in this framework is
*constructed from* the same ModuleSpec tree, parsing is exact — there is no
reflection gap between what the predictor sees and what runs.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.spec import LayerSpec, ModuleSpec, TrainPolicy


@dataclass(frozen=True)
class ParsedLayer:
    """One row of the parse table: a fine-grained layer in context."""

    path: str                  # e.g. "vlm/language_model/blocks/attn"
    module_path: str           # owning module, e.g. "vlm/language_model/blocks"
    modality: str
    layer: LayerSpec
    repeat: int                # scan-stack multiplicity
    scanned: bool              # True => params carry a leading layers axis
    trainable: bool


def parse_model(spec: ModuleSpec, policy: TrainPolicy) -> list[ParsedLayer]:
    rows: list[ParsedLayer] = []

    def visit(mod: ModuleSpec, prefix: str, repeat: int, scanned: bool):
        path = f"{prefix}/{mod.name}" if prefix else mod.name
        scanned = scanned or mod.repeat > 1 or mod.scanned
        repeat = repeat * mod.repeat
        trainable = policy.is_trainable(path)
        for layer in mod.layers:
            rows.append(ParsedLayer(
                path=f"{path}/{layer.name}", module_path=path,
                modality=mod.modality, layer=layer, repeat=repeat,
                scanned=scanned, trainable=trainable))
        for child in mod.children:
            visit(child, path, repeat, scanned)

    visit(spec, "", 1, False)
    return rows


def modules_of(rows: list[ParsedLayer]) -> dict[str, list[ParsedLayer]]:
    """Group the parse table by owning module (paper workflow step 2)."""
    out: dict[str, list[ParsedLayer]] = {}
    for r in rows:
        out.setdefault(r.module_path, []).append(r)
    return out


def total_params(rows: list[ParsedLayer], trainable_only: bool = False) -> int:
    return sum(r.layer.param_count * r.repeat for r in rows
               if r.trainable or not trainable_only)


def active_params(rows: list[ParsedLayer]) -> int:
    """MoE-aware 'active per token' parameter count (for MODEL_FLOPS)."""
    total = 0
    for r in rows:
        if r.layer.kind == "moe":
            m = r.layer.meta
            act_frac = (m["top_k"] + m["n_shared_experts"]) / max(
                m["n_experts"] + m["n_shared_experts"], 1)
            routed = sum(p.size for n, p in r.layer.params.items()
                         if n in ("wg", "wu", "wd"))
            rest = r.layer.param_count - routed
            frac_routed = routed * (m["top_k"] / max(m["n_experts"], 1))
            total += int((rest + frac_routed) * r.repeat)
        else:
            total += r.layer.param_count * r.repeat
    return total
