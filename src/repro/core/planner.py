"""OoM guard + configuration planner — the paper's purpose, closed-loop.

``check`` predicts a cell's peak per-device memory BEFORE any compile or
launch and compares it to the chip's HBM.  ``plan`` searches the cheap
knobs (gradient accumulation, remat policy) for the first configuration
that fits, using only Eq.1 arithmetic — microseconds per candidate, vs a
failed cluster launch per guess without it.  For searches over the FULL
knob space (mesh factorizations x optimizer x remat x accum x batch x
seq_len x chip), use the vectorized/memoized engine in
:mod:`repro.core.sweep`, which ``plan`` delegates to.

This is also where arctic-480b's published memory plan comes from: Adam's
fp32 states alone (~5.2 TiB) can never fit a 256-chip v5e pod, which the
guard flags analytically; the shipped config therefore uses Adafactor +
2-axis FSDP (see EXPERIMENTS.md).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core import factors as F
from repro.core import predictor as PR
from repro.core.spec import FULL_TRAIN, TrainPolicy

GiB = 1024 ** 3


# ---------------------------------------------------------------------------
# chip catalogue: per-device HBM for the accelerators the planner targets.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ChipSpec:
    name: str
    hbm_bytes: int
    vendor: str = "google"

    @property
    def hbm_gib(self) -> float:
        return self.hbm_bytes / GiB


CHIPS: dict[str, ChipSpec] = {
    "v5e": ChipSpec("v5e", 16 * GiB),
    "v5p": ChipSpec("v5p", 95 * GiB),
    "v6e": ChipSpec("v6e", 32 * GiB),
    "a100-40g": ChipSpec("a100-40g", 40 * GiB, vendor="nvidia"),
    "a100-80g": ChipSpec("a100-80g", 80 * GiB, vendor="nvidia"),
    "h100": ChipSpec("h100", 80 * GiB, vendor="nvidia"),
    "h200": ChipSpec("h200", 141 * GiB, vendor="nvidia"),
}

V5E_HBM = CHIPS["v5e"].hbm_bytes      # backward-compat alias
# XLA reserves working space; plan against a fraction of physical HBM.
HEADROOM = 0.92


def chip_hbm(chip: str) -> int:
    if chip not in CHIPS:
        raise KeyError(f"unknown chip {chip!r}; known: {sorted(CHIPS)}")
    return CHIPS[chip].hbm_bytes


@dataclass
class PlanReport:
    arch: str
    shape: str
    fits: bool
    peak_bytes: int
    budget_bytes: int
    grad_accum: int = 1
    remat: str = "block"
    note: str = ""
    prediction: Optional[PR.PredictedMemory] = None

    def __str__(self) -> str:
        verdict = "FITS" if self.fits else "OOM "
        return (f"[{verdict}] {self.arch} x {self.shape}: "
                f"peak {self.peak_bytes / GiB:.2f} GiB vs budget "
                f"{self.budget_bytes / GiB:.2f} GiB"
                + (f" (grad_accum={self.grad_accum}, remat={self.remat})"
                   if self.grad_accum > 1 else "")
                + (f" — {self.note}" if self.note else ""))


def check_parallel(cfg, mesh_shape: dict, kind: str,
                   seq_len: Optional[int] = None) -> None:
    """Reject parallelism plans the architecture / step kind cannot run.

    The ONE validation gate for the `expert` (ep) and `context` (cp)
    mesh axes — ``make_context`` (every per-cell path) and the columnar
    sweep (grid-level, ``SweepGrid.check_parallel``) both call it, so
    invalid combos fail with the same clean ValueError everywhere
    instead of a silent misprediction or a deep traceback:

    * ``expert`` axis on an arch without MoE layers (nothing to shard);
    * ``expert`` degree beyond — or not dividing — the routed-expert
      count (the EP all_to_all needs equal per-shard expert groups; a
      non-divisible axis would be silently inert in the model and
      unrunnable by the runtime);
    * ``context`` axis on a decode step (token-at-a-time: no seq dim to
      ring over — decode KV caches stay on `cache_seq`);
    * ``context`` degree that does not divide the sequence length (ring
      attention needs equal per-shard blocks; unlike head counts there
      is no graceful-replication story for a lopsided ring).
    """
    from repro.launch import mesh as M
    ep, cp = M.ep_degree(mesh_shape), M.cp_degree(mesh_shape)
    if ep > 1:
        if cfg.moe is None:
            raise ValueError(
                f"expert-parallel mesh axis (expert={ep}) on dense arch "
                f"{cfg.name!r}: no MoE layers to shard — drop the expert "
                f"axis or pick an MoE architecture")
        if ep > cfg.moe.n_experts:
            raise ValueError(
                f"expert={ep} exceeds {cfg.name!r}'s "
                f"{cfg.moe.n_experts} routed experts; cap the axis with "
                f"--max-expert {cfg.moe.n_experts} or shrink the mesh")
        if cfg.moe.n_experts % ep:
            raise ValueError(
                f"expert={ep} does not divide {cfg.name!r}'s "
                f"{cfg.moe.n_experts} routed experts: the EP all_to_all "
                f"needs equal per-shard expert groups (a non-divisible "
                f"axis would be silently inert in the memory model and "
                f"unrunnable by the shard_map runtime)")
    if cp > 1:
        if kind == "decode":
            raise ValueError(
                f"context-parallel mesh axis (context={cp}) is invalid "
                f"for decode: a token-at-a-time step has no sequence dim "
                f"to ring over (decode KV caches shard via cache_seq "
                f"instead)")
        if seq_len is not None and seq_len % cp:
            raise ValueError(
                f"context={cp} does not divide seq_len {seq_len}: ring "
                f"attention needs equal per-shard sequence blocks — use "
                f"a divisible seq_len or a smaller context axis")


def make_context(cfg, mesh_shape: dict, *, kind: str, global_batch: int,
                 seq_len: int, backend: str = "tpu", grad_accum: int = 1,
                 remat: Optional[str] = None,
                 optimizer: Optional[str] = None,
                 microbatches: int = 1,
                 schedule: str = "1f1b") -> F.PredictContext:
    """The ONE place a planner/sweep cell becomes a PredictContext — the
    sweep engine and ``check`` share it, so their predictions can never
    diverge on context construction.  The pipeline degree comes from the
    mesh's ``pipe`` axis; ``microbatches``/``schedule`` set how the batch
    fills that pipeline (inert when the mesh has no pipe axis); the
    `expert`/`context` axes are validated against the arch and step kind
    (``check_parallel``)."""
    from repro.core.stages import SCHEDULES
    from repro.launch import mesh as M
    if schedule not in SCHEDULES:
        raise ValueError(
            f"unknown schedule {schedule!r}; known: {SCHEDULES}")
    check_parallel(cfg, mesh_shape, kind, seq_len)
    opt = optimizer or cfg.optimizer
    return F.PredictContext(
        mesh_shape=mesh_shape, rules=M.arch_rules(cfg, kind),
        optimizer=opt, fsdp=cfg.fsdp, master_fp32=opt != "adafactor",
        remat=remat or cfg.remat, backend=backend,
        global_batch=global_batch, seq_len=seq_len,
        enc_seq=int(seq_len * cfg.encdec.enc_seq_ratio)
        if cfg.encdec else 0,
        kind=kind, max_len=seq_len, grad_accum=grad_accum,
        pp=M.pp_degree(mesh_shape), microbatches=microbatches,
        schedule=schedule)


def _resolve_shape(shape):
    """Accept a registered shape name or an ad-hoc ShapeConfig."""
    from repro.configs import SHAPES, ShapeConfig
    if isinstance(shape, ShapeConfig):
        return shape
    return SHAPES[shape]


def check(arch: str, shape_name, mesh_shape: dict,
          hbm_bytes: Optional[int] = None, policy: TrainPolicy = FULL_TRAIN,
          backend: str = "tpu", grad_accum: int = 1,
          remat: Optional[str] = None, optimizer: Optional[str] = None,
          chip: str = "v5e", headroom: float = HEADROOM,
          profile=None, microbatches: int = 1,
          schedule: str = "1f1b") -> PlanReport:
    """Reference single-cell evaluation: fresh build, no caches.

    ``shape_name`` may be a registered shape name ("train_4k") or a
    ShapeConfig; ``hbm_bytes`` overrides the ``chip`` lookup when given;
    ``profile`` (a repro.calibrate CalibrationProfile) corrects the
    prediction with measurement-fitted per-term coefficients + the
    ``chip`` constant.  A mesh with a ``pipe`` axis is evaluated
    per-pipeline-stage (core.stages) and the worst stage reported.
    """
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config(arch)
    shape = _resolve_shape(shape_name)
    model = build_model(cfg)
    ctx = make_context(cfg, mesh_shape, kind=shape.kind,
                       global_batch=shape.global_batch,
                       seq_len=shape.seq_len, backend=backend,
                       grad_accum=grad_accum, remat=remat,
                       optimizer=optimizer, microbatches=microbatches,
                       schedule=schedule)
    pred = PR.predict(model, policy, ctx, profile=profile, chip=chip)
    budget = int((hbm_bytes if hbm_bytes is not None
                  else chip_hbm(chip)) * headroom)
    return PlanReport(arch=arch, shape=shape.name,
                      fits=pred.peak_bytes <= budget,
                      peak_bytes=pred.peak_bytes, budget_bytes=budget,
                      grad_accum=grad_accum, remat=remat or cfg.remat,
                      prediction=pred)


def plan(arch: str, shape_name, mesh_shape: dict,
         hbm_bytes: Optional[int] = None, policy: TrainPolicy = FULL_TRAIN,
         backend: str = "tpu", chip: str = "v5e",
         headroom: float = HEADROOM, engine=None,
         profile=None) -> PlanReport:
    """First-fit search over (remat, grad_accum); pure arithmetic.

    Delegates to the memoized sweep engine so the candidate evaluations
    share the parsed model and the batch-independent factor sums; pass
    ``engine`` (a SweepEngine) to share those caches across calls and
    ``profile`` to plan against calibrated predictions.
    """
    from repro.core import sweep as SW
    from repro.configs import get_config

    shape = _resolve_shape(shape_name)
    budget = int((hbm_bytes if hbm_bytes is not None
                  else chip_hbm(chip)) * headroom)
    engine = engine or SW.SweepEngine()
    base = engine.report(arch, shape, mesh_shape, policy=policy,
                         backend=backend, budget_bytes=budget,
                         chip=chip, profile=profile)
    if base.fits or shape.kind != "train":
        return base
    cfg = get_config(arch)
    for remat in dict.fromkeys((cfg.remat, "block")):
        for accum in (1, 2, 4, 8, 16, 32):
            if shape.global_batch % accum:
                continue
            r = engine.report(arch, shape, mesh_shape, policy=policy,
                              backend=backend, budget_bytes=budget,
                              grad_accum=accum, remat=remat,
                              chip=chip, profile=profile)
            if r.fits:
                r.note = f"planner: accum x{accum} fits the budget"
                return r
    base.note = ("no (remat, grad_accum) configuration fits — needs a "
                 "bigger mesh, more sharding, or a leaner optimizer")
    return base


def plan_min_chips(arch: str, shape_name, chips=(4, 8, 16, 32, 64),
                   chip: str = "v5e", policy: TrainPolicy = FULL_TRAIN,
                   backend: str = "tpu", headroom: float = HEADROOM,
                   allow_pp: bool = True, max_pp: int = 8,
                   allow_ep: bool = False, max_ep: int = 8,
                   allow_cp: bool = False, max_cp: int = 8,
                   microbatches=(1, 4, 8), schedules=("1f1b", "gpipe"),
                   profile=None, engine=None):
    """Smallest chip count that fits the shape, pipeline parallelism
    allowed: sweeps every (data, model[, expert][, context][, pipe])
    factorization of each candidate chip count x microbatch count x
    schedule and returns the Pareto-min
    :class:`~repro.core.sweep.SweepResult` (None if nothing fits).
    ``allow_pp=False`` restricts to the 2-axis plans, so
    ``plan_min_chips(...) vs plan_min_chips(..., allow_pp=False)``
    quantifies what the pipe axis buys; ``allow_ep=True`` and
    ``allow_cp=True`` add the expert and context axes the same way.

    This is a SEARCH, so unlike an explicit ``planner.check`` mesh the
    enumerated factorizations that :func:`check_parallel` would reject
    (an expert degree beyond the arch's routed experts — or any expert
    degree > 1 on a dense arch — and context degrees that don't divide
    the shape's seq_len or that land on a decode shape) are simply
    FILTERED out of the candidate set rather than aborting the whole
    search; the remaining legal plans are swept and the Pareto-min
    returned (None when nothing fits or nothing is legal)."""
    from repro.core import sweep as SW
    from repro.configs import get_config
    shape = _resolve_shape(shape_name)
    axes: tuple = ("data", "model")
    max_axis: dict = {}
    if allow_ep:
        axes += ("expert",)
        max_axis["expert"] = max_ep
    if allow_cp:
        axes += ("context",)
        max_axis["context"] = max_cp
    if allow_pp:
        axes += ("pipe",)
        max_axis["pipe"] = max_pp
    grid = SW.SweepGrid(
        arch=arch, chips=tuple(chips), mesh_axes=axes,
        max_axis=max_axis or None, chip=chip,
        microbatches=tuple(microbatches) if allow_pp else (1,),
        schedules=tuple(schedules) if allow_pp else ("1f1b",),
        global_batches=(shape.global_batch,), seq_lens=(shape.seq_len,),
        kind=shape.kind, policy=policy, backend=backend,
        headroom=headroom, profile=profile)
    if allow_ep or allow_cp:
        cfg = get_config(SW.normalize_arch(arch))

        def legal(mesh: dict) -> bool:
            try:
                check_parallel(cfg, mesh, shape.kind, shape.seq_len)
                return True
            except ValueError:
                return False

        meshes = [m for m in grid.meshes() if legal(m)]
        if not meshes:
            return None
        grid.mesh_shapes = meshes
    res = (engine or SW.SweepEngine()).sweep(grid)
    return res.min_chips()


def adam_state_bytes(arch: str) -> int:
    """Analytic Adam fp32 state (m+v+master) for the full model — the
    arctic-480b infeasibility argument."""
    from repro.configs import get_config
    from repro.core.parser import parse_model, total_params
    from repro.models import build_model
    n = total_params(parse_model(build_model(get_config(arch)).spec,
                                 FULL_TRAIN))
    return n * 12
