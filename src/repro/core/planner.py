"""OoM guard + configuration planner — the paper's purpose, closed-loop.

``check`` predicts a cell's peak per-device memory BEFORE any compile or
launch and compares it to the chip's HBM.  ``plan`` searches the cheap
knobs (gradient accumulation, remat policy) for the first configuration
that fits, using only Eq.1 arithmetic — microseconds per candidate, vs a
failed cluster launch per guess without it.  For searches over the FULL
knob space (mesh factorizations x optimizer x remat x accum x batch x
seq_len x chip), use the vectorized/memoized engine in
:mod:`repro.core.sweep`, which ``plan`` delegates to.

This is also where arctic-480b's published memory plan comes from: Adam's
fp32 states alone (~5.2 TiB) can never fit a 256-chip v5e pod, which the
guard flags analytically; the shipped config therefore uses Adafactor +
2-axis FSDP (see EXPERIMENTS.md).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core import factors as F
from repro.core import predictor as PR
from repro.core.spec import FULL_TRAIN, TrainPolicy

GiB = 1024 ** 3


# ---------------------------------------------------------------------------
# chip catalogue: per-device HBM for the accelerators the planner targets.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ChipSpec:
    name: str
    hbm_bytes: int
    vendor: str = "google"

    @property
    def hbm_gib(self) -> float:
        return self.hbm_bytes / GiB


CHIPS: dict[str, ChipSpec] = {
    "v5e": ChipSpec("v5e", 16 * GiB),
    "v5p": ChipSpec("v5p", 95 * GiB),
    "v6e": ChipSpec("v6e", 32 * GiB),
    "a100-40g": ChipSpec("a100-40g", 40 * GiB, vendor="nvidia"),
    "a100-80g": ChipSpec("a100-80g", 80 * GiB, vendor="nvidia"),
    "h100": ChipSpec("h100", 80 * GiB, vendor="nvidia"),
    "h200": ChipSpec("h200", 141 * GiB, vendor="nvidia"),
}

V5E_HBM = CHIPS["v5e"].hbm_bytes      # backward-compat alias
# XLA reserves working space; plan against a fraction of physical HBM.
HEADROOM = 0.92


def chip_hbm(chip: str) -> int:
    if chip not in CHIPS:
        raise KeyError(f"unknown chip {chip!r}; known: {sorted(CHIPS)}")
    return CHIPS[chip].hbm_bytes


@dataclass
class PlanReport:
    arch: str
    shape: str
    fits: bool
    peak_bytes: int
    budget_bytes: int
    grad_accum: int = 1
    remat: str = "block"
    note: str = ""
    prediction: Optional[PR.PredictedMemory] = None

    def __str__(self) -> str:
        verdict = "FITS" if self.fits else "OOM "
        return (f"[{verdict}] {self.arch} x {self.shape}: "
                f"peak {self.peak_bytes / GiB:.2f} GiB vs budget "
                f"{self.budget_bytes / GiB:.2f} GiB"
                + (f" (grad_accum={self.grad_accum}, remat={self.remat})"
                   if self.grad_accum > 1 else "")
                + (f" — {self.note}" if self.note else ""))


def check_parallel(cfg, mesh_shape: dict, kind: str,
                   seq_len: Optional[int] = None) -> None:
    """Reject parallelism plans the architecture / step kind cannot run.

    The ONE validation gate for the `expert` (ep) and `context` (cp)
    mesh axes — ``make_context`` (every per-cell path) and the columnar
    sweep (grid-level, ``SweepGrid.check_parallel``) both call it, so
    invalid combos fail with the same clean ValueError everywhere
    instead of a silent misprediction or a deep traceback:

    * ``expert`` axis on an arch without MoE layers (nothing to shard);
    * ``expert`` degree beyond — or not dividing — the routed-expert
      count (the EP all_to_all needs equal per-shard expert groups; a
      non-divisible axis would be silently inert in the model and
      unrunnable by the runtime);
    * ``context`` axis on a decode step (token-at-a-time: no seq dim to
      ring over — decode KV caches stay on `cache_seq`);
    * ``context`` degree that does not divide the sequence length (ring
      attention needs equal per-shard blocks; unlike head counts there
      is no graceful-replication story for a lopsided ring).
    """
    from repro.launch import mesh as M
    ep, cp = M.ep_degree(mesh_shape), M.cp_degree(mesh_shape)
    if ep > 1:
        if cfg.moe is None:
            raise ValueError(
                f"expert-parallel mesh axis (expert={ep}) on dense arch "
                f"{cfg.name!r}: no MoE layers to shard — drop the expert "
                f"axis or pick an MoE architecture")
        if ep > cfg.moe.n_experts:
            raise ValueError(
                f"expert={ep} exceeds {cfg.name!r}'s "
                f"{cfg.moe.n_experts} routed experts; cap the axis with "
                f"--max-expert {cfg.moe.n_experts} or shrink the mesh")
        if cfg.moe.n_experts % ep:
            raise ValueError(
                f"expert={ep} does not divide {cfg.name!r}'s "
                f"{cfg.moe.n_experts} routed experts: the EP all_to_all "
                f"needs equal per-shard expert groups (a non-divisible "
                f"axis would be silently inert in the memory model and "
                f"unrunnable by the shard_map runtime)")
    if cp > 1:
        if kind == "decode":
            raise ValueError(
                f"context-parallel mesh axis (context={cp}) is invalid "
                f"for decode: a token-at-a-time step has no sequence dim "
                f"to ring over (decode KV caches shard via cache_seq "
                f"instead)")
        if seq_len is not None and seq_len % cp:
            raise ValueError(
                f"context={cp} does not divide seq_len {seq_len}: ring "
                f"attention needs equal per-shard sequence blocks — use "
                f"a divisible seq_len or a smaller context axis")


def check_serve(cfg, serve, kind: str) -> None:
    """Reject serving-fleet knobs the step kind / registry cannot honor.

    The serve twin of :func:`check_parallel` — ``make_context`` (every
    per-cell path), ``SweepGrid.check_serve`` (grid-level, both sweep
    modes) and the sweep CLI all route through it, so invalid serve
    plans fail with one clean ValueError everywhere.  Range errors
    (hit rate outside [0,1], utilization outside (0,1], non-page-aligned
    block sizes) are rejected even earlier, at ServeSpec construction.

    * any active serve knob on a train kind (the block pool, prefix
      cache, request mix and draft model are serving-runtime concepts —
      a train step has no KV pool to page);
    * a draft model on a non-decode kind (speculative decoding drafts
      ahead of the decode loop only);
    * a draft arch that is not in the config registry.
    """
    if serve is None or serve.is_neutral:
        return
    if kind == "train":
        raise ValueError(
            f"serve knobs (block_size/utilization/prefix-hit-rate/mix/"
            f"draft) are invalid for kind 'train': a train step has no "
            f"KV pool to page — drop them or sweep a serve kind")
    if serve.draft_arch:
        if kind != "decode":
            raise ValueError(
                f"draft_arch {serve.draft_arch!r} is invalid for kind "
                f"{kind!r}: speculative decoding is a decode-time "
                f"technique — drop the draft or use kind 'decode'")
        from repro.configs import registered_archs
        from repro.core.sweep import normalize_arch
        known = registered_archs()
        try:
            name = normalize_arch(serve.draft_arch)
        except KeyError:
            name = None
        if name not in known:
            raise ValueError(
                f"unknown draft arch {serve.draft_arch!r}; known: "
                f"{sorted(known)}")


def check_offload(kind: str, offload_opt: bool) -> None:
    """Reject the optimizer-offload knob on step kinds that hold no
    optimizer state.  The offload twin of :func:`check_parallel` /
    :func:`check_serve` — ``make_context`` (every per-cell path),
    ``SweepGrid.check_offload`` (grid-level, both sweep modes) and the
    sweep CLI all route through it."""
    if offload_opt and kind != "train":
        raise ValueError(
            f"--offload-optimizer is invalid for kind {kind!r}: serve "
            f"steps hold no optimizer state to offload — drop the knob "
            f"or sweep kind 'train'")


def make_context(cfg, mesh_shape: dict, *, kind: str, global_batch: int,
                 seq_len: int, backend: str = "tpu", grad_accum: int = 1,
                 remat: Optional[str] = None,
                 optimizer: Optional[str] = None,
                 microbatches: int = 1,
                 schedule: str = "1f1b",
                 serve=None, offload_opt: bool = False) -> F.PredictContext:
    """The ONE place a planner/sweep cell becomes a PredictContext — the
    sweep engine and ``check`` share it, so their predictions can never
    diverge on context construction.  The pipeline degree comes from the
    mesh's ``pipe`` axis; ``microbatches``/``schedule`` set how the batch
    fills that pipeline (inert when the mesh has no pipe axis); the
    `expert`/`context` axes are validated against the arch and step kind
    (``check_parallel``); serving-fleet knobs (``serve``, a
    repro.serve.pool.ServeSpec) are validated by ``check_serve`` and a
    fully-neutral spec is normalized to None, so neutral serve cells are
    bit-identical to pre-serve predictions (and hit the same memo keys).
    """
    from repro.core.stages import SCHEDULES
    from repro.launch import mesh as M
    if schedule not in SCHEDULES:
        raise ValueError(
            f"unknown schedule {schedule!r}; known: {SCHEDULES}")
    check_parallel(cfg, mesh_shape, kind, seq_len)
    check_serve(cfg, serve, kind)
    check_offload(kind, offload_opt)
    if serve is not None and serve.is_neutral:
        serve = None
    opt = optimizer or cfg.optimizer
    return F.PredictContext(
        mesh_shape=mesh_shape, rules=M.arch_rules(cfg, kind),
        optimizer=opt, fsdp=cfg.fsdp, master_fp32=opt != "adafactor",
        remat=remat or cfg.remat, backend=backend,
        global_batch=global_batch, seq_len=seq_len,
        enc_seq=int(seq_len * cfg.encdec.enc_seq_ratio)
        if cfg.encdec else 0,
        kind=kind, max_len=seq_len, grad_accum=grad_accum,
        pp=M.pp_degree(mesh_shape), microbatches=microbatches,
        schedule=schedule, serve=serve, offload_opt=offload_opt)


def _resolve_shape(shape):
    """Accept a registered shape name or an ad-hoc ShapeConfig."""
    from repro.configs import SHAPES, ShapeConfig
    if isinstance(shape, ShapeConfig):
        return shape
    return SHAPES[shape]


def check(arch: str, shape_name, mesh_shape: dict,
          hbm_bytes: Optional[int] = None, policy: TrainPolicy = FULL_TRAIN,
          backend: str = "tpu", grad_accum: int = 1,
          remat: Optional[str] = None, optimizer: Optional[str] = None,
          chip: str = "v5e", headroom: float = HEADROOM,
          profile=None, microbatches: int = 1,
          schedule: str = "1f1b", serve=None,
          offload_opt: bool = False,
          assembly: str = "legacy", residual=None) -> PlanReport:
    """Reference single-cell evaluation: fresh build, no caches.

    ``shape_name`` may be a registered shape name ("train_4k") or a
    ShapeConfig; ``hbm_bytes`` overrides the ``chip`` lookup when given;
    ``profile`` (a repro.calibrate CalibrationProfile) corrects the
    prediction with measurement-fitted per-term coefficients + the
    ``chip`` constant, and ``residual`` (a repro.calibrate.learned
    ResidualModel) adds the learned per-family correction on top.  A
    mesh with a ``pipe`` axis is evaluated per-pipeline-stage
    (core.stages) and the worst stage reported.
    ``assembly="liveness"`` checks against the interval-overlap peak
    (core.liveness) instead of the Eq.1 sum-of-maxima.
    """
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config(arch)
    shape = _resolve_shape(shape_name)
    model = build_model(cfg)
    ctx = make_context(cfg, mesh_shape, kind=shape.kind,
                       global_batch=shape.global_batch,
                       seq_len=shape.seq_len, backend=backend,
                       grad_accum=grad_accum, remat=remat,
                       optimizer=optimizer, microbatches=microbatches,
                       schedule=schedule, serve=serve,
                       offload_opt=offload_opt)
    pred = PR.predict(model, policy, ctx, profile=profile, chip=chip,
                      assembly=assembly)
    if residual is not None:
        from repro.calibrate.learned import apply_residual
        pred = apply_residual(pred, residual, cfg.family, ctx,
                              profile=profile)
    budget = int((hbm_bytes if hbm_bytes is not None
                  else chip_hbm(chip)) * headroom)
    return PlanReport(arch=arch, shape=shape.name,
                      fits=pred.peak_bytes <= budget,
                      peak_bytes=pred.peak_bytes, budget_bytes=budget,
                      grad_accum=grad_accum, remat=remat or cfg.remat,
                      prediction=pred)


def plan(arch: str, shape_name, mesh_shape: dict,
         hbm_bytes: Optional[int] = None, policy: TrainPolicy = FULL_TRAIN,
         backend: str = "tpu", chip: str = "v5e",
         headroom: float = HEADROOM, engine=None,
         profile=None, assembly: str = "legacy",
         residual=None) -> PlanReport:
    """First-fit search over (remat, grad_accum); pure arithmetic.

    Delegates to the memoized sweep engine so the candidate evaluations
    share the parsed model and the batch-independent factor sums; pass
    ``engine`` (a SweepEngine) to share those caches across calls,
    ``profile`` to plan against calibrated predictions (plus
    ``residual`` for the learned per-family correction), and
    ``assembly="liveness"`` to plan against the interval-overlap peak.
    """
    from repro.core import sweep as SW
    from repro.configs import get_config

    shape = _resolve_shape(shape_name)
    budget = int((hbm_bytes if hbm_bytes is not None
                  else chip_hbm(chip)) * headroom)
    engine = engine or SW.SweepEngine()
    base = engine.report(arch, shape, mesh_shape, policy=policy,
                         backend=backend, budget_bytes=budget,
                         chip=chip, profile=profile, assembly=assembly,
                         residual=residual)
    if base.fits or shape.kind != "train":
        return base
    cfg = get_config(arch)
    for remat in dict.fromkeys((cfg.remat, "block")):
        for accum in (1, 2, 4, 8, 16, 32):
            if shape.global_batch % accum:
                continue
            r = engine.report(arch, shape, mesh_shape, policy=policy,
                              backend=backend, budget_bytes=budget,
                              grad_accum=accum, remat=remat,
                              chip=chip, profile=profile,
                              assembly=assembly, residual=residual)
            if r.fits:
                r.note = f"planner: accum x{accum} fits the budget"
                return r
    base.note = ("no (remat, grad_accum) configuration fits — needs a "
                 "bigger mesh, more sharding, or a leaner optimizer")
    return base


def _search_grid(arch: str, shape, chips, chip, policy, backend,
                 headroom, allow_pp, max_pp, allow_ep, max_ep, allow_cp,
                 max_cp, microbatches, schedules, profile,
                 global_batches=None):
    """The (mesh x knob) grid plan_min_chips / plan_frontier search,
    with the illegal expert/context factorizations FILTERED out (None
    when nothing legal remains)."""
    from repro.core import sweep as SW
    from repro.configs import get_config
    axes: tuple = ("data", "model")
    max_axis: dict = {}
    if allow_ep:
        axes += ("expert",)
        max_axis["expert"] = max_ep
    if allow_cp:
        axes += ("context",)
        max_axis["context"] = max_cp
    if allow_pp:
        axes += ("pipe",)
        max_axis["pipe"] = max_pp
    grid = SW.SweepGrid(
        arch=arch, chips=tuple(chips), mesh_axes=axes,
        max_axis=max_axis or None, chip=chip,
        microbatches=tuple(microbatches) if allow_pp else (1,),
        schedules=tuple(schedules) if allow_pp else ("1f1b",),
        global_batches=tuple(global_batches) if global_batches is not None
        else (shape.global_batch,),
        seq_lens=(shape.seq_len,),
        kind=shape.kind, policy=policy, backend=backend,
        headroom=headroom, profile=profile)
    if allow_ep or allow_cp:
        cfg = get_config(SW.normalize_arch(arch))

        def legal(mesh: dict) -> bool:
            try:
                check_parallel(cfg, mesh, shape.kind, shape.seq_len)
                return True
            except ValueError:
                return False

        meshes = [m for m in grid.meshes() if legal(m)]
        if not meshes:
            return None
        grid.mesh_shapes = meshes
    return grid


def plan_min_chips(arch: str, shape_name, chips=(4, 8, 16, 32, 64),
                   chip: str = "v5e", policy: TrainPolicy = FULL_TRAIN,
                   backend: str = "tpu", headroom: float = HEADROOM,
                   allow_pp: bool = True, max_pp: int = 8,
                   allow_ep: bool = False, max_ep: int = 8,
                   allow_cp: bool = False, max_cp: int = 8,
                   microbatches=(1, 4, 8), schedules=("1f1b", "gpipe"),
                   profile=None, engine=None, search: str = "pruned",
                   stats=None, compute_engine: str = "numpy"):
    """Smallest chip count that fits the shape, pipeline parallelism
    allowed: sweeps every (data, model[, expert][, context][, pipe])
    factorization of each candidate chip count x microbatch count x
    schedule and returns the Pareto-min
    :class:`~repro.core.sweep.SweepResult` (None if nothing fits).
    ``allow_pp=False`` restricts to the 2-axis plans, so
    ``plan_min_chips(...) vs plan_min_chips(..., allow_pp=False)``
    quantifies what the pipe axis buys; ``allow_ep=True`` and
    ``allow_cp=True`` add the expert and context axes the same way.

    This is a SEARCH, so unlike an explicit ``planner.check`` mesh the
    enumerated factorizations that :func:`check_parallel` would reject
    (an expert degree beyond the arch's routed experts — or any expert
    degree > 1 on a dense arch — and context degrees that don't divide
    the shape's seq_len or that land on a decode shape) are simply
    FILTERED out of the candidate set rather than aborting the whole
    search; the remaining legal plans are swept and the Pareto-min
    returned (None when nothing fits or nothing is legal).

    ``search="pruned"`` (default) answers through
    :func:`repro.core.search.min_chips_search` — statics-floor bounds
    prune hopeless chip counts and the scan stops at the first feasible
    count, returning an answer IDENTICAL to the exhaustive reduction
    (``search="exhaustive"``, the pre-pruner behaviour) at a fraction
    of the cells; pass a :class:`repro.core.search.SearchStats` as
    ``stats`` to see the work accounting, and ``compute_engine="jax"``
    to run the surviving slices on the jitted columnar engine."""
    from repro.core import search as SR
    from repro.core import sweep as SW
    shape = _resolve_shape(shape_name)
    grid = _search_grid(arch, shape, chips, chip, policy, backend,
                        headroom, allow_pp, max_pp, allow_ep, max_ep,
                        allow_cp, max_cp, microbatches, schedules,
                        profile)
    if grid is None:
        return None
    engine = engine or SW.SweepEngine()
    if search == "exhaustive":
        return engine.sweep(grid, engine=compute_engine).min_chips()
    if search != "pruned":
        raise ValueError(f"search must be 'pruned' or 'exhaustive', "
                         f"got {search!r}")
    return SR.min_chips_search(grid, engine=engine, stats=stats,
                               compute_engine=compute_engine)


def plan_frontier(arch: str, shape_name, chips=(4, 8, 16, 32, 64),
                  global_batches=None, chip: str = "v5e",
                  policy: TrainPolicy = FULL_TRAIN, backend: str = "tpu",
                  headroom: float = HEADROOM,
                  allow_pp: bool = True, max_pp: int = 8,
                  allow_ep: bool = False, max_ep: int = 8,
                  allow_cp: bool = False, max_cp: int = 8,
                  microbatches=(1, 4, 8), schedules=("1f1b", "gpipe"),
                  profile=None, engine=None, search: str = "pruned",
                  stats=None, compute_engine: str = "numpy") -> list:
    """(n_chips, max fitting global batch) frontier over the same plan
    space as :func:`plan_min_chips`, swept across ``global_batches``
    (default: powers of two down from the shape's batch).  The pruned
    search scans each chip count's batch axis descending and stops at
    the first fit — identical answers to the exhaustive
    ``SweepResults.frontier()`` (cross-checked in tests) without paying
    for the cells below each frontier point."""
    from repro.core import search as SR
    from repro.core import sweep as SW
    shape = _resolve_shape(shape_name)
    if global_batches is None:
        gb, global_batches = shape.global_batch, []
        while gb >= 1:
            global_batches.append(gb)
            if gb == 1:
                break
            gb //= 2
    grid = _search_grid(arch, shape, chips, chip, policy, backend,
                        headroom, allow_pp, max_pp, allow_ep, max_ep,
                        allow_cp, max_cp, microbatches, schedules,
                        profile, global_batches=tuple(global_batches))
    if grid is None:
        return []
    engine = engine or SW.SweepEngine()
    if search == "exhaustive":
        return engine.sweep(grid, engine=compute_engine).frontier()
    if search != "pruned":
        raise ValueError(f"search must be 'pruned' or 'exhaustive', "
                         f"got {search!r}")
    return SR.frontier_search(grid, engine=engine, stats=stats,
                              compute_engine=compute_engine)


@dataclass
class ConcurrencyReport:
    """Answer to "max concurrent sequences per replica on chip X"."""

    arch: str
    chip: str
    mesh_shape: dict
    kind: str
    seq_len: int
    max_concurrency: int          # 0 when even one sequence OOMs
    peak_bytes: int               # peak at max_concurrency (or at 1 if 0)
    budget_bytes: int
    serve: Optional[object] = None

    def __str__(self) -> str:
        return (f"{self.arch} on {self.chip} x {self.mesh_shape}: "
                f"{self.max_concurrency} concurrent seqs @ "
                f"{self.seq_len} tokens ({self.peak_bytes / GiB:.2f} / "
                f"{self.budget_bytes / GiB:.2f} GiB)")


def plan_max_concurrency(arch: str, seq_len: int,
                         mesh_shape: Optional[dict] = None,
                         chip: str = "v5e", kind: str = "decode",
                         serve=None, backend: str = "tpu",
                         policy: TrainPolicy = FULL_TRAIN,
                         headroom: float = HEADROOM, cap: int = 65536,
                         profile=None, engine=None,
                         stats=None) -> ConcurrencyReport:
    """Max concurrent sequences one replica sustains on ``chip`` —
    ROADMAP question 1.  Peak bytes are monotone nondecreasing in the
    concurrency along batches aligned to the mesh's shard product
    (every gb-bearing term has a nonnegative coefficient at a FIXED
    mesh, and at aligned batches the shard denominators are maximal),
    so :func:`repro.core.search.monotone_max` brackets the answer with
    a galloping + binary search over the aligned ladder and resolves
    the final window exactly — unlike a naive binary search over raw
    integers, this stays exact on batch-sharded meshes (``data > 1``),
    where peak(gb) is NOT monotone off the ladder."""
    from repro.configs import ShapeConfig
    from repro.core import search as SR
    from repro.core import sweep as SW
    engine = engine or SW.SweepEngine()
    mesh_shape = dict(mesh_shape or {"data": 1, "model": 1})
    budget = int(chip_hbm(chip) * headroom)

    def peak(gb: int) -> int:
        shape = ShapeConfig("concurrency", seq_len, gb, kind)
        rep = engine.report(arch, shape, mesh_shape, policy=policy,
                            backend=backend, budget_bytes=budget,
                            chip=chip, profile=profile, serve=serve)
        return rep.peak_bytes

    best = SR.max_concurrency_search(peak, budget, cap,
                                     mesh_shape=mesh_shape, stats=stats)
    return ConcurrencyReport(
        arch=arch, chip=chip, mesh_shape=mesh_shape, kind=kind,
        seq_len=seq_len, max_concurrency=best,
        peak_bytes=peak(best if best else 1),
        budget_bytes=budget, serve=serve)


@dataclass
class FleetReport:
    """Answer to "replicas needed for N QPS at p99 context length"."""

    arch: str
    chip: str
    mesh_shape: dict
    qps: float
    latency_s: float
    seq_len: int                  # plan at the p99 context length
    concurrent_requests: int      # Little's law: ceil(qps * latency)
    per_replica: int              # plan_max_concurrency answer
    replicas: int
    chips_per_replica: int
    total_chips: int
    serve: Optional[object] = None

    def __str__(self) -> str:
        return (f"{self.arch}: {self.qps:g} QPS x {self.latency_s:g}s = "
                f"{self.concurrent_requests} in flight / {self.per_replica}"
                f" per replica -> {self.replicas} replicas "
                f"({self.total_chips} x {self.chip})")


def plan_replicas(arch: str, qps: float, seq_len: int,
                  latency_s: float = 10.0,
                  mesh_shape: Optional[dict] = None, chip: str = "v5e",
                  kind: str = "decode", serve=None, backend: str = "tpu",
                  policy: TrainPolicy = FULL_TRAIN,
                  headroom: float = HEADROOM, profile=None,
                  engine=None) -> FleetReport:
    """Replicas needed to serve ``qps`` at the p99 context ``seq_len`` —
    ROADMAP question 2.  Little's law sizes the in-flight population
    (``L = qps x latency``); :func:`plan_max_concurrency` sizes one
    replica; the fleet is the ceiling of the quotient."""
    import math
    from repro.launch import mesh as M
    if qps <= 0 or latency_s <= 0:
        raise ValueError(
            f"qps ({qps}) and latency_s ({latency_s}) must be positive")
    per = plan_max_concurrency(arch, seq_len, mesh_shape=mesh_shape,
                               chip=chip, kind=kind, serve=serve,
                               backend=backend, policy=policy,
                               headroom=headroom, profile=profile,
                               engine=engine)
    if per.max_concurrency == 0:
        raise ValueError(
            f"{arch} cannot serve even one {seq_len}-token sequence on "
            f"{chip} x {per.mesh_shape} (peak "
            f"{per.peak_bytes / GiB:.2f} GiB vs budget "
            f"{per.budget_bytes / GiB:.2f} GiB) — use a bigger mesh or "
            f"chip")
    concurrent = max(math.ceil(qps * latency_s), 1)
    replicas = -(-concurrent // per.max_concurrency)
    chips = M.mesh_chips(per.mesh_shape)
    return FleetReport(
        arch=arch, chip=chip, mesh_shape=per.mesh_shape, qps=qps,
        latency_s=latency_s, seq_len=seq_len,
        concurrent_requests=concurrent, per_replica=per.max_concurrency,
        replicas=replicas, chips_per_replica=chips,
        total_chips=replicas * chips, serve=serve)


def adam_state_bytes(arch: str) -> int:
    """Analytic Adam fp32 state (m+v+master) for the full model — the
    arctic-480b infeasibility argument."""
    from repro.configs import get_config
    from repro.core.parser import parse_model, total_params
    from repro.models import build_model
    n = total_params(parse_model(build_model(get_config(arch)).spec,
                                 FULL_TRAIN))
    return n * 12
