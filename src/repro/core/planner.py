"""OoM guard + configuration planner — the paper's purpose, closed-loop.

``check`` predicts a cell's peak per-device memory BEFORE any compile or
launch and compares it to the chip's HBM.  ``plan`` searches the cheap
knobs (gradient accumulation, remat policy) for the first configuration
that fits, using only Eq.1 arithmetic — microseconds per candidate, vs a
failed cluster launch per guess without it.

This is also where arctic-480b's published memory plan comes from: Adam's
fp32 states alone (~5.2 TiB) can never fit a 256-chip v5e pod, which the
guard flags analytically; the shipped config therefore uses Adafactor +
2-axis FSDP (see EXPERIMENTS.md).
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

from repro.core import factors as F
from repro.core import predictor as PR
from repro.core.spec import FULL_TRAIN, TrainPolicy

GiB = 1024 ** 3
V5E_HBM = 16 * GiB
# XLA reserves working space; plan against a fraction of physical HBM.
HEADROOM = 0.92


@dataclass
class PlanReport:
    arch: str
    shape: str
    fits: bool
    peak_bytes: int
    budget_bytes: int
    grad_accum: int = 1
    remat: str = "block"
    note: str = ""
    prediction: Optional[PR.PredictedMemory] = None

    def __str__(self) -> str:
        verdict = "FITS" if self.fits else "OOM "
        return (f"[{verdict}] {self.arch} x {self.shape}: "
                f"peak {self.peak_bytes / GiB:.2f} GiB vs budget "
                f"{self.budget_bytes / GiB:.2f} GiB"
                + (f" (grad_accum={self.grad_accum}, remat={self.remat})"
                   if self.grad_accum > 1 else "")
                + (f" — {self.note}" if self.note else ""))


def _context(cfg, shape, mesh_shape, *, backend="tpu", grad_accum=1,
             remat=None, optimizer=None) -> F.PredictContext:
    from repro.launch import mesh as M
    opt = optimizer or cfg.optimizer
    return F.PredictContext(
        mesh_shape=mesh_shape, rules=M.arch_rules(cfg, shape.kind),
        optimizer=opt, fsdp=cfg.fsdp, master_fp32=opt != "adafactor",
        remat=remat or cfg.remat, backend=backend,
        global_batch=shape.global_batch, seq_len=shape.seq_len,
        enc_seq=int(shape.seq_len * cfg.encdec.enc_seq_ratio)
        if cfg.encdec else 0,
        kind=shape.kind, max_len=shape.seq_len, grad_accum=grad_accum)


def check(arch: str, shape_name: str, mesh_shape: dict,
          hbm_bytes: int = V5E_HBM, policy: TrainPolicy = FULL_TRAIN,
          backend: str = "tpu", grad_accum: int = 1,
          remat: Optional[str] = None) -> PlanReport:
    from repro.configs import SHAPES, get_config
    from repro.models import build_model

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    ctx = _context(cfg, shape, mesh_shape, backend=backend,
                   grad_accum=grad_accum, remat=remat)
    pred = PR.predict(model, policy, ctx)
    budget = int(hbm_bytes * HEADROOM)
    return PlanReport(arch=arch, shape=shape_name,
                      fits=pred.peak_bytes <= budget,
                      peak_bytes=pred.peak_bytes, budget_bytes=budget,
                      grad_accum=grad_accum, remat=remat or cfg.remat,
                      prediction=pred)


def plan(arch: str, shape_name: str, mesh_shape: dict,
         hbm_bytes: int = V5E_HBM, policy: TrainPolicy = FULL_TRAIN,
         backend: str = "tpu") -> PlanReport:
    """First-fit search over (remat, grad_accum); pure arithmetic."""
    from repro.configs import SHAPES, get_config
    shape = SHAPES[shape_name]
    base = check(arch, shape_name, mesh_shape, hbm_bytes, policy, backend)
    if base.fits or shape.kind != "train":
        return base
    cfg = get_config(arch)
    for remat in (cfg.remat, "block"):
        for accum in (1, 2, 4, 8, 16, 32):
            if shape.global_batch % accum:
                continue
            r = check(arch, shape_name, mesh_shape, hbm_bytes, policy,
                      backend, grad_accum=accum, remat=remat)
            if r.fits:
                r.note = f"planner: accum x{accum} fits the budget"
                return r
    base.note = ("no (remat, grad_accum) configuration fits — needs a "
                 "bigger mesh, more sharding, or a leaner optimizer")
    return base


def adam_state_bytes(arch: str) -> int:
    """Analytic Adam fp32 state (m+v+master) for the full model — the
    arctic-480b infeasibility argument."""
    from repro.configs import get_config
    from repro.core.parser import parse_model, total_params
    from repro.models import build_model
    n = total_params(parse_model(build_model(get_config(arch)).spec,
                                 FULL_TRAIN))
    return n * 12
