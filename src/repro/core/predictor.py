"""Peak-memory predictor (paper workflow step 6-7 + Eq. 1).

``predict(model, policy, ctx)`` evaluates the four factors for every parsed
layer and aggregates them with a schedule model of the compiled XLA step:

    peak = M_param + M_opt + M_grad                (persistent + backward)
         + M_act_saved (remat-aware scan carries)
         + max transient working set (one block's recomputed backward)
         + loss-head terms (hidden + one vocab-sharded logits chunk)
         + batch inputs (+ KV/SSM caches for serving)

Per-module subtotals are reported so the multimodal structure (frozen
vision tower vs. trainable language model) is visible, as in the paper.
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field
from typing import Optional

from repro.configs import ArchConfig
from repro.core import factors as F
from repro.core.parser import ParsedLayer, parse_model
from repro.core.spec import TrainPolicy
from repro.mesh_ctx import shard_factor

GiB = 1024 ** 3


@dataclass
class PredictedMemory:
    param_bytes: int = 0
    grad_bytes: int = 0
    opt_bytes: int = 0
    act_saved_bytes: int = 0
    act_transient_bytes: int = 0
    loss_bytes: int = 0
    input_bytes: int = 0
    cache_bytes: int = 0
    # updated trainable params: the optimizer writes NEW buffers while the
    # donated inputs are still live, so they cannot alias — one extra copy
    # of the trainable params exists at the end of every train step.
    output_copy_bytes: int = 0
    # per-chip constant overhead added by an applied CalibrationProfile
    # (repro.calibrate); 0 on the uncalibrated path.
    calibration_bytes: int = 0
    # learned per-family correction added by an applied ResidualModel
    # (repro.calibrate.learned), the structure left over AFTER the affine
    # profile; 0 (bit-inert) when no model is active.  May be negative.
    residual_bytes: int = 0
    # serving-fleet terms (0 unless ctx.serve is active): the paged
    # KV-pool allocation (replaces the slen-bearing cache terms, which
    # then report only their fixed non-paged remainder in cache_bytes)
    # and the speculative-decode draft model's residency (params + its
    # own pool) on the first stage.
    pool_bytes: int = 0
    draft_bytes: int = 0
    # informational: pool bytes the prefix-cache hit rate saved vs. the
    # same cell at hit-rate 0.  NOT part of peak_bytes.
    hit_saved_bytes: int = 0
    # liveness assembly (core.liveness): how much the legacy sum-of-maxima
    # OVERSTATES the true interval-overlap peak.  0 on the legacy path, so
    # legacy predictions stay bit-identical; under assembly="liveness"
    # peak_bytes is the component sum MINUS this slack, while the component
    # fields keep reporting the legacy breakdown they always did.
    overlap_slack_bytes: int = 0
    # Eq.1 offload tier: host-DRAM bytes of the offloaded optimizer
    # states (ctx.offload_opt).  Host memory, not HBM — NOT part of
    # peak_bytes, and a CalibrationProfile leaves it unscaled.
    offload_bytes: int = 0
    # pipeline-parallel provenance: which of n_stages stages this
    # prediction describes (0/1 on the non-pipelined path).  predict()
    # returns the max-peak stage; predict_stages() returns all of them.
    stage: int = 0
    n_stages: int = 1
    per_module: dict = field(default_factory=dict)
    # liveness assembly only: profile-term group -> bytes live at the
    # peak event (liveness.Replay.group_at_peak); sums to peak_bytes.
    # None on the legacy path — calibrate.residual uses it to build
    # liveness design rows without re-walking the event program.
    liveness_groups: Optional[dict] = None

    @property
    def peak_bytes(self) -> int:
        return (self.param_bytes + self.grad_bytes + self.opt_bytes
                + self.act_saved_bytes + self.act_transient_bytes
                + self.loss_bytes + self.input_bytes + self.cache_bytes
                + self.output_copy_bytes + self.calibration_bytes
                + self.residual_bytes
                + self.pool_bytes + self.draft_bytes
                - self.overlap_slack_bytes)

    def summary(self) -> str:
        rows = [("params", self.param_bytes), ("grads", self.grad_bytes),
                ("opt", self.opt_bytes), ("act_saved", self.act_saved_bytes),
                ("act_trans", self.act_transient_bytes),
                ("loss", self.loss_bytes), ("inputs", self.input_bytes),
                ("cache", self.cache_bytes),
                ("out_copy", self.output_copy_bytes),
                ("calib", self.calibration_bytes)]
        if self.residual_bytes:
            rows += [("learned", self.residual_bytes)]
        if self.pool_bytes or self.draft_bytes or self.hit_saved_bytes:
            rows += [("kv_pool", self.pool_bytes),
                     ("draft", self.draft_bytes),
                     ("hit_saved", self.hit_saved_bytes)]
        if self.offload_bytes:
            rows += [("host_opt", self.offload_bytes)]
        if self.overlap_slack_bytes:
            rows += [("ovl_slack", -self.overlap_slack_bytes)]
        rows += [("PEAK", self.peak_bytes)]
        out = "\n".join(f"  {k:<10s} {v / GiB:9.3f} GiB" for k, v in rows)
        if self.n_stages > 1:
            out = (f"  stage      {self.stage} of {self.n_stages} "
                   f"(pipeline max)\n") + out
        return out


# ---------------------------------------------------------------------------
# Symbolic term-spec builders.  Each returns cell-independent
# :class:`repro.core.factors.TermSpec` lists whose symbolic dims are
# resolved against a knob environment (``factors.term_env`` scalar-side,
# int64 column arrays in ``core.batch``).  The scalar helpers below
# evaluate the SAME specs — the columnar path cannot diverge from them.
# ---------------------------------------------------------------------------


def loss_specs(cfg: ArchConfig, kind: str) -> list[F.TermSpec]:
    """hidden (B,S,D) bf16 saved + one logits chunk fp32 (vocab-sharded),
    forward + backward transient; serve steps keep one (B, 1, V) fp32
    logits row instead."""
    if kind != "train":
        return [F.TermSpec(dims=("gb", 1, cfg.vocab),
                           axes=("batch", None, "vocab"), nbytes=4)]
    return [F.TermSpec(dims=("mb", "seq", cfg.d_model),
                       axes=("batch", "seq", None), nbytes=2),
            F.TermSpec(dims=("mb", "chunk", cfg.vocab),
                       axes=("batch", None, "vocab"), nbytes=4, mult=2)]


def cache_specs(rows: list[ParsedLayer]) -> list[F.TermSpec]:
    """KV / latent / SSM cache byte terms for serving steps.

    Shapes/axes mirror the runtime cache layouts exactly (5-D GQA stacks,
    4-D MLA latents, 5-D SSM states) so non-divisible head counts replicate
    in prediction just as they do in execution.  On the cpu oracle a decode
    step's bf16 KV stacks additionally exist as a hoisted fp32 twin
    (XLA:CPU float normalization + LICM) — the ``cache_mult`` env dim.
    """
    specs: list[F.TermSpec] = []
    for r in rows:
        meta = r.layer.meta
        rep = meta.get("cache_repeat", r.repeat)
        if r.layer.kind == "attention" and "kv_bytes_per_token" in meta:
            tok = "tok_cross" if meta.get("cross") else "slen"
            if meta.get("attn_kind") == "mla":
                mla = meta["mla"]
                width = mla.kv_lora_rank + mla.qk_rope_head_dim
                specs.append(F.TermSpec(                   # bf16 latent
                    dims=(rep, "gb", tok, width, "cache_mult"),
                    axes=("layers", "batch", "cache_seq", None, None),
                    nbytes=2))
            else:
                hkv, hd = meta["n_kv_heads"], meta["head_dim"]
                specs.append(F.TermSpec(                   # k + v, bf16
                    dims=(rep, "gb", tok, hkv, hd, "cache_mult"),
                    axes=("layers", "batch", "cache_seq", "kv_heads", None,
                          None),
                    nbytes=2, mult=2))
        elif r.layer.kind == "ssm":
            h, p, n_st = meta["n_heads"], meta["head_dim"], meta["d_state"]
            specs.append(F.TermSpec(                       # fp32 state
                dims=(rep, "gb", h, p, n_st),
                axes=("layers", "batch", "ssm", None, None), nbytes=4))
            specs.append(F.TermSpec(                       # bf16 conv tail
                dims=(rep, "gb", meta["d_conv"] - 1, meta["conv_ch"],
                      "cache_mult"),
                axes=("layers", "batch", None, "ffn", None), nbytes=2))
    return specs


def _is_paged(spec: F.TermSpec) -> bool:
    """A cache term is pool-managed iff it grows with the live context
    (carries the ``slen`` dim).  Fixed-footprint terms — cross-attention
    caches over the encoder, SSM states, conv tails — are allocated once
    per sequence and never enter the block pool."""
    return "slen" in spec.dims


def pool_specs(rows: list[ParsedLayer]) -> list[F.TermSpec]:
    """The slen-growing cache terms of :func:`cache_specs`, re-keyed onto
    the ``pool_tok`` env dim: effective tokens per sequence after the
    serve knobs (block padding, utilization slack, prefix-cache hits,
    request mix).  With a neutral serve spec ``pool_tok == slen`` and
    these terms are byte-identical to their contiguous originals."""
    out = []
    for s in cache_specs(rows):
        if _is_paged(s):
            out.append(F.TermSpec(
                dims=tuple("pool_tok" if d == "slen" else d
                           for d in s.dims),
                axes=s.axes, nbytes=s.nbytes, mult=s.mult))
    return out


def fixed_cache_specs(rows: list[ParsedLayer]) -> list[F.TermSpec]:
    """The non-paged remainder of :func:`cache_specs` (see _is_paged)."""
    return [s for s in cache_specs(rows) if not _is_paged(s)]


def decode_transient_groups(
        rows: list[ParsedLayer]) -> list[list[F.TermSpec]]:
    """Per-attention-row spec groups of a decode step's transients: fp32
    scores over the cache, the in-scan cache-slice update copy, and (naive
    MLA) the per-layer expanded K/V.  The live transient is the worst
    row's group sum."""
    groups: list[list[F.TermSpec]] = []
    for r in rows:
        meta = r.layer.meta
        if r.layer.kind != "attention":
            continue
        h = meta.get("n_heads", 1)
        group = [F.TermSpec(dims=("gb", h, "slen"),     # scores + softmax
                            axes=("batch", "heads", "cache_seq"),
                            nbytes=4, mult=2)]
        if meta.get("attn_kind") == "mla":
            mla = meta["mla"]
            qk = mla.qk_nope_head_dim + mla.qk_rope_head_dim
            group.append(F.TermSpec(
                dims=("gb", "slen", h, qk + mla.v_head_dim),
                axes=("batch", "cache_seq", "heads", None), nbytes=2))
        elif "n_kv_heads" in meta:
            # dynamic-update-slice inside the layer scan cannot alias the
            # carried stack slice -> one layer's k+v update copy is live
            hkv, hd = meta["n_kv_heads"], meta["head_dim"]
            group.append(F.TermSpec(
                dims=("gb", "slen", hkv, hd),
                axes=("batch", "cache_seq", "kv_heads", None),
                nbytes=2, mult=2))
        groups.append(group)
    return groups


def boundary_specs(cfg: ArchConfig, kind: str) -> list[F.TermSpec]:
    """One stage-boundary activation buffer of the pipeline: the residual
    stream crossing a stage edge.  Train steps transfer one microbatch's
    (mb, S, D) bf16 block per edge (and the matching gradient on the way
    back — the x2 lives in :func:`repro.core.stages.boundary_edges`
    callers); prefill sends the full-batch block, decode one token row.
    """
    if kind == "decode":
        return [F.TermSpec(dims=("gb", 1, cfg.d_model),
                           axes=("batch", "seq", None), nbytes=2)]
    if kind == "prefill":
        return [F.TermSpec(dims=("gb", "seq", cfg.d_model),
                           axes=("batch", "seq", None), nbytes=2)]
    return [F.TermSpec(dims=("mb", "seq", cfg.d_model),
                       axes=("batch", "seq", None), nbytes=2)]


def boundary_mult(stage: int, pp: int, kind: str) -> int:
    """Live boundary-buffer count for a stage: edges touching it, doubled
    in training (forward activation + backward gradient per edge)."""
    from repro.core import stages as ST
    return ST.boundary_edges(stage, pp) * (2 if kind == "train" else 1)


def _boundary_bytes(cfg: ArchConfig, ctx: F.PredictContext, kind: str,
                    stage: int, n_stages: int) -> int:
    mult = boundary_mult(stage, n_stages, kind)
    if not mult:
        return 0
    env = F.term_env(ctx)
    return mult * sum(F.eval_term(s, env, ctx.mesh_shape, ctx.rules)
                      for s in boundary_specs(cfg, kind))


def embed_gather_const(rows: list[ParsedLayer], backend: str) -> int:
    """Tied (vocab-sharded) embedding tables are fully all-gathered by the
    token lookup — fp32 on the cpu oracle (float normalization).  Constant
    per (rows, backend): no cell knob touches it."""
    total = 0
    for r in rows:
        meta = r.layer.meta
        if r.layer.kind == "embedding" and meta.get("lookup_gather"):
            per = 4 if backend == "cpu" else 2
            total += meta["vocab"] * meta["d_model"] * per
    return total


# ---------------------------------------------------------------------------
# scalar evaluation of the spec groups above
# ---------------------------------------------------------------------------


def _loss_terms(cfg: ArchConfig, ctx: F.PredictContext) -> int:
    env = F.term_env(ctx)
    return sum(F.eval_term(s, env, ctx.mesh_shape, ctx.rules)
               for s in loss_specs(cfg, ctx.kind))


def _input_bytes(model, shape_kind: str, ctx: F.PredictContext) -> int:
    """Bytes of the batch arguments, sharded over batch.  Under pipeline
    parallelism the first stage stages one microbatch's inputs at a time
    (``eff_microbatches == 1`` without a pipeline, so this is the full
    batch on the non-pipelined path)."""
    from repro.configs import ShapeConfig
    shape = ShapeConfig(
        "tmp", ctx.seq_len,
        max(ctx.global_batch // ctx.eff_microbatches, 1), shape_kind)
    total = 0
    for arr in model.batch_spec(shape).values():
        denom = shard_factor(arr.shape,
                             ("batch",) + (None,) * (len(arr.shape) - 1),
                             ctx.mesh_shape, ctx.rules)
        total += math.prod(arr.shape) * arr.dtype.itemsize // max(denom, 1)
    return total


def _cache_bytes(model, ctx: F.PredictContext,
                 rows: list[ParsedLayer]) -> int:
    if ctx.kind == "train":
        return 0
    env = F.term_env(ctx)
    specs = fixed_cache_specs(rows) if ctx.serve is not None \
        else cache_specs(rows)
    return sum(F.eval_term(s, env, ctx.mesh_shape, ctx.rules)
               for s in specs)


def _pool_terms(rows: list[ParsedLayer],
                ctx: F.PredictContext) -> tuple[int, int]:
    """(pool_bytes, hit_saved_bytes) of the paged KV pool — the
    slen-growing cache terms re-priced at ``pool_tok`` tokens per
    sequence.  hit_saved is the delta vs. the same cell with the
    prefix-cache hit rate forced to 0 (informational, not in peak)."""
    if ctx.kind == "train" or ctx.serve is None:
        return 0, 0
    import dataclasses
    from repro.serve.pool import pool_tokens
    specs = pool_specs(rows)
    env = F.term_env(ctx)
    pool = sum(F.eval_term(s, env, ctx.mesh_shape, ctx.rules)
               for s in specs)
    saved = 0
    if ctx.serve.hit_bp:
        env0 = dict(env)
        env0["pool_tok"] = pool_tokens(
            ctx.max_len or ctx.seq_len,
            dataclasses.replace(ctx.serve, hit_bp=0))
        saved = sum(F.eval_term(s, env0, ctx.mesh_shape, ctx.rules)
                    for s in specs) - pool
    return pool, saved


@functools.lru_cache(maxsize=16)
def _draft_state(arch: str, kind: str):
    """(cfg, rows, rules) of a speculative-decode draft model — memoized:
    a pure function of (arch, kind), parsed under FULL_TRAIN (trainability
    is irrelevant at serve kinds, where grads/opt are zero by kind)."""
    from repro.configs import get_config
    from repro.core.spec import FULL_TRAIN
    from repro.launch.mesh import arch_rules
    from repro.models import build_model
    cfg = get_config(arch)
    rows = parse_model(build_model(cfg).spec, FULL_TRAIN)
    return cfg, rows, arch_rules(cfg, kind)


def draft_residency_bytes(ctx: F.PredictContext) -> int:
    """Speculative-decode draft-model residency: the draft's (frozen)
    params under ITS OWN sharding rules + fsdp flag, plus its KV pool and
    fixed caches under the same serve knobs (minus draft_arch — drafts
    don't nest).  Lives on the first pipeline stage with the inputs."""
    serve = ctx.serve
    if serve is None or not serve.draft_arch:
        return 0
    import dataclasses
    from repro.core.sweep import normalize_arch
    dcfg, drows, drules = _draft_state(normalize_arch(serve.draft_arch),
                                       ctx.kind)
    dctx = dataclasses.replace(
        ctx, rules=drules, fsdp=dcfg.fsdp,
        serve=dataclasses.replace(serve, draft_arch=""))
    params = sum(F.param_factor(r, dctx) for r in drows)
    env = F.term_env(dctx)
    caches = sum(F.eval_term(s, env, dctx.mesh_shape, dctx.rules)
                 for s in pool_specs(drows) + fixed_cache_specs(drows))
    return params + caches


def _decode_transients(rows: list[ParsedLayer], ctx: F.PredictContext) -> int:
    env = F.term_env(ctx)
    worst = 0
    for group in decode_transient_groups(rows):
        t = sum(F.eval_term(s, env, ctx.mesh_shape, ctx.rules)
                for s in group)
        worst = max(worst, t)
    return worst


def _embed_gather_bytes(rows: list[ParsedLayer],
                        ctx: F.PredictContext) -> int:
    return embed_gather_const(rows, ctx.backend)


# ---------------------------------------------------------------------------
# Component terms.  ``predict`` is a pure composition of the three term
# groups below; they are split out (and returned as immutable dataclasses)
# so the capacity-planning sweep engine (core.sweep) can memoize each group
# independently — the static terms don't change with batch/remat, the
# activation terms don't change with optimizer — while staying byte-identical
# to a monolithic evaluation, because this is the only implementation.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StaticTerms:
    """Per-run-invariant factors: params, grads, optimizer states.

    Depends on (rows, mesh, rules, optimizer, fsdp, master_fp32,
    eff_grad_bytes, kind) — NOT on batch size, seq_len, or remat.
    """

    param_bytes: int
    grad_bytes: int
    opt_bytes: int
    output_copy_bytes: int
    # host-DRAM residency of the offloaded optimizer states (the Eq.1
    # offload tier); 0 unless ctx.offload_opt, in which case opt_bytes
    # above is the staged device window over this total.
    host_opt_bytes: int = 0
    # ((module_path, param, grad, opt, trainable), ...) in row order
    per_module: tuple = ()


@dataclass(frozen=True)
class ActTermsAgg:
    """Activation factors: saved-for-backward + worst transient working set.

    Depends on (rows, mesh, rules, micro_batch, seq_len, remat, backend,
    kind) — NOT on the optimizer.
    """

    saved_bytes: int
    transient_bytes: int
    # ((module_path, act_bytes), ...) in row order
    per_module: tuple = ()


@dataclass(frozen=True)
class OverheadTerms:
    """Loss head, batch inputs, serve caches, embed all-gathers, and (on
    pipeline stages) the stage-boundary send/recv buffers."""

    loss_bytes: int
    input_bytes: int
    cache_bytes: int
    embed_gather_bytes: int
    boundary_bytes: int = 0
    # serving-fleet terms (ctx.serve active): paged pool on the stage's
    # rows, draft residency on the first stage, prefix-hit savings info
    pool_bytes: int = 0
    draft_bytes: int = 0
    hit_saved_bytes: int = 0


def compute_static(rows: list[ParsedLayer],
                   ctx: F.PredictContext) -> StaticTerms:
    param = grad = opt = out_copy = 0
    per: dict[str, list] = {}
    for r in rows:
        p = F.param_factor(r, ctx)
        g = F.grad_factor(r, ctx)
        o = F.opt_factor(r, ctx)
        if ctx.kind == "train" and r.trainable:
            out_copy += p
        param += p
        grad += g
        opt += o
        m = per.setdefault(r.module_path, [0, 0, 0, r.trainable])
        m[0] += p
        m[1] += g
        m[2] += o
    host = 0
    if ctx.offload_opt and opt:
        # Eq.1 offload tier: the (already TP/ZeRO-sharded) state total
        # moves to host DRAM; the device keeps the double-buffered
        # streaming window.  per_module keeps reporting the pre-offload
        # residency — it documents where the bytes COME from.
        host, opt = opt, F.offload_staged_bytes(opt)
    return StaticTerms(
        param_bytes=param, grad_bytes=grad, opt_bytes=opt,
        output_copy_bytes=out_copy, host_opt_bytes=host,
        per_module=tuple((k, v[0], v[1], v[2], v[3])
                         for k, v in per.items()))


def compute_acts(rows: list[ParsedLayer], ctx: F.PredictContext,
                 kind: str, stash: int = 1) -> ActTermsAgg:
    """``stash`` multiplies the saved-for-backward set: the number of
    in-flight microbatch activation copies a pipeline stage holds under
    its schedule (``core.stages.stash_count``; 1 without a pipeline)."""
    saved = 0
    per: dict[str, int] = {}
    for r in rows:
        a = F.act_factor_saved(r, ctx) * stash
        saved += a
        per[r.module_path] = per.get(r.module_path, 0) + a

    if ctx.kind == "train":
        # one block's recomputed backward (or fwd-only if frozen) is the
        # live transient while the scan walks backward: scanned rows sum
        # per module (the whole block recomputes), unscanned rows stand
        # alone
        worst = 0
        block_sums: dict[str, int] = {}
        for r in rows:
            t = F.act_factor_transient(r, ctx)
            if r.scanned:
                block_sums[r.module_path] = \
                    block_sums.get(r.module_path, 0) + t
            else:
                worst = max(worst, t)
        transient = max(worst, max(block_sums.values(), default=0))
    elif kind == "decode":
        transient = _decode_transients(rows, ctx)
    else:  # prefill: no backward — transient = one block's forward set
        per_block: dict[str, int] = {}
        for r in rows:
            if r.scanned:
                per_block[r.module_path] = per_block.get(r.module_path, 0) \
                    + F.act_factor_transient(r, ctx)
        transient = max(per_block.values()) if per_block else 0
    return ActTermsAgg(saved_bytes=saved, transient_bytes=transient,
                       per_module=tuple(per.items()))


def compute_overheads(model, rows: list[ParsedLayer],
                      ctx: F.PredictContext, kind: str, stage: int = 0,
                      n_stages: int = 1) -> OverheadTerms:
    """Overhead terms of one pipeline stage (the whole model by default):
    batch inputs live on the first stage, the loss head on the last,
    caches/embed-gathers wherever their rows landed, boundary buffers on
    every stage with a pipeline edge."""
    first = stage == 0
    last = stage == n_stages - 1
    pool, hit_saved = _pool_terms(rows, ctx)
    return OverheadTerms(
        loss_bytes=_loss_terms(model.cfg, ctx) if last else 0,
        input_bytes=_input_bytes(model, kind, ctx) if first else 0,
        cache_bytes=_cache_bytes(model, ctx, rows),
        embed_gather_bytes=_embed_gather_bytes(rows, ctx),
        boundary_bytes=_boundary_bytes(model.cfg, ctx, kind, stage,
                                       n_stages),
        pool_bytes=pool,
        draft_bytes=draft_residency_bytes(ctx) if first else 0,
        hit_saved_bytes=hit_saved)


def liveness_values(static: StaticTerms, acts: ActTermsAgg,
                    over: OverheadTerms, ctx: F.PredictContext,
                    pred: PredictedMemory = None, profile=None) -> dict:
    """Component byte values for the liveness event program
    (``core.liveness.COMPONENTS``).  With ``pred``+``profile`` given the
    values are the CALIBRATED ones: per-field scales come straight off the
    applied prediction and the act_transient group members are telescoped
    (``liveness.telescoped_transient``) so they sum back to the legacy
    group scale byte-exactly."""
    from repro.core import liveness as LV
    opt_trans = int(ctx.opt_transient_frac * static.opt_bytes)
    raw_trans = {"embed": over.embed_gather_bytes,
                 "boundary": over.boundary_bytes,
                 "transient": acts.transient_bytes,
                 "opt_transient": opt_trans}
    if profile is None:
        return {
            "base": (static.param_bytes + static.grad_bytes
                     + static.opt_bytes),
            "inputs": over.input_bytes, "cache": over.cache_bytes,
            "pool": over.pool_bytes, "draft": over.draft_bytes,
            "saved": acts.saved_bytes, "loss": over.loss_bytes,
            "out_copy": static.output_copy_bytes, **raw_trans,
        }
    c_t = profile.coef("act_transient")
    return {
        # chip constant: persistent allocator overhead -> rides the base
        "base": (pred.param_bytes + pred.grad_bytes + pred.opt_bytes
                 + pred.calibration_bytes),
        "inputs": pred.input_bytes, "cache": pred.cache_bytes,
        "pool": pred.pool_bytes, "draft": pred.draft_bytes,
        "saved": pred.act_saved_bytes, "loss": pred.loss_bytes,
        "out_copy": pred.output_copy_bytes,
        **LV.telescoped_transient(raw_trans,
                                  lambda v: int(round(v * c_t))),
    }


def assemble(static: StaticTerms, acts: ActTermsAgg, over: OverheadTerms,
             ctx: F.PredictContext, profile=None,
             chip: str = None, stage: int = 0,
             n_stages: int = 1, assembly: str = "legacy") -> PredictedMemory:
    """Compose the component groups into a prediction; when a
    CalibrationProfile (repro.calibrate.profile) is given, its per-term
    corrections + the ``chip`` constant are applied to the RAW composition
    (duck-typed — the profile scales, this module never imports it).

    ``assembly`` selects the peak model: ``"legacy"`` (default) keeps the
    Eq.1 sum-of-maxima bit-identical to every golden; ``"liveness"``
    replays the interval-overlap event program (core.liveness) and records
    the overestimate as ``overlap_slack_bytes``, so ``peak_bytes`` becomes
    the true overlap peak while the component breakdown stays legacy."""
    out = PredictedMemory(
        param_bytes=static.param_bytes, grad_bytes=static.grad_bytes,
        opt_bytes=static.opt_bytes,
        act_saved_bytes=acts.saved_bytes,
        # optimizer-update in-flight fp32 stacks (cpu oracle; ZeRO-sharded)
        # + pipeline boundary send/recv buffers: transient working set
        act_transient_bytes=(acts.transient_bytes
                             + over.embed_gather_bytes
                             + over.boundary_bytes
                             + int(ctx.opt_transient_frac
                                   * static.opt_bytes)),
        loss_bytes=over.loss_bytes, input_bytes=over.input_bytes,
        cache_bytes=over.cache_bytes,
        output_copy_bytes=static.output_copy_bytes,
        pool_bytes=over.pool_bytes, draft_bytes=over.draft_bytes,
        hit_saved_bytes=over.hit_saved_bytes,
        offload_bytes=static.host_opt_bytes,
        stage=stage, n_stages=n_stages)
    for path, p, g, o, trainable in static.per_module:
        out.per_module[path] = {"param": p, "grad": g, "opt": o, "act": 0,
                                "trainable": trainable}
    for path, a in acts.per_module:
        out.per_module[path]["act"] = a
    if profile is not None:
        out = profile.apply(out, chip)
    if assembly == "liveness":
        from repro.core import liveness as LV
        vals = liveness_values(static, acts, over, ctx, pred=out,
                               profile=profile)
        rep = LV.replay(LV.compile_program(ctx.kind), vals)
        slack = out.peak_bytes - rep.peak
        # every event prefix is a sub-sum of the non-negative component
        # values whose total IS the legacy peak -> slack can never go
        # negative; this is the soundness invariant docs/search.md leans on
        assert slack >= 0, (slack, vals)
        out.overlap_slack_bytes = slack
        out.liveness_groups = dict(rep.group_at_peak)
    elif assembly != "legacy":
        raise ValueError(f"unknown assembly {assembly!r}; "
                         f"expected one of ('legacy', 'liveness')")
    return out


def predict_stages(model, policy: TrainPolicy, ctx: F.PredictContext,
                   shape_kind: str = None,
                   rows: list[ParsedLayer] = None, profile=None,
                   chip: str = None,
                   assembly: str = "legacy") -> list[PredictedMemory]:
    """One prediction per pipeline stage (a single-element list when
    ``ctx.pp == 1`` — that element is bit-equal to the non-pipelined
    path, because it IS the non-pipelined path)."""
    from repro.core import stages as ST
    if rows is None:
        rows = parse_model(model.spec, policy)
    kind = shape_kind or ctx.kind
    if ctx.pp <= 1:
        return [assemble(compute_static(rows, ctx),
                         compute_acts(rows, ctx, kind),
                         compute_overheads(model, rows, ctx, kind), ctx,
                         profile=profile, chip=chip, assembly=assembly)]
    plan = ST.partition(rows, ctx.pp)
    out = []
    for s, srows in enumerate(plan.stages):
        srows = list(srows)
        stash = ST.stash_count(s, ctx.pp, ctx.eff_microbatches,
                               ctx.schedule)
        out.append(assemble(
            compute_static(srows, ctx),
            compute_acts(srows, ctx, kind, stash=stash),
            compute_overheads(model, srows, ctx, kind, stage=s,
                              n_stages=ctx.pp),
            ctx, profile=profile, chip=chip, stage=s, n_stages=ctx.pp,
            assembly=assembly))
    return out


def predict(model, policy: TrainPolicy, ctx: F.PredictContext,
            shape_kind: str = None,
            rows: list[ParsedLayer] = None, profile=None,
            chip: str = None, assembly: str = "legacy") -> PredictedMemory:
    """Peak prediction: the worst stage under pipeline parallelism (the
    whole model when ``ctx.pp == 1``); ties keep the earliest stage.
    Under ``assembly="liveness"`` the comparison key is the liveness peak
    (``peak_bytes`` already nets out ``overlap_slack_bytes``)."""
    preds = predict_stages(model, policy, ctx, shape_kind=shape_kind,
                           rows=rows, profile=profile, chip=chip,
                           assembly=assembly)
    best = preds[0]
    for p in preds[1:]:
        if p.peak_bytes > best.peak_bytes:
            best = p
    return best


def per_device(pred: PredictedMemory) -> int:
    return pred.peak_bytes
