"""Prediction-vs-ground-truth reporting (paper section 4)."""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

GiB = 1024 ** 3


@dataclass
class PredictionRecord:
    label: str
    predicted_bytes: int
    actual_bytes: int

    @property
    def ape(self) -> float:
        """Absolute percentage error."""
        if self.actual_bytes == 0:
            return 0.0
        return abs(self.predicted_bytes - self.actual_bytes) \
            / self.actual_bytes * 100.0


def mape(records: list[PredictionRecord]) -> float:
    if not records:
        return 0.0
    return float(np.mean([r.ape for r in records]))


def grouped_mape(groups: dict[str, list[PredictionRecord]]
                 ) -> list[tuple[str, int, float]]:
    """(group, n, MAPE%) rows, sorted by group — the per-arch/per-family
    accuracy table the calibration reporter emits (paper section 4)."""
    return [(k, len(v), mape(v)) for k, v in sorted(groups.items())]


def table(records: list[PredictionRecord], title: str = "") -> str:
    lines = []
    if title:
        lines.append(f"## {title}")
    lines.append(f"{'label':<40s} {'pred GiB':>10s} {'actual GiB':>11s} "
                 f"{'APE %':>7s}")
    for r in records:
        lines.append(f"{r.label:<40s} {r.predicted_bytes / GiB:>10.3f} "
                     f"{r.actual_bytes / GiB:>11.3f} {r.ape:>7.2f}")
    lines.append(f"{'MAPE':<40s} {'':>10s} {'':>11s} {mape(records):>7.2f}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Generic table writers (used by core.sweep's report output).
# ---------------------------------------------------------------------------


def markdown_table(headers, rows, title: str = "") -> str:
    """GitHub-flavoured markdown table from header names + row tuples."""
    headers = [str(h) for h in headers]
    body = [[str(c) for c in r] for r in rows]
    widths = [max(len(h), *(len(r[i]) for r in body)) if body else len(h)
              for i, h in enumerate(headers)]
    def line(cells):
        return "| " + " | ".join(c.ljust(w) for c, w in zip(cells, widths)) \
            + " |"
    out = []
    if title:
        out += [f"## {title}", ""]
    out.append(line(headers))
    out.append("|" + "|".join("-" * (w + 2) for w in widths) + "|")
    out.extend(line(r) for r in body)
    return "\n".join(out)


def csv_table(headers, rows) -> str:
    """CSV from header names + row tuples (no quoting — numeric/simple
    cells only, which is all the sweep emits)."""
    out = [",".join(str(h) for h in headers)]
    out.extend(",".join(str(c) for c in r) for r in rows)
    return "\n".join(out)


def csv(records: list[PredictionRecord]) -> str:
    out = ["label,predicted_bytes,actual_bytes,ape_pct"]
    for r in records:
        out.append(f"{r.label},{r.predicted_bytes},{r.actual_bytes},"
                   f"{r.ape:.3f}")
    out.append(f"MAPE,,,{mape(records):.3f}")
    return "\n".join(out)
