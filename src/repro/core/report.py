"""Prediction-vs-ground-truth reporting (paper section 4)."""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

GiB = 1024 ** 3


@dataclass
class PredictionRecord:
    label: str
    predicted_bytes: int
    actual_bytes: int

    @property
    def ape(self) -> float:
        """Absolute percentage error.  A record with no usable ground
        truth (``actual_bytes <= 0``) has no defined error — it returns
        NaN, never the 0.0 that once let a defective zero-measured record
        read as a PERFECT prediction and deflate every MAPE built on it.
        """
        if self.actual_bytes <= 0:
            return float("nan")
        return abs(self.predicted_bytes - self.actual_bytes) \
            / self.actual_bytes * 100.0


def split_valid(records: list[PredictionRecord]
                ) -> tuple[list[PredictionRecord], int]:
    """(records with usable ground truth, count excluded).  Zero/negative
    actuals are measurement defects: they are EXCLUDED from aggregate
    error arithmetic and reported as a count, never averaged in."""
    valid = [r for r in records if r.actual_bytes > 0]
    return valid, len(records) - len(valid)


def mape(records: list[PredictionRecord]) -> float:
    valid, _ = split_valid(records)
    if not valid:
        return 0.0
    return float(np.mean([r.ape for r in valid]))


def grouped_mape(groups: dict[str, list[PredictionRecord]]
                 ) -> list[tuple[str, int, float]]:
    """(group, n_valid, MAPE%) rows, sorted by group — the per-arch/
    per-family accuracy table the calibration reporter emits (paper
    section 4).  ``n_valid`` counts only records with usable ground
    truth (see :func:`split_valid`)."""
    out = []
    for k, v in sorted(groups.items()):
        valid, _ = split_valid(v)
        out.append((k, len(valid), mape(valid)))
    return out


def table(records: list[PredictionRecord], title: str = "") -> str:
    lines = []
    if title:
        lines.append(f"## {title}")
    lines.append(f"{'label':<40s} {'pred GiB':>10s} {'actual GiB':>11s} "
                 f"{'APE %':>7s}")
    for r in records:
        lines.append(f"{r.label:<40s} {r.predicted_bytes / GiB:>10.3f} "
                     f"{r.actual_bytes / GiB:>11.3f} {r.ape:>7.2f}")
    lines.append(f"{'MAPE':<40s} {'':>10s} {'':>11s} {mape(records):>7.2f}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Generic table writers (used by core.sweep's report output).
# ---------------------------------------------------------------------------


def markdown_table(headers, rows, title: str = "") -> str:
    """GitHub-flavoured markdown table from header names + row tuples."""
    headers = [str(h) for h in headers]
    body = [[str(c) for c in r] for r in rows]
    widths = [max(len(h), *(len(r[i]) for r in body)) if body else len(h)
              for i, h in enumerate(headers)]
    def line(cells):
        return "| " + " | ".join(c.ljust(w) for c, w in zip(cells, widths)) \
            + " |"
    out = []
    if title:
        out += [f"## {title}", ""]
    out.append(line(headers))
    out.append("|" + "|".join("-" * (w + 2) for w in widths) + "|")
    out.extend(line(r) for r in body)
    return "\n".join(out)


def csv_table(headers, rows) -> str:
    """CSV from header names + row tuples (no quoting — numeric/simple
    cells only, which is all the sweep emits)."""
    out = [",".join(str(h) for h in headers)]
    out.extend(",".join(str(c) for c in r) for r in rows)
    return "\n".join(out)


def csv(records: list[PredictionRecord]) -> str:
    out = ["label,predicted_bytes,actual_bytes,ape_pct"]
    for r in records:
        out.append(f"{r.label},{r.predicted_bytes},{r.actual_bytes},"
                   f"{r.ape:.3f}")
    out.append(f"MAPE,,,{mape(records):.3f}")
    return "\n".join(out)
