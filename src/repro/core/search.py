"""Monotone branch-and-bound searches over the Eq.1 knob lattice.

Pareto queries (``plan_min_chips``, ``plan_max_concurrency``, the
chips -> max-batch frontier) were answered by brute-force enumeration:
sweep the full knob cross-product, then reduce.  The byte terms have
exploitable structure —

* **statics floor**: every param / grad / optimizer-state byte lives in
  exactly one pipeline stage and is sharded by at most ``N / pp``
  within it, so the peak stage of ANY cell on ``N`` chips satisfies
  ``peak >= total_static_bytes // N`` (max >= mean over stages).  A chip
  count whose floor already exceeds the budget cannot contain a fitting
  cell and its whole slice is pruned without evaluation;
* **aligned-ladder monotonicity**: at a fixed mesh, every
  global-batch-bearing term is ``(gb-monotone numerator) // denom``
  where the denominator depends on gb only through divisibility.  At
  ``gb`` aligned to ``L`` = the product of the mesh's non-pipe axis
  sizes, every divisibility check a gb-derived dim can ever pass
  passes, so denominators are maximal and
  ``peak(gb) >= peak(L * (gb // L))`` for all gb, while peak is
  monotone *along* the multiples of L.  Binary search over the ladder
  brackets the answer into one L-window, which a descending scan
  resolves exactly — O(log(cap) + L) evaluations instead of O(cap),
  and exact for sharded-batch meshes where a naive binary search over
  raw integers is NOT sound (tests/test_search.py exhibits the
  non-monotone counterexample).

Both bounds are invariants, not heuristics: the searches return answers
*identical* to exhaustive enumeration (same cell, same tie-breaking),
cross-checked by the ``oracle=True`` mode which runs the brute-force
reduction next to the pruned one and asserts equality — enabled on
every tier-1 query in tests/test_search.py and gated at >= 20x fewer
cells evaluated in benchmarks/sweep_throughput.py --search (the
BENCH_search CI artifact).  The invariants themselves are
property-tested (tests/test_monotone_property.py) so a new knob that
breaks them fails CI before it can mis-prune; docs/search.md documents
how to add a monotone knob safely.

Pruning is disabled (searches degrade to exhaustive slicing, still
early-exiting) when a CalibrationProfile is active — fitted
coefficients and chip offsets void the raw-byte floor — so calibrated
answers stay unconditionally exact too.

Both bounds survive the liveness assembly (``grid.assembly ==
"liveness"``) unchanged: its peak is the max running-sum prefix of the
alloc/free event program, and the FIRST prefix already holds the
stage's persistent base (params + grads + optimizer states), so
``liveness peak >= per-stage statics`` and the ``floor // n`` bound
still under-approximates every cell (out-copy bytes are excluded from
the floor, so the base alone covers it).  For the ladder, every prefix
is a sub-sum of gb-aligned-monotone terms and a max of monotone
functions is monotone, so ``monotone_max`` stays exact.  The engines
assert the ordering per cell (``liveness <= legacy``, the
``overlap_slack_bytes >= 0`` invariant in ``predictor.assemble`` /
``batch.sweep_columnar``) and tests/test_search.py re-runs the oracle
searches under the liveness assembly (docs/search.md, "Adding a
monotone knob safely").
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field, replace

__all__ = [
    "SearchStats", "static_floor_bytes", "min_chips_search",
    "frontier_search", "monotone_max", "batch_align",
]


@dataclass
class SearchStats:
    """Work accounting for one pruned search (aggregated across queries
    when shared).  ``cells_evaluated + cells_pruned`` equals the cell
    count exhaustive enumeration would have paid for the same query."""

    cells_evaluated: int = 0     # cells actually swept
    cells_pruned: int = 0        # cells skipped via bounds / early exit
    probes: int = 0              # scalar report() evaluations
    bound_evals: int = 0         # statics-floor bound computations
    notes: list = field(default_factory=list)

    @property
    def total_cells(self) -> int:
        return self.cells_evaluated + self.cells_pruned

    @property
    def reduction(self) -> float:
        """Exhaustive-cells / evaluated-cells ratio (inf when the whole
        domain was pruned)."""
        work = self.cells_evaluated + self.probes
        if work == 0:
            return float("inf")
        return self.total_cells / work

    def merge(self, other: "SearchStats") -> None:
        self.cells_evaluated += other.cells_evaluated
        self.cells_pruned += other.cells_pruned
        self.probes += other.probes
        self.bound_evals += other.bound_evals
        self.notes.extend(other.notes)


# ---------------------------------------------------------------------------
# statics floor
# ---------------------------------------------------------------------------


#: lower bound on ``PredictContext.eff_grad_bytes``: bf16 grads when no
#: accumulation splits the step, fp32 accumulators otherwise — min(2, 4)
_GRAD_FLOOR_BYTES = 2


@functools.lru_cache(maxsize=None)
def _parsed_rows(arch: str, policy) -> tuple:
    from repro.configs import get_config
    from repro.core.parser import parse_model
    from repro.core.sweep import normalize_arch
    from repro.models import build_model

    return tuple(parse_model(
        build_model(get_config(normalize_arch(arch))).spec, policy))


@functools.lru_cache(maxsize=None)
def static_floor_bytes(arch: str, policy, kind: str = "train",
                       optimizer: str = None,
                       include_opt: bool = True) -> int:
    """Model-total static residency (params + grads + optimizer states)
    under ``policy`` dtypes — a sound lower bound on the summed
    per-stage statics of ANY cell: activations/transients only add,
    sharding divides the sum by at most the chip count (each byte lives
    on exactly one pipeline stage's shards; replication only grows the
    per-chip share), so peak-stage >= mean-stage gives
    ``peak >= this // n_chips`` (property-tested against full sweeps in
    tests/test_search.py / tests/test_monotone_property.py).

    Per factor:

    * params — exact (``factors.param_factor`` numerator);
    * grads  — ``_GRAD_FLOOR_BYTES`` per trainable element, the min of
      the two ``eff_grad_bytes`` branches (train kinds only);
    * opt    — exact ``factors.opt_bytes_for`` under the resolved
      optimizer (``None`` -> the arch default) and the deterministic
      ``master_fp32 = opt != "adafactor"`` rule from
      ``planner.make_context``; dropped when ``include_opt`` is False
      (grids whose offload axis can move these states off-device).
    """
    from repro.configs import get_config
    from repro.core.factors import _stacked, opt_bytes_for
    from repro.core.sweep import normalize_arch

    rows = _parsed_rows(arch, policy)
    total = sum(p.nbytes * row.repeat
                for row in rows for p in row.layer.params.values())
    if kind != "train":
        return total                      # serve kinds: params only
    opt = optimizer or get_config(normalize_arch(arch)).optimizer
    for row in rows:
        if not row.trainable:
            continue
        rep = 1 if row.scanned else row.repeat
        for p in row.layer.params.values():
            total += p.size * row.repeat * _GRAD_FLOOR_BYTES
            if include_opt:
                total += opt_bytes_for(p, _stacked(p, row)[0], opt,
                                       opt != "adafactor") * rep
    return total


def _floor_for(grid) -> int:
    """The statics floor valid for EVERY cell of the grid: the min over
    its arch / kind / optimizer axes (0 disables pruning — used when a
    profile is active, whose fitted coefficients could scale raw bytes
    down).  Optimizer states are included only when no cell can offload
    them to the host tier."""
    from repro.core.sweep import _seq

    if grid.profile is not None:
        return 0
    include_opt = True not in grid.offloads()
    opts = tuple(_seq(grid.optimizers)) or (None,)
    return min(static_floor_bytes(a, grid.policy, kind=k, optimizer=o,
                                  include_opt=include_opt)
               for a in _seq(grid.arch)
               for k in _seq(grid.kind)
               for o in opts)


def _budgets(grid) -> dict:
    from repro.core import planner as PL
    from repro.core.sweep import _seq

    return {c: int(PL.chip_hbm(c) * grid.headroom) for c in _seq(grid.chip)}


def _by_count(grid) -> dict:
    """Grid meshes grouped by chip count, insertion order preserved
    within each count (the tie-break order of the flat grid)."""
    from repro.launch.mesh import mesh_chips

    by_n: dict[int, list] = {}
    for m in grid.meshes():
        by_n.setdefault(mesh_chips(m), []).append(m)
    return by_n


def _slice(grid, meshes, **over):
    return replace(grid, chips=None, mesh_shapes=list(meshes), **over)


# ---------------------------------------------------------------------------
# min-chips search
# ---------------------------------------------------------------------------


def min_chips_search(grid, engine=None, stats: SearchStats = None,
                     oracle: bool = False, compute_engine: str = "numpy"):
    """Pruned twin of ``engine.sweep(grid).min_chips()``.

    Chip counts ascend; a count is swept only if the statics floor fits
    at least one chip type's budget (chip types it exceeds are dropped
    from the slice — their cells are provably non-fitting), and the
    search stops at the first count with a fitting cell.  The winning
    cell — including the (peak, index-order) tie-break — is identical
    to the exhaustive reduction: within one count the slice preserves
    the flat grid's relative cell order, and across counts the
    exhaustive primary key IS the chip count.
    """
    from repro.core import sweep as SW

    engine = engine or SW.SweepEngine()
    stats = stats if stats is not None else SearchStats()
    floor = _floor_for(grid)
    budgets = _budgets(grid)
    by_n = _by_count(grid)
    stats.bound_evals += len(by_n)
    best = None
    for n in sorted(by_n):
        meshes = by_n[n]
        chips_ok = tuple(c for c, b in budgets.items()
                         if floor // n <= b) or ()
        full = _slice(grid, meshes).size()
        if best is not None or not chips_ok:
            stats.cells_pruned += full
            continue
        sl = _slice(grid, meshes, chip=chips_ok)
        res = engine.sweep(sl, engine=compute_engine)
        stats.cells_evaluated += len(res)
        stats.cells_pruned += full - len(res)
        best = res.min_chips()
        # keep looping only to account remaining pruned cells
    if oracle:
        ref = engine.sweep(grid, engine=compute_engine).min_chips()
        _assert_same_cell(best, ref, "min_chips")
    return best


def _assert_same_cell(got, ref, what: str) -> None:
    if (got is None) != (ref is None):
        raise AssertionError(f"{what}: pruned={got!r} exhaustive={ref!r}")
    if got is None:
        return
    for f in ("arch", "chip", "n_chips", "mesh_shape", "optimizer",
              "remat", "schedule", "microbatches", "grad_accum",
              "global_batch", "seq_len", "peak_bytes", "fits"):
        g, r = getattr(got, f, None), getattr(ref, f, None)
        if g != r:
            raise AssertionError(
                f"{what}: pruned.{f}={g!r} != exhaustive.{f}={r!r}")


# ---------------------------------------------------------------------------
# frontier search
# ---------------------------------------------------------------------------


def frontier_search(grid, engine=None, stats: SearchStats = None,
                    oracle: bool = False,
                    compute_engine: str = "numpy") -> list:
    """Pruned twin of ``engine.sweep(grid).frontier()``: per chip count,
    scan the global-batch axis DESCENDING and stop at the first batch
    with a fitting cell — exact regardless of batch monotonicity (the
    scan only skips batches *below* a found maximum), with
    statics-floor pruning of hopeless chip counts."""
    from repro.core import sweep as SW
    from repro.core.sweep import _seq

    engine = engine or SW.SweepEngine()
    stats = stats if stats is not None else SearchStats()
    floor = _floor_for(grid)
    budgets = _budgets(grid)
    by_n = _by_count(grid)
    stats.bound_evals += len(by_n)
    gbs = sorted(set(int(g) for g in _seq(grid.global_batches)),
                 reverse=True)
    out = []
    for n in sorted(by_n):
        meshes = by_n[n]
        chips_ok = tuple(c for c, b in budgets.items() if floor // n <= b)
        if not chips_ok:
            stats.cells_pruned += _slice(grid, meshes).size()
            continue
        found = False
        for gb in gbs:
            full = _slice(grid, meshes, global_batches=(gb,)).size()
            if found:
                stats.cells_pruned += full
                continue
            sl = _slice(grid, meshes, chip=chips_ok,
                        global_batches=(gb,))
            res = engine.sweep(sl, engine=compute_engine)
            stats.cells_evaluated += len(res)
            stats.cells_pruned += full - len(res)
            if res.fit_count:
                out.append((n, gb))
                found = True
        # chip types dropped by the floor hold no fitting cells, so the
        # per-count max over the kept types equals the full grid's
    if oracle:
        ref = engine.sweep(grid, engine=compute_engine).frontier()
        if out != ref:
            raise AssertionError(
                f"frontier: pruned={out!r} != exhaustive={ref!r}")
    return out


# ---------------------------------------------------------------------------
# aligned-ladder concurrency search
# ---------------------------------------------------------------------------


def batch_align(mesh_shape: dict) -> int:
    """The batch-ladder alignment of a mesh: the product of its non-pipe
    axis sizes.  At global batches that are multiples of this, every
    divisibility check a batch-derived dim can ever pass passes (each
    mesh axis is used at most once per dim, so any applied shard
    product divides it), making the denominators maximal and the peak
    monotone along the ladder."""
    from repro.mesh_ctx import PIPE_AXIS

    out = 1
    for a, v in (mesh_shape or {}).items():
        if a != PIPE_AXIS:
            out *= max(int(v), 1)
    return out


def monotone_max(fits, cap: int, align: int = 1,
                 stats: SearchStats = None) -> int:
    """Largest ``x`` in [1, cap] with ``fits(x)``, where ``fits`` is
    monotone non-increasing along multiples of ``align`` and bounded by
    its aligned floor (``fits(x)`` implies ``fits(align * (x //
    align))``) — the aligned-ladder structure of the Eq.1 batch terms.
    With ``align == 1`` this is plain galloping + binary search.
    Returns 0 when nothing fits."""
    if cap < 1:
        return 0
    stats = stats if stats is not None else SearchStats()
    L = max(int(align), 1)

    def probe(x: int) -> bool:
        stats.probes += 1
        return bool(fits(x))

    def scan_desc(hi: int, lo: int) -> int:
        """First fitting value scanning hi..lo+1, else 0."""
        for x in range(hi, lo, -1):
            if probe(x):
                return x
        return 0

    if L > cap or not probe(L):
        # no aligned point fits => nothing >= L fits (aligned-floor
        # bound); resolve [1, min(L, cap+1)) exhaustively
        return scan_desc(min(L - 1, cap), 0)
    kmax = cap // L
    k = 1
    while 2 * k <= kmax and probe(2 * k * L):
        k *= 2
    lo_k, hi_k = k, min(2 * k, kmax)
    while lo_k < hi_k:                       # max fitting multiple
        mid = (lo_k + hi_k + 1) // 2
        if probe(mid * L):
            lo_k = mid
        else:
            hi_k = mid - 1
    base = lo_k * L
    # anything >= (lo_k+1)*L is ruled out (its aligned floor failed, or
    # it is beyond cap); the window (base, min((lo_k+1)*L - 1, cap)]
    # is scanned exhaustively
    top = min((lo_k + 1) * L - 1, cap)
    hit = scan_desc(top, base)
    return hit or base


def max_concurrency_search(peak, budget: int, cap: int,
                           mesh_shape: dict = None,
                           stats: SearchStats = None) -> int:
    """Largest concurrency whose ``peak(gb) <= budget`` — the engine of
    :func:`repro.core.planner.plan_max_concurrency`, exact for
    batch-sharded meshes via the aligned ladder."""
    return monotone_max(lambda gb: peak(gb) <= budget, cap,
                        align=batch_align(mesh_shape or {}), stats=stats)
