"""Spec trees: the single source of truth shared by model construction and
the memory-prediction framework.

The paper's *Model parser* (workflow step 1-4) decomposes a multimodal model
into modules and fine-grained layers.  In this system every architecture is
*built from* a :class:`ModuleSpec` tree, so the parser does not reflect over
a live object graph - the spec **is** the parse.  The same tree drives

* parameter allocation  (``models.param.init_params``),
* the forward pass      (each arch family's ``apply`` consumes the params
                         whose shapes the spec dictates),
* sharding              (``ParamSpec.axes`` are logical axis names mapped to
                         mesh axes by the policy in ``launch.mesh``),
* memory factorization  (``core.factors`` evaluates the four per-layer
                         factors off this tree).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator, Optional

import numpy as np

# ---------------------------------------------------------------------------
# Logical axis names used across the zoo.  launch.mesh.LOGICAL_RULES maps
# them onto physical mesh axes ("pod", "data", "model").
# ---------------------------------------------------------------------------
AXIS_LAYERS = "layers"        # scan-stacked block dimension
AXIS_VOCAB = "vocab"          # embedding / lm-head vocab dimension
AXIS_EMBED = "embed"          # model (residual) dimension
AXIS_HEADS = "heads"          # merged attention heads*head_dim output dim
AXIS_KV_HEADS = "kv_heads"    # merged kv heads*head_dim output dim
AXIS_FFN = "ffn"              # feed-forward hidden dimension
AXIS_EXPERTS = "experts"      # routed-expert dimension
AXIS_EXPERT_BUF = "expert_buf"  # MoE dispatch/capacity buffer dims (EP-only)
AXIS_LORA = "lora"            # MLA low-rank bottleneck dims
AXIS_CONV = "conv"            # conv kernel dims (mamba, vit patch)
AXIS_SSM = "ssm"              # ssm state / head dims


@dataclass(frozen=True)
class ParamSpec:
    """Shape/dtype/logical-sharding metadata for one parameter tensor."""

    shape: tuple[int, ...]
    dtype: str = "bfloat16"
    axes: tuple[Optional[str], ...] = ()
    init: str = "normal"          # "normal" | "zeros" | "ones" | "embed" | "ssm_a" | "dt_bias"
    init_scale: float = 1.0       # stddev multiplier (normal) / fan-in handled by caller

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes {self.axes} rank mismatch with shape {self.shape}")

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def bytes_per_elem(self) -> int:
        return dtype_bytes(self.dtype)

    @property
    def nbytes(self) -> int:
        return self.size * self.bytes_per_elem


def dtype_bytes(dtype: str) -> int:
    return {
        "float64": 8, "int64": 8,
        "float32": 4, "int32": 4, "uint32": 4,
        "bfloat16": 2, "float16": 2, "int16": 2,
        "int8": 1, "uint8": 1, "float8_e4m3fn": 1, "bool": 1,
    }[str(dtype)]


@dataclass(frozen=True)
class ActTerm:
    """One analytically-modelled activation tensor saved for backward.

    ``shape_fn(batch, seq) -> tuple`` gives the *global* (unsharded) shape;
    ``axes`` name each dim so the sharding model can divide by the mesh.
    """

    name: str
    shape: tuple[Any, ...]          # entries: int or "B" (batch) or "S" (seq) or "T" (enc seq)
    dtype: str = "bfloat16"
    axes: tuple[Optional[str], ...] = ()

    def concrete_shape(self, batch: int, seq: int, enc_seq: int = 0) -> tuple[int, ...]:
        out = []
        for d in self.shape:
            if d == "B":
                out.append(batch)
            elif d == "S":
                out.append(seq)
            elif d == "T":
                out.append(enc_seq)
            else:
                out.append(int(d))
        return tuple(out)


@dataclass
class LayerSpec:
    """A fine-grained layer (paper workflow step 4): nn.Linear-granularity.

    ``acts`` lists the activation tensors this layer must keep live for its
    backward pass *when no remat is applied*; the predictor combines them
    with the remat policy.  ``flops_per_token`` is used by the roofline
    napkin-math helpers (2*m*n*k counted once; fwd+bwd multipliers applied
    by the caller).
    """

    name: str
    kind: str                                   # "linear" | "embedding" | ...
    params: dict[str, ParamSpec] = field(default_factory=dict)
    acts: list[ActTerm] = field(default_factory=list)
    flops_per_token: float = 0.0                # forward MACs*2, per (global) token
    meta: dict[str, Any] = field(default_factory=dict)

    @property
    def param_count(self) -> int:
        return sum(p.size for p in self.params.values())

    @property
    def param_bytes(self) -> int:
        return sum(p.nbytes for p in self.params.values())


@dataclass
class ModuleSpec:
    """A modality-level module (paper workflow step 2): vision encoder,
    projector, language decoder, ...  ``repeat`` marks scan-stacked
    homogeneous blocks: the contained layers' params acquire a leading
    ``layers`` axis of that size and the activation/FLOP terms multiply.
    """

    name: str
    modality: str = "text"                      # "vision"|"text"|"audio"|"shared"
    layers: list[LayerSpec] = field(default_factory=list)
    children: list["ModuleSpec"] = field(default_factory=list)
    repeat: int = 1
    scanned: bool = False       # force a leading stack dim even when repeat==1

    # -- traversal ----------------------------------------------------------
    def walk(self, prefix: str = "", repeat: int = 1) -> Iterator[tuple[str, "ModuleSpec", int]]:
        """Yield (path, module, effective_repeat) depth-first."""
        path = f"{prefix}/{self.name}" if prefix else self.name
        eff = repeat * self.repeat
        yield path, self, eff
        for child in self.children:
            yield from child.walk(path, eff)

    def iter_layers(self) -> Iterator[tuple[str, LayerSpec, int]]:
        """Yield (layer_path, layer, effective_repeat) for every leaf layer."""
        for path, mod, eff in self.walk():
            for layer in mod.layers:
                yield f"{path}/{layer.name}", layer, eff

    # -- aggregates ----------------------------------------------------------
    @property
    def param_count(self) -> int:
        return sum(l.param_count * rep for _, l, rep in self.iter_layers())

    @property
    def param_bytes(self) -> int:
        return sum(l.param_bytes * rep for _, l, rep in self.iter_layers())

    def find(self, name: str) -> "ModuleSpec":
        for path, mod, _ in self.walk():
            if mod.name == name or path == name:
                return mod
        raise KeyError(name)


# ---------------------------------------------------------------------------
# Training behaviour (the paper's central multimodal concern): which modules
# are trainable.  LLaVA stage-1 trains only the projector; stage-2 trains
# projector + language model with the vision tower frozen.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TrainPolicy:
    """Maps module paths to trainable-ness.

    ``trainable_patterns`` are substring matches against the module path
    (e.g. ``("projector", "language_model")``).  An empty tuple with
    ``default_trainable=True`` trains everything (the unimodal case).
    """

    name: str = "full"
    trainable_patterns: tuple[str, ...] = ()
    default_trainable: bool = True

    def is_trainable(self, path: str) -> bool:
        if not self.trainable_patterns:
            return self.default_trainable
        return any(pat in path for pat in self.trainable_patterns)


FULL_TRAIN = TrainPolicy(name="full")
LLAVA_STAGE1 = TrainPolicy(name="llava_stage1",
                           trainable_patterns=("projector",),
                           default_trainable=False)
LLAVA_STAGE2 = TrainPolicy(name="llava_stage2",
                           trainable_patterns=("projector", "language_model"),
                           default_trainable=False)


def replace(obj, **kw):
    return dataclasses.replace(obj, **kw)
