"""Pipeline-stage partitioner: ParsedLayer rows -> balanced contiguous
stages.

Pipeline parallelism slices the model's layer sequence into ``pp``
contiguous stages, each resident on a disjoint set of chips (the ``pipe``
mesh axis).  The memory question per stage is exactly Eq.1 restricted to
that stage's rows, plus schedule-dependent terms (the in-flight microbatch
activation stash, stage-boundary send/recv buffers) — so the partition
itself must be a deterministic, pure function of the parse table that the
scalar predictor (``core.predictor``) and the columnar engine
(``core.batch``) share.  This module is that function.

Partition rules (property-tested in tests/test_stages.py):

* **Contiguity** — every stage holds a contiguous run of the row sequence;
  scan-stacked blocks split by repeat count (32 layers -> e.g. 8+8+8+8).
* **Exact cover** — each row's repeat units land in exactly one stage;
  summing any per-repeat quantity over stages reproduces the whole model.
* **Pinning** — everything before the first splittable segment (token
  embedding, vision tower, audio encoder, projector) is pinned to stage 0;
  everything after the last splittable segment (final norm, LM head) is
  pinned to the last stage.  Non-text towers are never split: a frozen (or
  trainable) vision/audio encoder rides with stage 0, the paper's
  multimodal front-end placement.
* **Balance** — the splittable middle (block stacks, unit = one block
  instance) is partitioned by a linear-partition DP minimizing the max
  stage weight, where a unit's weight is its parameter bytes (x4 when
  trainable, approximating the grad+opt states that ride along); the
  pinned front/tail weights load stages 0/pp-1 in the DP cost.  The
  optimum is never worse than the greedy bound
  ``total/pp + max_unit_weight``.

Schedule model (``stash_count``): under 1F1B stage *i* holds
``min(pp - i, microbatches)`` in-flight microbatch activation sets; GPipe
holds all ``microbatches`` on every stage.  With ``pp == 1`` there is no
pipeline and the stash is 1 regardless of schedule.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

from repro.core.parser import ParsedLayer

SCHEDULES = ("1f1b", "gpipe")

#: balance-weight multiplier for trainable units: grads + optimizer states
#: scale with trainable parameter bytes, frozen rows carry params only.
TRAINABLE_WEIGHT = 4


@dataclass(frozen=True)
class _Segment:
    """A maximal run of rows sharing one owning module."""

    rows: tuple                 # ParsedLayer rows (same module_path/repeat)
    splittable: bool            # scan stack that may split across stages

    @property
    def repeat(self) -> int:
        return self.rows[0].repeat

    def unit_weight(self) -> int:
        """Balance weight of ONE repeat instance."""
        w = 0
        for r in self.rows:
            per = sum(p.nbytes for p in r.layer.params.values())
            w += per * (TRAINABLE_WEIGHT if r.trainable else 1)
        return w

    def total_weight(self) -> int:
        return self.unit_weight() * self.repeat


@dataclass(frozen=True)
class StagePlan:
    """The partition of one parse table into ``pp`` stages."""

    pp: int
    stages: tuple               # tuple[tuple[ParsedLayer, ...], ...]
    weights: tuple              # per-stage balance weight (ints)

    def rows_of(self, stage: int) -> list:
        return list(self.stages[stage])


def _segments(rows: list) -> list[_Segment]:
    groups: list[list[ParsedLayer]] = []
    for r in rows:
        if groups and groups[-1][0].module_path == r.module_path:
            groups[-1].append(r)
        else:
            groups.append([r])
    segs = []
    for g in groups:
        splittable = (
            g[0].scanned and g[0].repeat > 1
            # only the text backbone's stacks split; vision/audio towers
            # stay whole (pinned with the front of the pipeline)
            and all(r.modality == "text" for r in g)
            # weight-tied python-unrolled blocks (zamba2 shared attention)
            # are invoked throughout the depth — they cannot live on one
            # contiguous slice, so they stay atomic
            and not any("invocation_repeat" in r.layer.meta
                        or "cache_repeat" in r.layer.meta for r in g))
        segs.append(_Segment(rows=tuple(g), splittable=splittable))
    return segs


def _linear_partition(weights: list[int], pp: int,
                      front: int, tail: int) -> list[int]:
    """Contiguous partition of ``weights`` into ``pp`` chunk sizes
    minimizing the max stage load, with ``front``/``tail`` preloaded onto
    the first/last stage.  Returns per-stage unit counts (sum == len)."""
    n = len(weights)
    prefix = [0]
    for w in weights:
        prefix.append(prefix[-1] + w)

    def span(i: int, j: int) -> int:               # sum of units [i, j)
        return prefix[j] - prefix[i]

    INF = float("inf")
    # best[s][j] = minimal max-load splitting units [0, j) into s+1 stages
    best = [[INF] * (n + 1) for _ in range(pp)]
    cut = [[0] * (n + 1) for _ in range(pp)]
    for j in range(n + 1):
        load = span(0, j) + front + (tail if pp == 1 else 0)
        best[0][j] = load
    for s in range(1, pp):
        extra = tail if s == pp - 1 else 0
        for j in range(n + 1):
            for i in range(j + 1):
                if best[s - 1][i] == INF:
                    continue
                cand = max(best[s - 1][i], span(i, j) + extra)
                if cand < best[s][j]:
                    best[s][j] = cand
                    cut[s][j] = i
    counts = [0] * pp
    j = n
    for s in range(pp - 1, 0, -1):
        i = cut[s][j]
        counts[s] = j - i
        j = i
    counts[0] = j
    return counts


def partition(rows: list, pp: int) -> StagePlan:
    """Assign the parse table to ``pp`` balanced contiguous stages.

    Deterministic in (rows, pp); ``pp == 1`` returns the whole table as
    one stage (the predictor's non-pipelined path is bit-equal by
    construction).  Stages may be empty when ``pp`` exceeds the number of
    splittable units.
    """
    if pp < 1:
        raise ValueError(f"pp must be >= 1, got {pp}")
    if pp == 1:
        total = sum(s.total_weight() for s in _segments(rows))
        return StagePlan(pp=1, stages=(tuple(rows),), weights=(total,))

    segs = _segments(rows)
    split_ids = [i for i, s in enumerate(segs) if s.splittable]
    if not split_ids:
        # nothing to distribute: everything is pinned to stage 0
        stages = [tuple(rows)] + [()] * (pp - 1)
        w = sum(s.total_weight() for s in segs)
        return StagePlan(pp=pp, stages=tuple(stages),
                         weights=(w,) + (0,) * (pp - 1))
    first, last = split_ids[0], split_ids[-1]
    front = segs[:first]                 # pinned to stage 0
    middle = segs[first:last + 1]        # distributed (may hold atomics)
    tail = segs[last + 1:]               # pinned to stage pp-1

    # expand the middle to units: one per repeat of a splittable segment,
    # one per whole atomic segment
    units: list[tuple[int, int]] = []    # (segment index in middle, weight)
    for mi, seg in enumerate(middle):
        if seg.splittable:
            units.extend((mi, seg.unit_weight())
                         for _ in range(seg.repeat))
        else:
            units.append((mi, seg.total_weight()))
    front_w = sum(s.total_weight() for s in front)
    tail_w = sum(s.total_weight() for s in tail)
    counts = _linear_partition([w for _, w in units], pp, front_w, tail_w)

    stage_rows: list[list[ParsedLayer]] = [[] for _ in range(pp)]
    weights = [0] * pp
    stage_rows[0].extend(r for s in front for r in s.rows)
    weights[0] += front_w
    pos = 0
    for s in range(pp):
        take = units[pos:pos + counts[s]]
        pos += counts[s]
        if not take:
            continue
        # contiguous unit run -> per-segment repeat chunks, in order
        chunk: dict[int, int] = {}
        for mi, _ in take:
            chunk[mi] = chunk.get(mi, 0) + 1
        for mi in sorted(chunk):
            seg = middle[mi]
            if seg.splittable:
                rep = chunk[mi]
                stage_rows[s].extend(replace(r, repeat=rep)
                                     for r in seg.rows)
                weights[s] += seg.unit_weight() * rep
            else:
                stage_rows[s].extend(seg.rows)
                weights[s] += seg.total_weight()
    stage_rows[pp - 1].extend(r for s in tail for r in s.rows)
    weights[pp - 1] += tail_w
    return StagePlan(pp=pp, stages=tuple(tuple(r) for r in stage_rows),
                     weights=tuple(weights))


def stash_count(stage: int, pp: int, microbatches: int,
                schedule: str = "1f1b") -> int:
    """In-flight microbatch activation sets held by ``stage`` during the
    steady state of the schedule (1 with no pipeline)."""
    if schedule not in SCHEDULES:
        raise ValueError(
            f"unknown schedule {schedule!r}; known: {SCHEDULES}")
    if pp <= 1:
        return 1
    m = max(microbatches, 1)
    if schedule == "gpipe":
        return m
    return max(min(pp - stage, m), 1)


def boundary_edges(stage: int, pp: int) -> int:
    """Pipeline edges touching ``stage``: recv-from-previous +
    send-to-next (0 with no pipeline)."""
    if pp <= 1:
        return 0
    return (1 if stage > 0 else 0) + (1 if stage < pp - 1 else 0)
