"""Capacity-planning sweep engine: the full knob space, in milliseconds.

The paper's estimator answers "will this config OOM?" for ONE cell;
capacity planning (xMem-style scheduler admission, cluster sizing) needs
that answer for 10^5-10^6 candidate configurations at once: every mesh
factorization of a chip count (including ``pipe`` pipeline, ``expert``
expert-parallel, and ``context`` ring-attention axes) x optimizer x
remat policy x pipeline schedule x microbatch count x grad-accum x
global batch x sequence length x chip type.
``sweep(SweepGrid(...))`` evaluates such a grid through a dual-mode
:class:`SweepEngine`:

* ``mode="columnar"`` (default) lowers the whole grid to the
  structure-of-arrays NumPy kernels in :mod:`repro.core.batch` — the
  Eq.1 terms are factored into cell-independent coefficients contracted
  against int64 knob columns, ~100x the per-cell throughput (a
  124k-cell grid evaluates in ~50 ms; BENCH_sweep.json tracks it);
* ``mode="cell"`` is the per-cell reference: parses/builds each
  architecture once, memoizes the three ``core.predictor`` component
  groups by exactly the context fields each reads, and composes cells
  through the same ``assemble`` a cell-by-cell ``planner.check`` uses.

The two modes are byte-identical — every verdict and every peak-bytes
value — with or without a calibration profile (asserted per-cell by
tests/test_batch.py and on the 7,152-cell parity set + a 124k-cell grid
by ``benchmarks/sweep_throughput.py --verify``).

Results are wrapped in a :class:`SweepResults` container with
Pareto-frontier queries ("max global batch that fits on N chips", "min
chips for this shape") and markdown/CSV report writers built on
:mod:`repro.core.report`; columnar sweeps answer the queries on arrays
and materialize :class:`SweepResult` rows lazily.

CLI::

    PYTHONPATH=src python -m repro.core.sweep --arch llava15_7b --chips 8 \
        --chip v5e --batch 16,32,64,128 --accum 1,2,4 --seq-len 2048
    PYTHONPATH=src python -m repro.core.sweep --arch llama3_1_8b \
        --chips 64 --mesh-axes data,model,pipe --max-pipe 4 \
        --schedule 1f1b,gpipe --microbatches 1,4,8 --batch 64 --seq-len 4096
    PYTHONPATH=src python -m repro.core.sweep --arch deepseek_v2_lite_16b \
        --chips 64 --mesh-axes data,model,expert,context,pipe \
        --max-expert 8 --max-context 4 --max-pipe 4 --batch 64 \
        --seq-len 8192

``--dry-run`` prints the per-knob cardinality table + a runtime estimate
first; ``--mode cell`` selects the reference path; an empty grid exits
with status 2 and a "0 cells matched" explanation.
"""

from __future__ import annotations

import re
import time
from dataclasses import dataclass
from typing import Iterator, Optional, Sequence, Union

from repro.core import planner as PL
from repro.core import predictor as PR
from repro.core import report as RPT
from repro.core.parser import parse_model
from repro.core.spec import (FULL_TRAIN, LLAVA_STAGE1, LLAVA_STAGE2,
                             TrainPolicy)

GiB = 1024 ** 3

POLICIES: dict[str, TrainPolicy] = {
    "full": FULL_TRAIN,
    "llava_stage1": LLAVA_STAGE1,
    "llava_stage2": LLAVA_STAGE2,
}


def normalize_arch(name: str) -> str:
    """Accept module-ish spellings ("llava15_7b") for registered archs."""
    from repro.configs import registered_archs
    known = registered_archs()
    if name in known:
        return name
    canon = lambda s: re.sub(r"[^a-z0-9]", "", s.lower())
    matches = [a for a in known if canon(a) == canon(name)]
    if len(matches) == 1:
        return matches[0]
    raise KeyError(f"unknown arch {name!r}; known: {known}")


# ---------------------------------------------------------------------------
# grid + result data model
# ---------------------------------------------------------------------------


def _seq(x) -> tuple:
    if x is None:
        return (None,)
    if isinstance(x, (str, int, float, dict)):
        return (x,)
    return tuple(x)


@dataclass
class SweepGrid:
    """The knob space of one sweep.  Every list-valued field is a grid
    axis; ``None`` entries mean "the architecture's default"."""

    arch: Union[str, Sequence[str]] = "llava15-7b"
    # mesh axes: either explicit mesh_shapes, or a chip count (chips) whose
    # factorizations over mesh_axes are enumerated via launch.mesh
    chips: Union[int, Sequence[int], None] = None
    mesh_axes: tuple[str, ...] = ("data", "model")
    mesh_shapes: Optional[Sequence[dict]] = None
    max_axis: Optional[dict] = None        # e.g. {"model": 16} ICI cap
    chip: Union[str, Sequence[str]] = "v5e"
    optimizers: Sequence[Optional[str]] = (None,)
    remats: Sequence[Optional[str]] = (None,)
    # pipeline-parallel knobs: the pipeline DEGREE comes from each mesh's
    # `pipe` axis (put "pipe" in mesh_axes or in explicit mesh_shapes);
    # these set how the batch fills it.  Inert on pipe-less meshes.
    schedules: Sequence[str] = ("1f1b",)
    microbatches: Sequence[int] = (1,)
    grad_accums: Sequence[int] = (1,)
    global_batches: Sequence[int] = (256,)
    seq_lens: Sequence[int] = (4096,)
    kind: str = "train"
    policy: TrainPolicy = FULL_TRAIN
    backend: str = "tpu"
    headroom: float = PL.HEADROOM
    keep_predictions: bool = False
    # measurement-fitted CalibrationProfile (repro.calibrate) applied to
    # every cell; its hash participates in the engine's memo keys
    profile: object = None
    # learned per-family ResidualModel (repro.calibrate.learned) applied
    # on top of the profile; its model_hash joins the memo keys the same
    # way.  None keeps every cell bit-identical to the profile-only path.
    residual_model: object = None
    # serving-fleet knobs (serve kinds only; the all-neutral combo is
    # normalized to serve=None so it stays bit-identical to a pre-serve
    # cell): paged-KV block sizes (0 = contiguous), pool utilizations,
    # prefix-cache hit rates over a shared prefix_len-token prefix,
    # request mixes (repro.serve.fleet.RequestMix or None), and
    # speculative-decode draft arches ("" = none).
    block_sizes: Sequence[int] = (0,)
    utilizations: Sequence[float] = (1.0,)
    prefix_hit_rates: Sequence[float] = (0.0,)
    prefix_len: int = 0
    mixes: Sequence = (None,)
    draft_archs: Sequence[str] = ("",)
    # Eq.1 offload-tier knob (train kinds only): False = optimizer states
    # resident in HBM, True = host-offloaded with only the
    # factors.offload_staged_bytes streaming window on device.
    offload_optimizer: Sequence[bool] = (False,)
    # peak assembly mode (core.liveness): "legacy" = Eq.1 sum-of-maxima
    # (default, bit-identical to every golden); "liveness" = the
    # interval-overlap peak from the alloc/free event program.  Not a
    # grid axis — one mode per sweep, and it joins the engine memo keys.
    assembly: str = "legacy"

    def offloads(self) -> tuple:
        """The offload axis, normalized to a bool tuple."""
        return tuple(bool(o) for o in _seq(self.offload_optimizer))

    def meshes(self) -> list[dict]:
        from repro.launch.mesh import enumerate_meshes
        if self.mesh_shapes is not None:
            return [dict(m) for m in self.mesh_shapes]
        if self.chips is None:
            raise ValueError("SweepGrid needs `chips` or `mesh_shapes`")
        out = []
        for n in _seq(self.chips):
            out.extend(enumerate_meshes(int(n), self.mesh_axes,
                                        self.max_axis))
        return out

    def serve_specs(self) -> tuple:
        """The serve axis: one Optional[ServeSpec] per combination of the
        serving-fleet knob lists, in deterministic cross-product order.
        The all-neutral combination maps to ``None`` (no serve spec), so
        a default grid has a single-element ``(None,)`` axis and every
        cell is bit-identical to a pre-serve sweep."""
        from repro.serve.fleet import RequestMix
        from repro.serve.pool import ServeSpec
        mixes = self.mixes if isinstance(self.mixes, (tuple, list)) \
            else (self.mixes,)
        mixes = tuple(mixes) or (None,)
        out = []
        for b in _seq(self.block_sizes):
            for u in _seq(self.utilizations):
                for h in _seq(self.prefix_hit_rates):
                    for m in mixes:
                        if m is not None and not isinstance(m, RequestMix):
                            raise ValueError(
                                f"mixes entries must be RequestMix or "
                                f"None, got {m!r}")
                        for d in _seq(self.draft_archs):
                            spec = ServeSpec.make(
                                block_size=int(b or 0),
                                utilization=float(u),
                                prefix_hit_rate=float(h),
                                prefix_len=int(self.prefix_len),
                                mix=m, draft_arch=str(d or ""))
                            out.append(None if spec.is_neutral else spec)
        return tuple(out)

    def size(self) -> int:
        """Cheap cell cardinality: exactly ``sum(1 for _ in cells())``
        without yielding a single cell object — guard rails for CLI users
        about to launch a million-cell sweep (see ``--dry-run``)."""
        pairs = sum(1 for a in _seq(self.grad_accums)
                    for g in _seq(self.global_batches) if not g % a)
        return (len(_seq(self.arch)) * len(_seq(self.chip))
                * len(self.meshes()) * len(_seq(self.optimizers))
                * len(self.offloads())
                * len(_seq(self.remats)) * len(_seq(self.schedules))
                * len(_seq(self.microbatches)) * len(self.serve_specs())
                * pairs * len(_seq(self.seq_lens)))

    def check_schedules(self) -> tuple:
        """Validate the schedule axis up front — the columnar path never
        builds per-cell PredictContexts, so it would otherwise treat an
        unknown schedule as 1F1B silently."""
        from repro.core.stages import SCHEDULES
        scheds = _seq(self.schedules)
        bad = [s for s in scheds if s not in SCHEDULES]
        if bad:
            raise ValueError(
                f"unknown schedule(s) {bad}; known: {SCHEDULES}")
        return scheds

    def check_parallel(self) -> None:
        """Validate the expert/context mesh axes against every
        (arch, mesh, seq) combo up front, through the SAME
        ``planner.check_parallel`` gate the per-cell path hits in
        ``make_context`` — so both sweep modes and the CLI reject an
        invalid grid with one clean ValueError instead of a traceback
        (or, columnar-side, a silent misprediction)."""
        from repro.configs import get_config
        meshes = self.meshes()
        if not any(m.get("expert", 1) > 1 or m.get("context", 1) > 1
                   for m in meshes):
            return
        for arch in _seq(self.arch):
            cfg = get_config(normalize_arch(arch))
            for mesh in meshes:
                for seq in _seq(self.seq_lens):
                    PL.check_parallel(cfg, mesh, self.kind, int(seq))

    def check_serve(self) -> None:
        """Validate the serving-fleet knob axes up front through the SAME
        ``planner.check_serve`` gate the per-cell path hits in
        ``make_context`` — both sweep modes and the CLI reject an
        invalid serve grid with one clean ValueError.  Range errors
        (hit rate outside [0,1] etc.) surface from ServeSpec
        construction inside ``serve_specs()`` itself."""
        from repro.configs import get_config
        specs = self.serve_specs()
        if all(s is None for s in specs):
            return
        for arch in _seq(self.arch):
            cfg = get_config(normalize_arch(arch))
            for spec in specs:
                PL.check_serve(cfg, spec, self.kind)

    def check_offload(self) -> None:
        """Validate the optimizer-offload axis up front through the SAME
        ``planner.check_offload`` gate the per-cell path hits in
        ``make_context`` — both sweep modes and the CLI reject offload
        on a serve kind with one clean ValueError."""
        for off in self.offloads():
            PL.check_offload(self.kind, off)

    def check_assembly(self) -> None:
        """Validate the assembly mode up front (the columnar path would
        otherwise fall back to legacy composition silently)."""
        from repro.core.liveness import ASSEMBLIES
        if self.assembly not in ASSEMBLIES:
            raise ValueError(f"unknown assembly {self.assembly!r}; "
                             f"known: {ASSEMBLIES}")

    def cells(self) -> Iterator["SweepCell"]:
        """Deterministic cell enumeration (first-fit order: cheap knobs
        vary fastest)."""
        self.check_schedules()
        self.check_parallel()
        self.check_serve()
        self.check_offload()
        self.check_assembly()
        meshes = self.meshes()
        serves = self.serve_specs()
        offs = self.offloads()
        for arch in _seq(self.arch):
            arch = normalize_arch(arch)
            for chip in _seq(self.chip):
                for mesh in meshes:
                    for opt in _seq(self.optimizers):
                        for off in offs:
                            for remat in _seq(self.remats):
                                for sched in _seq(self.schedules):
                                    for mb in _seq(self.microbatches):
                                        for srv in serves:
                                            yield from self._inner_cells(
                                                arch, chip, mesh, opt,
                                                off, remat, sched,
                                                int(mb), srv)

    def _inner_cells(self, arch, chip, mesh, opt, off, remat, sched,
                     mb, srv=None) -> Iterator["SweepCell"]:
        for accum in _seq(self.grad_accums):
            for gb in _seq(self.global_batches):
                if gb % accum:
                    continue
                for seq in _seq(self.seq_lens):
                    yield SweepCell(
                        arch=arch, chip=chip,
                        mesh=tuple(sorted(mesh.items())),
                        optimizer=opt, remat=remat,
                        schedule=sched, microbatches=mb,
                        grad_accum=int(accum), global_batch=int(gb),
                        seq_len=int(seq), kind=self.kind,
                        backend=self.backend, serve=srv,
                        offload=bool(off))


@dataclass(frozen=True)
class SweepCell:
    """One point of the grid (hashable; mesh stored as sorted items)."""

    arch: str
    chip: str
    mesh: tuple                    # (("data", 8), ("model", 2))
    optimizer: Optional[str]
    remat: Optional[str]
    grad_accum: int
    global_batch: int
    seq_len: int
    kind: str
    backend: str
    schedule: str = "1f1b"
    microbatches: int = 1
    # Optional repro.serve.pool.ServeSpec (frozen/hashable); None when
    # every serving-fleet knob is neutral
    serve: Optional[object] = None
    # Eq.1 offload-tier knob: host-offloaded optimizer states
    offload: bool = False

    @property
    def mesh_shape(self) -> dict:
        return dict(self.mesh)

    @property
    def n_chips(self) -> int:
        from repro.launch.mesh import mesh_chips
        return mesh_chips(self.mesh_shape)


@dataclass
class SweepResult:
    """Verdict for one cell: the knobs, the predicted peak, fit/OOM."""

    arch: str
    chip: str
    mesh_shape: dict
    n_chips: int
    optimizer: str                 # resolved (never None)
    remat: str                     # resolved
    grad_accum: int
    global_batch: int
    seq_len: int
    kind: str
    backend: str
    peak_bytes: int
    budget_bytes: int
    fits: bool
    schedule: str = "1f1b"
    microbatches: int = 1
    # serving-fleet provenance: the cell's ServeSpec (None when neutral)
    # and the peak stage's pool / draft / hit-savings bytes (all 0 when
    # serve is None)
    serve: Optional[object] = None
    pool_bytes: int = 0
    draft_bytes: int = 0
    hit_saved_bytes: int = 0
    # Eq.1 offload tier: knob + the peak stage's host-DRAM residency
    # (informational, outside the device peak)
    offload: bool = False
    offload_bytes: int = 0
    # liveness assembly: how much the legacy sum-of-maxima overstated the
    # winning stage's peak (0 on the legacy path; peak_bytes above is
    # already net of it)
    overlap_slack_bytes: int = 0
    prediction: Optional[PR.PredictedMemory] = None

    @property
    def micro_batch(self) -> int:
        return max(self.global_batch // max(self.grad_accum, 1), 1)

    @property
    def pp(self) -> int:
        from repro.launch.mesh import pp_degree
        return pp_degree(self.mesh_shape)

    @property
    def ep(self) -> int:
        from repro.launch.mesh import ep_degree
        return ep_degree(self.mesh_shape)

    @property
    def cp(self) -> int:
        from repro.launch.mesh import cp_degree
        return cp_degree(self.mesh_shape)

    @property
    def mesh_str(self) -> str:
        return "x".join(f"{k}={v}" for k, v in sorted(
            self.mesh_shape.items()))

    def __str__(self) -> str:
        verdict = "FITS" if self.fits else "OOM "
        pipe = (f" sched {self.schedule} micro {self.microbatches}"
                if self.pp > 1 else "")
        return (f"[{verdict}] {self.arch} {self.kind} on {self.n_chips}x"
                f"{self.chip} ({self.mesh_str}): batch {self.global_batch}"
                f" seq {self.seq_len} opt {self.optimizer} remat "
                f"{self.remat} accum {self.grad_accum}{pipe} -> peak "
                f"{self.peak_bytes / GiB:.2f} GiB vs "
                f"{self.budget_bytes / GiB:.2f} GiB")


_COLUMNS = ("arch", "chip", "mesh", "optimizer", "remat", "sched",
            "micro", "accum", "batch", "seq", "peak_gib", "budget_gib",
            "fits")

# serve columns appended when the grid has any active serving-fleet knob
# (the writers would otherwise silently drop the new SweepResult fields):
# per-sequence block count, pool/prefix-savings/draft bytes in GiB.
_SERVE_COLUMNS = ("block", "blocks_per_seq", "hit", "pool_gib",
                  "hit_saved_gib", "draft_gib")

# offload columns appended when the grid sweeps the offload knob: the
# per-cell knob value + the host-DRAM optimizer residency in GiB.
_OFFLOAD_COLUMNS = ("offload", "host_opt_gib")

# liveness column appended when the grid's assembly is "liveness": the
# legacy-minus-liveness overestimate of the winning stage, in GiB.
_LIVENESS_COLUMNS = ("ovl_slack_gib",)


def _row_of(r: SweepResult) -> tuple:
    return (r.arch, r.chip, r.mesh_str, r.optimizer, r.remat,
            r.schedule, r.microbatches,
            r.grad_accum, r.global_batch, r.seq_len,
            f"{r.peak_bytes / GiB:.3f}", f"{r.budget_bytes / GiB:.3f}",
            "yes" if r.fits else "NO")


def _serve_row_of(r: SweepResult) -> tuple:
    from repro.serve.pool import pool_blocks
    s = r.serve
    return (s.block_size if s else 0,
            pool_blocks(r.seq_len, s),
            f"{(s.hit_bp if s else 0) / 10000:.2f}",
            f"{r.pool_bytes / GiB:.3f}",
            f"{r.hit_saved_bytes / GiB:.3f}",
            f"{r.draft_bytes / GiB:.3f}")


def _offload_row_of(r: SweepResult) -> tuple:
    return ("yes" if r.offload else "no",
            f"{r.offload_bytes / GiB:.3f}")


def _liveness_row_of(r: SweepResult) -> tuple:
    return (f"{r.overlap_slack_bytes / GiB:.3f}",)


class SweepResults:
    """Structured sweep output + Pareto-frontier queries.

    Two backing stores, one API:

    * cell mode hands in a materialized ``results`` list;
    * columnar mode (``core.batch``) hands in ``columns`` — int64 arrays
      for the whole grid.  Rows are then materialized LAZILY: Pareto
      queries (``fitting`` counts, ``max_global_batch``, ``min_chips``,
      ``frontier``) and the report sort run on the arrays and only the
      rows actually returned become :class:`SweepResult` objects, so a
      500k-cell sweep answers "max batch on 256 chips" without building
      500k Python objects.  Query results are identical between the two
      stores (including tie-breaking order); asserted in tests.
    """

    def __init__(self, grid: SweepGrid, results: Optional[list] = None,
                 elapsed_s: float = 0.0, columns=None):
        self.grid = grid
        self.elapsed_s = elapsed_s
        self.columns = columns
        self._results: Optional[list[SweepResult]] = \
            list(results) if results is not None else None
        if self._results is None and columns is None:
            self._results = []

    @property
    def results(self) -> list[SweepResult]:
        """All rows, materializing (and caching) them when columnar."""
        if self._results is None:
            c = self.columns
            self._results = [c.result(i) for i in range(c.n)]
        return self._results

    def __len__(self) -> int:
        if self._results is None:
            return self.columns.n
        return len(self._results)

    def __iter__(self) -> Iterator[SweepResult]:
        return iter(self.results)

    @property
    def cells_per_sec(self) -> float:
        return len(self) / self.elapsed_s if self.elapsed_s else 0.0

    # -- fit queries ---------------------------------------------------------
    @property
    def fit_count(self) -> int:
        """Number of fitting cells (no row materialization)."""
        if self._results is None:
            return int(self.columns.fits.sum())
        return sum(1 for r in self._results if r.fits)

    def fitting(self) -> list[SweepResult]:
        if self._results is None:
            import numpy as np
            c = self.columns
            return [c.result(int(i)) for i in np.flatnonzero(c.fits)]
        return [r for r in self._results if r.fits]

    def _fit_mask(self, n_chips=None, chip=None, global_batch=None):
        import numpy as np
        c = self.columns
        mask = c.fits.copy()
        if n_chips is not None:
            mask &= c.n_chips == n_chips
        if global_batch is not None:
            mask &= c.global_batch == global_batch
        if chip is not None:
            if chip not in c.chip_names:
                return np.zeros(c.n, bool)
            mask &= c.chip_c == c.chip_names.index(chip)
        return mask

    # -- Pareto queries ------------------------------------------------------
    def max_global_batch(self, n_chips: Optional[int] = None,
                         chip: Optional[str] = None
                         ) -> Optional[SweepResult]:
        """Largest global batch that fits (optionally on exactly N chips /
        a given chip type); ties broken by smallest peak."""
        if self._results is None:
            import numpy as np
            c = self.columns
            idx = np.flatnonzero(self._fit_mask(n_chips=n_chips, chip=chip))
            if not len(idx):
                return None
            order = np.lexsort((c.peak_bytes[idx], -c.global_batch[idx]))
            return c.result(int(idx[order[0]]))
        cand = [r for r in self.fitting()
                if (n_chips is None or r.n_chips == n_chips)
                and (chip is None or r.chip == chip)]
        if not cand:
            return None
        return max(cand, key=lambda r: (r.global_batch, -r.peak_bytes))

    def min_chips(self, global_batch: Optional[int] = None,
                  chip: Optional[str] = None) -> Optional[SweepResult]:
        """Smallest chip count with a fitting config (optionally at a given
        global batch / chip type); ties broken by smallest peak."""
        if self._results is None:
            import numpy as np
            c = self.columns
            idx = np.flatnonzero(self._fit_mask(global_batch=global_batch,
                                                chip=chip))
            if not len(idx):
                return None
            order = np.lexsort((c.peak_bytes[idx], c.n_chips[idx]))
            return c.result(int(idx[order[0]]))
        cand = [r for r in self.fitting()
                if (global_batch is None or r.global_batch == global_batch)
                and (chip is None or r.chip == chip)]
        if not cand:
            return None
        return min(cand, key=lambda r: (r.n_chips, r.peak_bytes))

    def frontier(self) -> list[tuple[int, int]]:
        """(n_chips, max fitting global batch) pairs, ascending chips."""
        if self._results is None:
            import numpy as np
            c = self.columns
            mask = c.fits
            nc, gb = c.n_chips[mask], c.global_batch[mask]
            return [(int(u), int(gb[nc == u].max())) for u in np.unique(nc)]
        best: dict[int, int] = {}
        for r in self._results:
            if r.fits:
                best[r.n_chips] = max(best.get(r.n_chips, 0),
                                      r.global_batch)
        return sorted(best.items())

    # -- report writers ------------------------------------------------------
    def _sorted_indices(self):
        import numpy as np
        c = self.columns
        return np.lexsort((c.peak_bytes, -c.global_batch, ~c.fits))

    def sorted_results(self) -> list[SweepResult]:
        if self._results is None:
            c = self.columns
            return [c.result(int(i)) for i in self._sorted_indices()]
        return sorted(self._results,
                      key=lambda r: (not r.fits, -r.global_batch,
                                     r.peak_bytes))

    def _top_rows(self, limit: Optional[int]) -> tuple[list, int]:
        """Best ``limit`` rows (report order) + count of dropped rows,
        materializing only the returned rows when columnar."""
        if self._results is None:
            order = self._sorted_indices()
            keep = order if limit is None else order[:limit]
            rows = [self.columns.result(int(i)) for i in keep]
            return rows, len(order) - len(rows)
        rows = self.sorted_results()
        if limit is not None and len(rows) > limit:
            return rows[:limit], len(rows) - limit
        return rows, 0

    def _serve_active(self) -> bool:
        """True when the grid swept any non-neutral serving-fleet knob —
        the report then carries the serve columns instead of silently
        dropping the pool/draft fields."""
        try:
            return any(s is not None for s in self.grid.serve_specs())
        except (AttributeError, ValueError):
            return False

    def _offload_active(self) -> bool:
        """True when the grid swept the optimizer-offload knob — the
        report then carries the offload columns."""
        try:
            return any(self.grid.offloads())
        except (AttributeError, ValueError):
            return False

    def _liveness_active(self) -> bool:
        """True when the sweep ran under the liveness assembly — the
        report then carries the overlap-slack column."""
        return getattr(self.grid, "assembly", "legacy") == "liveness"

    def _report_columns(self):
        cols, extras = _COLUMNS, []
        if self._serve_active():
            cols, extras = cols + _SERVE_COLUMNS, extras + [_serve_row_of]
        if self._offload_active():
            cols, extras = (cols + _OFFLOAD_COLUMNS,
                            extras + [_offload_row_of])
        if self._liveness_active():
            cols, extras = (cols + _LIVENESS_COLUMNS,
                            extras + [_liveness_row_of])
        if not extras:
            return _COLUMNS, _row_of

        def row(r):
            out = _row_of(r)
            for extra in extras:
                out = out + extra(r)
            return out
        return cols, row

    def to_markdown(self, limit: Optional[int] = None,
                    title: str = "") -> str:
        rows, dropped = self._top_rows(limit)
        cols, row_of = self._report_columns()
        out = RPT.markdown_table(cols, [row_of(r) for r in rows],
                                 title=title)
        if dropped:
            out += f"\n\n_... {dropped} more cells (use to_csv() for all)_"
        return out

    def to_csv(self) -> str:
        cols, row_of = self._report_columns()
        return RPT.csv_table(cols,
                             [row_of(r) for r in self.sorted_results()])


# ---------------------------------------------------------------------------
# the memoized engine
# ---------------------------------------------------------------------------


class SweepEngine:
    """Memoized cell evaluator.

    Caches, per (arch, policy): the built model + parse table; and the
    three predictor component groups keyed by exactly the context fields
    each group reads (see core.predictor docstrings).  Composition goes
    through :func:`repro.core.predictor.assemble` — the same function the
    un-memoized path uses — so cached and fresh cells are byte-identical.
    """

    def __init__(self):
        self._arch: dict = {}        # (arch, policy) -> (cfg, model, rows)
        self._stages: dict = {}      # (arch, policy, pp) -> StagePlan
        self._static: dict = {}
        self._acts: dict = {}
        self._over: dict = {}
        self._pred: dict = {}        # assembled cells, keyed + profile hash

    # -- caches --------------------------------------------------------------
    def _arch_state(self, arch: str, policy: TrainPolicy):
        key = (arch, policy)
        hit = self._arch.get(key)
        if hit is None:
            from repro.configs import get_config
            from repro.models import build_model
            cfg = get_config(arch)
            model = build_model(cfg)
            rows = parse_model(model.spec, policy)
            hit = self._arch[key] = (cfg, model, rows)
        return hit

    def _stage_plan(self, arch: str, policy: TrainPolicy, pp: int):
        key = (arch, policy, pp)
        hit = self._stages.get(key)
        if hit is None:
            from repro.core import stages as ST
            _, _, rows = self._arch_state(arch, policy)
            hit = self._stages[key] = ST.partition(rows, pp)
        return hit

    def predict_cell(self, arch: str, policy: TrainPolicy,
                     ctx, profile=None,
                     chip: Optional[str] = None,
                     assembly: str = "legacy",
                     residual=None) -> PR.PredictedMemory:
        """Memoized twin of ``PR.predict(model, policy, ctx)``.

        The component caches are keyed WITHOUT the profile — the cached
        StaticTerms/ActTermsAgg/OverheadTerms are raw Eq.1 values a
        profile never touches, so raw and calibrated evaluations share
        them.  The profile (repro.calibrate CalibrationProfile) is
        applied at assemble time, and its hash keys the assembled-cell
        cache: a cell assembled under one profile can never be served
        under another (or under the uncalibrated path).  The assembly
        mode likewise joins only the assembled-cell keys — the raw
        component groups are shared between legacy and liveness, which
        is exactly the single-source-of-truth property the liveness
        event program relies on.  A learned ``residual`` model
        (repro.calibrate.learned.ResidualModel) corrects the assembled
        prediction; the corrected cell caches under the base key plus
        ``model_hash``, so two model versions can never serve each
        other's cells and ``residual=None`` shares the exact base
        objects.  Cached predictions are shared objects — treat them as
        read-only, as all callers do."""
        pred, pkey = self._predict_base(arch, policy, ctx, profile, chip,
                                        assembly)
        if residual is None:
            return pred
        rkey = (pkey, "residual", residual.model_hash)
        hit = self._pred.get(rkey)
        if hit is None:
            from repro.calibrate.learned import apply_residual
            cfg, _, _ = self._arch_state(arch, policy)
            hit = self._pred[rkey] = apply_residual(
                pred, residual, cfg.family, ctx, profile=profile)
        return hit

    def _predict_base(self, arch: str, policy: TrainPolicy, ctx,
                      profile=None, chip: Optional[str] = None,
                      assembly: str = "legacy"):
        """(prediction, assembled-cell memo key) — predict_cell's body,
        before any residual correction."""
        cfg, model, rows = self._arch_state(arch, policy)
        mkey = tuple(sorted(ctx.mesh_shape.items()))
        base = (arch, policy, ctx.kind, mkey, ctx.backend)
        if ctx.pp > 1:
            return self._predict_pipelined(model, base, ctx, arch, policy,
                                           profile, chip, assembly)

        skey = base + (ctx.optimizer, ctx.eff_grad_bytes, ctx.offload_opt)
        static = self._static.get(skey)
        if static is None:
            static = self._static[skey] = PR.compute_static(rows, ctx)

        akey = base + (ctx.remat, ctx.micro_batch, ctx.seq_len, ctx.enc_seq)
        if ctx.kind != "train":
            akey += (ctx.global_batch, ctx.max_len)
        acts = self._acts.get(akey)
        if acts is None:
            acts = self._acts[akey] = PR.compute_acts(rows, ctx, ctx.kind)

        okey = base + (ctx.global_batch, ctx.micro_batch, ctx.seq_len,
                       ctx.enc_seq, ctx.max_len, ctx.serve)
        over = self._over.get(okey)
        if over is None:
            over = self._over[okey] = PR.compute_overheads(
                model, rows, ctx, ctx.kind)

        # assemble() reads only the components + ctx.opt_transient_frac
        # (backend-derived, already in base); chip only matters once a
        # profile can add a chip constant
        phash = None if profile is None else profile.profile_hash
        pkey = (skey, akey, okey, phash,
                chip if phash is not None else None, assembly)
        pred = self._pred.get(pkey)
        if pred is None:
            pred = self._pred[pkey] = PR.assemble(
                static, acts, over, ctx, profile=profile, chip=chip,
                assembly=assembly)
        return pred, pkey

    def _predict_pipelined(self, model, base, ctx, arch, policy,
                           profile, chip, assembly="legacy"):
        """Memoized per-stage twin of ``PR.predict`` for ``ctx.pp > 1``:
        each stage's component groups cache independently (the stage
        identity joins the exact fields each group reads), and the
        worst-stage composition caches like a plain cell."""
        from repro.core import stages as ST
        pp, m = ctx.pp, ctx.eff_microbatches
        phash = None if profile is None else profile.profile_hash
        pkey = (base, "pipelined", ctx.optimizer, ctx.eff_grad_bytes,
                ctx.offload_opt,
                ctx.remat, ctx.pp_micro_batch, ctx.global_batch,
                ctx.seq_len, ctx.enc_seq, ctx.max_len, m, ctx.schedule,
                ctx.serve, phash, chip if phash is not None else None,
                assembly)
        pred = self._pred.get(pkey)
        if pred is not None:
            return pred, pkey
        plan = self._stage_plan(arch, policy, pp)
        best = None
        for s, srows in enumerate(plan.stages):
            sbase = base + (("stage", s, pp),)
            skey = sbase + (ctx.optimizer, ctx.eff_grad_bytes,
                            ctx.offload_opt)
            static = self._static.get(skey)
            if static is None:
                static = self._static[skey] = PR.compute_static(
                    list(srows), ctx)
            stash = ST.stash_count(s, pp, m, ctx.schedule)
            akey = sbase + (ctx.remat, ctx.pp_micro_batch, ctx.seq_len,
                            ctx.enc_seq, stash)
            if ctx.kind != "train":
                akey += (ctx.global_batch, ctx.max_len)
            acts = self._acts.get(akey)
            if acts is None:
                acts = self._acts[akey] = PR.compute_acts(
                    list(srows), ctx, ctx.kind, stash=stash)
            okey = sbase + (ctx.global_batch, ctx.pp_micro_batch,
                            ctx.seq_len, ctx.enc_seq, ctx.max_len, m,
                            ctx.serve)
            over = self._over.get(okey)
            if over is None:
                over = self._over[okey] = PR.compute_overheads(
                    model, list(srows), ctx, ctx.kind, stage=s,
                    n_stages=pp)
            sp = PR.assemble(static, acts, over, ctx, profile=profile,
                             chip=chip, stage=s, n_stages=pp,
                             assembly=assembly)
            if best is None or sp.peak_bytes > best.peak_bytes:
                best = sp
        self._pred[pkey] = best
        return best, pkey

    # -- cell evaluation -----------------------------------------------------
    def evaluate(self, cell: SweepCell, policy: TrainPolicy = FULL_TRAIN,
                 headroom: float = PL.HEADROOM,
                 keep_prediction: bool = False,
                 profile=None, assembly: str = "legacy",
                 residual=None) -> SweepResult:
        cfg, _, _ = self._arch_state(cell.arch, policy)
        ctx = PL.make_context(cfg, cell.mesh_shape, kind=cell.kind,
                              global_batch=cell.global_batch,
                              seq_len=cell.seq_len, backend=cell.backend,
                              grad_accum=cell.grad_accum, remat=cell.remat,
                              optimizer=cell.optimizer,
                              microbatches=cell.microbatches,
                              schedule=cell.schedule, serve=cell.serve,
                              offload_opt=cell.offload)
        pred = self.predict_cell(cell.arch, policy, ctx, profile=profile,
                                 chip=cell.chip, assembly=assembly,
                                 residual=residual)
        budget = int(PL.chip_hbm(cell.chip) * headroom)
        return SweepResult(
            arch=cell.arch, chip=cell.chip, mesh_shape=cell.mesh_shape,
            n_chips=cell.n_chips,
            optimizer=cell.optimizer or cfg.optimizer,
            remat=cell.remat or cfg.remat, grad_accum=cell.grad_accum,
            global_batch=cell.global_batch, seq_len=cell.seq_len,
            kind=cell.kind, backend=cell.backend,
            schedule=cell.schedule, microbatches=cell.microbatches,
            serve=cell.serve, pool_bytes=pred.pool_bytes,
            draft_bytes=pred.draft_bytes,
            hit_saved_bytes=pred.hit_saved_bytes,
            offload=cell.offload, offload_bytes=pred.offload_bytes,
            overlap_slack_bytes=pred.overlap_slack_bytes,
            peak_bytes=pred.peak_bytes, budget_bytes=budget,
            fits=pred.peak_bytes <= budget,
            prediction=pred if keep_prediction else None)

    def report(self, arch: str, shape, mesh_shape: dict, *,
               policy: TrainPolicy = FULL_TRAIN, backend: str = "tpu",
               budget_bytes: int, grad_accum: int = 1,
               remat: Optional[str] = None,
               optimizer: Optional[str] = None, chip: str = "v5e",
               profile=None, microbatches: int = 1,
               schedule: str = "1f1b", serve=None,
               offload_opt: bool = False,
               assembly: str = "legacy",
               residual=None) -> PL.PlanReport:
        """PlanReport-shaped single-cell evaluation (planner.plan's
        memoized backend); byte-identical to ``planner.check``."""
        shape = PL._resolve_shape(shape)
        cfg, _, _ = self._arch_state(arch, policy)
        ctx = PL.make_context(cfg, mesh_shape, kind=shape.kind,
                              global_batch=shape.global_batch,
                              seq_len=shape.seq_len, backend=backend,
                              grad_accum=grad_accum, remat=remat,
                              optimizer=optimizer,
                              microbatches=microbatches,
                              schedule=schedule, serve=serve,
                              offload_opt=offload_opt)
        pred = self.predict_cell(arch, policy, ctx, profile=profile,
                                 chip=chip, assembly=assembly,
                                 residual=residual)
        return PL.PlanReport(arch=arch, shape=shape.name,
                             fits=pred.peak_bytes <= budget_bytes,
                             peak_bytes=pred.peak_bytes,
                             budget_bytes=budget_bytes,
                             grad_accum=grad_accum,
                             remat=remat or cfg.remat, prediction=pred)

    def sweep(self, grid: SweepGrid, mode: str = "columnar",
              jobs: int = 1, engine: str = "numpy") -> SweepResults:
        """Evaluate every grid cell.

        ``mode="columnar"`` (default) lowers the whole grid to the
        structure-of-arrays kernels in :mod:`repro.core.batch` —
        byte-identical verdicts and peak bytes, orders of magnitude
        faster on large grids.  ``mode="cell"`` is the per-cell
        reference path.  ``engine`` selects the columnar compute
        engine: ``"numpy"`` (the reference) or ``"jax"`` — the jitted
        stage-scan twin in :mod:`repro.core.batch_jax`, byte-identical
        results, fastest on repeated/large sweeps once its tables and
        compiled composition are warm (docs/memory_model.md "Engines").
        Grids with ``keep_predictions=True`` always take the cell path
        (columnar mode does not materialize PredictedMemory
        breakdowns), as do grids with a learned ``residual_model`` (the
        per-cell correction is applied at predict_cell, not in the
        columnar kernels) and an environment without numpy.  ``jobs`` >
        1 splits the columnar component stage over worker threads
        (mesh-chunked; results are order-identical).
        """
        if mode not in ("columnar", "cell"):
            raise ValueError(
                f"unknown sweep mode {mode!r}; use 'columnar' or 'cell'")
        if engine not in ("numpy", "jax"):
            raise ValueError(
                f"unknown sweep engine {engine!r}; use 'numpy' or 'jax'")
        if engine == "jax":
            if mode == "cell":
                raise ValueError(
                    "engine='jax' lowers the columnar path; it cannot "
                    "drive mode='cell' (use engine='numpy')")
            if grid.keep_predictions:
                raise ValueError(
                    "engine='jax' does not materialize PredictedMemory "
                    "breakdowns; use engine='numpy' with "
                    "keep_predictions=True")
            if grid.residual_model is not None:
                raise ValueError(
                    "engine='jax' does not apply learned residual "
                    "models; use engine='numpy' (the residual grid "
                    "routes through the cell path)")
            from repro.core import batch_jax as BJ
            return BJ.sweep_columnar_jax(self, grid, jobs=jobs)
        if mode == "columnar" and not grid.keep_predictions \
                and grid.residual_model is None:
            try:
                from repro.core import batch as B
            except ImportError:          # no numpy -> reference path
                B = None
            if B is not None:
                return B.sweep_columnar(self, grid, jobs=jobs)
        t0 = time.perf_counter()
        results = [self.evaluate(cell, grid.policy, grid.headroom,
                                 grid.keep_predictions,
                                 profile=grid.profile,
                                 assembly=grid.assembly,
                                 residual=grid.residual_model)
                   for cell in grid.cells()]
        return SweepResults(grid=grid, results=results,
                            elapsed_s=time.perf_counter() - t0)


def sweep(grid: SweepGrid, engine=None,
          mode: str = "columnar", jobs: int = 1) -> SweepResults:
    """Run a capacity-planning sweep (fresh engine unless one is passed).

    ``engine`` accepts either a :class:`SweepEngine` instance or a
    compute-engine name (``"numpy"`` / ``"jax"``) — the string form is
    shorthand for a fresh SweepEngine driving that columnar engine."""
    if isinstance(engine, str):
        return SweepEngine().sweep(grid, mode=mode, jobs=jobs,
                                   engine=engine)
    return (engine or SweepEngine()).sweep(grid, mode=mode, jobs=jobs)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _int_list(s: str) -> tuple[int, ...]:
    return tuple(int(x) for x in s.split(",") if x)


def _float_list(s: str) -> tuple[float, ...]:
    return tuple(float(x) for x in s.split(",") if x)


def _str_list(s: Optional[str]) -> tuple:
    if not s:
        return (None,)
    return tuple(None if x in ("default", "arch") else x
                 for x in s.split(",") if x)


# order-of-magnitude planning rates for --dry-run's runtime estimate —
# the FALLBACK when BENCH_sweep.json (benchmarks/sweep_throughput.py)
# has no measured rate for the (mode, engine, assembly) triple on this
# machine.  The liveness assembly pays the event-program contraction on
# top of the legacy composition, hence the lower planning rates.
EST_CELLS_PER_SEC = {"columnar": 1_000_000, "columnar_jax": 10_000_000,
                     "cell": 15_000,
                     "columnar_liveness": 1_000_000,
                     "columnar_jax_liveness": 5_000_000,
                     "cell_liveness": 10_000}


def _rate_key(mode: str, engine: str = "numpy",
              assembly: str = "legacy") -> str:
    """BENCH_sweep.json ``modes`` key for a (mode, engine, assembly)
    triple — the numpy engine keeps the bare mode name and the legacy
    assembly adds no suffix, so historical BENCH files stay readable."""
    key = mode if (mode == "cell" or engine in (None, "numpy")) \
        else f"{mode}_{engine}"
    if assembly not in (None, "legacy"):
        key = f"{key}_{assembly}"
    return key


def _planning_rate(mode: str, engine: str = "numpy",
                   assembly: str = "legacy") -> tuple[float, str]:
    """(cells/sec, source) for --dry-run's runtime estimate: the last
    measured throughput for this exact (mode, engine, assembly) triple
    from BENCH_sweep.json when present, else the order-of-magnitude
    planning rate.  A measured rate for a DIFFERENT assembly never
    substitutes — the liveness contraction has its own cost profile."""
    import json
    import os
    key = _rate_key(mode, engine, assembly)
    try:
        from repro.calibrate.paths import repo_root
        path = os.path.join(str(repo_root()), "BENCH_sweep.json")
        with open(path) as f:
            rate = float(json.load(f)["modes"][key]["cells_per_sec"])
        if rate > 0:
            return rate, f"measured, {os.path.basename(path)}"
    except (ImportError, OSError, KeyError, ValueError, TypeError):
        pass
    return float(EST_CELLS_PER_SEC.get(key, EST_CELLS_PER_SEC[mode])), \
        "planning estimate; run benchmarks/sweep_throughput.py to measure"


def _preview(values, limit: int = 6) -> str:
    vals = [str(v) if v is not None else "default" for v in values]
    if len(vals) > limit:
        vals = vals[:limit] + ["..."]
    return ",".join(vals)


def _cardinality_table(grid: SweepGrid) -> str:
    """Per-knob cardinality breakdown of a grid — what ``size()``
    multiplies — so ``--dry-run`` users see where a cell explosion comes
    from before paying for it."""
    from repro.launch.mesh import cp_degree, ep_degree, pp_degree
    meshes = grid.meshes()
    pps = sorted({pp_degree(m) for m in meshes})
    eps = sorted({ep_degree(m) for m in meshes})
    cps = sorted({cp_degree(m) for m in meshes})
    degrees = [f"{k} degrees {_preview(v)}"
               for k, v in (("pp", pps), ("ep", eps), ("cp", cps))
               if len(v) > 1 or v != [1]]
    pairs = [(a, g) for a in _seq(grid.grad_accums)
             for g in _seq(grid.global_batches) if not g % a]
    rows = [
        ("arch", len(_seq(grid.arch)), _preview(_seq(grid.arch))),
        ("chip type", len(_seq(grid.chip)), _preview(_seq(grid.chip))),
        ("mesh", len(meshes),
         ", ".join(degrees) if degrees else "2-axis factorizations"),
        ("optimizer", len(_seq(grid.optimizers)),
         _preview(_seq(grid.optimizers))),
        ("remat", len(_seq(grid.remats)), _preview(_seq(grid.remats))),
        ("schedule", len(_seq(grid.schedules)),
         _preview(_seq(grid.schedules))),
        ("microbatches", len(_seq(grid.microbatches)),
         _preview(_seq(grid.microbatches))),
        ("accum x batch", len(pairs),
         _preview([f"{a}/{g}" for a, g in pairs])),
        ("seq len", len(_seq(grid.seq_lens)),
         _preview(_seq(grid.seq_lens))),
    ]
    serves = grid.serve_specs()
    if any(s is not None for s in serves):
        rows.insert(-2, ("serve", len(serves), _preview(
            ["neutral" if s is None else
             f"b{s.block_size}/u{s.util_bp / 10000:g}/h{s.hit_bp / 10000:g}"
             + (f"/d:{s.draft_arch}" if s.draft_arch else "")
             for s in serves])))
    offs = grid.offloads()
    if any(offs):
        rows.insert(-2, ("offload", len(offs),
                         _preview(["on" if o else "off" for o in offs])))
    out = [f"  {'knob':<14s} {'count':>5s}  values"]
    for name, count, vals in rows:
        out.append(f"  {name:<14s} {count:>5d}  {vals}")
    out.append(f"  {'total':<14s} {grid.size():>5d}  (product, after "
               f"divisibility filter)")
    return "\n".join(out)


def _empty_grid_msg() -> str:
    return ("0 cells matched: the grid produced no evaluable cells.  "
            "Common causes: no --batch value is divisible by any --accum "
            "value (cells with batch % accum != 0 are skipped), or "
            "--max-model filtered out every mesh factorization of "
            "--chips.  Relax one of those axes and re-run.")


def _parse_mesh(s: str) -> dict:
    out = {}
    for part in s.split(","):
        k, _, v = part.partition("=")
        if not k.strip() or not v.isdigit():
            raise ValueError(
                f"bad --mesh entry {part!r}: expected axis=int "
                f"(e.g. data=8,model=2)")
        out[k.strip()] = int(v)
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    p = argparse.ArgumentParser(
        prog="python -m repro.core.sweep",
        description="Capacity-planning sweep: mesh x optimizer x remat x "
                    "accum x batch x seq_len grids, memoized Eq.1 "
                    "arithmetic per cell.")
    p.add_argument("--arch", required=True,
                   help="architecture (e.g. llava15_7b / llava15-7b)")
    p.add_argument("--chips", type=_int_list, default=None,
                   help="chip count(s); all mesh factorizations are swept")
    p.add_argument("--mesh", action="append", metavar="data=8,model=2",
                   help="explicit mesh shape (repeatable; overrides "
                        "--chips enumeration)")
    p.add_argument("--mesh-axes", default="data,model",
                   help="axes used for --chips factorization (add `pipe` "
                        "to enumerate pipeline-parallel plans)")
    p.add_argument("--max-model", type=int, default=None,
                   help="cap the model (TP) axis size")
    p.add_argument("--max-pipe", type=int, default=None,
                   help="cap the pipe (PP) axis size")
    p.add_argument("--max-expert", type=int, default=None,
                   help="cap the expert (EP) axis size (MoE arches only)")
    p.add_argument("--max-context", type=int, default=None,
                   help="cap the context (CP / ring-attention) axis size "
                        "(train/prefill kinds only)")
    p.add_argument("--schedule", default="1f1b",
                   help="comma list of pipeline schedules (1f1b,gpipe)")
    p.add_argument("--microbatches", type=_int_list, default=(1,),
                   help="pipeline microbatch counts (inert without a "
                        "pipe mesh axis)")
    p.add_argument("--chip", default="v5e",
                   help=f"chip type(s), comma list of {sorted(PL.CHIPS)}")
    p.add_argument("--optimizer", default=None,
                   help="comma list (adamw,adafactor,adamw8bit); "
                        "default: arch optimizer")
    p.add_argument("--remat", default=None,
                   help="comma list (none,block,dots); default: arch remat")
    p.add_argument("--accum", type=_int_list, default=(1, 2, 4, 8),
                   help="gradient-accumulation factors")
    p.add_argument("--batch", type=_int_list, default=(256,),
                   help="global batch sizes")
    p.add_argument("--seq-len", type=_int_list, default=(4096,),
                   help="sequence lengths")
    p.add_argument("--kind", default="train",
                   choices=("train", "prefill", "decode"))
    p.add_argument("--block-size", type=_int_list, default=(0,),
                   metavar="B,B,...",
                   help="paged-KV block sizes in tokens (0 = contiguous; "
                        "positive values must be multiples of 8); serve "
                        "kinds only")
    p.add_argument("--utilization", type=_float_list, default=(1.0,),
                   metavar="U,U,...",
                   help="KV-pool utilizations in (0,1]; allocated pool "
                        "bytes are inflated by 1/U (fragmentation slack)")
    p.add_argument("--prefix-hit-rate", type=_float_list, default=(0.0,),
                   metavar="H,H,...",
                   help="prefix-cache hit rates in [0,1] over the shared "
                        "--prefix-len token prefix")
    p.add_argument("--prefix-len", type=int, default=0,
                   help="shared-prefix token count the hit rate discounts")
    p.add_argument("--mix", action="append", default=None,
                   metavar="P[:LxW,...]",
                   help="in-flight request mix: prefill fraction P plus "
                        "an optional final-context histogram, e.g. "
                        "0.3:512x1,2048x3 (repeatable)")
    p.add_argument("--draft-arch", default="",
                   help="comma list of speculative-decode draft arches "
                        "('' = none); decode kind only")
    p.add_argument("--offload-optimizer", default="off",
                   choices=("off", "on", "both"),
                   help="optimizer-state host offload (Eq.1 offload "
                        "tier): off (default), on, or both to sweep the "
                        "knob; train kind only")
    p.add_argument("--policy", default="full", choices=sorted(POLICIES))
    p.add_argument("--backend", default="tpu", choices=("tpu", "cpu"))
    p.add_argument("--headroom", type=float, default=PL.HEADROOM)
    p.add_argument("--profile", metavar="PATH", default=None,
                   help="CalibrationProfile JSON (python -m repro.calibrate"
                        " fit) applied to every cell's prediction")
    p.add_argument("--residual-model", metavar="PATH", default=None,
                   help="learned ResidualModel JSON (python -m "
                        "repro.calibrate fit-residual) applied on top of "
                        "--profile; forces the cell path")
    p.add_argument("--mode", choices=("columnar", "cell"),
                   default="columnar",
                   help="columnar: vectorized batch evaluation (default); "
                        "cell: per-cell reference path (byte-identical, "
                        "much slower on large grids)")
    p.add_argument("--engine", choices=("numpy", "jax"), default="numpy",
                   help="columnar compute engine: numpy (reference, "
                        "default) or jax (jitted contraction, "
                        "byte-identical; pays a one-off compile, then "
                        "~10x the numpy rate on large grids)")
    p.add_argument("--assembly", choices=("legacy", "liveness"),
                   default="legacy",
                   help="peak assembly: legacy Eq.1 sum-of-maxima "
                        "(default) or liveness interval-overlap peak "
                        "from the alloc/free event program "
                        "(docs/memory_model.md)")
    p.add_argument("--jobs", type=int, default=1,
                   help="worker threads for the columnar component stage "
                        "(mesh-chunked; identical results)")
    p.add_argument("--dry-run", action="store_true",
                   help="print the cell count + estimated runtime and "
                        "exit without evaluating anything")
    p.add_argument("--top", type=int, default=20,
                   help="rows to print (full grid goes to --csv/--md)")
    p.add_argument("--csv", metavar="PATH", help="write full CSV report")
    p.add_argument("--md", metavar="PATH", help="write markdown report")
    args = p.parse_args(argv)

    if args.chips is None and not args.mesh:
        p.error("need --chips N or at least one --mesh")

    try:
        arch = normalize_arch(args.arch)
        for c in args.chip.split(","):
            PL.chip_hbm(c)
        from repro.core.stages import SCHEDULES
        for s in args.schedule.split(","):
            if s not in SCHEDULES:
                raise ValueError(
                    f"unknown schedule {s!r}; known: {SCHEDULES}")
        meshes = [_parse_mesh(m) for m in args.mesh] if args.mesh else None
        from repro.serve.fleet import parse_mix
        mixes = tuple(parse_mix(m) for m in args.mix) if args.mix \
            else (None,)
    except (KeyError, ValueError) as e:
        p.error(str(e))
    profile = None
    if args.profile:
        from repro.calibrate.profile import CalibrationProfile
        try:
            profile = CalibrationProfile.load(args.profile)
        except (OSError, ValueError) as e:
            p.error(f"--profile: {e}")
    residual = None
    if args.residual_model:
        from repro.calibrate.learned import ResidualModel
        try:
            residual = ResidualModel.load(args.residual_model)
        except (OSError, ValueError) as e:
            p.error(f"--residual-model: {e}")
        if residual.base_profile_hash != (profile.profile_hash
                                          if profile else None):
            p.error(f"--residual-model was fitted over profile "
                    f"{residual.base_profile_hash or 'raw'}; pass the "
                    f"matching --profile")
        if args.engine == "jax":
            p.error("--residual-model routes through the cell path; "
                    "use --engine numpy")
    max_axis = {}
    if args.max_model:
        max_axis["model"] = args.max_model
    if args.max_pipe:
        max_axis["pipe"] = args.max_pipe
    if args.max_expert:
        max_axis["expert"] = args.max_expert
    if args.max_context:
        max_axis["context"] = args.max_context
    grid = SweepGrid(
        arch=arch,
        chips=args.chips,
        mesh_axes=tuple(args.mesh_axes.split(",")),
        mesh_shapes=meshes,
        max_axis=max_axis or None,
        chip=tuple(args.chip.split(",")),
        optimizers=_str_list(args.optimizer),
        remats=_str_list(args.remat),
        schedules=tuple(args.schedule.split(",")),
        microbatches=args.microbatches,
        grad_accums=args.accum, global_batches=args.batch,
        seq_lens=args.seq_len, kind=args.kind,
        policy=POLICIES[args.policy], backend=args.backend,
        headroom=args.headroom, profile=profile,
        residual_model=residual,
        block_sizes=args.block_size, utilizations=args.utilization,
        prefix_hit_rates=args.prefix_hit_rate,
        prefix_len=args.prefix_len, mixes=mixes,
        draft_archs=tuple(args.draft_arch.split(","))
        if args.draft_arch else ("",),
        offload_optimizer={"off": (False,), "on": (True,),
                           "both": (False, True)}[args.offload_optimizer],
        assembly=args.assembly)
    try:
        # reject ep-on-dense / ep > n_experts / cp-on-decode /
        # non-divisible cp — and serve knobs on train kinds / bad block
        # alignment / out-of-range rates / unknown draft arches /
        # optimizer offload on serve kinds — with a clean argparse
        # error, before any evaluation (and before --dry-run estimates
        # a doomed grid)
        grid.check_parallel()
        grid.check_serve()
        grid.check_offload()
    except ValueError as e:
        p.error(str(e))

    if args.mode == "cell" and args.engine != "numpy":
        p.error("--engine jax requires --mode columnar (the cell path "
                "is the per-cell reference)")

    if args.dry_run:
        n = grid.size()
        rate, source = _planning_rate(args.mode, args.engine,
                                      args.assembly)
        est = n / rate
        print(f"dry run: {n:,} cells")
        print(_cardinality_table(grid))
        print(f"estimated runtime in --mode {args.mode} --engine "
              f"{args.engine} --assembly {args.assembly}: ~{est:.1f}s "
              f"({rate:,.0f} cells/s — {source})")
        if n == 0:
            print(_empty_grid_msg())
            return 2
        return 0

    res = sweep(grid, mode=args.mode, jobs=args.jobs, engine=args.engine)
    if len(res) == 0:
        print(_empty_grid_msg())
        return 2
    n_fit = res.fit_count
    title = (f"capacity sweep: {arch} {args.kind} on {args.chip} "
             f"({args.backend} prediction)"
             + (f" [profile {profile.profile_hash}]" if profile else "")
             + (f" [residual {residual.model_hash}]" if residual else "")
             + (" [liveness]" if args.assembly == "liveness" else ""))
    print(f"# {title}")
    print(f"{len(res)} cells in {res.elapsed_s:.3f}s "
          f"({res.cells_per_sec:,.0f} cells/s, mode={args.mode}, "
          f"engine={args.engine}); {n_fit} fit")
    if res.frontier():
        print("\nPareto frontier (chips -> max fitting global batch):")
        for chips, batch in res.frontier():
            print(f"  {chips:>6d} chips : batch {batch}")
    best = res.max_global_batch()
    if best is not None:
        print(f"\nbest: {best}")
    print()
    print(res.to_markdown(limit=args.top))
    if args.csv:
        with open(args.csv, "w") as f:
            f.write(res.to_csv() + "\n")
        print(f"\nwrote {args.csv}")
    if args.md:
        with open(args.md, "w") as f:
            f.write(res.to_markdown(title=title) + "\n")
        print(f"wrote {args.md}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
