"""Ground truth + roofline inputs extracted from compiled XLA artifacts.

* ``memory_stats``    — per-device peak from ``compiled.memory_analysis()``
  (arguments + temps + unaliased outputs); this is the quantity whose
  overflow aborts a TPU job, i.e. the OoM the paper predicts.
* ``cost_stats``      — HLO FLOPs / bytes-accessed from ``cost_analysis()``.
* ``collective_stats``— parsed from the post-SPMD HLO text: per collective
  op, operand bytes and estimated wire bytes (ring terms), for the
  roofline's collective term.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|s32|u32|s64|u64|"
                       r"f8e4m3fn|f8e5m2|f16|bf16|f32|f64|c64|c128)"
                       r"\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_GROUP_RE = re.compile(r"replica_groups=\{?\[?([0-9,\s\{\}\[\]]*)")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of all typed shapes appearing in a string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)       # op -> count
    operand_bytes: dict = field(default_factory=dict)  # op -> bytes (per dev)
    wire_bytes: dict = field(default_factory=dict)   # op -> est wire bytes

    @property
    def total_operand_bytes(self) -> int:
        return sum(self.operand_bytes.values())

    @property
    def total_wire_bytes(self) -> int:
        return sum(self.wire_bytes.values())


def _group_size(line: str, default: int) -> int:
    m = _GROUP_RE.search(line)
    if not m:
        return default
    first = m.group(1).split("}")[0].split("]")[0]
    ids = [x for x in first.replace("{", " ").replace("[", " ")
           .split(",") if x.strip().isdigit()]
    return max(len(ids), 1)


def collective_stats(hlo_text: str, n_devices: int = 1) -> CollectiveStats:
    """Parse per-device collective traffic from post-optimization HLO.

    ``operand_bytes``: sum of result-shape bytes per op (per device).
    ``wire_bytes``: ring estimates — all-reduce 2x(g-1)/g, gather/scatter
    and all-to-all (g-1)/g, permute 1x.
    """
    out = CollectiveStats()
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        line = hlo_text[m.start():hlo_text.find("\n", m.start())]
        if "-done(" in line:
            continue                       # counted at -start
        nbytes = shape_bytes(shape_str)
        g = _group_size(line, n_devices)
        if op == "all-reduce":
            wire = int(2 * nbytes * (g - 1) / max(g, 1))
        elif op == "collective-permute":
            wire = nbytes
        else:                              # all-gather / rs / a2a
            wire = int(nbytes * (g - 1) / max(g, 1))
        out.counts[op] = out.counts.get(op, 0) + 1
        out.operand_bytes[op] = out.operand_bytes.get(op, 0) + nbytes
        out.wire_bytes[op] = out.wire_bytes.get(op, 0) + wire
    return out


@dataclass
class MemoryStats:
    argument_bytes: int
    output_bytes: int
    temp_bytes: int
    alias_bytes: int

    @property
    def total_bytes(self) -> int:
        return (self.argument_bytes + self.temp_bytes
                + self.output_bytes - self.alias_bytes)


def memory_stats(compiled) -> MemoryStats:
    ma = compiled.memory_analysis()
    return MemoryStats(
        argument_bytes=ma.argument_size_in_bytes,
        output_bytes=ma.output_size_in_bytes,
        temp_bytes=ma.temp_size_in_bytes,
        alias_bytes=ma.alias_size_in_bytes)


@dataclass
class CostStats:
    flops: float
    bytes_accessed: float


def cost_stats(compiled) -> CostStats:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return CostStats(flops=float(ca.get("flops", 0.0)),
                     bytes_accessed=float(ca.get("bytes accessed", 0.0)))


# ---------------------------------------------------------------------------
# Loop-aware HLO analysis.
#
# XLA's cost_analysis() counts a while-loop BODY once, not per iteration —
# for scan-stacked models that undercounts FLOPs/bytes/collectives by
# ~n_layers.  This walks the computation call graph (entry -> while bodies,
# fusions, calls), multiplies by loop trip counts (parsed from the loop
# condition's comparison constant), and accumulates:
#   * dot FLOPs (2 * output_elems * contraction_size),
#   * bytes accessed at fusion/instruction granularity,
#   * collective operand/wire bytes (including collectives inside loops).
# ---------------------------------------------------------------------------

_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+) (?:\([^)]*\) -> .*?)?\{",
                      re.M)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+) = ((?:\([^)]*\))|(?:\S+))\s+"
    r"([\w\-]+)\((.*)", re.M)
_CALLS_RE = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)="
                       r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DNUM_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _parse_computations(txt: str) -> dict:
    """computation name -> list of raw instruction lines."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in txt.splitlines():
        if line.endswith("{") and ("=" not in line.split("(")[0]):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if line.strip() == "}":
            continue
        if cur is not None and "=" in line:
            comps[cur].append(line)
    return comps


def _first_shape(s: str) -> tuple[str, list[int]]:
    m = _SHAPE_RE.search(s)
    if not m:
        return "", []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d] if dims else []


_REF_RE = re.compile(r"%([\w.\-]+)")


def _operand_names(rest: str) -> list[str]:
    """%operand references in the call portion of an instruction line."""
    args = rest.split(")", 1)[0]
    return _REF_RE.findall(args)


def _trip_count(cond_lines: list[str]) -> int:
    """Largest integer literal in the loop condition — lax.scan lowers to
    `lt(iter, constant(N))`, so this is the trip count."""
    best = 1
    for line in cond_lines:
        for c in _CONST_RE.findall(line):
            best = max(best, int(c))
    return best


@dataclass
class LoopAwareStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collectives: CollectiveStats = field(default_factory=CollectiveStats)


def loop_aware_stats(txt: str, n_devices: int = 1) -> LoopAwareStats:
    comps = _parse_computations(txt)
    out = LoopAwareStats()

    # name -> result shape string, from every defining instruction (operand
    # references in HLO calls carry no inline shapes)
    def_shape: dict[str, str] = {}
    for lines in comps.values():
        for line in lines:
            m = _INSTR_RE.match(line)
            if m:
                def_shape[m.group(1)] = m.group(2)

    def operand_bytes(rest: str) -> int:
        return sum(shape_bytes(def_shape.get(nm, ""))
                   for nm in _operand_names(rest))

    def lhs_dims(rest: str) -> list[int]:
        names = _operand_names(rest)
        if not names:
            return []
        _, dims = _first_shape(def_shape.get(names[0], ""))
        return dims

    def visit(comp: str, mult: float, seen: tuple):
        if comp not in comps or comp in seen:
            return
        for line in comps[comp]:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, result_shape, op, rest = m.groups()
            if op == "while":
                calls = dict(re.findall(r"(body|condition)=%?([\w.\-]+)",
                                        line))
                trips = _trip_count(comps.get(calls.get("condition", ""),
                                              []))
                visit(calls.get("body", ""), mult * trips, seen + (comp,))
                continue
            if op in ("call", "conditional"):
                for grp in _CALLS_RE.findall(line):
                    for c in grp.split(","):
                        visit(c.strip().lstrip("%"), mult, seen + (comp,))
                continue
            if op == "fusion":
                cm = re.search(r"calls=%?([\w.\-]+)", line)
                if cm:
                    visit(cm.group(1), mult, seen + (comp,))
                # bytes at fusion granularity.  In-place-update fusions
                # (some operand shape == result shape: dus-carried stacks,
                # accumulators) touch the whole buffer ONCE across the
                # loop, not per iteration — else saved-activation stacks
                # would be counted n_layers x their size.
                rbytes = shape_bytes(result_shape)
                in_place = any(
                    def_shape.get(nm, "") == result_shape
                    for nm in _operand_names(rest))
                out.bytes_accessed += rbytes if in_place else mult * rbytes
                continue
            if op in ("dot", "convolution"):
                _, odims = _first_shape(result_shape)
                oelems = 1
                for d in odims:
                    oelems *= d
                lhs = lhs_dims(rest)
                k = 1
                dm = _DNUM_RE.search(line)
                if dm and lhs:
                    for ci in dm.group(1).split(","):
                        if ci.strip().isdigit() and int(ci) < len(lhs):
                            k *= lhs[int(ci)]
                elif lhs:
                    k = lhs[-1]
                out.flops += mult * 2.0 * oelems * k
                out.bytes_accessed += mult * (shape_bytes(result_shape)
                                              + operand_bytes(rest))
                continue
            if op in ("dynamic-update-slice", "copy", "copy-start"):
                rbytes = shape_bytes(result_shape)
                in_place = any(def_shape.get(nm, "") == result_shape
                               for nm in _operand_names(rest))
                out.bytes_accessed += rbytes if in_place else mult * rbytes
                continue
            if op in ("all-reduce", "all-gather", "reduce-scatter",
                      "all-to-all", "collective-permute",
                      "all-reduce-start", "all-gather-start",
                      "collective-permute-start"):
                base = op.replace("-start", "")
                nbytes = shape_bytes(result_shape)
                g = _group_size(line, n_devices)
                if base == "all-reduce":
                    wire = int(2 * nbytes * (g - 1) / max(g, 1))
                elif base == "collective-permute":
                    wire = nbytes
                else:
                    wire = int(nbytes * (g - 1) / max(g, 1))
                c = out.collectives
                c.counts[base] = c.counts.get(base, 0) + int(mult)
                c.operand_bytes[base] = c.operand_bytes.get(base, 0) \
                    + int(mult * nbytes)
                c.wire_bytes[base] = c.wire_bytes.get(base, 0) \
                    + int(mult * wire)
                continue
            if op in ("get-tuple-element", "tuple", "parameter", "bitcast",
                      "constant", "after-all", "opt-barrier"):
                continue          # aliases / bookkeeping: no HBM traffic
            # remaining top-level ops (elementwise, transpose, slice...):
            # result bytes per execution
            out.bytes_accessed += mult * shape_bytes(result_shape)

    entries = [c for c in comps if c.startswith("main") or c == "entry"]
    entry = entries[0] if entries else next(iter(comps), None)
    # ENTRY computation is the last one in PJRT dumps more often; find the
    # one nobody calls instead.
    called = set()
    for lines in comps.values():
        for line in lines:
            for grp in _CALLS_RE.findall(line):
                for c in grp.split(","):
                    called.add(c.strip().lstrip("%"))
    roots = [c for c in comps if c not in called]
    entry = roots[-1] if roots else entry
    if entry:
        visit(entry, 1.0, ())
    return out
