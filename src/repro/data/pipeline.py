"""Deterministic, shard-aware synthetic data pipeline.

Every (step, host_shard) pair maps to a unique counter-based RNG stream, so

* restarts resume mid-epoch without replaying or skipping batches,
* elastic rescaling (different host count) re-partitions the SAME global
  batch sequence — shard s of S takes rows [s*B/S, (s+1)*B/S),
* straggler mitigation can hand a shard's range to another host and produce
  bit-identical data.

The generator is numpy-side (host memory), matching a real ingest pipeline;
``global_batch()`` assembles a jax array with the requested sharding.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.configs import ArchConfig, ShapeConfig


def _rng(step: int, row0: int, tag: int) -> np.random.Generator:
    # Philox takes a 2-word (uint64) key: (tag | step, row0) — unique per
    # (step, shard-row-offset, stream tag).
    return np.random.Generator(
        np.random.Philox(key=[(tag << 48) | step, row0]))


@dataclass
class SyntheticPipeline:
    cfg: ArchConfig
    shape: ShapeConfig
    n_shards: int = 1
    shard_id: int = 0

    def _rows(self) -> tuple[int, int]:
        B = self.shape.global_batch
        per = B // self.n_shards
        return self.shard_id * per, per

    def shard_batch(self, step: int) -> dict:
        """Host-local rows of the global batch for `step` (numpy)."""
        row0, rows = self._rows()
        S = self.shape.seq_len
        cfg = self.cfg
        g = _rng(step, row0, tag=1)
        tokens = g.integers(0, cfg.vocab, (rows, S), dtype=np.int32)
        batch = {"tokens": tokens,
                 "labels": np.roll(tokens, -1, axis=1).astype(np.int32)}
        if cfg.family == "vlm":
            n_img = cfg.vlm.n_image_tokens
            s_text = max(S - n_img, 1)
            batch["tokens"] = batch["tokens"][:, :s_text]
            batch["labels"] = batch["labels"][:, :s_text]
            gi = _rng(step, row0, tag=2)
            if cfg.vlm.vision_tower:
                n_patch = (cfg.vlm.vit_image_size // cfg.vlm.vit_patch) ** 2
                batch["patches"] = gi.normal(
                    0, 0.5, (rows, n_patch, 3 * cfg.vlm.vit_patch ** 2)
                ).astype(np.float32)
            else:
                batch["patch_embeds"] = gi.normal(
                    0, 0.5, (rows, n_img, cfg.vlm.d_vision)).astype(np.float32)
        elif cfg.family == "encdec":
            gi = _rng(step, row0, tag=3)
            T_enc = int(S * cfg.encdec.enc_seq_ratio)
            batch["frames"] = gi.normal(
                0, 0.5, (rows, T_enc, cfg.encdec.d_frontend)
            ).astype(np.float32)
        return batch

    def global_batch(self, step: int) -> dict:
        """Assemble the full global batch (single-process convenience)."""
        saved = self.n_shards, self.shard_id
        try:
            parts = []
            for s in range(self.n_shards):
                self.shard_id = s
                parts.append(self.shard_batch(step))
            return {k: np.concatenate([p[k] for p in parts], axis=0)
                    for k in parts[0]}
        finally:
            self.n_shards, self.shard_id = saved
