"""Pallas TPU kernels for the framework's compute hot-spots.

* ``flash_attention`` — blocked online-softmax attention (fwd + FA2 bwd),
  GQA-aware tiling; the memory behaviour the paper's activation factor
  models (no S x S materialization).
* ``rmsnorm``         — fused norm fwd/bwd.
* ``ssd``             — Mamba-2 chunked state-space scan with VMEM-resident
  inter-chunk state.

Each kernel ships with a pure-jnp oracle in ``ref.py`` and is validated in
interpret mode across shape/dtype sweeps in ``tests/test_kernels.py``.
The training graphs use mathematically-identical pure-``lax`` paths (see
``models.attention`` / ``models.mamba``) so the CPU dry-run oracle and the
TPU hot path share one definition.
"""

from repro.kernels.ops import flash_attention, rmsnorm, ssd_scan  # noqa: F401
