"""Pallas TPU flash-attention kernel (forward + backward).

TPU-native adaptation of FlashAttention-2 for the GQA/MLA attention in this
framework:

* The (block_q, block_k) probability tile lives in VMEM; m/l/acc
  accumulators persist in VMEM scratch across the innermost (sequential)
  KV-grid dimension — the HBM->VMEM->MXU pipeline XLA cannot express for
  online softmax.
* Tiles are MXU-aligned: block sizes default to 128/256 multiples; the
  contraction dim D (64..256 for the zoo's heads) rides the lane dim.
* GQA is handled in the index maps (KV head = q head // group), so no
  KV duplication is ever materialized.
* Causality skips fully-masked tiles via ``pl.when`` (halves the work,
  the same win the paper's roofline sees on HLO FLOPs).

Backward follows FA2: one pass re-streaming KV tiles per q tile for dq,
and a KV-stationary pass for dk/dv.  ``ops.flash_attention`` wires these
into a ``jax.custom_vjp``; ``ref.py`` is the pure-jnp oracle; tests sweep
shapes/dtypes in interpret mode.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                m_scr, l_scr, acc_scr, *,
                scale: float, causal: bool, block_q: int, block_k: int,
                sq: int, skv: int, q_offset: int):
    """Grid: (B, H, nq, nk); nk is innermost/sequential."""
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q + q_offset          # absolute pos of q row 0
    k_start = ki * block_k

    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale   # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)           # (bk, Dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        mask = k_pos < skv                                  # kv padding
        if causal:
            mask = mask & (k_pos <= q_pos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                 # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                              # (bq, bk)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv

    if causal:   # skip tiles strictly above the causal diagonal
        pl.when(k_start <= q_start + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0, :] = (m_scr[...] + jnp.log(l))[:, 0]


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "q_offset", "interpret"))
def flash_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, block_q: int = 256, block_k: int = 256,
              q_offset: int = 0, interpret: bool = False):
    """q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D/Dv) -> (out, lse).

    out: (B, Sq, H, Dv); lse: (B, H, Sq) fp32.
    """
    B, Sq, H, D = q.shape
    _, Skv, Hkv, Dv = v.shape
    assert H % Hkv == 0
    G = H // Hkv
    scale = D ** -0.5

    block_q = min(block_q, _ceil_to(Sq, 128))
    block_k = min(block_k, _ceil_to(Skv, 128))
    sq_pad = _ceil_to(Sq, block_q)
    skv_pad = _ceil_to(Skv, block_k)
    if sq_pad != Sq:
        q = jnp.pad(q, ((0, 0), (0, sq_pad - Sq), (0, 0), (0, 0)))
    if skv_pad != Skv:
        k = jnp.pad(k, ((0, 0), (0, skv_pad - Skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv_pad - Skv), (0, 0), (0, 0)))
    nq, nk = sq_pad // block_q, skv_pad // block_k

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, sq=Sq, skv=Skv, q_offset=q_offset)

    out, lse = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, Dv),
                         lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, 1, Dv),
                         lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, qi, ki: (b, h, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, sq_pad, H, Dv), q.dtype),
            jax.ShapeDtypeStruct((B, H, sq_pad), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq], lse[:, :, :Sq]


# ---------------------------------------------------------------------------
# backward: dq pass (q-stationary) + dkv pass (kv-stationary)
# ---------------------------------------------------------------------------


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               dq_scr, *, scale, causal, block_q, block_k, skv, q_offset):
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q_start = pl.program_id(2) * block_q + q_offset
    k_start = ki * block_k

    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        do = do_ref[0, :, 0, :].astype(jnp.float32)
        lse = lse_ref[0, 0, :][:, None]
        delta = delta_ref[0, 0, :][:, None]

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        mask = k_pos < skv
        if causal:
            mask = mask & (k_pos <= q_pos)
        p = jnp.where(mask, jnp.exp(s - lse), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta)
        dq_scr[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if causal:
        pl.when(k_start <= q_start + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(ki == nk - 1)
    def _finalize():
        dq_ref[0, :, 0, :] = (dq_scr[...] * scale).astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *,
                scale, causal, block_q, block_k, skv, q_offset, group):
    """Grid: (B, Hkv, nk, G, nq); (G, nq) innermost so one (b, hkv, ki)
    accumulates over every query head in the group and every q tile."""
    qi = pl.program_id(4)
    gi = pl.program_id(3)
    nq = pl.num_programs(4)
    ng = pl.num_programs(3)

    @pl.when((qi == 0) & (gi == 0))
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q_start = qi * block_q + q_offset
    k_start = pl.program_id(2) * block_k

    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        do = do_ref[0, :, 0, :].astype(jnp.float32)
        lse = lse_ref[0, 0, :][:, None]
        delta = delta_ref[0, 0, :][:, None]

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        mask = k_pos < skv
        if causal:
            mask = mask & (k_pos <= q_pos)
        p = jnp.where(mask, jnp.exp(s - lse), 0.0)          # (bq, bk)
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta)
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if causal:
        pl.when(k_start <= q_start + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when((qi == nq - 1) & (gi == ng - 1))
    def _finalize():
        # q was pre-scaled inside _compute, so ds^T @ q already carries the
        # 1/sqrt(D) factor — no extra scale here.
        dk_ref[0, :, 0, :] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, :, 0, :] = dv_scr[...].astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "q_offset", "interpret"))
def flash_bwd(q, k, v, out, lse, dout, *, causal: bool = True,
              block_q: int = 256, block_k: int = 256, q_offset: int = 0,
              interpret: bool = False):
    """FA2 backward. Returns (dq, dk, dv)."""
    B, Sq, H, D = q.shape
    _, Skv, Hkv, Dv = v.shape
    G = H // Hkv
    scale = D ** -0.5

    delta = jnp.einsum("bshd,bshd->bhs", dout.astype(jnp.float32),
                       out.astype(jnp.float32))            # (B, H, Sq)

    block_q = min(block_q, _ceil_to(Sq, 128))
    block_k = min(block_k, _ceil_to(Skv, 128))
    sq_pad = _ceil_to(Sq, block_q)
    skv_pad = _ceil_to(Skv, block_k)
    if sq_pad != Sq:
        pad = sq_pad - Sq
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dout = jnp.pad(dout, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # padded q rows: lse = +inf would give p = 0; use NEG_INF-safe pad
        lse = jnp.pad(lse, ((0, 0), (0, 0), (0, pad)),
                      constant_values=jnp.inf)
        delta = jnp.pad(delta, ((0, 0), (0, 0), (0, pad)))
    if skv_pad != Skv:
        pad = skv_pad - Skv
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq, nk = sq_pad // block_q, skv_pad // block_k

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k, skv=Skv,
                          q_offset=q_offset),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, Dv),
                         lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_q, 1, Dv),
                         lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, qi, ki: (b, h, qi)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, qi, ki: (b, h, qi)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, sq_pad, H, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, dout, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k, skv=Skv,
                          q_offset=q_offset, group=G),
        grid=(B, Hkv, nk, G, nq),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D),
                         lambda b, hk, ki, g, qi: (b, qi, hk * G + g, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, hk, ki, g, qi: (b, ki, hk, 0)),
            pl.BlockSpec((1, block_k, 1, Dv),
                         lambda b, hk, ki, g, qi: (b, ki, hk, 0)),
            pl.BlockSpec((1, block_q, 1, Dv),
                         lambda b, hk, ki, g, qi: (b, qi, hk * G + g, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b, hk, ki, g, qi: (b, hk * G + g, qi)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b, hk, ki, g, qi: (b, hk * G + g, qi)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, hk, ki, g, qi: (b, ki, hk, 0)),
            pl.BlockSpec((1, block_k, 1, Dv),
                         lambda b, hk, ki, g, qi: (b, ki, hk, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, skv_pad, Hkv, D), k.dtype),
            jax.ShapeDtypeStruct((B, skv_pad, Hkv, Dv), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, D), jnp.float32),
            pltpu.VMEM((block_k, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, dout, lse, delta)

    return dq[:, :Sq], dk[:, :Skv], dv[:, :Skv]
