"""jit'd public wrappers for the Pallas kernels.

``flash_attention`` is a drop-in for ``repro.models.attention``'s pure-lax
path: same signature, same (B, S, H, D) layouts, differentiable via
``jax.custom_vjp`` over the fwd/bwd kernels.  On non-TPU backends pass
``interpret=True`` (tests do) — the kernel body executes in Python with
identical math.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssd as _ssd


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool = True, block: int = 256,
                    q_offset: int = 0, interpret: bool = False):
    out, _ = _fa.flash_fwd(q, k, v, causal=causal, block_q=block,
                           block_k=block, q_offset=q_offset,
                           interpret=interpret)
    return out


def _fwd(q, k, v, causal, block, q_offset, interpret):
    out, lse = _fa.flash_fwd(q, k, v, causal=causal, block_q=block,
                             block_k=block, q_offset=q_offset,
                             interpret=interpret)
    return out, (q, k, v, out, lse)


def _bwd(causal, block, q_offset, interpret, res, dout):
    q, k, v, out, lse = res
    dq, dk, dv = _fa.flash_bwd(q, k, v, out, lse, dout, causal=causal,
                               block_q=block, block_k=block,
                               q_offset=q_offset, interpret=interpret)
    return dq, dk, dv


flash_attention.defvjp(_fwd, _bwd)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def rmsnorm(x, scale, eps: float = 1e-5, interpret: bool = False):
    return _rn.rmsnorm_fwd(x, scale, eps=eps, interpret=interpret)


def _rn_fwd(x, scale, eps, interpret):
    return _rn.rmsnorm_fwd(x, scale, eps=eps, interpret=interpret), (x, scale)


def _rn_bwd(eps, interpret, res, dy):
    x, scale = res
    return _rn.rmsnorm_bwd(x, scale, dy, eps=eps, interpret=interpret)


rmsnorm.defvjp(_rn_fwd, _rn_bwd)


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------


def ssd_scan(x, dt, A, B, C, chunk: int = 256, interpret: bool = False):
    """Differentiable via jax autodiff through the kernel is not supported;
    training uses models.mamba.ssd_chunked (pure lax).  This wrapper is the
    serving/prefill hot path."""
    return _ssd.ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=interpret)
