"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the mathematical definition the kernel must match; tests
sweep shapes/dtypes and ``assert_allclose`` kernel-vs-oracle in interpret
mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, causal: bool = True, q_offset: int = 0):
    """Naive O(S^2) GQA attention.  q: (B,Sq,H,D); k/v: (B,Skv,Hkv,D/Dv).
    Returns (out (B,Sq,H,Dv), lse (B,H,Sq) fp32)."""
    B, Sq, H, D = q.shape
    _, Skv, Hkv, Dv = v.shape
    G = H // Hkv
    kf = jnp.repeat(k, G, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, G, axis=2).astype(jnp.float32)
    qf = q.astype(jnp.float32) * D ** -0.5
    s = jnp.einsum("bshd,bthd->bhst", qf, kf)
    if causal:
        q_pos = q_offset + jnp.arange(Sq)
        mask = jnp.arange(Skv)[None, :] <= q_pos[:, None]
        s = jnp.where(mask[None, None], s, NEG_INF)
    lse = jax.nn.logsumexp(s, axis=-1)                     # (B,H,Sq)
    p = jnp.exp(s - lse[..., None])
    out = jnp.einsum("bhst,bthd->bshd", p, vf)
    return out.astype(q.dtype), lse


def rmsnorm_ref(x, scale, eps: float = 1e-5):
    """x: (..., D); scale: (D,)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def ssd_ref(x, dt, A, B, C, initial_state=None):
    """Sequential Mamba-2 SSD recurrence (group size 1).

    x: (b, S, H, P); dt: (b, S, H) post-softplus; A: (H,) negative;
    B, C: (b, S, N).  Returns (y (b,S,H,P), final_state (b,H,P,N) fp32).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    st = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((b, H, P, N), jnp.float32))
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A[None, :])                # (b, H)
        dBx = jnp.einsum("bn,bhp,bh->bhpn", B[:, t].astype(jnp.float32),
                         x[:, t].astype(jnp.float32),
                         dt[:, t].astype(jnp.float32))
        st = st * dA[..., None, None] + dBx
        ys.append(jnp.einsum("bhpn,bn->bhp", st,
                             C[:, t].astype(jnp.float32)))
    return jnp.stack(ys, 1).astype(x.dtype), st
