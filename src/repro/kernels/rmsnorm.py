"""Pallas TPU fused RMSNorm (forward + input/scale gradients).

RMSNorm is applied 2x per block across the whole zoo; unfused it costs
three HBM round-trips (square-reduce, rsqrt-mul, scale-mul).  The kernel
streams a (block_rows, D) tile through VMEM once, computing the row
statistic and the normalized output in a single pass; the backward kernel
fuses the dx formula (one pass) and emits per-tile partial dscale that the
wrapper sums (deterministic, no atomics).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                    # (br, D)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)[None, :]
                  ).astype(o_ref.dtype)


def _bwd_kernel(x_ref, s_ref, dy_ref, dx_ref, dscale_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    dy = dy_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)[None, :]
    D = x.shape[-1]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = x * inv
    dxhat = dy * s
    # dx = inv * (dxhat - xhat * mean(dxhat * xhat))
    dx = inv * (dxhat - xhat * jnp.mean(dxhat * xhat, axis=-1,
                                        keepdims=True))
    dx_ref[...] = dx.astype(dx_ref.dtype)
    dscale_ref[0, :] = jnp.sum(dy * xhat, axis=0)


def _rows(x):
    return int(x.size // x.shape[-1])


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm_fwd(x, scale, eps: float = 1e-5, block_rows: int = 512,
                interpret: bool = False):
    shape = x.shape
    D = shape[-1]
    R = _rows(x)
    x2 = x.reshape(R, D)
    block_rows = min(block_rows, R)
    pad = (-R) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n = (R + pad) // block_rows
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, eps=eps),
        grid=(n,),
        in_specs=[pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R + pad, D), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out[:R].reshape(shape)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm_bwd(x, scale, dy, eps: float = 1e-5, block_rows: int = 512,
                interpret: bool = False):
    shape = x.shape
    D = shape[-1]
    R = _rows(x)
    x2 = x.reshape(R, D)
    dy2 = dy.reshape(R, D)
    block_rows = min(block_rows, R)
    pad = (-R) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
        dy2 = jnp.pad(dy2, ((0, pad), (0, 0)))
    n = (R + pad) // block_rows
    dx, dscale_parts = pl.pallas_call(
        functools.partial(_bwd_kernel, eps=eps),
        grid=(n,),
        in_specs=[pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,)),
                  pl.BlockSpec((block_rows, D), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
                   pl.BlockSpec((1, D), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((R + pad, D), x.dtype),
                   jax.ShapeDtypeStruct((n, D), jnp.float32)],
        interpret=interpret,
    )(x2, scale, dy2)
    dscale = dscale_parts.sum(0).astype(scale.dtype)
    return dx[:R].reshape(shape), dscale
