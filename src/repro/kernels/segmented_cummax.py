"""Accelerated twins of :func:`repro.core.batch.liveness_peak_batch`.

The liveness assembly reduces every cell's alloc/free event program to a
segmented cummax: an ``(n_events, n_cells)`` int64 delta stack whose
per-cell peak is the max over running event-axis prefix sums.  The numpy
``cumsum(...).max(axis=0)`` in ``core.batch`` stays the reference; this
module evaluates the same reduction on accelerator backends:

* ``backend="jax"``    — a jitted cumsum + max-reduce (one compilation
  per (n_events, n_cells) shape);
* ``backend="pallas"`` — a Pallas kernel tiling the cell axis into VMEM
  blocks; the event axis (a handful of events, static per program) is
  unrolled at trace time into straight-line ``add``/``maximum`` vector
  ops, so each block does one pass over its tile with the running sum
  held in registers.  ``interpret=True`` runs it on CPU with identical
  integer math (pass ``interpret=False`` on TPU).

Exactness: int64 adds and maxes are associativity-free here — the
running sum is evaluated in event order, matching ``liveness.replay``'s
scalar prefix walk element-for-element.  Padding lanes are all-zero
columns whose peak is 0 and are sliced off before returning.

``use_backend("jax"|"pallas")`` installs the accelerated twin as
``core.batch``'s liveness-peak implementation for the dynamic extent of
the context, so full columnar liveness sweeps route the prefix-max
through the kernel; parity with the reference is asserted on real
sweeps in tests/test_segmented_cummax.py.
"""

from __future__ import annotations

import contextlib
import functools

import numpy as np

I64 = np.int64

_BLOCK = 256


# ---------------------------------------------------------------------------
# jax backend: jitted cumsum + max-reduce
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _jax_eval():
    import jax
    import jax.numpy as jnp

    def run(deltas):
        return jnp.cumsum(deltas, axis=0).max(axis=0)

    return jax.jit(run)


# ---------------------------------------------------------------------------
# pallas backend: unrolled running sum on VMEM tiles
# ---------------------------------------------------------------------------


def _pallas_kernel(deltas_ref, peak_ref, *, n_events):
    import jax.numpy as jnp

    run = deltas_ref[0, :]
    peak = run
    for e in range(1, n_events):        # static: unrolls at trace time
        run = run + deltas_ref[e, :]
        peak = jnp.maximum(peak, run)
    peak_ref[...] = peak[None, :]


@functools.lru_cache(maxsize=None)
def _pallas_eval(n_events, n_pad, block, interpret):
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    grid = (n_pad // block,)
    call = pl.pallas_call(
        functools.partial(_pallas_kernel, n_events=n_events),
        grid=grid,
        in_specs=[pl.BlockSpec((n_events, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad), jnp.int64),
        interpret=interpret,
    )
    return jax.jit(lambda d: call(d)[0])


# ---------------------------------------------------------------------------
# drop-in twin + backend switch
# ---------------------------------------------------------------------------


def segmented_cummax(deltas, backend: str = "jax", block: int = _BLOCK,
                     interpret: bool = True) -> np.ndarray:
    """Drop-in twin of :func:`repro.core.batch.liveness_peak_batch`
    (``backend="numpy"`` delegates to the reference; ``"jax"`` and
    ``"pallas"`` produce byte-identical int64 peaks)."""
    deltas = np.asarray(deltas, I64)
    if backend == "numpy":
        return np.cumsum(deltas, axis=0).max(axis=0)
    if backend not in ("jax", "pallas"):
        raise ValueError(f"unknown segmented-cummax backend {backend!r}")
    n_events, n = deltas.shape

    import jax
    from jax.experimental import enable_x64

    with enable_x64():
        if backend == "jax":
            out = _jax_eval()(deltas)
        else:
            blk = min(block, max(n, 1))
            pad = (-n) % blk
            if pad:                 # all-zero lanes peak at 0, discarded
                deltas = np.pad(deltas, ((0, 0), (0, pad)))
            fn = _pallas_eval(n_events, n + pad, blk, interpret)
            out = fn(deltas)[:n]
        return np.asarray(out, I64)


@contextlib.contextmanager
def use_backend(backend: str = "jax", interpret: bool = True):
    """Route ``core.batch.liveness_peak_batch`` through an accelerated
    backend for the dynamic extent of the context (``"numpy"`` is a
    no-op).  Used by tests to run real columnar liveness sweeps through
    the kernels and assert byte-parity, and by on-device sweeps where
    the prefix-max should stay on the accelerator."""
    from repro.core import batch as B

    if backend == "numpy":
        yield
        return
    impl = functools.partial(segmented_cummax, backend=backend,
                             interpret=interpret)
    prev = B._liveness_peak_impl
    B._liveness_peak_impl = impl
    try:
        yield
    finally:
        B._liveness_peak_impl = prev
