"""Accelerated twins of :func:`repro.core.batch.batch_shard_factor`.

The greedy axis-assignment pass (divisibility masks, one-use-per-axis,
the FSDP/ZeRO ``extra`` sweep) is the inner loop of columnar table
building: every TermSpec resolves its shard denominator through it, a
few hundred times per stage-table group.  The numpy transliteration in
``core.batch`` stays the reference; this module *packs* the greedy
program — the (dim, axis, pass) step sequence that the reference's
Python loops walk — into flat int32 step arrays and evaluates all
elements of the broadcast domain in one fused pass:

* ``backend="jax"``   — a jitted ``lax.fori_loop`` over the packed
  steps (one compilation per (n_dims, n_axes, n_steps, n_cells) shape,
  shared by every program with that shape);
* ``backend="pallas"`` — a Pallas kernel with the step list closed over
  as Python constants, so the body unrolls into straight-line vector
  ops on a (dims+axes, block) VMEM tile; ``interpret=True`` runs it on
  CPU with identical integer math (pass ``interpret=False`` on TPU).

Exactness: the packed form drops the reference's ``live`` size-1 axis
skip — a size-1 axis multiplies every factor by 1 and marking it used
only ever blocks another x1 attempt, so including such steps is
value-identical per element (the reference documents the same argument
for all-ones *columns*; here it holds per cell).  Globally dead axes
are still dropped host-side as a pure optimisation.  Everything is
int64 + floor-division under ``jax.experimental.enable_x64`` — parity
with the reference is asserted step-for-step on randomized programs and
on real sweeps in tests/test_shard_factor.py.

``use_backend("jax"|"pallas")`` installs the accelerated twin as
``core.batch``'s shard-factor implementation for the dynamic extent of
the context, so full columnar sweeps (and therefore the jax engine's
table building) route divisibility resolution through the kernel.
"""

from __future__ import annotations

import contextlib
import functools

import numpy as np

from repro.mesh_ctx import PIPE_AXIS

I64 = np.int64

_BLOCK = 256


# ---------------------------------------------------------------------------
# program packing
# ---------------------------------------------------------------------------

# step flags: 0 = rules pass; 1 = extra pass; 2 = extra pass, first step
# of a new extra axis (resets the per-axis `assigned` register)
_RULES, _EXTRA, _EXTRA_FIRST = 0, 1, 2


def pack_program(axes, rules: dict, extra=(), axis_names=()):
    """Flatten the greedy assignment into (dim, axis, flag) step triples.

    ``axis_names`` lists the mesh axes that participate (order defines
    the axis ids of the packed program); axes not in it are skipped,
    mirroring the reference's ``live`` filter.  Returns
    ``(steps, names)`` where ``steps`` is a tuple of int triples and
    ``names`` the axis-id -> name order actually referenced.
    """
    ids: dict[str, int] = {}
    steps: list[tuple[int, int, int]] = []
    allowed = set(axis_names)
    for i, ax in enumerate(axes):
        if not ax:
            continue
        for a in rules.get(ax, ()):
            if a == PIPE_AXIS or a not in allowed:
                continue
            steps.append((i, ids.setdefault(a, len(ids)), _RULES))
    for a in extra:
        if a == PIPE_AXIS or a not in allowed:
            continue
        first = True
        for i in range(len(axes)):
            if axes[i] == "layers":     # never FSDP/ZeRO-shard the stack dim
                continue
            steps.append((i, ids.setdefault(a, len(ids)),
                          _EXTRA_FIRST if first else _EXTRA))
            first = False
    names = [a for a, _ in sorted(ids.items(), key=lambda kv: kv[1])]
    return tuple(steps), names


# ---------------------------------------------------------------------------
# jax backend: jitted fori_loop over packed step arrays
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _jax_eval():
    import jax
    import jax.numpy as jnp
    from jax import lax

    def run(arrs, sizes, dim_i, ax_i, flags):
        n = arrs.shape[1]
        init = (jnp.ones_like(arrs),                    # per-dim totals
                jnp.zeros(sizes.shape, bool),           # per-axis used
                jnp.ones((n,), arrs.dtype),             # denom
                jnp.zeros((n,), bool))                  # extra `assigned`

        def step(k, carry):
            totals, used, denom, assigned = carry
            d, a, fl = dim_i[k], ax_i[k], flags[k]
            assigned = jnp.where(fl == _EXTRA_FIRST, False, assigned)
            sv = sizes[a]
            ok = (arrs[d] % (totals[d] * sv) == 0) & ~used[a]
            ok = ok & jnp.where(fl > 0, ~assigned, True)
            mul = jnp.where(ok, sv, 1)
            return (totals.at[d].multiply(mul), used.at[a].set(used[a] | ok),
                    denom * mul, jnp.where(fl > 0, assigned | ok, assigned))

        return lax.fori_loop(0, dim_i.shape[0], step, init)[2]

    return jax.jit(run)


# ---------------------------------------------------------------------------
# pallas backend: unrolled step program on VMEM tiles
# ---------------------------------------------------------------------------


def _pallas_kernel(arrs_ref, sizes_ref, denom_ref, *, steps):
    import jax.numpy as jnp

    arrs = arrs_ref[...]
    sizes = sizes_ref[...]
    totals = jnp.ones_like(arrs)
    used = jnp.zeros(sizes.shape, bool)
    denom = jnp.ones_like(arrs[0])
    assigned = jnp.zeros_like(denom, bool)
    for d, a, fl in steps:                  # static: unrolls at trace time
        if fl == _EXTRA_FIRST:
            assigned = jnp.zeros_like(assigned)
        ok = (arrs[d] % (totals[d] * sizes[a]) == 0) & ~used[a]
        if fl:
            ok = ok & ~assigned
        mul = jnp.where(ok, sizes[a], 1)
        totals = totals.at[d].multiply(mul)
        denom = denom * mul
        used = used.at[a].set(used[a] | ok)
        if fl:
            assigned = assigned | ok
    denom_ref[...] = denom[None, :]


@functools.lru_cache(maxsize=None)
def _pallas_eval(steps, n_dims, n_axes, n_pad, block, interpret):
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    grid = (n_pad // block,)
    call = pl.pallas_call(
        functools.partial(_pallas_kernel, steps=steps),
        grid=grid,
        in_specs=[pl.BlockSpec((n_dims, block), lambda i: (0, i)),
                  pl.BlockSpec((n_axes, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad), jnp.int64),
        interpret=interpret,
    )
    return jax.jit(lambda a, s: call(a, s)[0])


# ---------------------------------------------------------------------------
# drop-in twin + backend switch
# ---------------------------------------------------------------------------


def shard_factor(dims, axes, sizes: dict, rules: dict, extra=(),
                 backend: str = "jax", block: int = _BLOCK,
                 interpret: bool = True) -> np.ndarray:
    """Drop-in twin of :func:`repro.core.batch.batch_shard_factor`.

    ``backend="numpy"`` delegates to the reference; ``"jax"`` and
    ``"pallas"`` evaluate the packed program (byte-identical int64).
    """
    if backend == "numpy":
        from repro.core import batch as B
        return B.batch_shard_factor(dims, axes, sizes, rules, extra)
    if backend not in ("jax", "pallas"):
        raise ValueError(f"unknown shard-factor backend {backend!r}")

    arrs = [np.asarray(d, I64) for d in dims]
    svals = {a: np.asarray(v, I64) for a, v in sizes.items()}
    shape = np.broadcast_shapes(*(a.shape for a in arrs),
                                *(v.shape for v in svals.values()))
    live = [a for a, v in svals.items() if np.any(v > 1)]
    steps, names = pack_program(axes, rules, extra, axis_names=live)
    if not steps or not arrs:
        return np.broadcast_to(np.ones((), I64), shape)

    n = int(np.prod(shape, dtype=np.int64)) if shape else 1
    a2 = np.stack([np.broadcast_to(a, shape).reshape(n) for a in arrs])
    s2 = np.stack([np.broadcast_to(svals[a], shape).reshape(n)
                   for a in names])

    import jax
    from jax.experimental import enable_x64

    with enable_x64():
        if backend == "jax":
            st = np.asarray(steps, np.int32)
            out = _jax_eval()(a2, s2, st[:, 0], st[:, 1], st[:, 2])
        else:
            blk = min(block, max(n, 1))
            pad = (-n) % blk
            if pad:                         # padded lanes: 1 % 1 == 0, discarded
                a2 = np.pad(a2, ((0, 0), (0, pad)), constant_values=1)
                s2 = np.pad(s2, ((0, 0), (0, pad)), constant_values=1)
            fn = _pallas_eval(steps, a2.shape[0], s2.shape[0], n + pad,
                              blk, interpret)
            out = fn(a2, s2)[:n]
        return np.asarray(out, I64).reshape(shape)


@contextlib.contextmanager
def use_backend(backend: str = "jax", interpret: bool = True):
    """Route ``core.batch.batch_shard_factor`` through an accelerated
    backend for the dynamic extent of the context (``"numpy"`` is a
    no-op).  Used by tests to run real columnar sweeps through the
    kernels and assert byte-parity, and by on-device sweeps where the
    divisibility pass should stay on the accelerator."""
    from repro.core import batch as B

    if backend == "numpy":
        yield
        return
    impl = functools.partial(shard_factor, backend=backend,
                             interpret=interpret)
    prev = B._shard_factor_impl
    B._shard_factor_impl = impl
    try:
        yield
    finally:
        B._shard_factor_impl = prev
