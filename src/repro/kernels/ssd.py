"""Pallas TPU kernel for the Mamba-2 SSD (state-space duality) scan.

TPU adaptation of the chunked dual form (arXiv:2405.21060 §6): the
within-chunk quadratic term is three MXU matmuls on a (Q, Q) decay-masked
score tile held in VMEM; the across-chunk linear recurrence is carried in
a VMEM scratch state (P, N) that persists over the innermost (sequential)
chunk-grid dimension — the Pallas twin of ``lax.scan`` with zero HBM
traffic for the state.

Grid: (B, H, n_chunks).  B/C are per-group (G == 1) and shared across
heads; the decay vector a = dt * A[h] is precomputed by the wrapper
(cheap elementwise) so the kernel consumes only MXU/VPU-shaped operands.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, a_ref, b_ref, c_ref, y_ref, st_final_ref, st_scr,
                *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        st_scr[...] = jnp.zeros_like(st_scr)

    xdt = xdt_ref[0, 0, :, :].astype(jnp.float32)          # (Q, P)
    a = a_ref[0, 0, :].astype(jnp.float32)                 # (Q,)
    Bc = b_ref[0, :, :].astype(jnp.float32)                # (Q, N)
    Cc = c_ref[0, :, :].astype(jnp.float32)                # (Q, N)

    a_cum = jnp.cumsum(a)                                  # (Q,)
    a_tot = a_cum[-1]

    # decay matrix L[i, j] = exp(sum_{k=j+1..i} a_k), lower-triangular
    seg = a_cum[:, None] - a_cum[None, :]                  # (Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y_diag = jax.lax.dot_general(L * scores, xdt,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    st = st_scr[...]                                       # (P, N)
    y_off = jax.lax.dot_general(Cc, st, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) \
        * jnp.exp(a_cum)[:, None]                          # (Q, P)

    decay_to_end = jnp.exp(a_tot - a_cum)                  # (Q,)
    st_delta = jax.lax.dot_general(xdt * decay_to_end[:, None], Bc,
                                   (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)
    st_scr[...] = st * jnp.exp(a_tot) + st_delta           # (P, N)

    y_ref[0, 0, :, :] = (y_diag + y_off).astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _finalize():
        st_final_ref[0, 0, :, :] = st_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, chunk: int = 256, interpret: bool = False):
    """Chunked SSD.  x: (b, S, H, P); dt: (b, S, H) post-softplus;
    A: (H,) negative reals; B, C: (b, S, N) (group dim already squeezed).
    Returns (y (b, S, H, P), final_state (b, H, P, N) fp32)."""
    b, S, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk

    xdt = (x.astype(jnp.float32)
           * dt.astype(jnp.float32)[..., None])            # (b, Sp, H, P)
    xdt = jnp.moveaxis(xdt, 2, 1)                          # (b, H, Sp, P)
    a = jnp.moveaxis(dt.astype(jnp.float32)
                     * A.astype(jnp.float32)[None, None, :], 2, 1)  # (b,H,Sp)

    y, st = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(b, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1, chunk, N), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, H, Sp, P), x.dtype),
            jax.ShapeDtypeStruct((b, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xdt, a, B, C)

    y = jnp.moveaxis(y, 1, 2)[:, :S]                       # (b, S, H, P)
    return y, st
