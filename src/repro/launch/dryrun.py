import os

# APPEND the host-platform device-count flag (must happen before the jax
# import below); a user-supplied XLA_FLAGS is preserved, and an existing
# device-count setting wins over ours.
_DEVICE_COUNT_FLAG = "--xla_force_host_platform_device_count=512"
if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = " ".join(
        filter(None, [os.environ.get("XLA_FLAGS", ""),
                      _DEVICE_COUNT_FLAG]))

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes with ShapeDtypeStruct stand-ins (no allocation), record
XLA memory/cost/collective analysis AND the paper-framework's memory
prediction side by side.

    python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
    python -m repro.launch.dryrun --all            # every cell, subprocesses
    python -m repro.launch.dryrun --all --multi-pod

Artifacts land in experiments/dryrun/<arch>__<shape>__<mesh>.json
(the same directory repro.calibrate's MeasurementStore ingests by
default) and are consumed by benchmarks/ and EXPERIMENTS.md.
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, cells, get_config, skipped_cells
from repro.core import factors as FA
from repro.core import predictor as PR
from repro.core import xla_metrics as XM
from repro.core.spec import FULL_TRAIN
from repro.launch import mesh as M
from repro.mesh_ctx import mesh_axis_sizes, mesh_context
from repro.models import build_model
from repro.models import param as PM
from repro.train import OptimizerConfig, TrainState, make_train_step
from repro.train.optimizer import opt_state_specs

from repro.calibrate.paths import dryrun_dir

# pathlib repo-root resolution shared with the calibration MeasurementStore
# (write side and ingest side can never disagree on the artifact home)
OUT_DIR = str(dryrun_dir())


def input_specs(arch: str, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    cfg = get_config(arch)
    model = build_model(cfg)
    return model.batch_spec(SHAPES[shape_name])


def _state_specs(model, opt_cfg):
    params = model.param_specs()
    mask = PM.trainable_mask(model.spec, FULL_TRAIN)
    trainable, _ = PM.partition_params(params, mask)
    opt = opt_state_specs(trainable, opt_cfg)
    return TrainState(params=params, opt=opt,
                      step=jax.ShapeDtypeStruct((), jnp.int32)), mask


def lower_cell(arch: str, shape_name: str, multi_pod: bool = False,
               rules_override=None, remat=None, opt_name=None):
    """Lower + compile one cell; returns (record, compiled)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    mesh = M.make_production_mesh(multi_pod=multi_pod)
    rules = {**M.arch_rules(cfg, shape.kind), **(rules_override or {})}
    opt_cfg = OptimizerConfig(name=opt_name or cfg.optimizer)

    with mesh_context(mesh, rules):
        psh = M.param_shardings(model, mesh)
        if shape.kind == "train":
            state_specs, mask = _state_specs(model, opt_cfg)
            axes_tree = model.param_axes()
            t_axes = jax.tree.map(lambda m, ax: ax if m else None, mask,
                                  axes_tree)
            t_specs, _ = PM.partition_params(state_specs.params, mask)
            osh = M.opt_shardings(model, mesh, t_specs, opt_cfg, t_axes)
            zsh = M.zero_grad_shardings(mesh, t_specs, t_axes)
            batch = model.batch_spec(shape)
            bsh = M.batch_shardings(mesh, batch)
            step_fn = make_train_step(model, FULL_TRAIN, opt_cfg,
                                      zero_shardings=zsh, remat=remat)
            state_sh = TrainState(params=psh, opt=osh,
                                  step=NamedSharding(mesh, P()))
            jitted = jax.jit(step_fn,
                             in_shardings=(state_sh, bsh),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_specs, batch)
        elif shape.kind == "prefill":
            batch = model.batch_spec(shape)
            bsh = M.batch_shardings(mesh, batch)
            fn = lambda p, b: model.prefill(p, b)
            jitted = jax.jit(fn, in_shardings=(psh, bsh))
            lowered = jitted.lower(model.param_specs(), batch)
        else:  # decode
            B = shape.global_batch
            if cfg.family == "encdec":
                cache = jax.eval_shape(
                    lambda: model.init_cache(B, shape.seq_len,
                                             enc_len=shape.seq_len))
            else:
                cache = jax.eval_shape(
                    lambda: model.init_cache(B, shape.seq_len))
            csh = M.cache_shardings(mesh, cache, cfg)
            token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
            from repro.mesh_ctx import resolve_pspec
            tsh = NamedSharding(mesh, resolve_pspec((B, 1), ("batch", None),
                                                    mesh))
            fn = lambda p, t, c: model.decode_step(p, t, c)
            jitted = jax.jit(fn, in_shardings=(psh, tsh, csh),
                             donate_argnums=(2,))
            lowered = jitted.lower(model.param_specs(), token, cache)

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    n_dev = mesh.devices.size
    mem = XM.memory_stats(compiled)
    cost = XM.cost_stats(compiled)
    hlo_txt = compiled.as_text()
    coll = XM.collective_stats(hlo_txt, n_dev)
    # loop-aware accounting: XLA cost_analysis counts while bodies ONCE;
    # these numbers multiply by trip counts (scan-stacked layers, flash
    # chunk loops, chunked losses) — the roofline reads THESE.
    la = XM.loop_aware_stats(hlo_txt, n_dev)

    # the paper framework's prediction for the same cell
    ctx = FA.PredictContext(
        mesh_shape=mesh_axis_sizes(mesh), rules=rules,
        optimizer=opt_cfg.name, fsdp=cfg.fsdp,
        master_fp32=opt_cfg.name != "adafactor",
        remat=remat or cfg.remat,
        global_batch=shape.global_batch, seq_len=shape.seq_len,
        enc_seq=int(shape.seq_len * cfg.encdec.enc_seq_ratio)
        if cfg.encdec else 0,
        kind=shape.kind, max_len=shape.seq_len)
    pred = PR.predict(model, FULL_TRAIN, ctx)

    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mesh_shape": mesh_axis_sizes(mesh),
        "n_devices": n_dev, "kind": shape.kind,
        "compile_seconds": round(compile_s, 2),
        "memory": {
            "argument_bytes": mem.argument_bytes,
            "output_bytes": mem.output_bytes,
            "temp_bytes": mem.temp_bytes,
            "alias_bytes": mem.alias_bytes,
            "total_bytes": mem.total_bytes,
        },
        "predicted": {
            "param_bytes": pred.param_bytes,
            "grad_bytes": pred.grad_bytes,
            "opt_bytes": pred.opt_bytes,
            "act_saved_bytes": pred.act_saved_bytes,
            "act_transient_bytes": pred.act_transient_bytes,
            "loss_bytes": pred.loss_bytes,
            "input_bytes": pred.input_bytes,
            "cache_bytes": pred.cache_bytes,
            "peak_bytes": pred.peak_bytes,
        },
        "cost": {"flops_per_device": cost.flops,
                 "bytes_accessed_per_device": cost.bytes_accessed},
        "collectives": {
            "counts": coll.counts,
            "operand_bytes_per_device": coll.operand_bytes,
            "wire_bytes_per_device": coll.wire_bytes,
            "total_wire_bytes_per_device": coll.total_wire_bytes,
        },
        "loop_aware": {
            "flops_per_device": la.flops,
            "bytes_accessed_per_device": la.bytes_accessed,
            "collective_counts": la.collectives.counts,
            "collective_wire_bytes": la.collectives.wire_bytes,
            "total_wire_bytes_per_device":
                la.collectives.total_wire_bytes,
        },
    }
    return record, compiled


def run_cell(arch, shape_name, multi_pod, out_dir) -> dict:
    record, compiled = lower_cell(arch, shape_name, multi_pod)
    print(compiled.memory_analysis())
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    print({k: v for k, v in sorted(ca.items())
           if k in ("flops", "bytes accessed")})
    os.makedirs(out_dir, exist_ok=True)
    fn = os.path.join(out_dir,
                      f"{arch}__{shape_name}__{record['mesh']}.json")
    with open(fn, "w") as f:
        json.dump(record, f, indent=1)
    gib = 1024 ** 3
    print(f"[dryrun] {arch} x {shape_name} x {record['mesh']}: "
          f"OK compile={record['compile_seconds']}s "
          f"xla_total={record['memory']['total_bytes'] / gib:.2f} GiB "
          f"pred={record['predicted']['peak_bytes'] / gib:.2f} GiB "
          f"colls={record['collectives']['counts']}")
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=os.path.normpath(OUT_DIR))
    args = ap.parse_args()

    if args.all:
        pods = [False, True] if args.both_meshes else [args.multi_pod]
        failures = []
        for arch, shape_name in cells():
            for mp in pods:
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape_name,
                       "--out", args.out] + (["--multi-pod"] if mp else [])
                r = subprocess.run(cmd, capture_output=True, text=True)
                tail = (r.stdout + r.stderr).strip().splitlines()
                print(tail[-1] if tail else "(no output)")
                if r.returncode != 0:
                    failures.append((arch, shape_name, mp,
                                     "\n".join(tail[-15:])))
        for a, s, mp, err in failures:
            print(f"FAILED: {a} x {s} multi_pod={mp}\n{err}\n")
        for a, s, why in skipped_cells():
            print(f"SKIPPED: {a} x {s}: {why}")
        sys.exit(1 if failures else 0)

    run_cell(args.arch, args.shape, args.multi_pod, args.out)


if __name__ == "__main__":
    main()
