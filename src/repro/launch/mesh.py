"""Production meshes and the sharding policy.

``make_production_mesh`` builds the assigned meshes: (16, 16) single pod
(256 chips) and (2, 16, 16) multi-pod (512 chips; ``pod`` is the
DCN-connected data-parallel axis).  Importing this module never touches
jax device state — everything is a function.

``param_shardings`` / ``opt_shardings`` / ``batch_shardings`` derive
NamedShardings from the spec tree's logical axes through the single
resolution path in ``repro.mesh_ctx`` — the same path the memory predictor
uses arithmetically.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.mesh_ctx import (CONTEXT_AXIS, DEFAULT_RULES, EXPERT_AXIS,
                            PIPE_AXIS, assign_axes, mesh_axis_sizes,
                            resolve_pspec)
from repro.models.registry import Model
from repro.train.optimizer import OptimizerConfig, opt_state_specs


def _auto_axis_types(n: int) -> dict:
    """`axis_types` kwarg for jax.make_mesh on jax versions that have it
    (jax.sharding.AxisType landed after 0.4.x; Auto is that default
    behaviour, so omitting the kwarg is equivalent on older versions)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto_axis_types(len(axes)))


def divisors(n: int) -> list[int]:
    """Positive divisors of ``n``, ascending."""
    small, large = [], []
    d = 1
    while d * d <= n:
        if n % d == 0:
            small.append(d)
            if d != n // d:
                large.append(n // d)
        d += 1
    return small + large[::-1]


def factorizations(n: int, k: int) -> list[tuple[int, ...]]:
    """All ordered ``k``-tuples of positive ints whose product is ``n``.

    Ordered means (2, 8) and (8, 2) are distinct — mesh axes are named, so
    data=2/model=8 and data=8/model=2 are different parallelism plans.
    """
    if n < 1 or k < 1:
        raise ValueError(f"need n >= 1, k >= 1; got n={n}, k={k}")
    if k == 1:
        return [(n,)]
    out = []
    for d in divisors(n):
        for rest in factorizations(n // d, k - 1):
            out.append((d,) + rest)
    return out


def enumerate_meshes(n_chips: int,
                     axes: tuple[str, ...] = ("data", "model"),
                     max_axis: Optional[dict] = None) -> list[dict]:
    """Every mesh shape that lays ``n_chips`` out over the named ``axes``.

    The capacity-planning sweep feeds each of these to the memory predictor
    to find which parallelism plans fit.  ``max_axis`` caps individual axes
    (e.g. ``{"model": 16}`` — an ICI-connected TP axis rarely exceeds a
    pod's torus dimension; ``{"pipe": 8}`` bounds pipeline depth).
    Results are deduplicated and sorted by descending data-parallel degree
    (the conventional preference: DP is the cheapest axis,
    collectives-wise).  Including :data:`~repro.mesh_ctx.PIPE_AXIS` in
    ``axes`` enumerates pipeline-parallel plans: chips along ``pipe`` hold
    disjoint layer stages (core.stages) and never shard tensors.
    Including :data:`~repro.mesh_ctx.EXPERT_AXIS` /
    :data:`~repro.mesh_ctx.CONTEXT_AXIS` enumerates expert-parallel and
    context-parallel (ring-attention) plans, capped by
    ``{"expert": N}`` / ``{"context": N}`` (CLI ``--max-expert`` /
    ``--max-context``); the planner rejects plans that are invalid for
    the architecture or step kind (``planner.check_parallel``).
    """
    seen: set[tuple[int, ...]] = set()
    out: list[dict] = []
    for f in factorizations(n_chips, len(axes)):
        if f in seen:
            continue
        seen.add(f)
        if max_axis and any(f[i] > max_axis.get(a, f[i])
                            for i, a in enumerate(axes)):
            continue
        out.append(dict(zip(axes, f)))
    out.sort(key=lambda m: tuple(-m[a] for a in axes))
    return out


def mesh_chips(mesh_shape: dict) -> int:
    """Total chip count of a mesh-shape dict."""
    total = 1
    for v in mesh_shape.values():
        total *= v
    return total


def pp_degree(mesh_shape: dict) -> int:
    """Pipeline-stage count of a mesh shape (1 when it has no pipe axis)."""
    return int(mesh_shape.get(PIPE_AXIS, 1))


def ep_degree(mesh_shape: dict) -> int:
    """Expert-parallel degree of a mesh shape (1 without an expert axis)."""
    return int(mesh_shape.get(EXPERT_AXIS, 1))


def cp_degree(mesh_shape: dict) -> int:
    """Context-parallel degree of a mesh shape (1 without a context axis)."""
    return int(mesh_shape.get(CONTEXT_AXIS, 1))


def make_smoke_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh for CPU tests (exercises the same code paths)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         **_auto_axis_types(2))


# ---------------------------------------------------------------------------
# sharding policy
# ---------------------------------------------------------------------------


def arch_rules(cfg, kind: str = "train") -> dict:
    """Per-arch logical->physical rule overrides."""
    rules = dict(DEFAULT_RULES)
    if kind in ("train", "prefill") and cfg.seq_parallel:
        # Sequence parallelism: the residual stream (and therefore the
        # per-layer saved scan carry — the dominant training activation)
        # is sharded over `model` as well as `data`.  Attention math stays
        # global; GSPMD inserts the gather/scatter collectives.  Without
        # this, 30B+ archs cannot fit 16 GiB/chip at train_4k.
        rules["seq"] = ("model",)
    if kind in ("train", "prefill"):
        # Context parallelism (ring attention): the seq dim of every
        # activation shards over `context` FIRST, SP's `model` split on
        # what stays divisible.  Decode is token-at-a-time — no seq dim
        # to split — so cp is rejected there (planner.check_parallel)
        # and the decode `cache_seq` rule below never names `context`.
        rules["seq"] = (CONTEXT_AXIS,) + rules["seq"]
    if kind == "prefill":
        # prefill caches derive from the seq-sharded residual stream, so
        # XLA lays them out seq-sharded over `model` (matches SP) — and,
        # under ring attention, over `context` first: each cp rank
        # computes and holds only its sequence block's KV.  (Decode
        # below is different: cp is rejected there, and its caches
        # shard over `model` only.)
        rules["cache_seq"] = (CONTEXT_AXIS, "model")
    elif kind == "decode":
        # Decode caches shard their sequence dim over `model`: none of the
        # zoo's GQA head counts fill a 16-way axis (8, 5, 16...), so
        # head-sharding strands memory, while seq-sharding divides the one
        # buffer that dominates serving (observed 16x: llama3.2 decode_32k
        # cache 28.4 -> 1.8 GiB/device).  MLA latents have no head dim at
        # all.  XLA turns the per-step attention into a sharded partial
        # softmax + cross-shard reduce.
        rules["cache_seq"] = ("model",)
    return rules


def param_shardings(model: Model, mesh: Mesh) -> Any:
    axes_tree = model.param_axes()
    specs_tree = model.param_specs()
    extra = ("data",) if model.cfg.fsdp else ()

    def leaf(ax, sd):
        return NamedSharding(mesh, resolve_pspec(sd.shape, ax, mesh,
                                                 extra=extra))

    return jax.tree.map(leaf, axes_tree, specs_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def opt_shardings(model: Model, mesh: Mesh, trainable_specs: Any,
                  opt_cfg: OptimizerConfig,
                  trainable_axes: Any) -> Any:
    """ZeRO sharding: optimizer-state leaves inherit the param's logical
    axes where shapes line up, plus an extra `data` shard."""
    state_specs = opt_state_specs(trainable_specs, opt_cfg)

    def leaf_state(pspec_axes, pshape, st):
        if st is None:
            return None
        out = {}
        for name, s in st.items():
            if tuple(s.shape) == tuple(pshape):
                ax = pspec_axes
            elif len(s.shape) == len(pshape) - 1 \
                    and tuple(s.shape) == tuple(pshape[:-1]):
                ax = pspec_axes[:-1]                 # adafactor v_row
            elif len(s.shape) == len(pshape) - 1 \
                    and tuple(s.shape) == tuple(pshape[:-2] + pshape[-1:]):
                ax = pspec_axes[:-2] + pspec_axes[-1:]  # adafactor v_col
            else:
                ax = (None,) * len(s.shape)          # 8-bit blocks etc.
            out[name] = NamedSharding(
                mesh, resolve_pspec(s.shape, ax, mesh, extra=("data",)))
        return out

    # axes leaves are tuples => is_leaf stops descent there; the matching
    # state subtree (a dict of arrays) is passed whole to leaf_state.
    return jax.tree.map(
        lambda ax, sd, st: leaf_state(ax, sd.shape if sd is not None else (),
                                      st),
        trainable_axes, trainable_specs, state_specs,
        is_leaf=lambda x: isinstance(x, tuple) or x is None)


def zero_grad_shardings(mesh: Mesh, trainable_specs: Any,
                        trainable_axes: Any) -> Any:
    """Reduce-scatter target sharding for gradients (param axes + data)."""
    def leaf(ax, sd):
        if sd is None:
            return None
        return NamedSharding(mesh, resolve_pspec(sd.shape, ax, mesh,
                                                 extra=("data",)))
    return jax.tree.map(leaf, trainable_axes, trainable_specs,
                        is_leaf=lambda x: isinstance(x, tuple) or x is None)


def batch_shardings(mesh: Mesh, batch_spec: dict) -> dict:
    return {
        k: NamedSharding(mesh, resolve_pspec(
            v.shape, ("batch",) + (None,) * (len(v.shape) - 1), mesh))
        for k, v in batch_spec.items()}


def cache_shardings(mesh: Mesh, cache_spec: Any, cfg) -> Any:
    """KV/SSM cache shardings: (layers, batch, seq, heads...) with batch
    over data and heads (or cache_seq) over model."""
    rules = arch_rules(cfg, kind="decode")

    def leaf(sd):
        if sd is None:
            return None
        shape = sd.shape
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        if len(shape) == 1:                       # e.g. cache["len"]
            return NamedSharding(mesh, P())
        axes: list = [None] * len(shape)
        axes[0] = "layers"
        if len(shape) >= 2:
            axes[1] = "batch"
        if len(shape) == 5:                       # (L, B, S, Hkv, hd)
            axes[2] = "cache_seq"
            axes[3] = "kv_heads"
        elif len(shape) == 4:                     # (L, B, S, r) or ssm
            axes[2] = "cache_seq"
            axes[3] = "ssm"
        elif len(shape) == 3:
            axes[2] = "ffn"
        return NamedSharding(mesh,
                             resolve_pspec(shape, axes, mesh, rules=rules))

    return jax.tree.map(leaf, cache_spec)
