"""Production training launcher: the paper's workflow end-to-end.

    python -m repro.launch.train --arch llama3.2-3b --steps 100 --reduced
    python -m repro.launch.train --arch qwen3-32b --shape train_4k \
        --check-only                      # OoM guard on the target mesh

Flow: predict peak memory on the TARGET mesh (OoM guard; refuses doomed
launches) -> build mesh + shardings -> fault-tolerant training loop
(async checkpoints, restart, straggler mitigation).  On this CPU container
use --reduced for a runnable smoke; on a real pod the same entrypoint
drives the full configs.
"""

import argparse
import os

GiB = 1024 ** 3


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config + tiny batch (CPU smoke)")
    ap.add_argument("--check-only", action="store_true",
                    help="run the OoM guard for the production mesh, exit")
    ap.add_argument("--data", type=int, default=16)
    ap.add_argument("--model", type=int, default=16)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    args = ap.parse_args()

    from repro.configs import SHAPES, ShapeConfig, get_config
    from repro.core import planner

    mesh_shape = {"data": args.data, "model": args.model}

    # ---- step 1: the paper — predict BEFORE launching --------------------
    report = planner.plan(args.arch, args.shape, mesh_shape, backend="tpu")
    print(report)
    if args.check_only:
        return
    if not report.fits and not args.reduced:
        raise SystemExit("OoM guard: refusing to launch a doomed job "
                         "(use the planner's suggestion or --reduced)")

    # ---- step 2: build and train -----------------------------------------
    import jax
    import jax.numpy as jnp
    from repro.checkpoint import Checkpointer
    from repro.core.spec import FULL_TRAIN
    from repro.data.pipeline import SyntheticPipeline
    from repro.launch import mesh as M
    from repro.mesh_ctx import mesh_context
    from repro.models import build_model, param as PM
    from repro.runtime import FaultConfig, ResilientTrainer
    from repro.train import OptimizerConfig, TrainState, make_train_step
    from repro.train.optimizer import init_opt_state

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    if args.reduced:
        cfg = cfg.reduced()
        shape = ShapeConfig("smoke", 64, 4, "train")
    model = build_model(cfg)
    opt_cfg = OptimizerConfig(name=cfg.optimizer,
                              master_fp32=cfg.optimizer != "adafactor")

    n_dev = len(jax.devices())
    mesh = None
    if n_dev > 1:
        d = min(args.data, n_dev)
        mesh = M.make_smoke_mesh(d, max(n_dev // d, 1))

    with mesh_context(mesh, M.arch_rules(cfg) if mesh else None):
        params = model.init(jax.random.PRNGKey(0))
        mask = PM.trainable_mask(model.spec, FULL_TRAIN)
        trainable, _ = PM.partition_params(params, mask)
        state = TrainState(params=params,
                           opt=init_opt_state(trainable, opt_cfg),
                           step=jnp.int32(0))
        print(f"launch: {cfg.name} ({PM.count_params(params) / 1e6:.1f}M "
              f"params), mesh={mesh.shape if mesh else 'single-device'}, "
              f"optimizer={opt_cfg.name}, grad_accum={args.grad_accum}")

        pipe = SyntheticPipeline(cfg, shape)
        step_fn = jax.jit(make_train_step(model, FULL_TRAIN, opt_cfg,
                                          grad_accum=args.grad_accum),
                          donate_argnums=(0,))
        trainer = ResilientTrainer(
            train_step=step_fn, pipeline=pipe,
            checkpointer=Checkpointer(args.ckpt_dir, keep=3),
            fault_cfg=FaultConfig(ckpt_every=max(args.steps // 4, 10)),
            make_batch=lambda s: {k: jnp.asarray(v) for k, v in
                                  pipe.global_batch(s).items()})
        state, history = trainer.run(state, 0, args.steps,
                                     log_every=max(args.steps // 5, 1))
    print(f"done: loss {history[0]['loss']:.3f} -> "
          f"{history[-1]['loss']:.3f} over {args.steps} steps; "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
