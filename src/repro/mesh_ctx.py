"""Logical-axis sharding context.

Models are written against *logical* axis names (see core.spec).  A single
rule table maps logical axes to physical mesh axes; divisibility is checked
against the concrete shape so non-divisible dims gracefully replicate (e.g.
smollm's 15 heads on a 16-way model axis).

The SAME resolution logic is used by the live model code (as
``with_sharding_constraint``/``NamedSharding``) and by the memory predictor
(as arithmetic shard factors) — so the prediction can never disagree with
the runtime about what is sharded where.  ``extra`` axes implement
FSDP/ZeRO: they are greedily assigned to the first divisible, still-free
dimension (params for FSDP, optimizer states for ZeRO).
"""

from __future__ import annotations

import contextlib
import math
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# The pipeline-parallel physical axis: chips along it hold different
# pipeline STAGES (disjoint layer slices, see core.stages), so no tensor
# dimension is ever sharded over it — assign_axes skips it in both the
# rule pass and the FSDP/ZeRO extra pass even if a rule table names it.
# Its degree reaches the predictor as PredictContext.pp.
PIPE_AXIS = "pipe"

# The expert-parallel physical axis: chips along it hold disjoint routed
# EXPERTS.  Unlike `pipe` it IS a tensor-sharding axis, but only the MoE
# logical dims name it (`experts` weight stacks, `expert_buf` dispatch
# buffers) — dense layers carry neither, so `expert` can never shard a
# dense tensor.  Its degree reaches the predictor as PredictContext.ep.
EXPERT_AXIS = "expert"

# The context-parallel (ring-attention) physical axis: shards the `seq`
# dim of train/prefill activations (launch.mesh.arch_rules prepends it to
# the `seq` rule), with the per-hop ring KV send/recv transient modelled
# in core.factors.ring_kv_spec.  Decode KV caches stay on `cache_seq`
# (never mapped to this axis).  Degree reaches PredictContext.cp.
CONTEXT_AXIS = "context"

# logical axis -> tuple of physical mesh axes (applied together)
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),                  # sequence-parallel policies set ("model",) etc.
                                # and launch.mesh.arch_rules prepends
                                # CONTEXT_AXIS for train/prefill
    "vocab": ("model",),
    "embed": (),                # residual dim replicated by default
    "embed_cols": ("model",),   # untied embedding tables shard columns:
                                # a vocab-sharded table would be fully
                                # all-gathered by the token lookup
    "heads": ("model",),
    "kv_heads": ("model",),
    "ffn": ("model",),
    "experts": (EXPERT_AXIS, "model"),  # routed-expert stacks: EP first,
                                        # TP on what stays divisible
    "expert_buf": (EXPERT_AXIS,),       # MoE dispatch/capacity buffers
                                        # shard over EP only
    "lora": ("model",),
    "conv": (),
    "ssm": ("model",),
    "layers": (),
    "cache_seq": (),            # serve policies may shard cache seq
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: dict[str, tuple[str, ...]] = dict(DEFAULT_RULES)


_CTX = _Ctx()


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Activate a mesh + logical rule table for model code."""
    old_mesh, old_rules = _CTX.mesh, _CTX.rules
    _CTX.mesh = mesh
    _CTX.rules = {**DEFAULT_RULES, **(rules or {})}
    try:
        if mesh is not None:
            with mesh:                      # enter Mesh context manager
                yield
        else:
            yield
    finally:
        _CTX.mesh, _CTX.rules = old_mesh, old_rules


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def current_rules() -> dict:
    return dict(_CTX.rules)


def mesh_axis_sizes(mesh=None) -> dict[str, int]:
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return {}
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def assign_axes(shape: Sequence[int],
                axes: Sequence[Optional[str]],
                sizes: dict[str, int],
                rules: Optional[dict] = None,
                extra: Sequence[str] = ()) -> list[list[str]]:
    """Core resolution: per-dim list of physical mesh axes.

    Base pass maps each dim's logical axis through ``rules`` (skipping
    non-divisible / already-used physical axes); the ``extra`` pass then
    greedily adds each extra physical axis to the first dim that stays
    divisible (FSDP / ZeRO sharding).  The pipeline axis (:data:`PIPE_AXIS`)
    partitions *layers*, not tensors, and is never assigned.
    """
    rules = rules if rules is not None else _CTX.rules
    used: set[str] = set()
    per_dim: list[list[str]] = [[] for _ in shape]
    for i, (dim, ax) in enumerate(zip(shape, axes)):
        if not ax:
            continue
        total = 1
        for a in rules.get(ax, ()):
            if a == PIPE_AXIS or a not in sizes or a in used:
                continue
            if dim % (total * sizes[a]) == 0:
                per_dim[i].append(a)
                used.add(a)
                total *= sizes[a]
    for a in extra:
        if a == PIPE_AXIS or a not in sizes or a in used:
            continue
        best = None
        for i, dim in enumerate(shape):
            # Never FSDP/ZeRO-shard the scan-stack dim: a stack sharded on
            # `layers` cannot be sliced per iteration, so XLA all-gathers
            # the ENTIRE depth-stacked weight before the loop (observed
            # +12 GiB on qwen3-32b).  Sharding a contraction dim instead
            # yields the per-layer deferred all-gather real FSDP does.
            if axes[i] == "layers":
                continue
            total = math.prod(sizes[x] for x in per_dim[i])
            if dim % (total * sizes[a]) == 0:
                best = i
                break
        if best is not None:
            per_dim[best].append(a)
            used.add(a)
    return per_dim


def _to_pspec(per_dim: list[list[str]]) -> P:
    entries: list = [tuple(d) if len(d) > 1 else (d[0] if d else None)
                     for d in per_dim]
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def resolve_pspec(shape: Sequence[int],
                  axes: Sequence[Optional[str]],
                  mesh=None,
                  rules: Optional[dict] = None,
                  extra: Sequence[str] = ()) -> P:
    sizes = mesh_axis_sizes(mesh)
    return _to_pspec(assign_axes(shape, axes, sizes, rules, extra))


def shard_factor(shape: Sequence[int],
                 axes: Sequence[Optional[str]],
                 mesh_shape: dict[str, int],
                 rules: Optional[dict] = None,
                 extra: Sequence[str] = ()) -> int:
    """Total shard count implied by the resolved spec (arithmetic twin of
    :func:`resolve_pspec`, usable without a live mesh)."""
    rules = rules if rules is not None else dict(DEFAULT_RULES)
    per_dim = assign_axes(shape, axes, mesh_shape, rules, extra)
    return math.prod(mesh_shape[a] for d in per_dim for a in d)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axes (no-op when no mesh)."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = resolve_pspec(x.shape, axes, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(shape: Sequence[int],
                   axes: Sequence[Optional[str]],
                   mesh=None,
                   extra: Sequence[str] = ()) -> Optional[NamedSharding]:
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve_pspec(shape, axes, mesh, extra=extra))
