"""Attention: GQA (llama/qwen/mistral-style) and MLA (deepseek/minicpm-style).

Three compute paths share one math definition:

* ``chunked_attention`` — flash-equivalent pure-``lax`` path (never
  materializes the S x S score matrix; KV is processed in chunks with a
  running-max online softmax).  Used for training/prefill lowering and as
  the oracle for the Pallas kernel.
* ``repro.kernels.flash_attention`` — the Pallas TPU kernel (hot path on
  real hardware; validated in interpret mode against this module).
* ``decode_attention`` — single-token query against a KV cache.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.spec import (ActTerm, LayerSpec, ParamSpec,
                             AXIS_EMBED, AXIS_HEADS, AXIS_KV_HEADS, AXIS_LORA)
from repro.mesh_ctx import shard
from repro.models.layers import apply_rope, rmsnorm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------


def gqa_spec(name: str, d_model: int, n_heads: int, n_kv_heads: int,
             head_dim: int, qk_norm: bool = False,
             dtype: str = "bfloat16") -> LayerSpec:
    params = {
        "wq": ParamSpec((d_model, n_heads * head_dim), dtype,
                        (AXIS_EMBED, AXIS_HEADS)),
        "wk": ParamSpec((d_model, n_kv_heads * head_dim), dtype,
                        (AXIS_EMBED, AXIS_KV_HEADS)),
        "wv": ParamSpec((d_model, n_kv_heads * head_dim), dtype,
                        (AXIS_EMBED, AXIS_KV_HEADS)),
        "wo": ParamSpec((n_heads * head_dim, d_model), dtype,
                        (AXIS_HEADS, AXIS_EMBED)),
    }
    if qk_norm:
        params["q_norm"] = ParamSpec((head_dim,), dtype, (None,), init="ones")
        params["k_norm"] = ParamSpec((head_dim,), dtype, (None,), init="ones")
    proj_flops = 2.0 * d_model * head_dim * (2 * n_heads + 2 * n_kv_heads)
    return LayerSpec(
        name=name, kind="attention", params=params,
        acts=[
            # 4-D head layouts mirror the runtime's reshape-then-shard order:
            # a head count that does not divide the mesh axis replicates in
            # BOTH the live code and the prediction (e.g. smollm's 15 heads).
            ActTerm(f"{name}.in", ("B", "S", d_model), dtype,
                    ("batch", "seq", AXIS_EMBED)),
            ActTerm(f"{name}.q", ("B", "S", n_heads, head_dim), dtype,
                    ("batch", "seq", AXIS_HEADS, None)),
            ActTerm(f"{name}.k", ("B", "S", n_kv_heads, head_dim), dtype,
                    ("batch", "seq", AXIS_KV_HEADS, None)),
            ActTerm(f"{name}.v", ("B", "S", n_kv_heads, head_dim), dtype,
                    ("batch", "seq", AXIS_KV_HEADS, None)),
            ActTerm(f"{name}.ctx", ("B", "S", n_heads, head_dim), dtype,
                    ("batch", "seq", AXIS_HEADS, None)),
            # flash softmax statistics (fp32 lse per head per position)
            ActTerm(f"{name}.lse", ("B", n_heads, "S"), "float32",
                    ("batch", "heads", "seq")),
        ],
        flops_per_token=proj_flops,
        meta={"n_heads": n_heads, "n_kv_heads": n_kv_heads,
              "head_dim": head_dim, "qk_norm": qk_norm, "d_model": d_model,
              "kv_bytes_per_token": 2 * n_kv_heads * head_dim,
              "attn_kind": "gqa"})


def mla_spec(name: str, d_model: int, n_heads: int, mla,
             dtype: str = "bfloat16") -> LayerSpec:
    """DeepSeek-V2-style multi-head latent attention.

    Decode caches only (kv_lora + rope_dim) per token — the spec records
    that via ``kv_bytes_per_token`` so cache prediction is exact.
    """
    qk_head = mla.qk_nope_head_dim + mla.qk_rope_head_dim
    params: dict[str, ParamSpec] = {}
    if mla.q_lora_rank:
        params["wq_a"] = ParamSpec((d_model, mla.q_lora_rank), dtype,
                                   (AXIS_EMBED, AXIS_LORA))
        params["q_norm"] = ParamSpec((mla.q_lora_rank,), dtype, (None,),
                                     init="ones")
        params["wq_b"] = ParamSpec((mla.q_lora_rank, n_heads * qk_head),
                                   dtype, (AXIS_LORA, AXIS_HEADS))
        q_flops = 2.0 * d_model * mla.q_lora_rank \
            + 2.0 * mla.q_lora_rank * n_heads * qk_head
    else:
        params["wq"] = ParamSpec((d_model, n_heads * qk_head), dtype,
                                 (AXIS_EMBED, AXIS_HEADS))
        q_flops = 2.0 * d_model * n_heads * qk_head
    params.update({
        "wkv_a": ParamSpec((d_model, mla.kv_lora_rank + mla.qk_rope_head_dim),
                           dtype, (AXIS_EMBED, None)),
        "kv_norm": ParamSpec((mla.kv_lora_rank,), dtype, (None,), init="ones"),
        "wkv_b": ParamSpec((mla.kv_lora_rank,
                            n_heads * (mla.qk_nope_head_dim + mla.v_head_dim)),
                           dtype, (AXIS_LORA, AXIS_HEADS)),
        "wo": ParamSpec((n_heads * mla.v_head_dim, d_model), dtype,
                        (AXIS_HEADS, AXIS_EMBED)),
    })
    flops = (q_flops
             + 2.0 * d_model * (mla.kv_lora_rank + mla.qk_rope_head_dim)
             + 2.0 * mla.kv_lora_rank * n_heads
             * (mla.qk_nope_head_dim + mla.v_head_dim)
             + 2.0 * n_heads * mla.v_head_dim * d_model)
    return LayerSpec(
        name=name, kind="attention", params=params,
        acts=[
            ActTerm(f"{name}.in", ("B", "S", d_model), dtype,
                    ("batch", "seq", AXIS_EMBED)),
            ActTerm(f"{name}.q", ("B", "S", n_heads, qk_head), dtype,
                    ("batch", "seq", AXIS_HEADS, None)),
            ActTerm(f"{name}.kv_latent", ("B", "S",
                                          mla.kv_lora_rank + mla.qk_rope_head_dim),
                    dtype, ("batch", "seq", None)),
            ActTerm(f"{name}.k", ("B", "S", n_heads, qk_head), dtype,
                    ("batch", "seq", AXIS_HEADS, None)),
            ActTerm(f"{name}.v", ("B", "S", n_heads, mla.v_head_dim), dtype,
                    ("batch", "seq", AXIS_HEADS, None)),
            ActTerm(f"{name}.ctx", ("B", "S", n_heads, mla.v_head_dim), dtype,
                    ("batch", "seq", AXIS_HEADS, None)),
            ActTerm(f"{name}.lse", ("B", n_heads, "S"), "float32",
                    ("batch", "heads", "seq")),
        ],
        flops_per_token=flops,
        meta={"n_heads": n_heads, "head_dim": qk_head,
              "v_head_dim": mla.v_head_dim, "mla": mla,
              "d_model": d_model,
              "kv_bytes_per_token": 2 * (mla.kv_lora_rank + mla.qk_rope_head_dim),
              "attn_kind": "mla"})


# ---------------------------------------------------------------------------
# chunked (flash-equivalent) attention core
# ---------------------------------------------------------------------------


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool = True, chunk: int = 1024,
                      q_offset: int = 0,
                      kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Online-softmax attention over KV chunks.

    q: (B, Sq, H, Dq); k: (B, Skv, Hkv, Dq); v: (B, Skv, Hkv, Dv); H % Hkv == 0.
    ``q_offset``: absolute position of q[0] (decode/prefill continuation).
    ``kv_len``: optional dynamic number of valid KV positions (masking).
    Returns (B, Sq, H, Dv).
    """
    B, Sq, H, Dq = q.shape
    _, Skv, Hkv, _ = k.shape
    Dv = v.shape[-1]
    G = H // Hkv
    scale = Dq ** -0.5
    qg = (q * scale).reshape(B, Sq, Hkv, G, Dq)

    chunk = min(chunk, Skv)
    n_chunks = -(-Skv // chunk)
    pad = n_chunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, Hkv, Dq)
    vc = v.reshape(B, n_chunks, chunk, Hkv, Dv)

    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, inputs):
        m, l, acc = carry
        ci, kci, vci = inputs
        kv_pos = ci * chunk + jnp.arange(chunk)
        # scores: (B, Sq, Hkv, G, chunk); qg dims = (b, s, kv-head h, group g, d)
        s = jnp.einsum("bshgd,bchd->bshgc", qg, kci.astype(qg.dtype),
                       preferred_element_type=jnp.float32)
        mask = jnp.ones((Sq, chunk), jnp.bool_)
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        mask = mask & (kv_pos[None, :] < (kv_len if kv_len is not None
                                          else Skv - 0))
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bshgc,bchd->bshgd", p.astype(vci.dtype), vci,
                        preferred_element_type=jnp.float32)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    acc0 = jnp.zeros((B, Sq, Hkv, G, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (jnp.arange(n_chunks), kc.swapaxes(0, 1), vc.swapaxes(0, 1)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, H, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# flash attention (custom_vjp): FA2 memory profile in pure lax.
# Forward saves only (q, k, v, out, lse); backward recomputes scores per KV
# chunk — without this, autodiff through the chunk scan stores every
# per-chunk probability matrix, i.e. the full S^2 tensor.
# ---------------------------------------------------------------------------


def _chunk_layout(x, chunk):
    """(B, S, h, d) -> (n_chunks, B, chunk, h, d) with zero padding."""
    B, S, h, d = x.shape
    n = -(-S // chunk)
    pad = n * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return x.reshape(B, n, chunk, h, d).swapaxes(0, 1), n


def _flash_fwd_impl(q, k, v, causal, chunk, q_offset):
    """Two-level blocked online-softmax attention.

    Blocks over BOTH the query and the KV sequence dims so the largest live
    score tensor is (B, q_chunk, Hkv, G, kv_chunk) — the lowered-HLO twin of
    the Pallas kernel's VMEM tiling.  Returns (out, lse) with
    lse: (B, Sq, Hkv, G) fp32.
    """
    B, Sq, H, Dq = q.shape
    _, Skv, Hkv, _ = k.shape
    Dv = v.shape[-1]
    G = H // Hkv
    scale = Dq ** -0.5

    kv_chunk = min(chunk, Skv)
    q_chunk = min(chunk, Sq)
    # tiles stay in the input dtype — the fp32 upcast happens per-tile
    # inside the body (a whole-q fp32 copy would be gathered/stored)
    qc, nq = _chunk_layout(q.reshape(B, Sq, Hkv * G, Dq), q_chunk)
    qc = qc.reshape(nq, B, q_chunk, Hkv, G, Dq)
    kc, nk = _chunk_layout(k, kv_chunk)
    vc, _ = _chunk_layout(v, kv_chunk)

    def q_body(_, q_in):
        qi, qci_raw = q_in
        qci = qci_raw.astype(jnp.float32) * scale
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_body(carry, kv_in):
            m, l, acc = carry
            ci, kci, vci = kv_in
            kv_pos = ci * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bshgd,bchd->bshgc", qci,
                           kci.astype(jnp.float32))
            mask = kv_pos[None, :] < Skv
            if causal:
                mask = mask & (kv_pos[None, :] <= q_pos[:, None])
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bshgc,bchd->bshgd", p, vci.astype(jnp.float32))
            return (m_new, l_new, acc * alpha[..., None] + pv), None

        m0 = jnp.full((B, q_chunk, Hkv, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, q_chunk, Hkv, G), jnp.float32)
        acc0 = jnp.zeros((B, q_chunk, Hkv, G, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_body), (m0, l0, acc0),
            (jnp.arange(nk), kc, vc))
        lse_c = m + jnp.log(jnp.maximum(l, 1e-30))
        out_c = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        return None, (out_c, lse_c)

    _, (out_c, lse_c) = jax.lax.scan(
        jax.checkpoint(q_body), None, (jnp.arange(nq), qc))
    out = out_c.swapaxes(0, 1).reshape(B, nq * q_chunk, H, Dv)[:, :Sq]
    lse = lse_c.swapaxes(0, 1).reshape(B, nq * q_chunk, Hkv, G)[:, :Sq]
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, chunk: int = 1024,
                    q_offset: int = 0):
    """q: (B,Sq,H,Dq); k/v: (B,Skv,Hkv,D*); returns (B,Sq,H,Dv)."""
    out, _ = _flash_fwd_impl(q, k, v, causal, chunk, q_offset)
    return out


def _flash_fwd(q, k, v, causal, chunk, q_offset):
    out, lse = _flash_fwd_impl(q, k, v, causal, chunk, q_offset)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, chunk, q_offset, res, dout):
    """FA2-style backward, blocked over BOTH q and kv chunks.

    Outer scan walks q chunks carrying full-KV dk/dv accumulators
    (B, Skv_pad, Hkv, D) fp32; the inner scan walks kv chunks recomputing
    the (q_chunk x kv_chunk) probability tile.
    """
    q, k, v, out, lse = res
    B, Sq, H, Dq = q.shape
    _, Skv, Hkv, _ = k.shape
    Dv = v.shape[-1]
    G = H // Hkv
    scale = Dq ** -0.5

    kv_chunk = min(chunk, Skv)
    q_chunk = min(chunk, Sq)
    qc, nq = _chunk_layout(q.reshape(B, Sq, Hkv * G, Dq), q_chunk)
    qc = qc.reshape(nq, B, q_chunk, Hkv, G, Dq)
    kc, nk = _chunk_layout(k, kv_chunk)
    vc, _ = _chunk_layout(v, kv_chunk)
    Skv_pad = nk * kv_chunk

    dog = dout.astype(jnp.float32).reshape(B, Sq, Hkv, G, Dv)
    og = out.astype(jnp.float32).reshape(B, Sq, Hkv, G, Dv)
    delta_full = (dog * og).sum(-1)                       # (B,Sq,Hkv,G)
    dogc, _ = _chunk_layout(dog.reshape(B, Sq, Hkv * G, Dv), q_chunk)
    dogc = dogc.reshape(nq, B, q_chunk, Hkv, G, Dv)
    dc, _ = _chunk_layout(delta_full[..., None].reshape(B, Sq, Hkv * G, 1),
                          q_chunk)
    dc = dc.reshape(nq, B, q_chunk, Hkv, G)
    lc, _ = _chunk_layout(lse[..., None].reshape(B, Sq, Hkv * G, 1), q_chunk)
    lc = lc.reshape(nq, B, q_chunk, Hkv, G)

    def q_body(carry, q_in):
        dk_acc, dv_acc = carry
        qi, qci_raw, doci, deltci, lsec = q_in
        qci = qci_raw.astype(jnp.float32) * scale
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_body(dq_c, kv_in):
            ci, kci, vci = kv_in
            kv_pos = ci * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bshgd,bchd->bshgc", qci,
                           kci.astype(jnp.float32))
            mask = kv_pos[None, :] < Skv
            if causal:
                mask = mask & (kv_pos[None, :] <= q_pos[:, None])
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            p = jnp.exp(s - lsec[..., None])               # (B,qc,Hkv,G,c)
            dv_c = jnp.einsum("bshgc,bshgd->bchd", p, doci)
            dp = jnp.einsum("bshgd,bchd->bshgc", doci,
                            vci.astype(jnp.float32))
            ds = p * (dp - deltci[..., None])
            dq_c = dq_c + jnp.einsum("bshgc,bchd->bshgd", ds,
                                     kci.astype(jnp.float32))
            dk_c = jnp.einsum("bshgc,bshgd->bchd", ds, qci)
            return dq_c, (dk_c, dv_c)

        dq0 = jnp.zeros((B, q_chunk, Hkv, G, Dq), jnp.float32)
        dq_c, (dk_parts, dv_parts) = jax.lax.scan(
            jax.checkpoint(kv_body), dq0, (jnp.arange(nk), kc, vc))
        dk_acc = dk_acc + dk_parts.swapaxes(0, 1).reshape(
            B, Skv_pad, Hkv, Dq)
        dv_acc = dv_acc + dv_parts.swapaxes(0, 1).reshape(
            B, Skv_pad, Hkv, Dv)
        return (dk_acc, dv_acc), dq_c

    dk0 = jnp.zeros((B, Skv_pad, Hkv, Dq), jnp.float32)
    dv0 = jnp.zeros((B, Skv_pad, Hkv, Dv), jnp.float32)
    (dk, dv), dq_c = jax.lax.scan(
        jax.checkpoint(q_body), (dk0, dv0), (jnp.arange(nq), qc, dogc, dc, lc))
    dq = (dq_c.swapaxes(0, 1).reshape(B, nq * q_chunk, Hkv, G, Dq)[:, :Sq]
          * scale).reshape(B, Sq, H, Dq).astype(q.dtype)
    return dq, dk[:, :Skv].astype(k.dtype), dv[:, :Skv].astype(v.dtype)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def reference_attention(q, k, v, causal=True, q_offset=0, kv_len=None):
    """Naive O(S^2)-memory oracle (tests only)."""
    B, Sq, H, Dq = q.shape
    _, Skv, Hkv, _ = k.shape
    G = H // Hkv
    k = jnp.repeat(k, G, axis=2)
    v = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * Dq ** -0.5
    q_pos = q_offset + jnp.arange(Sq)
    kv_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), jnp.bool_)
    if causal:
        mask = mask & (kv_pos[None, :] <= q_pos[:, None])
    if kv_len is not None:
        mask = mask & (kv_pos[None, :] < kv_len)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", p.astype(v.dtype), v)


# ---------------------------------------------------------------------------
# full layer applies
# ---------------------------------------------------------------------------


def _attn_tile_axes(n_heads: int) -> tuple:
    """Layout for q/ctx INSIDE attention.

    The flash scan runs its full trip count on every device, so a
    seq-sharded q leaves each device computing every head's full-S^2 tile
    work (observed 16x redundant FLOPs on qwen3 prefill).  When the head
    count fills the model axis, force head sharding for the attention body
    — heads then partition the tile loops and SP still shards the residual
    stream outside.  Non-divisible head counts keep the seq layout.
    """
    from repro.mesh_ctx import current_rules, mesh_axis_sizes
    sizes = mesh_axis_sizes()
    rules = current_rules()
    m = 1
    for a in rules.get("heads", ()):
        m *= sizes.get(a, 1)
    if m > 1 and n_heads % m == 0:
        return ("batch", None, "heads", None)
    return ("batch", "seq", "heads", None)


def gqa_forward(p: dict, x: jax.Array, *, n_heads: int, n_kv_heads: int,
                head_dim: int, theta: float, qk_norm: bool = False,
                norm_eps: float = 1e-5, causal: bool = True,
                positions: Optional[jax.Array] = None,
                chunk: int = 1024) -> jax.Array:
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q = (x @ p["wq"]).reshape(B, S, n_heads, head_dim)
    k = (x @ p["wk"]).reshape(B, S, n_kv_heads, head_dim)
    v = (x @ p["wv"]).reshape(B, S, n_kv_heads, head_dim)
    if qk_norm:
        q = rmsnorm({"scale": p["q_norm"]}, q, norm_eps)
        k = rmsnorm({"scale": p["k_norm"]}, k, norm_eps)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    axes = _attn_tile_axes(n_heads)
    q = shard(q, *axes)
    ctx = flash_attention(q, k, v, causal, chunk)
    ctx = shard(ctx, *axes)
    return ctx.reshape(B, S, n_heads * head_dim) @ p["wo"]


def gqa_decode(p: dict, x: jax.Array, cache: dict, *, n_heads: int,
               n_kv_heads: int, head_dim: int, theta: float,
               qk_norm: bool = False, norm_eps: float = 1e-5) -> tuple:
    """One-token decode: x (B, 1, d); cache {'k','v': (B, S_max, Hkv, D),
    'len': (B,)} -> (out, new_cache)."""
    B = x.shape[0]
    pos = cache["len"][:, None]                                   # (B,1)
    q = (x @ p["wq"]).reshape(B, 1, n_heads, head_dim)
    k = (x @ p["wk"]).reshape(B, 1, n_kv_heads, head_dim)
    v = (x @ p["wv"]).reshape(B, 1, n_kv_heads, head_dim)
    if qk_norm:
        q = rmsnorm({"scale": p["q_norm"]}, q, norm_eps)
        k = rmsnorm({"scale": p["k_norm"]}, k, norm_eps)
    q = apply_rope(q, pos, theta)
    k = apply_rope(k, pos, theta)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), cache["len"][0], axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), cache["len"][0], axis=1)
    ctx = decode_attention(q, k_cache, v_cache, cache["len"] + 1)
    out = ctx.reshape(B, 1, n_heads * head_dim) @ p["wo"]
    return out, {"k": k_cache, "v": v_cache, "len": cache["len"] + 1}


def decode_attention(q, k_cache, v_cache, kv_len):
    """q: (B, 1, H, D); caches: (B, S_max, Hkv, D); kv_len: (B,)."""
    B, _, H, Dq = q.shape
    _, Smax, Hkv, _ = k_cache.shape
    G = H // Hkv
    qg = (q * Dq ** -0.5).reshape(B, 1, Hkv, G, Dq)
    s = jnp.einsum("bshgd,bthd->bshgt", qg, k_cache.astype(qg.dtype),
                   preferred_element_type=jnp.float32)
    valid = jnp.arange(Smax)[None] < kv_len[:, None]              # (B, Smax)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    piv = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bshgt,bthd->bshgd", piv.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return ctx.reshape(B, 1, H, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLA applies
# ---------------------------------------------------------------------------


def _mla_qkv(p: dict, x: jax.Array, mla, n_heads: int, norm_eps: float):
    B, S, _ = x.shape
    qk_head = mla.qk_nope_head_dim + mla.qk_rope_head_dim
    if "wq_a" in p:
        qa = rmsnorm({"scale": p["q_norm"]}, x @ p["wq_a"], norm_eps)
        q = (qa @ p["wq_b"]).reshape(B, S, n_heads, qk_head)
    else:
        q = (x @ p["wq"]).reshape(B, S, n_heads, qk_head)
    kv_a = x @ p["wkv_a"]                                         # (B,S,r+rope)
    latent, k_rope = jnp.split(kv_a, [mla.kv_lora_rank], axis=-1)
    latent = rmsnorm({"scale": p["kv_norm"]}, latent, norm_eps)
    return q, latent, k_rope


def _mla_expand_kv(p: dict, latent: jax.Array, k_rope: jax.Array,
                   positions: jax.Array, mla, n_heads: int):
    B, S, _ = latent.shape
    kv = (latent @ p["wkv_b"]).reshape(
        B, S, n_heads, mla.qk_nope_head_dim + mla.v_head_dim)
    k_nope, v = jnp.split(kv, [mla.qk_nope_head_dim], axis=-1)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, theta=10000.0)
    k_rope = jnp.broadcast_to(k_rope, (B, S, n_heads, mla.qk_rope_head_dim))
    k = jnp.concatenate([k_nope, k_rope], axis=-1)
    return k, v


def mla_forward(p: dict, x: jax.Array, *, n_heads: int, mla,
                norm_eps: float = 1e-5, causal: bool = True,
                positions: Optional[jax.Array] = None,
                chunk: int = 1024) -> jax.Array:
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, latent, k_rope = _mla_qkv(p, x, mla, n_heads, norm_eps)
    q_nope, q_rope = jnp.split(q, [mla.qk_nope_head_dim], axis=-1)
    q = jnp.concatenate([q_nope, apply_rope(q_rope, positions, 10000.0)],
                        axis=-1)
    k, v = _mla_expand_kv(p, latent, k_rope, positions, mla, n_heads)
    axes = _attn_tile_axes(n_heads)
    q = shard(q, *axes)
    k = shard(k, *axes)
    ctx = flash_attention(q, k, v, causal, chunk)
    ctx = shard(ctx, *axes)
    return ctx.reshape(B, S, n_heads * mla.v_head_dim) @ p["wo"]


def mla_decode(p: dict, x: jax.Array, cache: dict, *, n_heads: int, mla,
               norm_eps: float = 1e-5) -> tuple:
    """MLA decode caches only the latent (+ rope key): cache
    {'latent': (B, S_max, r), 'k_rope': (B, S_max, rope), 'len': (B,)}."""
    B = x.shape[0]
    pos = cache["len"][:, None]
    q, latent, k_rope = _mla_qkv(p, x, mla, n_heads, norm_eps)
    q_nope, q_rope = jnp.split(q, [mla.qk_nope_head_dim], axis=-1)
    q = jnp.concatenate([q_nope, apply_rope(q_rope, pos, 10000.0)], axis=-1)
    lat_c = jax.lax.dynamic_update_slice_in_dim(
        cache["latent"], latent.astype(cache["latent"].dtype),
        cache["len"][0], axis=1)
    kr_c = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
        cache["len"][0], axis=1)
    Smax = lat_c.shape[1]
    positions = jnp.broadcast_to(jnp.arange(Smax), (B, Smax))
    k, v = _mla_expand_kv(p, lat_c, kr_c, positions, mla, n_heads)
    ctx = decode_attention(q, k, v, cache["len"] + 1)
    out = ctx.reshape(B, 1, n_heads * mla.v_head_dim) @ p["wo"]
    return out, {"latent": lat_c, "k_rope": kr_c, "len": cache["len"] + 1}
