"""Encoder-decoder backbone (seamless-m4t-large-v2).

Speech frontend is a STUB per the assignment: inputs are precomputed frame
embeddings (B, T_enc, d_frontend).  Encoder: bidirectional transformer.
Decoder: causal self-attention + cross-attention over encoder memory.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.core.spec import ModuleSpec, AXIS_EMBED
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.attention import (flash_attention, decode_attention,
                                    gqa_spec, gqa_forward, gqa_decode)
from repro.models.layers import apply_rope


def encdec_model_spec(cfg: ArchConfig) -> ModuleSpec:
    e = cfg.encdec
    frontend = ModuleSpec(
        name="frontend_proj", modality="audio",
        layers=[L.linear_spec("proj", e.d_frontend, cfg.d_model,
                              axes=(None, AXIS_EMBED))])
    enc_block = ModuleSpec(
        name="encoder_blocks", modality="audio", repeat=e.n_enc_layers,
        scanned=True,
        layers=[L.rmsnorm_spec("norm1", cfg.d_model, cfg.dtype),
                T.attn_spec_for(cfg),
                L.rmsnorm_spec("norm2", cfg.d_model, cfg.dtype),
                L.mlp_spec("ffn", cfg.d_model, cfg.d_ff, cfg.dtype)])
    enc_final = ModuleSpec(name="encoder_head", modality="audio",
                           layers=[L.rmsnorm_spec("enc_norm", cfg.d_model,
                                                  cfg.dtype)])
    encoder = ModuleSpec(name="speech_encoder", modality="audio",
                         children=[frontend, enc_block, enc_final])

    dec_block = ModuleSpec(
        name="decoder_blocks", modality="text", repeat=cfg.n_layers,
        scanned=True,
        layers=[L.rmsnorm_spec("norm1", cfg.d_model, cfg.dtype),
                T.attn_spec_for(cfg),
                L.rmsnorm_spec("norm_x", cfg.d_model, cfg.dtype),
                _cross_attn_spec(cfg),
                L.rmsnorm_spec("norm2", cfg.d_model, cfg.dtype),
                L.mlp_spec("ffn", cfg.d_model, cfg.d_ff, cfg.dtype)])
    decoder = ModuleSpec(
        name="text_decoder", modality="text",
        children=[
            ModuleSpec(name="embed", modality="text",
                       layers=[L.embedding_spec("tok", cfg.vocab, cfg.d_model,
                                                cfg.dtype, tied=cfg.tie_embeddings)]),
            dec_block,
            ModuleSpec(name="head", modality="text",
                       layers=[L.rmsnorm_spec("final_norm", cfg.d_model,
                                              cfg.dtype),
                               L.lm_head_spec("lm_head", cfg.d_model,
                                              cfg.vocab, cfg.dtype)]),
        ])
    return ModuleSpec(name="encdec", modality="multimodal",
                      children=[encoder, decoder])


def _cross_attn_spec(cfg: ArchConfig):
    s = gqa_spec("cross_attn", cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                 cfg.resolved_head_dim, dtype=cfg.dtype)
    s.meta["cross"] = True
    return s


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------


def encode(cfg: ArchConfig, p: dict, frames: jax.Array,
           remat: Optional[str] = None) -> jax.Array:
    enc = p["speech_encoder"]
    x = L.linear(enc["frontend_proj"]["proj"], frames)
    hd = cfg.resolved_head_dim
    remat = remat if remat is not None else cfg.remat

    def body(carry, bp):
        x = carry
        h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
        B, S, _ = h.shape
        a = gqa_forward(bp["attn"], h, n_heads=cfg.n_heads,
                        n_kv_heads=cfg.n_kv_heads, head_dim=hd,
                        theta=cfg.rope_theta, norm_eps=cfg.norm_eps,
                        causal=False)
        x = x + a
        h = L.rmsnorm(bp["norm2"], x, cfg.norm_eps)
        x = x + L.mlp(bp["ffn"], h)
        return x, None

    x, _ = jax.lax.scan(T._remat(body, remat), x, enc["encoder_blocks"])
    return L.rmsnorm(enc["encoder_head"]["enc_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# decoder train / prefill / decode
# ---------------------------------------------------------------------------


def _cross_kv(cfg: ArchConfig, cp: dict, memory: jax.Array):
    B, Te, _ = memory.shape
    hd = cfg.resolved_head_dim
    k = (memory @ cp["wk"]).reshape(B, Te, cfg.n_kv_heads, hd)
    v = (memory @ cp["wv"]).reshape(B, Te, cfg.n_kv_heads, hd)
    return k, v


def _decoder_block(cfg, bp, x, memory, positions):
    hd = cfg.resolved_head_dim
    h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
    x = x + gqa_forward(bp["attn"], h, n_heads=cfg.n_heads,
                        n_kv_heads=cfg.n_kv_heads, head_dim=hd,
                        theta=cfg.rope_theta, norm_eps=cfg.norm_eps,
                        causal=True, positions=positions)
    h = L.rmsnorm(bp["norm_x"], x, cfg.norm_eps)
    B, S, _ = h.shape
    q = (h @ bp["cross_attn"]["wq"]).reshape(B, S, cfg.n_heads, hd)
    k, v = _cross_kv(cfg, bp["cross_attn"], memory)
    ctx = flash_attention(q, k, v, False, 1024)
    x = x + ctx.reshape(B, S, -1) @ bp["cross_attn"]["wo"]
    h = L.rmsnorm(bp["norm2"], x, cfg.norm_eps)
    return x + L.mlp(bp["ffn"], h)


def encdec_loss(cfg: ArchConfig, params: dict, batch: dict,
                remat: Optional[str] = None):
    """batch: {'frames': (B, T, d_frontend), 'tokens': (B, S),
    'labels': (B, S)}."""
    p = params["encdec"]
    memory = encode(cfg, p, batch["frames"], remat)
    dec = p["text_decoder"]
    x = T.embed_tokens(cfg, dec, batch["tokens"])
    remat = remat if remat is not None else cfg.remat

    def body(carry, bp):
        return _decoder_block(cfg, bp, carry, memory, None), None

    x, _ = jax.lax.scan(T._remat(body, remat), x, dec["decoder_blocks"])
    x = L.rmsnorm(dec["head"]["final_norm"], x, cfg.norm_eps)
    loss_sum, n_tok = T.chunked_xent(cfg, dec, x, batch["labels"])
    loss = loss_sum / jnp.maximum(n_tok, 1.0)
    return loss, {"xent": loss, "n_tok": n_tok}


def encdec_prefill(cfg: ArchConfig, params: dict, batch: dict):
    """Encode + decoder prefill; cache holds self KV + cross KV per layer."""
    p = params["encdec"]
    memory = encode(cfg, p, batch["frames"])
    dec = p["text_decoder"]
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = T.embed_tokens(cfg, dec, tokens)

    def body(carry, bp):
        x = carry
        h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
        kv = T._prefill_kv(cfg, bp["attn"], h)
        ck, cv = _cross_kv(cfg, bp["cross_attn"], memory)
        x = _decoder_block(cfg, bp, x, memory, None)
        return x, dict(kv, cross_k=ck.astype(jnp.bfloat16),
                       cross_v=cv.astype(jnp.bfloat16))

    x, kv = jax.lax.scan(T._remat(body, cfg.remat), x, dec["decoder_blocks"])
    cache = {"blocks": kv, "len": jnp.full((B,), S, jnp.int32)}
    x = L.rmsnorm(dec["head"]["final_norm"], x[:, -1:], cfg.norm_eps)
    return T.lm_logits(cfg, dec, x), cache


def encdec_init_cache(cfg: ArchConfig, batch: int, max_len: int,
                      enc_len: int) -> dict:
    hd = cfg.resolved_head_dim
    L_ = cfg.n_layers
    kv = {"k": jnp.zeros((L_, batch, max_len, cfg.n_kv_heads, hd), jnp.bfloat16),
          "v": jnp.zeros((L_, batch, max_len, cfg.n_kv_heads, hd), jnp.bfloat16),
          "cross_k": jnp.zeros((L_, batch, enc_len, cfg.n_kv_heads, hd),
                               jnp.bfloat16),
          "cross_v": jnp.zeros((L_, batch, enc_len, cfg.n_kv_heads, hd),
                               jnp.bfloat16)}
    return {"blocks": kv, "len": jnp.zeros((batch,), jnp.int32)}


def encdec_decode_step(cfg: ArchConfig, params: dict, token: jax.Array,
                       cache: dict):
    p = params["encdec"]["text_decoder"]
    x = T.embed_tokens(cfg, p, token)
    length = cache["len"]
    hd = cfg.resolved_head_dim

    def body(x, inp):
        bp, lc = inp
        h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
        a, nc = gqa_decode(bp["attn"], h,
                           {"k": lc["k"], "v": lc["v"], "len": length},
                           n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                           head_dim=hd, theta=cfg.rope_theta,
                           norm_eps=cfg.norm_eps)
        x = x + a
        h = L.rmsnorm(bp["norm_x"], x, cfg.norm_eps)
        B = h.shape[0]
        q = (h @ bp["cross_attn"]["wq"]).reshape(B, 1, cfg.n_heads, hd)
        enc_len = jnp.full((B,), lc["cross_k"].shape[1], jnp.int32)
        ctx = decode_attention(q, lc["cross_k"], lc["cross_v"], enc_len)
        x = x + ctx.reshape(B, 1, -1) @ bp["cross_attn"]["wo"]
        h = L.rmsnorm(bp["norm2"], x, cfg.norm_eps)
        x = x + L.mlp(bp["ffn"], h)
        nc.pop("len")
        return x, dict(nc, cross_k=lc["cross_k"], cross_v=lc["cross_v"])

    x, nc = jax.lax.scan(body, x, (p["decoder_blocks"], cache["blocks"]))
    x = L.rmsnorm(p["head"]["final_norm"], x, cfg.norm_eps)
    return T.lm_logits(cfg, p, x), {"blocks": nc, "len": length + 1}
