"""Hybrid SSM + shared-attention model (zamba2-2.7b).

54 Mamba-2 blocks (scan-stacked per segment) with 2 weight-tied ("shared")
full-attention transformer blocks applied before every ``attn_every``-th
mamba layer, alternating A/B (zamba2's global shared blocks; per-invocation
LoRA omitted — see DESIGN.md).  The KV cache exists only for the shared
blocks' invocations, which is why this arch runs long_500k.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.core.spec import ModuleSpec
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.attention import gqa_spec, gqa_forward, gqa_decode
from repro.models.mamba import (mamba2_spec, mamba2_forward, mamba2_decode,
                                mamba2_init_state)
from repro.models.ssm_lm import _meta as _ssm_meta


def _n_attn_invocations(cfg: ArchConfig) -> int:
    return cfg.n_layers // cfg.hybrid.attn_every


def hybrid_model_spec(cfg: ArchConfig, name: str = "language_model") -> ModuleSpec:
    shared = ModuleSpec(
        name="shared_attn", modality="text",
        repeat=cfg.hybrid.shared_attn_blocks, scanned=True,
        layers=[L.rmsnorm_spec("norm1", cfg.d_model, cfg.dtype),
                gqa_spec("attn", cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                         cfg.resolved_head_dim, dtype=cfg.dtype),
                L.rmsnorm_spec("norm2", cfg.d_model, cfg.dtype),
                L.mlp_spec("ffn", cfg.d_model, cfg.d_ff, cfg.dtype)])
    # Weight tying: 2 distinct blocks, but n_layers/attn_every INVOCATIONS.
    # Params/grads/opt scale with the weight count (repeat=2); activations
    # and KV-cache slots scale with invocations, and the invocations are
    # python-unrolled (no scan remat).  The predictor reads these markers.
    for lyr in shared.layers:
        lyr.meta["invocation_repeat"] = _n_attn_invocations(cfg)
    shared.layers[1].meta["cache_repeat"] = _n_attn_invocations(cfg)
    children = [
        ModuleSpec(name="embed", modality="text",
                   layers=[L.embedding_spec("tok", cfg.vocab, cfg.d_model,
                                            cfg.dtype, tied=cfg.tie_embeddings)]),
        shared,
        ModuleSpec(name="blocks", modality="text", repeat=cfg.n_layers,
                   scanned=True,
                   layers=[L.rmsnorm_spec("norm", cfg.d_model, cfg.dtype),
                           mamba2_spec("mixer", cfg.d_model, cfg.ssm,
                                       cfg.dtype)]),
        ModuleSpec(name="head", modality="text",
                   layers=[L.rmsnorm_spec("final_norm", cfg.d_model,
                                          cfg.dtype)]),
    ]
    return ModuleSpec(name=name, modality="text", children=children)


def _shared_block(cfg: ArchConfig, sp, x: jax.Array) -> jax.Array:
    h = L.rmsnorm(sp["norm1"], x, cfg.norm_eps)
    x = x + gqa_forward(sp["attn"], h, n_heads=cfg.n_heads,
                        n_kv_heads=cfg.n_kv_heads,
                        head_dim=cfg.resolved_head_dim,
                        theta=cfg.rope_theta, norm_eps=cfg.norm_eps)
    h = L.rmsnorm(sp["norm2"], x, cfg.norm_eps)
    return x + L.mlp(sp["ffn"], h)


def _segments(cfg: ArchConfig, p: dict):
    """Yield (shared_block_params_for_segment, mamba_param_slice)."""
    every = cfg.hybrid.attn_every
    n_seg = _n_attn_invocations(cfg)
    nb = cfg.hybrid.shared_attn_blocks
    for s in range(n_seg):
        sp = jax.tree.map(lambda a: a[s % nb], p["shared_attn"])
        stack = jax.tree.map(lambda a: a[s * every:(s + 1) * every],
                             p["blocks"])
        yield s, sp, stack


def hybrid_backbone(cfg: ArchConfig, p: dict, x: jax.Array,
                    remat: Optional[str] = None) -> jax.Array:
    meta = _ssm_meta(cfg)
    remat = remat if remat is not None else cfg.remat

    def mamba_body(x, bp):
        h = L.rmsnorm(bp["norm"], x, cfg.norm_eps)
        return x + mamba2_forward(bp["mixer"], h, meta, cfg.norm_eps), None

    for s, sp, stack in _segments(cfg, p):
        x = _shared_block(cfg, sp, x)
        x, _ = jax.lax.scan(T._remat(mamba_body, remat), x, stack)
    return L.rmsnorm(p["head"]["final_norm"], x, cfg.norm_eps)


def hybrid_loss(cfg: ArchConfig, params: dict, batch: dict,
                remat: Optional[str] = None):
    p = params["language_model"]
    x = T.embed_tokens(cfg, p, batch["tokens"])
    hidden = hybrid_backbone(cfg, p, x, remat)
    loss_sum, n_tok = T.chunked_xent(cfg, p, hidden, batch["labels"])
    loss = loss_sum / jnp.maximum(n_tok, 1.0)
    return loss, {"xent": loss, "n_tok": n_tok}


def hybrid_init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    meta = _ssm_meta(cfg)
    one = mamba2_init_state(meta, batch)
    ssm_stack = jax.tree.map(
        lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), one)
    n_inv = _n_attn_invocations(cfg)
    hd = cfg.resolved_head_dim
    kv = {"k": jnp.zeros((n_inv, batch, max_len, cfg.n_kv_heads, hd),
                         jnp.bfloat16),
          "v": jnp.zeros((n_inv, batch, max_len, cfg.n_kv_heads, hd),
                         jnp.bfloat16)}
    return {"blocks": ssm_stack, "attn": kv,
            "len": jnp.zeros((batch,), jnp.int32)}


def hybrid_decode_step(cfg: ArchConfig, params: dict, token: jax.Array,
                       cache: dict):
    p = params["language_model"]
    meta = _ssm_meta(cfg)
    x = T.embed_tokens(cfg, p, token)
    length = cache["len"]
    new_kv = {"k": [], "v": []}
    ssm_out = []

    def mamba_body(x, inp):
        bp, st = inp
        h = L.rmsnorm(bp["norm"], x, cfg.norm_eps)
        y, new_st = mamba2_decode(bp["mixer"], h, st, meta, cfg.norm_eps)
        return x + y, new_st

    for s, sp, stack in _segments(cfg, p):
        lc = {"k": cache["attn"]["k"][s], "v": cache["attn"]["v"][s],
              "len": length}
        h = L.rmsnorm(sp["norm1"], x, cfg.norm_eps)
        a, nc = gqa_decode(sp["attn"], h, lc, n_heads=cfg.n_heads,
                           n_kv_heads=cfg.n_kv_heads,
                           head_dim=cfg.resolved_head_dim,
                           theta=cfg.rope_theta, norm_eps=cfg.norm_eps)
        x = x + a
        h = L.rmsnorm(sp["norm2"], x, cfg.norm_eps)
        x = x + L.mlp(sp["ffn"], h)
        new_kv["k"].append(nc["k"])
        new_kv["v"].append(nc["v"])

        every = cfg.hybrid.attn_every
        st_slice = jax.tree.map(
            lambda a: a[s * every:(s + 1) * every], cache["blocks"])
        x, new_st = jax.lax.scan(mamba_body, x, (stack, st_slice))
        ssm_out.append(new_st)

    new_cache = {
        "blocks": jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *ssm_out),
        "attn": {"k": jnp.stack(new_kv["k"]), "v": jnp.stack(new_kv["v"])},
        "len": length + 1,
    }
    x = L.rmsnorm(p["head"]["final_norm"], x, cfg.norm_eps)
    return T.lm_logits(cfg, p, x), new_cache


def hybrid_prefill(cfg: ArchConfig, params: dict, batch: dict):
    """Chunked-SSD prefill + KV materialization for shared-attn invocations."""
    from repro.models.ssm_lm import ssm_prefill  # reuse building blocks
    p = params["language_model"]
    meta = _ssm_meta(cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = T.embed_tokens(cfg, p, tokens)

    from repro.models.mamba import _causal_conv, _split_proj, ssd_chunked

    def mamba_body(x, bp):
        h = L.rmsnorm(bp["norm"], x, cfg.norm_eps)
        mp = bp["mixer"]
        zxbcdt = h @ mp["in_proj"]
        z, xin, Bv, Cv, dt = _split_proj(zxbcdt, meta)
        xbc = jnp.concatenate([xin, Bv, Cv], axis=-1)
        conv_tail = xbc[:, -(meta["d_conv"] - 1):].astype(jnp.bfloat16)
        xbc = jax.nn.silu(_causal_conv(xbc, mp["conv_w"], mp["conv_b"]))
        G, N = meta["n_groups"], meta["d_state"]
        xin, Bv, Cv = jnp.split(
            xbc, [meta["d_inner"], meta["d_inner"] + G * N], axis=-1)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + mp["dt_bias"])
        A = -jnp.exp(mp["A_log"])
        H, P = meta["n_heads"], meta["head_dim"]
        y, final = ssd_chunked(xin.reshape(B, S, H, P), dt, A,
                               Bv.reshape(B, S, G, N), Cv.reshape(B, S, G, N),
                               chunk=meta["chunk"])
        y = (y + xin.reshape(B, S, H, P)
             * mp["D"][None, None, :, None]).astype(x.dtype)
        y = L.rmsnorm({"scale": mp["norm_scale"]},
                      y.reshape(B, S, H * P) * jax.nn.silu(z), cfg.norm_eps)
        return x + (y @ mp["out_proj"]).astype(x.dtype), \
            {"ssm": final, "conv": conv_tail}

    kv_k, kv_v, ssm_states = [], [], []
    for s, sp, stack in _segments(cfg, p):
        h = L.rmsnorm(sp["norm1"], x, cfg.norm_eps)
        kv = T._prefill_kv(cfg, sp["attn"], h)
        kv_k.append(kv["k"])
        kv_v.append(kv["v"])
        x = _shared_block(cfg, sp, x)
        x, st = jax.lax.scan(mamba_body, x, stack)
        ssm_states.append(st)

    cache = {
        "blocks": jax.tree.map(lambda *xs: jnp.concatenate(xs, 0),
                               *ssm_states),
        "attn": {"k": jnp.stack(kv_k), "v": jnp.stack(kv_v)},
        "len": jnp.full((B,), S, jnp.int32),
    }
    x = L.rmsnorm(p["head"]["final_norm"], x[:, -1:], cfg.norm_eps)
    return T.lm_logits(cfg, p, x), cache
