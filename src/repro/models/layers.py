"""Fine-grained layers: spec builders + functional applies.

Every builder returns a :class:`LayerSpec` whose ``params`` dict matches the
pytree that ``param.init_params`` allocates and whose ``acts``/``flops``
metadata feed the memory predictor.  Apply functions are pure and consume
``params[layer_name]`` sub-dicts.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.spec import (ActTerm, LayerSpec, ParamSpec,
                             AXIS_EMBED, AXIS_FFN, AXIS_HEADS,
                             AXIS_KV_HEADS, AXIS_LORA, AXIS_VOCAB)
from repro.mesh_ctx import shard

# ---------------------------------------------------------------------------
# linear
# ---------------------------------------------------------------------------


def linear_spec(name: str, d_in: int, d_out: int,
                axes=(AXIS_EMBED, AXIS_FFN), dtype: str = "bfloat16",
                bias: bool = False, out_act_axes=("batch", None, AXIS_FFN),
                init_scale: float = 1.0) -> LayerSpec:
    params = {"w": ParamSpec((d_in, d_out), dtype, axes, init_scale=init_scale)}
    if bias:
        params["b"] = ParamSpec((d_out,), dtype, (axes[1],), init="zeros")
    return LayerSpec(
        name=name, kind="linear", params=params,
        acts=[ActTerm(f"{name}.in", ("B", "S", d_in), dtype,
                      ("batch", "seq", axes[0]))],
        flops_per_token=2.0 * d_in * d_out,
        meta={"d_in": d_in, "d_out": d_out})


def linear(p: dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------


def embedding_spec(name: str, vocab: int, d_model: int,
                   dtype: str = "bfloat16", tied: bool = False) -> LayerSpec:
    """Untied tables shard columns (embed_cols -> model): the lookup then
    never gathers the table.  Tied tables must stay vocab-sharded for the
    vocab-parallel loss; the lookup's table all-gather is modelled by the
    predictor (meta['lookup_gather'])."""
    axes = (AXIS_VOCAB, AXIS_EMBED) if tied else (None, "embed_cols")
    return LayerSpec(
        name=name, kind="embedding",
        params={"w": ParamSpec((vocab, d_model), dtype, axes, init="embed")},
        acts=[ActTerm(f"{name}.ids", ("B", "S"), "int32", ("batch", "seq"))],
        flops_per_token=0.0,
        meta={"vocab": vocab, "d_model": d_model, "lookup_gather": tied})


def embed(p: dict, ids: jax.Array) -> jax.Array:
    return jnp.take(p["w"], ids, axis=0)


def unembed(p: dict, x: jax.Array) -> jax.Array:
    """Logits in fp32 (loss numerics)."""
    return (x @ p["w"].T).astype(jnp.float32)


def lm_head_spec(name: str, d_model: int, vocab: int,
                 dtype: str = "bfloat16") -> LayerSpec:
    return LayerSpec(
        name=name, kind="linear",
        params={"w": ParamSpec((d_model, vocab), dtype,
                               (AXIS_EMBED, AXIS_VOCAB))},
        acts=[ActTerm(f"{name}.in", ("B", "S", d_model), dtype,
                      ("batch", "seq", AXIS_EMBED))],
        flops_per_token=2.0 * d_model * vocab,
        meta={"d_in": d_model, "d_out": vocab})


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_spec(name: str, d: int, dtype: str = "bfloat16") -> LayerSpec:
    return LayerSpec(
        name=name, kind="rmsnorm",
        params={"scale": ParamSpec((d,), dtype, (None,), init="ones")},
        acts=[ActTerm(f"{name}.in", ("B", "S", d), dtype,
                      ("batch", "seq", AXIS_EMBED))],
        flops_per_token=5.0 * d,
        meta={"d": d})


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_spec(name: str, d: int, dtype: str = "bfloat16") -> LayerSpec:
    return LayerSpec(
        name=name, kind="layernorm",
        params={"scale": ParamSpec((d,), dtype, (None,), init="ones"),
                "bias": ParamSpec((d,), dtype, (None,), init="zeros")},
        acts=[ActTerm(f"{name}.in", ("B", "S", d), dtype,
                      ("batch", "seq", AXIS_EMBED))],
        flops_per_token=8.0 * d,
        meta={"d": d})


def layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_spec(name: str, d_model: int, d_ff: int,
             dtype: str = "bfloat16", gated: bool = True) -> LayerSpec:
    if gated:
        params = {
            "wg": ParamSpec((d_model, d_ff), dtype, (AXIS_EMBED, AXIS_FFN)),
            "wu": ParamSpec((d_model, d_ff), dtype, (AXIS_EMBED, AXIS_FFN)),
            "wd": ParamSpec((d_ff, d_model), dtype, (AXIS_FFN, AXIS_EMBED)),
        }
        flops = 2.0 * d_model * d_ff * 3
        n_ff_acts = 3
    else:
        params = {
            "wu": ParamSpec((d_model, d_ff), dtype, (AXIS_EMBED, AXIS_FFN)),
            "wd": ParamSpec((d_ff, d_model), dtype, (AXIS_FFN, AXIS_EMBED)),
        }
        flops = 2.0 * d_model * d_ff * 2
        n_ff_acts = 2
    return LayerSpec(
        name=name, kind="mlp", params=params,
        acts=[ActTerm(f"{name}.in", ("B", "S", d_model), dtype,
                      ("batch", "seq", AXIS_EMBED))]
             + [ActTerm(f"{name}.h{i}", ("B", "S", d_ff), dtype,
                        ("batch", "seq", AXIS_FFN)) for i in range(n_ff_acts)],
        flops_per_token=flops,
        meta={"d_model": d_model, "d_ff": d_ff, "gated": gated})


def mlp(p: dict, x: jax.Array) -> jax.Array:
    if "wg" in p:
        g = x @ p["wg"]
        u = x @ p["wu"]
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(x @ p["wu"])
    h = shard(h, "batch", "seq", "ffn")
    return h @ p["wd"]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)                     # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) rotated pairwise; positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                          # (D/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
