"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Training/prefill uses the chunked dual form: quadratic attention-like
computation *within* fixed-size chunks plus a linear state recurrence
*across* chunks (``lax.scan``) — never materializing an S x S matrix.
Decode is the O(1) recurrent step on a (H, P, N) state per layer.

``repro.kernels.ssd`` provides the Pallas TPU kernel for the within-chunk
part; this module is its oracle.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.spec import (ActTerm, LayerSpec, ParamSpec,
                             AXIS_CONV, AXIS_EMBED, AXIS_FFN, AXIS_SSM)
from repro.mesh_ctx import shard
from repro.models.layers import rmsnorm


def mamba2_spec(name: str, d_model: int, ssm, dtype: str = "bfloat16") -> LayerSpec:
    d_inner = ssm.d_inner(d_model)
    H = ssm.n_heads(d_model)
    G, N = ssm.n_groups, ssm.d_state
    d_in_proj = 2 * d_inner + 2 * G * N + H
    conv_ch = d_inner + 2 * G * N
    params = {
        "in_proj": ParamSpec((d_model, d_in_proj), dtype, (AXIS_EMBED, AXIS_FFN)),
        "conv_w": ParamSpec((ssm.d_conv, conv_ch), dtype, (AXIS_CONV, AXIS_FFN)),
        "conv_b": ParamSpec((conv_ch,), dtype, (AXIS_FFN,), init="zeros"),
        "A_log": ParamSpec((H,), "float32", (AXIS_SSM,), init="ssm_a"),
        "D": ParamSpec((H,), "float32", (AXIS_SSM,), init="ones"),
        "dt_bias": ParamSpec((H,), "float32", (AXIS_SSM,), init="dt_bias"),
        "norm_scale": ParamSpec((d_inner,), dtype, (AXIS_FFN,), init="ones"),
        "out_proj": ParamSpec((d_inner, d_model), dtype, (AXIS_FFN, AXIS_EMBED)),
    }
    flops = 2.0 * d_model * d_in_proj + 2.0 * d_inner * d_model \
        + 2.0 * ssm.d_conv * conv_ch \
        + 2.0 * 2 * H * ssm.head_dim * N  # state update + readout per token
    return LayerSpec(
        name=name, kind="ssm", params=params,
        acts=[
            ActTerm(f"{name}.in", ("B", "S", d_model), dtype,
                    ("batch", "seq", AXIS_EMBED)),
            ActTerm(f"{name}.zxbcdt", ("B", "S", d_in_proj), dtype,
                    ("batch", "seq", AXIS_FFN)),
            ActTerm(f"{name}.conv", ("B", "S", conv_ch), dtype,
                    ("batch", "seq", AXIS_FFN)),
            ActTerm(f"{name}.y", ("B", "S", d_inner), dtype,
                    ("batch", "seq", AXIS_FFN)),
            # per-chunk states saved by the scan across chunks
            ActTerm(f"{name}.chunk_states",
                    ("B", "S", H * ssm.head_dim * N // ssm.chunk), "float32",
                    ("batch", "seq", AXIS_SSM)),
        ],
        flops_per_token=flops,
        meta={"d_inner": d_inner, "n_heads": H, "head_dim": ssm.head_dim,
              "d_state": N, "n_groups": G, "d_conv": ssm.d_conv,
              "chunk": ssm.chunk, "d_in_proj": d_in_proj, "conv_ch": conv_ch,
              "state_bytes": 4 * H * ssm.head_dim * N
              + 2 * (ssm.d_conv - 1) * conv_ch})


# ---------------------------------------------------------------------------
# chunked SSD core
# ---------------------------------------------------------------------------


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., Q) -> (..., Q, Q) with out[i, j] = sum_{k=j+1..i} a_k (i>=j),
    -inf above the diagonal."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, chunk: int,
                initial_state: Optional[jax.Array] = None):
    """SSD dual form.

    x: (b, S, H, P); dt: (b, S, H) (already softplus'd);
    A: (H,) negative reals; B, C: (b, S, G, N) with G == 1 supported.
    Returns (y: (b, S, H, P), final_state: (b, H, P, N)).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    assert B.shape[2] == 1, "n_groups == 1 supported"
    Bm, Cm = B[:, :, 0], C[:, :, 0]                     # (b, S, N)

    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk
    xc = x.reshape(b, nc, chunk, H, P)
    dtc = dt.reshape(b, nc, chunk, H)
    Bc = Bm.reshape(b, nc, chunk, N)
    Cc = Cm.reshape(b, nc, chunk, N)

    s0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((b, H, P, N), jnp.float32))

    def body(st, inp):
        """One chunk: intra-chunk quadratic term + inter-chunk state pass.
        Scanning keeps the (b, H, Q, Q) decay matrix a per-chunk temp."""
        xq, dtq, Bq, Cq = inp                            # (b,Q,H,P) (b,Q,H) ...
        a = jnp.moveaxis(dtq * A[None, None, :], -1, 1)  # (b, H, Q) <= 0
        a_cum = jnp.cumsum(a, axis=-1)
        a_tot = a_cum[..., -1]                           # (b, H)
        L = jnp.exp(_segsum(a))                          # (b, H, Q, Q)
        scores = jnp.einsum("bqn,bkn->bqk", Cq.astype(jnp.float32),
                            Bq.astype(jnp.float32))      # (b, Q, Q)
        xdt = (xq * dtq[..., None]).astype(jnp.float32)  # (b, Q, H, P)
        y_diag = jnp.einsum("bhqk,bqk,bkhp->bqhp", L, scores, xdt)
        y_off = jnp.einsum("bqn,bhq,bhpn->bqhp",
                           Cq.astype(jnp.float32), jnp.exp(a_cum), st)
        decay_to_end = jnp.exp(a_tot[..., None] - a_cum)  # (b, H, Q)
        new_st = st * jnp.exp(a_tot)[..., None, None] \
            + jnp.einsum("bhq,bqn,bqhp->bhpn",
                         decay_to_end, Bq.astype(jnp.float32), xdt)
        return new_st, (y_diag + y_off).astype(x.dtype)

    final, yc = jax.lax.scan(
        jax.checkpoint(body), s0,
        (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0),
         jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0)))
    y = jnp.moveaxis(yc, 0, 1).reshape(b, Sp, H, P)[:, :S]
    return y, final


def ssd_reference(x, dt, A, B, C, initial_state=None):
    """Naive sequential recurrence (oracle for tests)."""
    b, S, H, P = x.shape
    N = B.shape[-1]
    Bm, Cm = B[:, :, 0], C[:, :, 0]
    st = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((b, H, P, N), jnp.float32))
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A[None, :])              # (b, H)
        dBx = jnp.einsum("bn,bhp,bh->bhpn", Bm[:, t].astype(jnp.float32),
                         x[:, t].astype(jnp.float32), dt[:, t])
        st = st * dA[..., None, None] + dBx
        ys.append(jnp.einsum("bhpn,bn->bhp", st, Cm[:, t].astype(jnp.float32)))
    return jnp.stack(ys, 1).astype(x.dtype), st


# ---------------------------------------------------------------------------
# full block applies
# ---------------------------------------------------------------------------


def _split_proj(zxbcdt: jax.Array, meta: dict):
    d_inner, G, N, H = (meta["d_inner"], meta["n_groups"], meta["d_state"],
                        meta["n_heads"])
    z, x, B, C, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + G * N,
                 2 * d_inner + 2 * G * N], axis=-1)
    return z, x, B, C, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv: x (B, S, C), w (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for k in range(K):
        out = out + xp[:, k:k + x.shape[1]].astype(jnp.float32) \
            * w[k].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def mamba2_forward(p: dict, hidden: jax.Array, meta: dict,
                   norm_eps: float = 1e-5) -> jax.Array:
    Bsz, S, _ = hidden.shape
    H, P, N, G = (meta["n_heads"], meta["head_dim"], meta["d_state"],
                  meta["n_groups"])
    zxbcdt = hidden @ p["in_proj"]
    z, x, Bv, Cv, dt = _split_proj(zxbcdt, meta)
    xbc = jnp.concatenate([x, Bv, Cv], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    x, Bv, Cv = jnp.split(xbc, [meta["d_inner"], meta["d_inner"] + G * N],
                          axis=-1)
    x = shard(x, "batch", "seq", "ffn")
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(x.reshape(Bsz, S, H, P), dt,
                       A, Bv.reshape(Bsz, S, G, N), Cv.reshape(Bsz, S, G, N),
                       chunk=meta["chunk"])
    y = (y + x.reshape(Bsz, S, H, P)
         * p["D"][None, None, :, None]).astype(hidden.dtype)
    y = y.reshape(Bsz, S, H * P)
    y = rmsnorm({"scale": p["norm_scale"]}, y * jax.nn.silu(z), norm_eps)
    return (y @ p["out_proj"]).astype(hidden.dtype)


def mamba2_init_state(meta: dict, batch: int, dtype=jnp.float32) -> dict:
    H, P, N = meta["n_heads"], meta["head_dim"], meta["d_state"]
    return {
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, meta["d_conv"] - 1, meta["conv_ch"]),
                          jnp.bfloat16),
    }


def mamba2_decode(p: dict, hidden: jax.Array, state: dict, meta: dict,
                  norm_eps: float = 1e-5) -> tuple:
    """hidden: (B, 1, d_model); O(1) recurrent step."""
    Bsz = hidden.shape[0]
    H, P, N, G = (meta["n_heads"], meta["head_dim"], meta["d_state"],
                  meta["n_groups"])
    zxbcdt = hidden @ p["in_proj"]
    z, x, Bv, Cv, dt = _split_proj(zxbcdt[:, 0], meta)
    xbc = jnp.concatenate([x, Bv, Cv], axis=-1)          # (B, conv_ch)
    window = jnp.concatenate(
        [state["conv"], xbc[:, None].astype(state["conv"].dtype)], axis=1)
    conv = (window.astype(jnp.float32)
            * p["conv_w"].astype(jnp.float32)[None]).sum(1) \
        + p["conv_b"].astype(jnp.float32)
    xbc = jax.nn.silu(conv).astype(hidden.dtype)
    x, Bv, Cv = jnp.split(xbc, [meta["d_inner"], meta["d_inner"] + G * N],
                          axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B, H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None, :])                                 # (B, H)
    xh = x.reshape(Bsz, H, P).astype(jnp.float32)
    dBx = jnp.einsum("bn,bhp,bh->bhpn", Bv.astype(jnp.float32), xh, dt)
    ssm = state["ssm"] * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", ssm, Cv.astype(jnp.float32))
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(Bsz, 1, H * P).astype(hidden.dtype)
    y = rmsnorm({"scale": p["norm_scale"]},
                y * jax.nn.silu(z)[:, None], norm_eps)
    out = (y @ p["out_proj"]).astype(hidden.dtype)
    new_state = {"ssm": ssm, "conv": window[:, 1:]}
    return out, new_state
