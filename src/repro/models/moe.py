"""Mixture-of-Experts with production expert parallelism.

Dispatch follows the classic EP pattern (GShard/DeepSpeed-MoE adapted to
TPU-native ``shard_map``):

  1. tokens are (re)sharded over *all* mesh axes (``data`` x ``model``);
  2. each shard routes locally (softmax -> top-k -> capacity with drop);
  3. ``jax.lax.all_to_all`` over the ``model`` axis exchanges fixed-capacity
     per-expert buffers (EP: experts live on model shards);
  4. local grouped expert FFN (SwiGLU per expert);
  5. reverse all_to_all + weighted combine.

When no mesh is active (CPU smoke tests) a mathematically identical dense
fallback runs every expert on every token with combine weights.

For the MEMORY MODEL the spec below carries the expert-parallel metadata:
the routed weight stacks' leading ``E`` dim is the ``experts`` logical
axis (rule: ``mesh_ctx.EXPERT_AXIS`` first, then TP on what stays
divisible) and the dispatch/capacity buffers carry the EP-only
``expert_buf`` axis — so a mesh with an ``expert`` axis divides exactly
the MoE weights and dispatch buffers, never a dense layer's tensors.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.spec import (ActTerm, LayerSpec, ParamSpec,
                             AXIS_EMBED, AXIS_EXPERTS, AXIS_EXPERT_BUF,
                             AXIS_FFN)
from repro.mesh_ctx import current_mesh, mesh_axis_sizes


def moe_spec(name: str, d_model: int, moe, dtype: str = "bfloat16") -> LayerSpec:
    E, F = moe.n_experts, moe.d_expert
    params = {
        "router": ParamSpec((d_model, E), "float32", (AXIS_EMBED, None)),
        "wg": ParamSpec((E, d_model, F), dtype, (AXIS_EXPERTS, AXIS_EMBED, None)),
        "wu": ParamSpec((E, d_model, F), dtype, (AXIS_EXPERTS, AXIS_EMBED, None)),
        "wd": ParamSpec((E, F, d_model), dtype, (AXIS_EXPERTS, None, AXIS_EMBED)),
    }
    if moe.n_shared_experts:
        Fs = F * moe.n_shared_experts
        params.update({
            "shared_wg": ParamSpec((d_model, Fs), dtype, (AXIS_EMBED, AXIS_FFN)),
            "shared_wu": ParamSpec((d_model, Fs), dtype, (AXIS_EMBED, AXIS_FFN)),
            "shared_wd": ParamSpec((Fs, d_model), dtype, (AXIS_FFN, AXIS_EMBED)),
        })
    # active-expert FLOPs per token (top_k routed + shared)
    flops = 2.0 * d_model * E \
        + 2.0 * 3 * d_model * F * (moe.top_k + moe.n_shared_experts)
    cap = moe.capacity_factor
    return LayerSpec(
        name=name, kind="moe", params=params,
        acts=[
            ActTerm(f"{name}.in", ("B", "S", d_model), dtype,
                    ("batch", "seq", AXIS_EMBED)),
            ActTerm(f"{name}.router", ("B", "S", E), "float32",
                    ("batch", "seq", None)),
            # dispatched expert buffers (top_k * capacity_factor copies);
            # the capacity dim carries the EP-only `expert_buf` axis: each
            # expert shard holds its own experts' fixed-capacity blocks
            ActTerm(f"{name}.dispatch",
                    ("B", "S", int(d_model * moe.top_k * cap)), dtype,
                    ("batch", "seq", AXIS_EXPERT_BUF)),
            ActTerm(f"{name}.h",
                    ("B", "S", int(3 * F * moe.top_k * cap)), dtype,
                    ("batch", "seq", AXIS_EXPERT_BUF)),
        ] + ([ActTerm(f"{name}.shared_h",
                      ("B", "S", 3 * F * moe.n_shared_experts), dtype,
                      ("batch", "seq", AXIS_FFN))]
             if moe.n_shared_experts else []),
        flops_per_token=flops,
        meta={"n_experts": E, "top_k": moe.top_k, "d_expert": F,
              "d_model": d_model, "capacity_factor": cap,
              "n_shared_experts": moe.n_shared_experts})


# ---------------------------------------------------------------------------
# routing helpers
# ---------------------------------------------------------------------------


def _route(logits: jax.Array, top_k: int):
    """softmax -> top-k -> renormalize. logits: (T, E) fp32."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)               # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return top_p, top_i, probs


def load_balance_loss(probs: jax.Array, top_i: jax.Array, n_experts: int):
    """Switch-style aux loss: E * sum_e f_e * P_e."""
    f = jnp.mean(jax.nn.one_hot(top_i, n_experts,
                                dtype=jnp.float32).sum(-2), axis=0)
    p = probs.mean(0)
    return n_experts * jnp.sum(f * p / max(top_i.shape[-1], 1))


def _expert_ffn(wg, wu, wd, xb):
    """xb: (E_loc, C_tot, D); weights (E_loc, D, F)/(E_loc, F, D)."""
    g = jnp.einsum("ecd,edf->ecf", xb, wg)
    u = jnp.einsum("ecd,edf->ecf", xb, wu)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd)


def _capacity(t_loc: int, top_k: int, n_experts: int, cf: float) -> int:
    c = int(t_loc * top_k * cf / n_experts)
    return max(8, -(-c // 8) * 8)


# ---------------------------------------------------------------------------
# expert-parallel path (shard_map over the live mesh)
# ---------------------------------------------------------------------------


def _ep_local(x, router_w, wg, wu, wd, *, top_k: int, n_experts: int,
              cf: float, ep_axis: str, ep_size: int):
    """Runs per device under shard_map.

    x: (B_loc, S_loc, D) local tokens; wg/wu/wd: (E_loc, ...) local experts.
    The (B*S) flatten happens HERE, on local data: a global reshape across
    a (batch x seq)-sharded layout forces SPMD into full rematerialization
    (observed 16 GiB all-gathers on deepseek train_4k).
    """
    B_loc, S_loc, D = x.shape
    x = x.reshape(B_loc * S_loc, D)
    T = B_loc * S_loc
    E = n_experts
    C = _capacity(T, top_k, E, cf)
    logits = x.astype(jnp.float32) @ router_w            # (T, E)
    top_p, top_i, probs = _route(logits, top_k)

    flat_e = top_i.reshape(-1)                           # (T*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot            # slot before me
    slot = (pos * onehot).sum(-1)                        # (T*k,)
    slot = jnp.where(slot < C, slot, C)                  # C == drop sentinel

    xk = jnp.repeat(x, top_k, axis=0)                    # (T*k, D)
    send = jnp.zeros((E, C, D), x.dtype)
    send = send.at[flat_e, slot].add(xk, mode="drop")

    if ep_size > 1:
        # (E, C, D) -> (E_loc, ep*C, D): each shard keeps its experts,
        # receiving every source shard's capacity block.
        recv = jax.lax.all_to_all(send, ep_axis, split_axis=0,
                                  concat_axis=1, tiled=True)
    else:
        recv = send
    out_b = _expert_ffn(wg, wu, wd, recv)                # (E_loc, ep*C, D)
    if ep_size > 1:
        back = jax.lax.all_to_all(out_b, ep_axis, split_axis=1,
                                  concat_axis=0, tiled=True)
    else:
        back = out_b                                     # (E, C, D)

    gathered = back.at[flat_e, slot].get(mode="fill", fill_value=0)
    y = (gathered.reshape(T, top_k, D).astype(jnp.float32)
         * top_p[..., None]).sum(1)
    return y.astype(x.dtype).reshape(B_loc, S_loc, D)


def moe_forward(p: dict, x: jax.Array, meta: dict) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss)."""
    B, S, D = x.shape
    E, top_k, cf = meta["n_experts"], meta["top_k"], meta["capacity_factor"]
    mesh = current_mesh()
    sizes = mesh_axis_sizes(mesh)

    use_ep = False
    if mesh is not None:
        batch_axes = tuple(a for a in ("pod", "data") if a in sizes)
        nb = 1
        for a in batch_axes:
            nb *= sizes[a]
        ep = sizes.get("model", 1)
        use_ep = (B % max(nb, 1) == 0 and S % max(ep, 1) == 0
                  and E % max(ep, 1) == 0)

    if use_ep:
        ep = sizes.get("model", 1)
        # tokens stay 3-D: batch over data, seq over model (matches SP), so
        # the shard_map boundary never reshapes across shardings.
        fn = shard_map(
            functools.partial(_ep_local, top_k=top_k, n_experts=E, cf=cf,
                              ep_axis="model", ep_size=ep),
            mesh=mesh,
            in_specs=(P(batch_axes, "model", None), P(None, None),
                      P("model", None, None), P("model", None, None),
                      P("model", None, None)),
            out_specs=P(batch_axes, "model", None),
            check_rep=False)
        y = fn(x, p["router"], p["wg"], p["wu"], p["wd"])
        # aux loss from a (cheap, duplicated) global router eval so the
        # scalar is well-defined across shards (3-D einsum: no reshape).
        logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                            p["router"])
        _, top_i, probs = _route(logits.reshape(-1, E), top_k)
        aux = load_balance_loss(probs, top_i, E)
    else:
        y, aux = _dense_moe(p, x.reshape(B * S, D), meta)
        y = y.reshape(B, S, D)

    if meta["n_shared_experts"]:
        g = jnp.einsum("bsd,df->bsf", x, p["shared_wg"])
        u = jnp.einsum("bsd,df->bsf", x, p["shared_wu"])
        y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                           p["shared_wd"])
    return y, aux


def _dense_moe(p: dict, tokens: jax.Array, meta: dict):
    """Fallback: every expert on every token (tiny configs / no mesh)."""
    E, top_k = meta["n_experts"], meta["top_k"]
    logits = tokens.astype(jnp.float32) @ p["router"]
    top_p, top_i, probs = _route(logits, top_k)
    w = jnp.zeros_like(probs).at[jnp.arange(tokens.shape[0])[:, None],
                                 top_i].set(top_p)       # (T, E)
    h = jnp.einsum("td,edf->etf", tokens, p["wg"])
    u = jnp.einsum("td,edf->etf", tokens, p["wu"])
    yo = jnp.einsum("etf,efd->etd", jax.nn.silu(h) * u, p["wd"])
    y = jnp.einsum("etd,te->td", yo.astype(jnp.float32), w)
    return y.astype(tokens.dtype), load_balance_loss(probs, top_i, E)
