"""Parameter allocation and pytree utilities driven by the spec tree.

Params are nested dicts mirroring the ModuleSpec tree:
``{module_name: {layer_name: {param_name: array}}}`` with scan-stacked
modules (``repeat > 1``) receiving a leading ``layers`` axis on every leaf.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spec import AXIS_LAYERS, ModuleSpec, ParamSpec, TrainPolicy


def _init_leaf(key: jax.Array, p: ParamSpec, stack: int) -> jax.Array:
    shape = (stack,) + tuple(p.shape) if stack else tuple(p.shape)
    dtype = jnp.dtype(p.dtype)
    if p.init == "zeros":
        return jnp.zeros(shape, dtype)
    if p.init == "ones":
        return jnp.ones(shape, dtype)
    if p.init == "ssm_a":
        # Mamba A_log init: log of uniform [1, 16)
        u = jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if p.init == "dt_bias":
        # softplus^-1 of dt in [1e-3, 1e-1]
        dt = jnp.exp(jax.random.uniform(key, shape, jnp.float32)
                     * (np.log(0.1) - np.log(1e-3)) + np.log(1e-3))
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    # "normal" / "embed": truncated-normal-ish scaled by fan-in
    fan_in = p.shape[0] if len(p.shape) >= 2 else max(p.shape[-1] if p.shape else 1, 1)
    scale = p.init_scale / np.sqrt(max(fan_in, 1))
    if p.init == "embed":
        scale = p.init_scale * 0.02
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_params(spec: ModuleSpec, key: jax.Array) -> dict:
    """Allocate the full parameter pytree for a spec tree."""

    def init_module(mod: ModuleSpec, key: jax.Array, stack: int) -> dict:
        out: dict[str, Any] = {}
        if mod.repeat > 1 or mod.scanned:
            stack = max(stack, 1) * mod.repeat
        n = len(mod.layers) + len(mod.children)
        keys = jax.random.split(key, max(n, 1))
        ki = 0
        for layer in mod.layers:
            lkeys = jax.random.split(keys[ki], max(len(layer.params), 1))
            ki += 1
            out[layer.name] = {
                name: _init_leaf(lk, p, stack)
                for lk, (name, p) in zip(lkeys, layer.params.items())
            }
        for child in mod.children:
            out[child.name] = init_module(child, keys[ki], stack)
            ki += 1
        return out

    return {spec.name: init_module(spec, key, 0)}


def param_specs(spec: ModuleSpec) -> dict:
    """ShapeDtypeStruct pytree matching :func:`init_params` (no allocation)."""

    def specs_module(mod: ModuleSpec, stack: int) -> dict:
        out: dict[str, Any] = {}
        if mod.repeat > 1 or mod.scanned:
            stack = max(stack, 1) * mod.repeat
        for layer in mod.layers:
            out[layer.name] = {}
            for name, p in layer.params.items():
                shape = (stack,) + tuple(p.shape) if stack else tuple(p.shape)
                out[layer.name][name] = jax.ShapeDtypeStruct(shape, jnp.dtype(p.dtype))
        for child in mod.children:
            out[child.name] = specs_module(child, stack)
        return out

    return {spec.name: specs_module(spec, 0)}


def param_axes(spec: ModuleSpec) -> dict:
    """Pytree of logical-axis tuples matching the param pytree layout."""

    def axes_module(mod: ModuleSpec, stacked: bool) -> dict:
        out: dict[str, Any] = {}
        stacked = stacked or mod.repeat > 1 or mod.scanned
        for layer in mod.layers:
            out[layer.name] = {}
            for name, p in layer.params.items():
                axes = tuple(p.axes) if p.axes else (None,) * len(p.shape)
                if stacked:
                    axes = (AXIS_LAYERS,) + axes
                out[layer.name][name] = axes
        for child in mod.children:
            out[child.name] = axes_module(child, stacked)
        return out

    return {spec.name: axes_module(spec, False)}


def trainable_mask(spec: ModuleSpec, policy: TrainPolicy) -> dict:
    """Pytree of bools: which params receive gradients under the policy."""

    def mask_module(mod: ModuleSpec, path: str) -> dict:
        out: dict[str, Any] = {}
        flag = policy.is_trainable(path)
        for layer in mod.layers:
            out[layer.name] = {name: flag for name in layer.params}
        for child in mod.children:
            out[child.name] = mask_module(child, f"{path}/{child.name}")
        return out

    return {spec.name: mask_module(spec, spec.name)}


def partition_params(params: dict, mask: dict) -> tuple[dict, dict]:
    """Split a param pytree into (trainable, frozen) by a boolean mask tree.

    Non-selected leaves are replaced by ``None`` so the two trees can be
    merged back with :func:`merge_params`.
    """
    trainable = jax.tree.map(lambda p, m: p if m else None, params, mask,
                             is_leaf=lambda x: x is None)
    frozen = jax.tree.map(lambda p, m: None if m else p, params, mask,
                          is_leaf=lambda x: x is None)
    return trainable, frozen


def merge_params(trainable: dict, frozen: dict) -> dict:
    return jax.tree.map(lambda t, f: t if t is not None else f,
                        trainable, frozen,
                        is_leaf=lambda x: x is None)


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree)
               if x is not None)


def cast_tree(tree, dtype) -> Any:
    def cast(x):
        if x is None:
            return None
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(cast, tree, is_leaf=lambda x: x is None)
