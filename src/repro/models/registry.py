"""Unified model interface over all architecture families.

``build_model(cfg)`` returns a :class:`Model` exposing:

* ``spec``         — the ModuleSpec tree (consumed by core.parser)
* ``init(key)``    — parameter pytree
* ``loss(params, batch)``            — scalar loss + metrics (training)
* ``prefill(params, batch)``         — logits + populated cache
* ``decode_step(params, token, cache)`` — one-token serve step
* ``init_cache(batch, max_len)``     — zeroed cache pytree
* ``batch_spec(shape)``              — ShapeDtypeStructs for every input
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, ShapeConfig
from repro.core.spec import ModuleSpec
from repro.models import param as PM
from repro.models import transformer as T


@dataclass
class Model:
    cfg: ArchConfig
    spec: ModuleSpec
    loss: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable

    def init(self, key: jax.Array) -> dict:
        return PM.init_params(self.spec, key)

    def param_specs(self) -> dict:
        return PM.param_specs(self.spec)

    def param_axes(self) -> dict:
        return PM.param_axes(self.spec)

    # ------------------------------------------------------------------
    def batch_spec(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of this shape."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        tok = lambda b, s: jax.ShapeDtypeStruct((b, s), jnp.int32)
        if shape.kind == "decode":
            return {"token": tok(B, 1)}
        if cfg.family == "vlm":
            n_img = cfg.vlm.n_image_tokens
            s_text = max(S - n_img, 1)
            batch = {"tokens": tok(B, s_text), "labels": tok(B, s_text)}
            if cfg.vlm.vision_tower:
                n_patch = (cfg.vlm.vit_image_size // cfg.vlm.vit_patch) ** 2
                batch["patches"] = jax.ShapeDtypeStruct(
                    (B, n_patch, 3 * cfg.vlm.vit_patch ** 2),
                    jnp.dtype(cfg.dtype))
            else:
                batch["patch_embeds"] = jax.ShapeDtypeStruct(
                    (B, n_img, cfg.vlm.d_vision), jnp.dtype(cfg.dtype))
            if shape.kind == "prefill":
                batch.pop("labels")
            return batch
        if cfg.family == "encdec":
            T_enc = int(S * cfg.encdec.enc_seq_ratio)
            batch = {"frames": jax.ShapeDtypeStruct(
                        (B, T_enc, cfg.encdec.d_frontend), jnp.dtype(cfg.dtype)),
                     "tokens": tok(B, S), "labels": tok(B, S)}
            if shape.kind == "prefill":
                batch.pop("labels")
            return batch
        batch = {"tokens": tok(B, S), "labels": tok(B, S)}
        if shape.kind == "prefill":
            batch.pop("labels")
        return batch


def build_model(cfg: ArchConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe"):
        spec = T.lm_spec(cfg)
        return Model(
            cfg=cfg, spec=spec,
            loss=lambda p, b, **kw: T.lm_loss(cfg, p, b["tokens"],
                                              b["labels"], **kw),
            prefill=lambda p, b: T.lm_prefill(cfg, p, b["tokens"]),
            decode_step=lambda p, t, c: T.lm_decode_step(cfg, p, t, c),
            init_cache=lambda b, m: T.init_kv_cache(cfg, b, m))
    if fam == "ssm":
        from repro.models import ssm_lm as S
        spec = S.ssm_model_spec(cfg)
        return Model(
            cfg=cfg, spec=spec,
            loss=lambda p, b, **kw: S.ssm_loss(cfg, p, b, **kw),
            prefill=lambda p, b: S.ssm_prefill(cfg, p, b),
            decode_step=lambda p, t, c: S.ssm_decode_step(cfg, p, t, c),
            init_cache=lambda b, m: S.ssm_init_cache(cfg, b, m))
    if fam == "hybrid":
        from repro.models import hybrid as H
        spec = H.hybrid_model_spec(cfg)
        return Model(
            cfg=cfg, spec=spec,
            loss=lambda p, b, **kw: H.hybrid_loss(cfg, p, b, **kw),
            prefill=lambda p, b: H.hybrid_prefill(cfg, p, b),
            decode_step=lambda p, t, c: H.hybrid_decode_step(cfg, p, t, c),
            init_cache=lambda b, m: H.hybrid_init_cache(cfg, b, m))
    if fam == "vlm":
        from repro.models import vlm as V
        spec = V.vlm_model_spec(cfg)
        return Model(
            cfg=cfg, spec=spec,
            loss=lambda p, b, **kw: V.vlm_loss(cfg, p, b, **kw),
            prefill=lambda p, b: V.vlm_prefill(cfg, p, b),
            decode_step=lambda p, t, c: V.vlm_decode_step(cfg, p, t, c),
            init_cache=lambda b, m: T.init_kv_cache(cfg, b, m))
    if fam == "encdec":
        from repro.models import encdec as E
        spec = E.encdec_model_spec(cfg)
        return Model(
            cfg=cfg, spec=spec,
            loss=lambda p, b, **kw: E.encdec_loss(cfg, p, b, **kw),
            prefill=lambda p, b: E.encdec_prefill(cfg, p, b),
            decode_step=lambda p, t, c: E.encdec_decode_step(cfg, p, t, c),
            init_cache=lambda b, m, enc_len=None: E.encdec_init_cache(
                cfg, b, m, enc_len or m))
    raise ValueError(f"unknown family {fam!r}")
