"""Pure-SSM language model (mamba2-1.3b): embed -> 48x [norm + Mamba2] ->
norm -> tied logits.  Decode is O(1) per token via the recurrent state."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.core.spec import ModuleSpec
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.mamba import (mamba2_spec, mamba2_forward, mamba2_decode,
                                mamba2_init_state)


def ssm_model_spec(cfg: ArchConfig, name: str = "language_model") -> ModuleSpec:
    children = [
        ModuleSpec(name="embed", modality="text",
                   layers=[L.embedding_spec("tok", cfg.vocab, cfg.d_model,
                                            cfg.dtype, tied=cfg.tie_embeddings)]),
        ModuleSpec(name="blocks", modality="text", repeat=cfg.n_layers,
                   scanned=True,
                   layers=[L.rmsnorm_spec("norm", cfg.d_model, cfg.dtype),
                           mamba2_spec("mixer", cfg.d_model, cfg.ssm,
                                       cfg.dtype)]),
        ModuleSpec(name="head", modality="text",
                   layers=[L.rmsnorm_spec("final_norm", cfg.d_model,
                                          cfg.dtype)]),
    ]
    return ModuleSpec(name=name, modality="text", children=children)


def _meta(cfg: ArchConfig) -> dict:
    return mamba2_spec("mixer", cfg.d_model, cfg.ssm, cfg.dtype).meta


def ssm_backbone(cfg: ArchConfig, p: dict, x: jax.Array,
                 remat: Optional[str] = None) -> jax.Array:
    meta = _meta(cfg)
    remat = remat if remat is not None else cfg.remat

    def body(x, bp):
        h = L.rmsnorm(bp["norm"], x, cfg.norm_eps)
        return x + mamba2_forward(bp["mixer"], h, meta, cfg.norm_eps), None

    x, _ = jax.lax.scan(T._remat(body, remat), x, p["blocks"])
    return L.rmsnorm(p["head"]["final_norm"], x, cfg.norm_eps)


def ssm_loss(cfg: ArchConfig, params: dict, batch: dict,
             remat: Optional[str] = None):
    p = params["language_model"]
    x = T.embed_tokens(cfg, p, batch["tokens"])
    hidden = ssm_backbone(cfg, p, x, remat)
    loss_sum, n_tok = T.chunked_xent(cfg, p, hidden, batch["labels"])
    loss = loss_sum / jnp.maximum(n_tok, 1.0)
    return loss, {"xent": loss, "n_tok": n_tok}


def ssm_init_cache(cfg: ArchConfig, batch: int, max_len: int = 0) -> dict:
    meta = _meta(cfg)
    one = mamba2_init_state(meta, batch)
    stack = jax.tree.map(
        lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), one)
    return {"blocks": stack, "len": jnp.zeros((batch,), jnp.int32)}


def ssm_decode_step(cfg: ArchConfig, params: dict, token: jax.Array,
                    cache: dict):
    p = params["language_model"]
    meta = _meta(cfg)
    x = T.embed_tokens(cfg, p, token)

    def body(x, inp):
        bp, st = inp
        h = L.rmsnorm(bp["norm"], x, cfg.norm_eps)
        y, new_st = mamba2_decode(bp["mixer"], h, st, meta, cfg.norm_eps)
        return x + y, new_st

    x, new_states = jax.lax.scan(body, x, (p["blocks"], cache["blocks"]))
    x = L.rmsnorm(p["head"]["final_norm"], x, cfg.norm_eps)
    return T.lm_logits(cfg, p, x), {"blocks": new_states,
                                    "len": cache["len"] + 1}


def ssm_prefill(cfg: ArchConfig, params: dict, batch: dict):
    """Run the chunked-SSD forward over the prompt, materializing the final
    recurrent state per layer as the cache."""
    p = params["language_model"]
    meta = _meta(cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = T.embed_tokens(cfg, p, tokens)

    from repro.models.mamba import (_causal_conv, _split_proj, ssd_chunked)

    def body(x, bp):
        h = L.rmsnorm(bp["norm"], x, cfg.norm_eps)
        mp = bp["mixer"]
        zxbcdt = h @ mp["in_proj"]
        z, xin, Bv, Cv, dt = _split_proj(zxbcdt, meta)
        xbc = jnp.concatenate([xin, Bv, Cv], axis=-1)
        conv_tail = xbc[:, -(meta["d_conv"] - 1):].astype(jnp.bfloat16)
        xbc = jax.nn.silu(_causal_conv(xbc, mp["conv_w"], mp["conv_b"]))
        xin, Bv, Cv = jnp.split(
            xbc, [meta["d_inner"], meta["d_inner"] + meta["n_groups"]
                  * meta["d_state"]], axis=-1)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + mp["dt_bias"])
        A = -jnp.exp(mp["A_log"])
        H, P = meta["n_heads"], meta["head_dim"]
        G, N = meta["n_groups"], meta["d_state"]
        y, final_state = ssd_chunked(xin.reshape(B, S, H, P), dt, A,
                                     Bv.reshape(B, S, G, N),
                                     Cv.reshape(B, S, G, N),
                                     chunk=meta["chunk"])
        y = (y + xin.reshape(B, S, H, P)
             * mp["D"][None, None, :, None]).astype(x.dtype)
        y = y.reshape(B, S, H * P)
        y = L.rmsnorm({"scale": mp["norm_scale"]}, y * jax.nn.silu(z),
                      cfg.norm_eps)
        return x + (y @ mp["out_proj"]).astype(x.dtype), \
            {"ssm": final_state, "conv": conv_tail}

    x, states = jax.lax.scan(body, x, p["blocks"])
    x = L.rmsnorm(p["head"]["final_norm"], x[:, -1:], cfg.norm_eps)
    cache = {"blocks": states, "len": jnp.full((B,), S, jnp.int32)}
    return T.lm_logits(cfg, p, x), cache
