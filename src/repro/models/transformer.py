"""Decoder-only LM family: llama / qwen (GQA), minicpm / deepseek (MLA),
dense or MoE FFN.  Blocks are scan-stacked (O(1) HLO in depth) with a
selectable remat policy; the loss uses a chunked, vocab-sharded
cross-entropy that never materializes the full (B, S, V) logits.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.core.spec import ActTerm, LayerSpec, ModuleSpec, ParamSpec, AXIS_EMBED
from repro.mesh_ctx import shard
from repro.models import layers as L
from repro.models.attention import (gqa_decode, gqa_forward, mla_decode,
                                    mla_forward, gqa_spec, mla_spec)
from repro.models.moe import moe_forward, moe_spec

LOSS_CHUNK = 512


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------


def attn_spec_for(cfg: ArchConfig) -> LayerSpec:
    if cfg.mla:
        return mla_spec("attn", cfg.d_model, cfg.n_heads, cfg.mla, cfg.dtype)
    return gqa_spec("attn", cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.resolved_head_dim, cfg.qk_norm, cfg.dtype)


def _block_layers(cfg: ArchConfig, ffn: str) -> list[LayerSpec]:
    layers = [L.rmsnorm_spec("norm1", cfg.d_model, cfg.dtype),
              attn_spec_for(cfg),
              L.rmsnorm_spec("norm2", cfg.d_model, cfg.dtype)]
    if ffn == "moe":
        layers.append(moe_spec("ffn", cfg.d_model, cfg.moe, cfg.dtype))
        if cfg.moe.dense_residual:
            layers.append(L.mlp_spec("dense_ffn", cfg.d_model, cfg.d_ff,
                                     cfg.dtype))
    else:
        layers.append(L.mlp_spec("ffn", cfg.d_model, cfg.d_ff, cfg.dtype))
    return layers


def lm_spec(cfg: ArchConfig, name: str = "language_model") -> ModuleSpec:
    children = [ModuleSpec(
        name="embed", modality="text",
        layers=[L.embedding_spec("tok", cfg.vocab, cfg.d_model, cfg.dtype,
                                 tied=cfg.tie_embeddings)])]
    n_moe_dense = cfg.moe.n_dense_layers if cfg.moe else 0
    if cfg.moe:
        if n_moe_dense:
            children.append(ModuleSpec(
                name="dense_blocks", modality="text", repeat=n_moe_dense,
                scanned=True, layers=_block_layers(cfg, "mlp")))
        children.append(ModuleSpec(
            name="blocks", modality="text", repeat=cfg.n_layers - n_moe_dense,
            scanned=True, layers=_block_layers(cfg, "moe")))
    else:
        children.append(ModuleSpec(
            name="blocks", modality="text", repeat=cfg.n_layers,
            scanned=True, layers=_block_layers(cfg, "mlp")))
    final = [L.rmsnorm_spec("final_norm", cfg.d_model, cfg.dtype)]
    if not cfg.tie_embeddings:
        final.append(L.lm_head_spec("lm_head", cfg.d_model, cfg.vocab,
                                    cfg.dtype))
    children.append(ModuleSpec(name="head", modality="text", layers=final))
    return ModuleSpec(name=name, modality="text", children=children)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _attn_apply(cfg: ArchConfig, bp: dict, h: jax.Array,
                positions: Optional[jax.Array], chunk: int) -> jax.Array:
    if cfg.mla:
        return mla_forward(bp, h, n_heads=cfg.n_heads, mla=cfg.mla,
                           norm_eps=cfg.norm_eps, positions=positions,
                           chunk=chunk)
    return gqa_forward(bp, h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                       head_dim=cfg.resolved_head_dim, theta=cfg.rope_theta,
                       qk_norm=cfg.qk_norm, norm_eps=cfg.norm_eps,
                       positions=positions, chunk=chunk)


def _block_apply(cfg: ArchConfig, moe_block: bool, bp: dict, x: jax.Array,
                 positions, chunk: int) -> tuple[jax.Array, jax.Array]:
    x = shard(x, "batch", "seq", "embed")
    h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
    x = x + _attn_apply(cfg, bp["attn"], h, positions, chunk)
    h = L.rmsnorm(bp["norm2"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if moe_block:
        y, aux = moe_forward(bp["ffn"], h, _moe_meta(cfg))
        if cfg.moe.dense_residual:
            y = y + L.mlp(bp["dense_ffn"], h)
        x = x + y
    else:
        x = x + L.mlp(bp["ffn"], h)
    return x, aux


def _moe_meta(cfg: ArchConfig) -> dict:
    return moe_spec("ffn", cfg.d_model, cfg.moe, cfg.dtype).meta


@jax.custom_vjp
def _pin(x: jax.Array) -> jax.Array:
    """AD-transparent optimization barrier.

    ``lax.optimization_barrier`` has no differentiation rule in this jax
    version, so wrapping it in a custom VJP keeps the forward barrier
    (which pins the bf16 scan carry — see ``_scan_blocks``) while giving
    the backward pass an explicit rule: barrier the cotangent too, which
    symmetrically stops XLA from hoisting the bwd convert of the carried
    gradient stack out of the loop.
    """
    return jax.lax.optimization_barrier(x)


def _pin_fwd(x):
    return _pin(x), None


def _pin_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_pin.defvjp(_pin_fwd, _pin_bwd)


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)              # "block": save carries only


def _scan_blocks(cfg: ArchConfig, moe_block: bool, stack: dict, x: jax.Array,
                 positions, chunk: int, remat: str) -> tuple[jax.Array, jax.Array]:
    def body(carry, bp):
        x, aux = carry
        # Barrier pins the bf16 carry: without it XLA hoists the backward
        # pass's bf16->f32 convert of the saved-carry STACK out of the while
        # loop, materializing an fp32 copy of every layer's residual (2x the
        # dominant activation buffer; observed +7.5 GiB on smollm train_4k).
        x = _pin(x)
        x, a = _block_apply(cfg, moe_block, bp, x, positions, chunk)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(_remat(body, remat), (x, jnp.zeros((), jnp.float32)),
                               stack)
    return x, aux


def lm_backbone(cfg: ArchConfig, p: dict, embeds: jax.Array,
                positions=None, remat: Optional[str] = None,
                chunk: int = 1024) -> tuple[jax.Array, jax.Array]:
    """embeds: (B, S, D) -> (hidden (B, S, D), moe_aux)."""
    remat = remat if remat is not None else cfg.remat
    x = embeds
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe and cfg.moe.n_dense_layers:
        x, a = _scan_blocks(cfg, False, p["dense_blocks"], x, positions,
                            chunk, remat)
        aux += a
    x, a = _scan_blocks(cfg, bool(cfg.moe), p["blocks"], x, positions,
                        chunk, remat)
    aux += a
    return L.rmsnorm(p["head"]["final_norm"], x, cfg.norm_eps), aux


def embed_tokens(cfg: ArchConfig, p: dict, tokens: jax.Array) -> jax.Array:
    return L.embed(p["embed"]["tok"], tokens)


def lm_logits(cfg: ArchConfig, p: dict, hidden: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return L.unembed(p["embed"]["tok"], hidden)
    return L.linear(p["head"]["lm_head"], hidden).astype(jnp.float32)


# ---------------------------------------------------------------------------
# chunked cross-entropy (never materializes (B, S, V))
# ---------------------------------------------------------------------------


def chunked_xent(cfg: ArchConfig, p: dict, hidden: jax.Array,
                 labels: jax.Array, chunk: int = LOSS_CHUNK):
    """hidden: (B, S, D); labels: (B, S) with -100 = masked.
    Returns (sum_loss, n_tokens)."""
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)
    n_chunks = (S + pad) // chunk
    hc = hidden.reshape(B, n_chunks, chunk, D).swapaxes(0, 1)
    lc = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_loss(h, l):
        logits = lm_logits(cfg, p, h)                     # (B, c, V) fp32
        logits = shard(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        mask = l >= 0
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(l, 0)[..., None], axis=-1)[..., 0]
        return (jnp.where(mask, lse - tgt, 0.0).sum(),
                mask.sum().astype(jnp.float32))

    def body(carry, inp):
        h, l = inp
        s, n = chunk_loss(h, l)
        return (carry[0] + s, carry[1] + n), None

    (loss_sum, n_tok), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc))
    return loss_sum, n_tok


def lm_loss(cfg: ArchConfig, params: dict, tokens: jax.Array,
            labels: jax.Array, remat: Optional[str] = None):
    p = params[next(iter(params))] if "language_model" not in params \
        else params["language_model"]
    x = embed_tokens(cfg, p, tokens)
    hidden, aux = lm_backbone(cfg, p, x, remat=remat)
    loss_sum, n_tok = chunked_xent(cfg, p, hidden, labels)
    loss = loss_sum / jnp.maximum(n_tok, 1.0)
    if cfg.moe:
        loss = loss + 0.01 * aux / max(cfg.n_layers, 1)
    return loss, {"xent": loss_sum / jnp.maximum(n_tok, 1.0),
                  "aux": aux, "n_tok": n_tok}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    """Stacked (L-leading) cache pytree for the scanned blocks."""
    n_moe_dense = cfg.moe.n_dense_layers if cfg.moe else 0
    n_scan = cfg.n_layers - n_moe_dense

    def one(n):
        if cfg.mla:
            m = cfg.mla
            return {"latent": jnp.zeros((n, batch, max_len, m.kv_lora_rank),
                                        jnp.bfloat16),
                    "k_rope": jnp.zeros((n, batch, max_len, m.qk_rope_head_dim),
                                        jnp.bfloat16)}
        hd = cfg.resolved_head_dim
        return {"k": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, hd),
                               jnp.bfloat16),
                "v": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, hd),
                               jnp.bfloat16)}

    cache = {"blocks": one(n_scan), "len": jnp.zeros((batch,), jnp.int32)}
    if n_moe_dense:
        cache["dense_blocks"] = one(n_moe_dense)
    return cache


def _decode_block(cfg: ArchConfig, moe_block: bool, bp: dict, x: jax.Array,
                  layer_cache: dict, length: jax.Array):
    h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
    cache_in = dict(layer_cache, len=length)
    if cfg.mla:
        a, new_cache = mla_decode(bp["attn"], h, cache_in, n_heads=cfg.n_heads,
                                  mla=cfg.mla, norm_eps=cfg.norm_eps)
    else:
        a, new_cache = gqa_decode(bp["attn"], h, cache_in,
                                  n_heads=cfg.n_heads,
                                  n_kv_heads=cfg.n_kv_heads,
                                  head_dim=cfg.resolved_head_dim,
                                  theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
                                  norm_eps=cfg.norm_eps)
    x = x + a
    h = L.rmsnorm(bp["norm2"], x, cfg.norm_eps)
    if moe_block:
        y, _ = moe_forward(bp["ffn"], h, _moe_meta(cfg))
        if cfg.moe.dense_residual:
            y = y + L.mlp(bp["dense_ffn"], h)
        x = x + y
    else:
        x = x + L.mlp(bp["ffn"], h)
    new_cache.pop("len")
    return x, new_cache


def lm_decode_step(cfg: ArchConfig, params: dict, token: jax.Array,
                   cache: dict):
    """token: (B, 1) -> (logits (B, 1, V), new cache)."""
    p = params.get("language_model") or params[next(iter(params))]
    x = embed_tokens(cfg, p, token)
    length = cache["len"]

    def scan_stack(x, stack, stack_cache, moe_block):
        def body(x, inp):
            bp, lc = inp
            x, nc = _decode_block(cfg, moe_block, bp, x, lc, length)
            return x, nc
        return jax.lax.scan(body, x, (stack, stack_cache))

    new_cache = {"len": length + 1}
    if cfg.moe and cfg.moe.n_dense_layers:
        x, nc = scan_stack(x, p["dense_blocks"], cache["dense_blocks"], False)
        new_cache["dense_blocks"] = nc
    x, nc = scan_stack(x, p["blocks"], cache["blocks"], bool(cfg.moe))
    new_cache["blocks"] = nc
    x = L.rmsnorm(p["head"]["final_norm"], x, cfg.norm_eps)
    return lm_logits(cfg, p, x), new_cache


def lm_prefill(cfg: ArchConfig, params: dict, tokens: jax.Array,
               remat: Optional[str] = None):
    """Full-sequence prefill: returns last-position logits + populated cache.

    Cache layout matches :func:`init_kv_cache` with max_len == S.
    """
    p = params.get("language_model") or params[next(iter(params))]
    B, S = tokens.shape
    x = embed_tokens(cfg, p, tokens)
    remat = remat if remat is not None else cfg.remat

    def scan_stack(x, stack, moe_block):
        def body(carry, bp):
            x = carry
            h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
            kv = _prefill_kv(cfg, bp["attn"], h)
            x, _ = _block_apply(cfg, moe_block, bp, x, None, 1024)
            return x, kv
        return jax.lax.scan(_remat(body, remat), x, stack)

    caches = {}
    if cfg.moe and cfg.moe.n_dense_layers:
        x, kv = scan_stack(x, p["dense_blocks"], False)
        caches["dense_blocks"] = kv
    x, kv = scan_stack(x, p["blocks"], bool(cfg.moe))
    caches["blocks"] = kv
    caches["len"] = jnp.full((B,), S, jnp.int32)
    x = L.rmsnorm(p["head"]["final_norm"], x[:, -1:], cfg.norm_eps)
    return lm_logits(cfg, p, x), caches


def _prefill_kv(cfg: ArchConfig, ap: dict, h: jax.Array) -> dict:
    """Recompute the cacheable K/V (or MLA latent) for a full sequence."""
    from repro.models.attention import _mla_qkv
    from repro.models.layers import apply_rope
    B, S, _ = h.shape
    if cfg.mla:
        _, latent, k_rope = _mla_qkv(ap, h, cfg.mla, cfg.n_heads, cfg.norm_eps)
        return {"latent": latent.astype(jnp.bfloat16),
                "k_rope": k_rope.astype(jnp.bfloat16)}
    hd = cfg.resolved_head_dim
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    k = (h @ ap["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        k = L.rmsnorm({"scale": ap["k_norm"]}, k, cfg.norm_eps)
    k = apply_rope(k, positions, cfg.rope_theta)
    v = (h @ ap["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    return {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}
