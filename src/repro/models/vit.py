"""CLIP-style ViT vision tower (real params — used by the paper-repro
llava15-7b config, where it is FROZEN during both training stages)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.spec import ActTerm, LayerSpec, ModuleSpec, ParamSpec, AXIS_EMBED
from repro.models import layers as L
from repro.models.attention import flash_attention


def vit_spec(vlm, dtype: str = "bfloat16") -> ModuleSpec:
    d = vlm.d_vision
    n_patches = (vlm.vit_image_size // vlm.vit_patch) ** 2
    patch_dim = 3 * vlm.vit_patch ** 2
    head_dim = d // vlm.vit_heads
    embed = ModuleSpec(
        name="patch_embed", modality="vision",
        layers=[
            L.linear_spec("proj", patch_dim, d, axes=(None, AXIS_EMBED)),
            LayerSpec("pos_embed", "embedding",
                      params={"w": ParamSpec((n_patches + 1, d), dtype,
                                             (None, AXIS_EMBED), init="embed"),
                              "cls": ParamSpec((d,), dtype, (AXIS_EMBED,),
                                               init="embed")},
                      acts=[], flops_per_token=0.0,
                      meta={"n_patches": n_patches}),
            L.layernorm_spec("ln_pre", d, dtype),
        ])
    block = ModuleSpec(
        name="blocks", modality="vision", repeat=vlm.vit_layers, scanned=True,
        layers=[
            L.layernorm_spec("ln1", d, dtype),
            _vit_attn_spec(d, vlm.vit_heads, head_dim, dtype),
            L.layernorm_spec("ln2", d, dtype),
            L.mlp_spec("mlp", d, vlm.vit_d_ff, dtype, gated=False),
        ])
    post = ModuleSpec(name="post", modality="vision",
                      layers=[L.layernorm_spec("ln_post", d, dtype)])
    return ModuleSpec(name="vision_tower", modality="vision",
                      children=[embed, block, post])


def _vit_attn_spec(d, n_heads, head_dim, dtype):
    from repro.models.attention import gqa_spec
    spec = gqa_spec("attn", d, n_heads, n_heads, head_dim, dtype=dtype)
    spec.meta["causal"] = False
    return spec


def vit_forward(params: dict, patches: jax.Array, vlm,
                norm_eps: float = 1e-5) -> jax.Array:
    """patches: (B, n_patches, 3*patch^2) pre-extracted pixel patches."""
    p = params["vision_tower"]
    emb = p["patch_embed"]
    x = L.linear(emb["proj"], patches)
    B = x.shape[0]
    cls = jnp.broadcast_to(emb["pos_embed"]["cls"], (B, 1, x.shape[-1]))
    x = jnp.concatenate([cls.astype(x.dtype), x], axis=1)
    x = x + emb["pos_embed"]["w"][None, :x.shape[1]]
    x = L.layernorm(emb["ln_pre"], x, norm_eps)

    blocks = p["blocks"]
    n_heads = vlm.vit_heads
    head_dim = vlm.d_vision // n_heads

    def block(x, bp):
        h = L.layernorm(bp["ln1"], x, norm_eps)
        B_, S_, _ = h.shape
        q = (h @ bp["attn"]["wq"]).reshape(B_, S_, n_heads, head_dim)
        k = (h @ bp["attn"]["wk"]).reshape(B_, S_, n_heads, head_dim)
        v = (h @ bp["attn"]["wv"]).reshape(B_, S_, n_heads, head_dim)
        ctx = flash_attention(q, k, v, False, 1024)
        x = x + ctx.reshape(B_, S_, -1) @ bp["attn"]["wo"]
        h = L.layernorm(bp["ln2"], x, norm_eps)
        x = x + L.mlp(bp["mlp"], h)
        return x, None

    x, _ = jax.lax.scan(block, x, blocks)
    x = L.layernorm(p["post"]["ln_post"], x, norm_eps)
    return x[:, 1:]                                      # drop CLS
