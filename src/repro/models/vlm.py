"""Vision-language models.

* llava-next-mistral-7b (assigned arch): STUB anyres frontend — the input is
  precomputed patch embeddings (B, n_image_tokens, d_vision); projector +
  Mistral backbone are real.
* llava15-7b (paper repro): REAL CLIP ViT-L/14 vision tower (frozen per the
  paper's training stages) + 2-layer MLP projector + Vicuna-7B.

Sequence layout: [projected image tokens | text embeddings]; loss is
computed on text positions only (image labels = -100).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.core.spec import ModuleSpec, LayerSpec, ParamSpec, AXIS_EMBED
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.vit import vit_spec, vit_forward


def projector_spec(cfg: ArchConfig) -> ModuleSpec:
    v = cfg.vlm
    layers = []
    d_in = v.d_vision
    for i in range(v.projector_layers):
        layers.append(L.linear_spec(f"fc{i}", d_in, cfg.d_model,
                                    axes=(None, AXIS_EMBED), bias=True))
        d_in = cfg.d_model
    return ModuleSpec(name="projector", modality="vision", layers=layers)


def vlm_model_spec(cfg: ArchConfig) -> ModuleSpec:
    children = []
    if cfg.vlm.vision_tower:
        children.append(vit_spec(cfg.vlm, cfg.dtype))
    children.append(projector_spec(cfg))
    children.append(T.lm_spec(cfg, name="language_model"))
    return ModuleSpec(name="vlm", modality="multimodal", children=children)


def project_image(cfg: ArchConfig, p: dict, feats: jax.Array) -> jax.Array:
    x = feats
    for i in range(cfg.vlm.projector_layers):
        x = L.linear(p["projector"][f"fc{i}"], x)
        if i < cfg.vlm.projector_layers - 1:
            x = jax.nn.gelu(x)
    return x


def vlm_embeds(cfg: ArchConfig, params: dict, batch: dict):
    """batch: {'tokens': (B, S_text), 'patch_embeds' | 'patches'} ->
    (embeds (B, S_total, D), labels offset)."""
    p = params["vlm"]
    if cfg.vlm.vision_tower:
        feats = vit_forward(p, batch["patches"], cfg.vlm, cfg.norm_eps)
    else:
        feats = batch["patch_embeds"]
    img = project_image(cfg, p, feats).astype(jnp.dtype(cfg.dtype))
    txt = T.embed_tokens(cfg, p["language_model"], batch["tokens"])
    return jnp.concatenate([img, txt], axis=1)


def vlm_loss(cfg: ArchConfig, params: dict, batch: dict,
             remat: Optional[str] = None):
    p = params["vlm"]
    embeds = vlm_embeds(cfg, params, batch)
    B, S_total, _ = embeds.shape
    n_img = S_total - batch["tokens"].shape[1]
    hidden, aux = T.lm_backbone(cfg, p["language_model"], embeds, remat=remat)
    labels = jnp.concatenate(
        [jnp.full((B, n_img), -100, jnp.int32), batch["labels"]], axis=1)
    loss_sum, n_tok = T.chunked_xent(cfg, p["language_model"], hidden, labels)
    loss = loss_sum / jnp.maximum(n_tok, 1.0)
    if cfg.moe:
        loss = loss + 0.01 * aux / max(cfg.n_layers, 1)
    return loss, {"xent": loss, "aux": aux, "n_tok": n_tok}


def vlm_prefill(cfg: ArchConfig, params: dict, batch: dict):
    """Prefill over [image tokens | text]; returns logits + cache."""
    p = params["vlm"]
    embeds = vlm_embeds(cfg, params, batch)
    # Reuse the LM prefill by driving the backbone directly.
    lm = p["language_model"]
    B, S, _ = embeds.shape

    def scan_stack(x, stack):
        def body(carry, bp):
            x = carry
            h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
            kv = T._prefill_kv(cfg, bp["attn"], h)
            x, _ = T._block_apply(cfg, bool(cfg.moe), bp, x, None, 1024)
            return x, kv
        return jax.lax.scan(T._remat(body, cfg.remat), x, stack)

    x, kv = scan_stack(embeds, lm["blocks"])
    cache = {"blocks": kv, "len": jnp.full((B,), S, jnp.int32)}
    x = L.rmsnorm(lm["head"]["final_norm"], x[:, -1:], cfg.norm_eps)
    return T.lm_logits(cfg, lm, x), cache


def vlm_decode_step(cfg: ArchConfig, params: dict, token: jax.Array,
                    cache: dict):
    return T.lm_decode_step(cfg, {"language_model":
                                  params["vlm"]["language_model"]},
                            token, cache)
