from repro.runtime.fault_tolerance import ResilientTrainer, FaultConfig  # noqa: F401
