"""Fault-tolerant training driver: checkpoint/restart, failure recovery,
straggler mitigation, elastic rescaling.

On a real cluster the failure signal comes from the coordination service
(heartbeat loss); here the driver exposes the same control flow with an
injectable failure source so the logic is testable:

* every ``ckpt_every`` steps the state is checkpointed asynchronously;
* a step failure (device loss / preemption) triggers restore-from-latest
  and replay — the deterministic pipeline regenerates the exact batches;
* per-step wall times feed an EWMA straggler detector; a flagged shard's
  data range is reassigned to healthy hosts (deterministic re-partition);
* ``rescale(new_n_shards)`` re-partitions data and re-shards the restored
  state onto a new mesh (elastic scaling) — checkpoints are mesh-agnostic.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import numpy as np

from repro.checkpoint import Checkpointer


@dataclass
class FaultConfig:
    ckpt_every: int = 50
    max_restarts: int = 3
    straggler_factor: float = 2.0    # step slower than factor*EWMA => flag
    ewma_alpha: float = 0.2


@dataclass
class ResilientTrainer:
    """Drives ``train_step`` with checkpoint/restart semantics."""

    train_step: Callable              # (state, batch) -> (state, metrics)
    pipeline: Any                     # data pipeline (shard_batch/global_batch)
    checkpointer: Checkpointer
    fault_cfg: FaultConfig = field(default_factory=FaultConfig)
    make_batch: Optional[Callable] = None   # step -> batch (overrides pipeline)
    failure_injector: Optional[Callable] = None  # step -> bool (tests)
    on_straggler: Optional[Callable] = None
    # memory autopilot hook (repro.autopilot.Autopilot) + its telemetry
    # source (step -> observed bytes / dryrun record / None).  When both
    # are set, every step is admission-controlled: the autopilot
    # observes BEFORE the step runs so a mitigation lands ahead of the
    # allocation that would have OOMed, and every restart re-validates
    # the mesh through planner.check_parallel via on_restart.
    autopilot: Optional[Any] = None
    memory_source: Optional[Callable] = None

    _ewma: Optional[float] = None
    restarts: int = 0                       # lifetime stat (never resets)
    _consecutive_failures: int = 0          # the abort budget
    straggler_events: list = field(default_factory=list)

    def _batch(self, step: int):
        if self.make_batch is not None:
            return self.make_batch(step)
        return self.pipeline.global_batch(step)

    def run(self, state, start_step: int, n_steps: int,
            log_every: int = 0) -> tuple[Any, list]:
        history = []
        step = start_step
        while step < start_step + n_steps:
            if self.autopilot is not None and self.memory_source is not None:
                # admission control: classify the upcoming step's memory
                # before launching it, so a mitigation beats the OOM
                self.autopilot.observe(step, self.memory_source(step))
            batch = self._batch(step)
            t0 = time.monotonic()
            try:
                if self.failure_injector and self.failure_injector(step):
                    raise RuntimeError(f"injected failure at step {step}")
                state, metrics = self.train_step(state, batch)
            except Exception:
                # `restarts` is the lifetime stat; the abort decision
                # rides the CONSECUTIVE counter (reset on success), so a
                # long run with occasional recovered failures is never
                # killed by its uptime.
                self.restarts += 1
                self._consecutive_failures += 1
                if self._consecutive_failures > self.fault_cfg.max_restarts:
                    raise
                restored_step, restored = self.checkpointer.restore_latest(
                    like=state)
                if restored is not None:
                    state = restored
                    step = int(restored_step)
                # else: replay from start_step state (no ckpt yet)
                if self.autopilot is not None:
                    self.autopilot.on_restart(step)
                continue
            self._consecutive_failures = 0
            dt = time.monotonic() - t0
            self._track_stragglers(step, dt)
            history.append({"step": step, **{k: float(np.asarray(v))
                                             for k, v in metrics.items()}})
            step += 1
            if step % self.fault_cfg.ckpt_every == 0:
                self.checkpointer.save_async(step, state)
            if log_every and step % log_every == 0:
                print(f"step {step}: " + ", ".join(
                    f"{k}={v:.4f}" for k, v in history[-1].items()
                    if k != "step"))
        self.checkpointer.save_async(step, state)
        self.checkpointer.wait()
        return state, history

    def _track_stragglers(self, step: int, dt: float) -> None:
        if self._ewma is None:
            self._ewma = dt
            return
        if dt > self.fault_cfg.straggler_factor * self._ewma:
            self.straggler_events.append((step, dt, self._ewma))
            if self.on_straggler:
                self.on_straggler(step, dt)
            # Mitigation: deterministic pipeline lets healthy hosts take
            # over the slow shard's row range next step — rotate onto
            # the NEXT shard, which is always a different, valid id.
            if hasattr(self.pipeline, "n_shards") \
                    and self.pipeline.n_shards > 1:
                self.pipeline.shard_id = ((self.pipeline.shard_id + 1)
                                          % self.pipeline.n_shards)
        a = self.fault_cfg.ewma_alpha
        self._ewma = (1 - a) * self._ewma + a * dt

    # -- elastic scaling ----------------------------------------------------
    def rescale(self, new_n_shards: int) -> None:
        """Re-partition the data pipeline for a new host count; state
        resharding happens at restore time via mesh-agnostic checkpoints.
        With an autopilot attached the elastic resize re-validates the
        mesh (planner.check_parallel) before the run resumes."""
        self.pipeline.n_shards = new_n_shards
        self.pipeline.shard_id = min(self.pipeline.shard_id,
                                     new_n_shards - 1)
        if self.autopilot is not None:
            self.autopilot.on_restart(-1)
