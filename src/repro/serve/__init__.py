"""Serving: jit'd prefill/decode steps plus the fleet memory model.

``pool``/``fleet`` are pure-python (importable without jax — the sweep
and planner paths need them cheaply); the jax-backed serve-step entry
points are re-exported lazily so ``from repro.serve import pool`` never
pays for (or requires) a jax import.
"""

from repro.serve.fleet import RequestMix, expected_len, parse_mix  # noqa: F401
from repro.serve.pool import (PAGE_TOKENS, PoolAccounting,  # noqa: F401
                              ServeSpec, pool_accounting, pool_blocks,
                              pool_tokens)

_STEP_EXPORTS = ("make_prefill_step", "make_decode_step", "generate",
                 "pad_cache")


def __getattr__(name):
    if name in _STEP_EXPORTS:
        from repro.serve import serve_step
        return getattr(serve_step, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_STEP_EXPORTS))
