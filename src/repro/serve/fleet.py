"""Request-mix math for the serving-fleet memory model.

A production decode fleet runs CONTINUOUS BATCHING: at any instant the
in-flight batch mixes requests that are still prefilling with requests
that are decoding, and their context lengths follow the live traffic
distribution rather than one fixed ``seq_len``.  :class:`RequestMix`
captures that occupancy as two exact-integer knobs:

* ``prefill_bp`` — basis points (x1e-4) of in-flight requests currently
  in their prefill phase.  A chunk-prefilled request has, on average,
  written about half its final context into the pool, so prefill-phase
  slots are charged ``len // 2`` tokens (the chunked-prefill midpoint);
  decode-phase slots hold their full context.
* ``hist`` — a ``((seq_len, weight), ...)`` histogram of final context
  lengths.  Empty means "every request runs to the cell's seq_len".

Everything here is plain-integer arithmetic (no floats) so the scalar
predictor and the columnar batch engine provably agree byte-for-byte.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

BP = 10000  # basis-point denominator: all rates are ints x 1e-4


@dataclass(frozen=True)
class RequestMix:
    """In-flight request-mix distribution (see module docstring)."""

    prefill_bp: int = 0                       # prefill-phase share, x1e-4
    hist: Tuple[Tuple[int, int], ...] = ()    # ((final_len, weight), ...)

    def __post_init__(self):
        if not (0 <= self.prefill_bp <= BP):
            raise ValueError(
                f"mix prefill fraction {self.prefill_bp / BP} outside "
                f"[0, 1]")
        for length, weight in self.hist:
            if length <= 0 or weight <= 0:
                raise ValueError(
                    f"mix histogram entries need positive length and "
                    f"weight, got ({length}, {weight})")

    @classmethod
    def make(cls, prefill_frac: float = 0.0,
             hist: Tuple[Tuple[int, int], ...] = ()) -> "RequestMix":
        return cls(prefill_bp=int(round(prefill_frac * BP)),
                   hist=tuple((int(l), int(w)) for l, w in hist))

    @property
    def is_identity(self) -> bool:
        """True when this mix cannot change expected tokens-per-slot."""
        return self.prefill_bp == 0 and not self.hist


def expected_len(seq_len: int, mix: Optional[RequestMix]) -> int:
    """Expected live context tokens held by one in-flight request slot.

    Histogram lengths are capped at ``seq_len`` (a slot can never hold
    more context than the cell's KV capacity); prefill-phase slots are
    charged the chunked-prefill midpoint ``len // 2``.  Exact integer
    arithmetic, floor-rounded, clamped to >= 1.
    """
    seq_len = int(seq_len)
    if mix is None or mix.is_identity:
        return seq_len
    hist = mix.hist or ((seq_len, 1),)
    num = sum(min(int(l), seq_len) * int(w) for l, w in hist)
    den = sum(int(w) for _, w in hist)
    decode_bp = BP - mix.prefill_bp
    # E[tokens] = E[len]*(1-p) + E[len//2]*p, all floor arithmetic
    half = sum((min(int(l), seq_len) // 2) * int(w) for l, w in hist)
    return max((num * decode_bp + half * mix.prefill_bp) // (BP * den), 1)


def parse_mix(text: str) -> Optional[RequestMix]:
    """Parse the CLI mix syntax ``P[:LxW,LxW,...]``.

    ``P`` is the prefill fraction in [0, 1]; the optional histogram lists
    ``final_len x weight`` pairs.  Examples::

        0.3                      # 30% prefilling, contexts at seq_len
        0.25:512x1,2048x3        # plus a 1:3 length histogram
    """
    text = text.strip()
    if not text:
        return None
    head, _, tail = text.partition(":")
    try:
        frac = float(head)
    except ValueError:
        raise ValueError(f"bad mix {text!r}: prefill fraction {head!r} "
                         f"is not a number") from None
    hist = []
    if tail:
        for part in tail.split(","):
            l, x, w = part.partition("x")
            if not x:
                raise ValueError(f"bad mix {text!r}: histogram entry "
                                 f"{part!r} is not LENxWEIGHT")
            hist.append((int(l), int(w)))
    mix = RequestMix.make(frac, tuple(hist))
    return None if mix.is_identity else mix
