"""Block-paged KV-pool accounting for the serving-fleet memory model.

xMem-style observation: on a real serving fleet the per-layer KV math is
the easy part — what dominates estimation error is the ALLOCATOR: the
KV cache lives in a pool of fixed-size token blocks (vLLM-style paged
attention), shared-prefix blocks are deduplicated by the prefix cache,
and the pool runs below 100% utilization because of fragmentation and
reservation slack.  :class:`ServeSpec` captures those knobs, and
:func:`pool_tokens` folds them into ONE effective tokens-per-sequence
count that the predictor substitutes for ``slen`` in every paged cache
term (the ``pool_tok`` TermSpec variable).

All rates are stored as exact basis-point integers so the scalar and
columnar prediction paths are byte-identical by construction.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.serve.fleet import BP, RequestMix, expected_len

#: paged-KV blocks must be a positive multiple of this token quantum so
#: block tables stay lane-aligned with the page-aligned head dims
PAGE_TOKENS = 8


@dataclass(frozen=True)
class ServeSpec:
    """Serving-fleet knobs for one sweep cell (all-neutral == absent).

    ``block_size=0`` means contiguous (unpaged) allocation; ``util_bp``
    is pool utilization x1e-4 (allocated bytes are inflated by its
    inverse); ``hit_bp`` x1e-4 of the shared ``prefix_len``-token prefix
    is served from the prefix cache instead of per-sequence blocks;
    ``mix`` reshapes tokens-per-slot for continuous batching;
    ``draft_arch`` adds speculative-decode draft-model residency.
    """

    block_size: int = 0
    util_bp: int = BP
    hit_bp: int = 0
    prefix_len: int = 0
    mix: Optional[RequestMix] = None
    draft_arch: str = ""

    def __post_init__(self):
        if self.block_size < 0 or (
                self.block_size and self.block_size % PAGE_TOKENS):
            raise ValueError(
                f"block_size {self.block_size} is not page-aligned: "
                f"paged-KV blocks must be a positive multiple of "
                f"{PAGE_TOKENS} tokens (0 = contiguous)")
        if not (0 < self.util_bp <= BP):
            raise ValueError(
                f"pool utilization {self.util_bp / BP} outside (0, 1]")
        if not (0 <= self.hit_bp <= BP):
            raise ValueError(
                f"prefix-cache hit rate {self.hit_bp / BP} outside [0, 1]")
        if self.prefix_len < 0:
            raise ValueError(f"prefix_len {self.prefix_len} is negative")
        if self.hit_bp and self.prefix_len <= 0:
            raise ValueError(
                f"prefix-cache hit rate {self.hit_bp / BP} needs a "
                f"positive --prefix-len (the shared-prefix token count)")

    @classmethod
    def make(cls, block_size: int = 0, utilization: float = 1.0,
             prefix_hit_rate: float = 0.0, prefix_len: int = 0,
             mix: Optional[RequestMix] = None,
             draft_arch: str = "") -> "ServeSpec":
        """Float-friendly constructor; rates are rounded to basis points."""
        return cls(block_size=int(block_size),
                   util_bp=int(round(utilization * BP)),
                   hit_bp=int(round(prefix_hit_rate * BP)),
                   prefix_len=int(prefix_len),
                   mix=mix, draft_arch=draft_arch)

    @property
    def is_neutral(self) -> bool:
        """True when every knob is at the value that cannot change any
        byte — such a spec is normalized to None so prior cells stay
        bit-identical."""
        return (self.block_size == 0 and self.util_bp == BP
                and self.hit_bp == 0
                and (self.mix is None or self.mix.is_identity)
                and not self.draft_arch)


@dataclass(frozen=True)
class PoolAccounting:
    """Exact token ledger for one sequence slot in the paged pool.

    Conservation invariant (tested property):
    ``pool_tokens == unique + pad_slack + frag_slack``.
    """

    live: int          # expected live context tokens (after the mix)
    shared: int        # prefix tokens eligible for prefix-cache sharing
    unique: int        # tokens this slot must actually store
    blocks: int        # allocated blocks (0 when contiguous)
    alloc_tokens: int  # block-granular allocation (== unique when contiguous)
    pool_tokens: int   # allocation inflated by 1/utilization
    pad_slack: int     # alloc_tokens - unique (last-block padding)
    frag_slack: int    # pool_tokens - alloc_tokens (fragmentation share)


def pool_accounting(seq_len: int, spec: ServeSpec) -> PoolAccounting:
    """Full block-pool ledger for one sequence at context ``seq_len``.

    A paged pool is sized in WHOLE blocks: the 1/utilization inflation
    applies to the block count, so ``pool_tokens`` stays block-aligned
    (a pool with dangling partial blocks is not something a block
    allocator can hand out — and alignment also keeps the ``cache_seq``
    shard divisibility of the pool terms independent of the hit rate).
    Contiguous allocation (``block_size=0``) inflates raw tokens."""
    live = expected_len(seq_len, spec.mix)
    shared = min(spec.prefix_len, live) if spec.hit_bp else 0
    unique = live - spec.hit_bp * shared // BP
    if spec.block_size:
        blocks = -(-unique // spec.block_size)
        alloc = blocks * spec.block_size
        pool = -(-blocks * BP // spec.util_bp) * spec.block_size
    else:
        blocks = 0
        alloc = unique
        pool = -(-alloc * BP // spec.util_bp)  # ceil: under-utilized pool
    return PoolAccounting(live=live, shared=shared, unique=unique,
                          blocks=blocks, alloc_tokens=alloc,
                          pool_tokens=pool, pad_slack=alloc - unique,
                          frag_slack=pool - alloc)


def pool_tokens(seq_len: int, spec: Optional[ServeSpec]) -> int:
    """Effective pool tokens per sequence — the ``pool_tok`` TermSpec
    variable.  ``spec=None`` (no serve knobs) degenerates to ``seq_len``
    exactly, so neutral cells stay bit-identical to prior main."""
    if spec is None:
        return int(seq_len)
    return pool_accounting(seq_len, spec).pool_tokens


def pool_blocks(seq_len: int, spec: Optional[ServeSpec]) -> int:
    """Allocated blocks per sequence (0 for contiguous / no serve)."""
    if spec is None:
        return 0
    return pool_accounting(seq_len, spec).blocks
