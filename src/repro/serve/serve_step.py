"""Serving steps: batched prefill and single-token decode with donated
caches.  The paper's §5 names inference KV-cache memory as future work —
this module (with core.predictor's cache factor) implements it.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.registry import Model


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch):
        logits, cache = model.prefill(params, batch)
        return logits, cache
    return prefill_step


def make_decode_step(model: Model) -> Callable:
    """decode_step(params, token, cache) -> (next_token, logits, cache).

    Cache is donated by the launcher (argnums set at jit time) so the
    update aliases in place — the memory the predictor models.
    """
    def decode_step(params, token, cache):
        logits, new_cache = model.decode_step(params, token, cache)
        next_token = jnp.argmax(logits[:, -1], axis=-1)[:, None] \
            .astype(jnp.int32)
        return next_token, logits, new_cache
    return decode_step


# cache leaves with a growable sequence dim (axis 2 of (L, B, S, ...)).
_SEQ_KEYS = {"k", "v", "latent", "k_rope"}


def pad_cache(cache, extra: int):
    """Grow KV-style cache capacity by ``extra`` positions.

    Prefill builds a cache sized to the prompt; decoding needs headroom.
    Only sequence-indexed leaves grow — SSM states, conv windows and
    encoder cross-attention memories are length-free / fixed.
    """
    def walk(node):
        if isinstance(node, dict):
            return {k: (jnp.pad(v, [(0, 0), (0, 0), (0, extra)]
                                + [(0, 0)] * (v.ndim - 3))
                        if k in _SEQ_KEYS and hasattr(v, "ndim")
                        and v.ndim >= 3 else walk(v))
                    for k, v in node.items()}
        return node

    return walk(cache)


def generate(model: Model, params, batch, max_new_tokens: int = 16):
    """Greedy generation loop (examples / tests)."""
    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model), donate_argnums=(2,))
    logits, cache = prefill(params, batch)
    cache = pad_cache(cache, max_new_tokens)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = [tok]
    for _ in range(max_new_tokens - 1):
        tok, _, cache = decode(params, tok, cache)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
