"""Optimizers: AdamW (fp32 master + m + v), 8-bit Adam (int8 m/v with
per-block fp32 scales — a distributed-memory trick), and Adafactor
(factored second moment — required to fit arctic-480b on a v5e pod).

States are plain pytrees so ZeRO sharding is purely a matter of the
NamedShardings the launcher assigns (see launch.mesh.opt_shardings); the
byte accounting here is mirrored exactly by core.factors.opt_bytes_for.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

BLOCK = 256  # 8-bit Adam quantization block


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # adamw | adamw8bit | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    master_fp32: bool = True       # adam variants keep an fp32 master copy


# ---------------------------------------------------------------------------
# 8-bit block quantization helpers
# ---------------------------------------------------------------------------


def _quant8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0
    q = jnp.round(fp / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale[:, 0]


def _dequant8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    x = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    return x[: _size(shape)].reshape(shape)


def _size(shape) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


# ---------------------------------------------------------------------------
# per-leaf state init
# ---------------------------------------------------------------------------


def _leaf_state(p: jax.Array, cfg: OptimizerConfig) -> dict:
    if cfg.name == "adamw":
        st = {"m": jnp.zeros(p.shape, jnp.float32),
              "v": jnp.zeros(p.shape, jnp.float32)}
    elif cfg.name == "adamw8bit":
        nblk = -(-_size(p.shape) // BLOCK)
        st = {"m_q": jnp.zeros((nblk, BLOCK), jnp.int8),
              "m_s": jnp.zeros((nblk,), jnp.float32),
              "v_q": jnp.zeros((nblk, BLOCK), jnp.int8),
              "v_s": jnp.zeros((nblk,), jnp.float32)}
    elif cfg.name == "adafactor":
        if p.ndim >= 2:
            st = {"v_row": jnp.zeros(p.shape[:-1], jnp.float32),
                  "v_col": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                     jnp.float32)}
        else:
            st = {"v": jnp.zeros(p.shape, jnp.float32)}
    else:
        raise ValueError(cfg.name)
    if cfg.name in ("adamw", "adamw8bit") and cfg.master_fp32:
        st["master"] = p.astype(jnp.float32)
    return st


def init_opt_state(trainable: Any, cfg: OptimizerConfig) -> Any:
    return jax.tree.map(
        lambda p: _leaf_state(p, cfg) if p is not None else None,
        trainable, is_leaf=lambda x: x is None)


def opt_state_specs(trainable_specs: Any, cfg: OptimizerConfig) -> Any:
    """ShapeDtypeStruct twin of init_opt_state (for dry-runs)."""
    def leaf(p):
        if p is None:
            return None
        st = jax.eval_shape(lambda q: _leaf_state(q, cfg),
                            jax.ShapeDtypeStruct(p.shape, p.dtype))
        return st
    return jax.tree.map(leaf, trainable_specs, is_leaf=lambda x: x is None)


# ---------------------------------------------------------------------------
# update
# ---------------------------------------------------------------------------


def _adam_update(g, m, v, step, cfg: OptimizerConfig):
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    mhat = m / (1 - cfg.b1 ** step)
    vhat = v / (1 - cfg.b2 ** step)
    return mhat / (jnp.sqrt(vhat) + cfg.eps), m, v


def _leaf_update(p, g, st, step, cfg: OptimizerConfig):
    g = g.astype(jnp.float32)
    master = st.get("master") if isinstance(st, dict) else None
    x = master if master is not None else p.astype(jnp.float32)

    if cfg.name == "adamw":
        upd, m, v = _adam_update(g, st["m"], st["v"], step, cfg)
        new = {"m": m, "v": v}
    elif cfg.name == "adamw8bit":
        m = _dequant8(st["m_q"], st["m_s"], p.shape)
        # v is stored in sqrt-space: halves the dynamic range an int8 grid
        # must cover, which is what keeps 8-bit Adam tracking fp32 Adam.
        v = _dequant8(st["v_q"], st["v_s"], p.shape) ** 2
        upd, m, v = _adam_update(g, m, v, step, cfg)
        mq, ms = _quant8(m)
        vq, vs = _quant8(jnp.sqrt(v))
        new = {"m_q": mq, "m_s": ms, "v_q": vq, "v_s": vs}
    else:  # adafactor
        g2 = g * g + 1e-30
        if p.ndim >= 2:
            v_row = cfg.b2 * st["v_row"] + (1 - cfg.b2) * g2.mean(-1)
            v_col = cfg.b2 * st["v_col"] + (1 - cfg.b2) * g2.mean(-2)
            r = v_row / jnp.maximum(v_row.mean(-1, keepdims=True), 1e-30)
            vhat = r[..., None] * v_col[..., None, :]
            new = {"v_row": v_row, "v_col": v_col}
        else:
            vhat = cfg.b2 * st["v"] + (1 - cfg.b2) * g2
            new = {"v": vhat}
        upd = g / jnp.sqrt(vhat + cfg.eps)
        # update clipping (Adafactor RMS rule)
        rms = jnp.sqrt(jnp.mean(upd * upd) + 1e-30)
        upd = upd / jnp.maximum(1.0, rms)

    x = x - cfg.lr * (upd + cfg.weight_decay * x)
    if master is not None:
        new["master"] = x
    return x.astype(p.dtype), new


def _stackable(p, s) -> bool:
    """Depth-stacked leaf whose state slices per layer (scan-chunkable)."""
    if p.ndim < 3 or p.shape[0] <= 1:
        return False
    return all(hasattr(v, "shape") and v.shape[:1] == p.shape[:1]
               for v in s.values())


def _leaf_update_chunked(p, g, s, step, cfg: OptimizerConfig):
    """Scan the update over the depth-stack dim.

    The fp32 math temps of a monolithic update materialize the WHOLE
    stacked weight in fp32 (observed +20 GiB across Adafactor temps on
    arctic-480b); scanning yields one layer's temps at a time.  For
    Adafactor the per-layer RMS clip is the semantically correct reading
    of the per-tensor rule for stacked distinct layers.
    """
    def body(_, xs):
        p_i, g_i, s_i = xs
        np_i, ns_i = _leaf_update(p_i, g_i, s_i, step, cfg)
        return None, (np_i, ns_i)

    _, (new_p, new_s) = jax.lax.scan(body, None, (p, g, s))
    return new_p, new_s


def apply_updates(trainable: Any, grads: Any, state: Any, step: jax.Array,
                  cfg: OptimizerConfig, chunked: bool = True) -> tuple[Any, Any]:
    """Returns (new_trainable, new_state); None leaves pass through."""
    flat_p, treedef = jax.tree.flatten(trainable,
                                       is_leaf=lambda x: x is None)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state)
    new_p, new_s = [], []
    for p, g, s in zip(flat_p, flat_g, flat_s):
        if p is None:
            new_p.append(None)
            new_s.append(None)
            continue
        if chunked and cfg.name != "adamw8bit" and _stackable(p, s):
            np_, ns = _leaf_update_chunked(p, g, s, step, cfg)
        else:
            np_, ns = _leaf_update(p, g, s, step, cfg)
        new_p.append(np_)
        new_s.append(ns)
    return (jax.tree.unflatten(treedef, new_p),
            jax.tree.unflatten(treedef, new_s))
