"""Training step: trainable/frozen partition (the paper's multimodal
training behaviour), gradient accumulation with a ZeRO-sharded accumulator,
optional int8 gradient wire-compression, donated state.

The step is a single compiled XLA program: grads are produced in the param
sharding (TP), constrained to the ZeRO spec (reduce-scatter over ``data``)
before the optimizer update, and the updated params are broadcast back
(all-gather) — DeepSpeed ZeRO-2 semantics expressed as pjit shardings.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.mesh_ctx import current_mesh, named_sharding
from repro.models import param as PM
from repro.models.registry import Model
from repro.core.spec import TrainPolicy
from repro.train.optimizer import (OptimizerConfig, apply_updates,
                                   init_opt_state)


@dataclass
class TrainState:
    params: Any          # full model params (compute dtype)
    opt: Any             # optimizer state for trainable leaves
    step: jax.Array


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt, s.step), None),
    lambda aux, ch: TrainState(*ch))


def init_train_state(model: Model, policy: TrainPolicy,
                     opt_cfg: OptimizerConfig, key: jax.Array) -> TrainState:
    params = model.init(key)
    mask = PM.trainable_mask(model.spec, policy)
    trainable, _ = PM.partition_params(params, mask)
    opt = init_opt_state(trainable, opt_cfg)
    return TrainState(params=params, opt=opt,
                      step=jnp.zeros((), jnp.int32))


def _compress_grads_int8(grads):
    """Emulated wire compression: quantize/dequantize gradients (the real
    deployment compresses the reduce-scatter payload; numerics match)."""
    def q(g):
        if g is None:
            return None
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        return (jnp.round(g / scale).astype(jnp.int8).astype(g.dtype)
                * scale)
    return jax.tree.map(q, grads, is_leaf=lambda x: x is None)


def _constrain(tree, shardings):
    if shardings is None:
        return tree
    return jax.tree.map(
        lambda x, s: x if (x is None or s is None)
        else jax.lax.with_sharding_constraint(x, s),
        tree, shardings, is_leaf=lambda x: x is None)


def make_train_step(model: Model, policy: TrainPolicy,
                    opt_cfg: OptimizerConfig, *,
                    grad_accum: int = 1,
                    zero_shardings: Any = None,
                    compress_grads: bool = False,
                    remat: Optional[str] = None) -> Callable:
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    ``batch`` leaves are (global_batch, ...); with ``grad_accum > 1`` they
    must be reshapeable to (accum, global_batch/accum, ...).
    ``zero_shardings``: optional pytree of NamedShardings (trainable layout)
    applied to grads/accumulators — the ZeRO-2 reduce-scatter point.
    """
    mask = PM.trainable_mask(model.spec, policy)

    def loss_for(trainable, frozen, batch):
        params = PM.merge_params(trainable, frozen)
        loss, metrics = model.loss(params, batch, remat=remat)
        return loss, metrics

    def train_step(state: TrainState, batch: dict):
        trainable, frozen = PM.partition_params(state.params, mask)
        grad_fn = jax.value_and_grad(loss_for, has_aux=True)

        if grad_accum == 1:
            (loss, metrics), grads = grad_fn(trainable, frozen, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((grad_accum, -1) + x.shape[1:]), batch)

            def accum_body(carry, mb):
                acc, loss_sum = carry
                (loss, _), g = grad_fn(trainable, frozen, mb)
                g = _constrain(jax.tree.map(
                    lambda a, b: None if a is None else a + b,
                    acc, g, is_leaf=lambda x: x is None), zero_shardings)
                return (g, loss_sum + loss), None

            zeros = jax.tree.map(
                lambda p: None if p is None
                else jnp.zeros(p.shape, jnp.float32),
                trainable, is_leaf=lambda x: x is None)
            zeros = _constrain(zeros, zero_shardings)
            (grads, loss_sum), _ = jax.lax.scan(
                accum_body, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(
                lambda g: None if g is None else g / grad_accum,
                grads, is_leaf=lambda x: x is None)
            loss = loss_sum / grad_accum
            metrics = {"xent": loss}

        if compress_grads:
            grads = _compress_grads_int8(grads)
        grads = _constrain(grads, zero_shardings)

        step = state.step + 1
        new_trainable, new_opt = apply_updates(
            trainable, grads, state.opt, step.astype(jnp.float32), opt_cfg)
        params = PM.merge_params(new_trainable, frozen)
        metrics = dict(metrics, loss=loss,
                       grad_norm=_global_norm(grads))
        return TrainState(params=params, opt=new_opt, step=step), metrics

    return train_step


def _global_norm(grads) -> jax.Array:
    leaves = [g for g in jax.tree.leaves(
        grads, is_leaf=lambda x: x is None) if g is not None]
    if not leaves:
        return jnp.zeros((), jnp.float32)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))
