# Package marker so `python -m tests.regen_golden` works from the repo
# root; pytest still discovers test modules normally (rootdir on sys.path).
