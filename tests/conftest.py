"""Shared fixtures.  Tests run on the default 1-CPU-device backend; tests
needing a small multi-device mesh spawn it via the xdist-safe subprocess
helpers or use the 1x1 mesh (same code paths, degenerate sizes).

NOTE: --xla_force_host_platform_device_count is deliberately NOT set here —
only launch/dryrun.py uses placeholder devices (per the brief).  Tests that
need >1 device run in a subprocess (see test_moe_ep / test_distributed).
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n_devices: int = 4) -> str:
    """Run a python snippet in a subprocess with N fake CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = os.path.abspath(SRC)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900)
    if r.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{r.stdout}\n{r.stderr}")
    return r.stdout


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tiny_batch(model, shape, key=None):
    """Concrete random batch matching model.batch_spec(shape)."""
    key = key if key is not None else jax.random.PRNGKey(1)
    out = {}
    for name, sd in model.batch_spec(shape).items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(sd.dtype, jnp.integer):
            out[name] = jax.random.randint(sub, sd.shape, 0,
                                           model.cfg.vocab, sd.dtype)
        else:
            out[name] = jax.random.normal(sub, sd.shape, sd.dtype) * 0.3
    return out
