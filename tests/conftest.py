"""Shared fixtures.  Tests run on the default 1-CPU-device backend; tests
needing a small multi-device mesh spawn it via the xdist-safe subprocess
helpers or use the 1x1 mesh (same code paths, degenerate sizes).

NOTE: --xla_force_host_platform_device_count is deliberately NOT set here —
only launch/dryrun.py uses placeholder devices (per the brief).  Tests that
need >1 device run in a subprocess (see test_moe_ep / test_distributed).

Session-scoped caches (tier-1 wall-clock): building a reduced model and
``model.init``-ing its params costs ~2s per arch, and the full-size spec
trees / parse tables behind the predictor parity tests are pure functions
of (arch, policy) — both used to be rebuilt per test.  ``reduced_zoo``
and ``sweep_engine`` build each exactly once per session; everything they
hand out is treated as read-only by convention (jax arrays are immutable,
parse tables are frozen dataclass rows).
"""

import os
import subprocess
import sys

# Tier-1 runs on XLA:CPU and only asserts NUMERICS, never executable
# speed — so skip XLA's backend optimization passes, which dominate the
# per-arch train-step compile times (full suite ~142s -> ~100s).  Must
# happen before the first `import jax` of the session (conftest is);
# appended so an explicit user XLA_FLAGS still wins.
_OPT_FLAG = "--xla_backend_optimization_level=0"
if "--xla_backend_optimization_level" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = \
        (os.environ.get("XLA_FLAGS", "") + " " + _OPT_FLAG).strip()

import jax
import jax.numpy as jnp
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# Shared hypothesis profile for the property suites (test_batch_property,
# test_mesh_ctx, test_serve_property, test_stages_property,
# test_monotone_property): fixed seed (derandomize), no deadline flakes
# on shared CI runners, explicit example budget.  Local runs without
# hypothesis installed skip those suites via importorskip — the ONLY
# self-skips tier-1 carries — but in CI that skip is a silent coverage
# hole, so with CI=1 a missing hypothesis is a hard session error:
# requirements-dev.txt installs it, and this assert guarantees the
# property suites leave zero self-skips on every CI run.
try:
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile(
        "ci", derandomize=True, deadline=None, max_examples=50,
        print_blob=True)
    _hyp_settings.load_profile(
        os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:                                   # pragma: no cover
    if os.environ.get("CI"):
        raise RuntimeError(
            "CI=1 but hypothesis is not importable: the property suites "
            "would self-skip. Install requirements-dev.txt.")


def run_with_devices(code: str, n_devices: int = 4) -> str:
    """Run a python snippet in a subprocess with N fake CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices}"
                        f" {_OPT_FLAG}")
    env["PYTHONPATH"] = os.path.abspath(SRC)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900)
    if r.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{r.stdout}\n{r.stderr}")
    return r.stdout


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tiny_batch(model, shape, key=None):
    """Concrete random batch matching model.batch_spec(shape)."""
    key = key if key is not None else jax.random.PRNGKey(1)
    out = {}
    for name, sd in model.batch_spec(shape).items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(sd.dtype, jnp.integer):
            out[name] = jax.random.randint(sub, sd.shape, 0,
                                           model.cfg.vocab, sd.dtype)
        else:
            out[name] = jax.random.normal(sub, sd.shape, sd.dtype) * 0.3
    return out


# ---------------------------------------------------------------------------
# session-scoped model/engine caches
# ---------------------------------------------------------------------------


class ReducedZoo:
    """Memoized (cfg.reduced(), model, params) per arch — the expensive
    trio behind every per-arch smoke test.  Params are initialized ONCE
    with PRNGKey(0), exactly what each test did individually."""

    def __init__(self):
        self._cache = {}

    def __call__(self, arch: str):
        hit = self._cache.get(arch)
        if hit is None:
            from repro.configs import get_config
            from repro.models import build_model
            cfg = get_config(arch).reduced()
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            hit = self._cache[arch] = (cfg, model, params)
        return hit


@pytest.fixture(scope="session")
def reduced_zoo():
    return ReducedZoo()


@pytest.fixture(scope="session")
def sweep_engine():
    """One shared SweepEngine: memoizes full-size spec trees, parse
    tables, and component groups across every predictor/parity test.
    Cached cells are byte-identical to cold evaluation by construction
    (asserted by test_sweep_cache_hits_are_identical_to_cold)."""
    from repro.core.sweep import SweepEngine
    return SweepEngine()


@pytest.fixture(scope="session")
def zoo_rows(sweep_engine):
    """Memoized full-size (cfg, model, rows) per (arch, policy) — the
    parse tables the partitioner/factor tests walk."""
    from repro.core.spec import FULL_TRAIN

    def get(arch, policy=FULL_TRAIN):
        return sweep_engine._arch_state(arch, policy)

    return get
