"""Golden-snapshot generator: the frozen per-component byte breakdown.

    PYTHONPATH=src python -m tests.regen_golden          # all arches
    PYTHONPATH=src python -m tests.regen_golden llava15_7b ...

For every registered architecture x train/prefill/decode at ONE canonical
cell (mesh ``data=2,model=2``, global batch 8, seq 1024, tpu backend,
chip v5e) this writes ``tests/golden/<arch>.json`` holding every
:class:`repro.core.predictor.PredictedMemory` component — raw AND under a
fixed calibration profile — plus the per-module breakdown.

``tests/test_golden.py`` replays the same cells and fails with a
diff-style message naming the FIRST divergent component on any byte
change, so refactors of the memory model can no longer drift bytes
silently.  Regenerating is an explicit, reviewable act: run this module
and commit the JSON diff.
"""

from __future__ import annotations

import json
import os

from repro.calibrate.profile import CalibrationProfile
from repro.configs import ShapeConfig
from repro.core import planner as PL

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")

#: the canonical cell every snapshot is taken at
CANON_MESH = {"data": 2, "model": 2}
CANON_SEQ = 1024
CANON_BATCH = 8
CANON_CHIP = "v5e"
CANON_BACKEND = "tpu"
KINDS = ("train", "prefill", "decode")

#: paged-serving snapshot leg: decode at the SAME canonical cell under
#: fixed serving-fleet knobs (no draft model, so each golden stays a
#: one-arch artifact); the plain "decode" leg above keeps freezing the
#: contiguous-KV path
SERVE_KIND = "decode_paged"

#: Eq.1 offload-tier leg: train at the SAME canonical cell with the
#: optimizer states host-offloaded (factors.offload_staged_bytes keeps
#: only the double-buffered staging window on device); the plain
#: "train" leg above keeps freezing the no-offload path byte-for-byte
OFFLOAD_KIND = "train_offload"

#: liveness-assembly leg: train at the SAME canonical cell with the
#: interval-overlap peak (``assembly="liveness"``); the plain "train"
#: leg above keeps freezing the legacy sum-of-maxima path byte-for-byte
LIVENESS_KIND = "train_liveness"

#: PredictedMemory fields frozen per cell, in assertion order
COMPONENTS = ("param_bytes", "grad_bytes", "opt_bytes", "act_saved_bytes",
              "act_transient_bytes", "loss_bytes", "input_bytes",
              "cache_bytes", "output_copy_bytes", "calibration_bytes",
              "peak_bytes")

#: the serve leg additionally freezes the paged-KV pool, the prefix-hit
#: savings and the (zero, draft-free) draft residency
SERVE_COMPONENTS = COMPONENTS + ("pool_bytes", "hit_saved_bytes",
                                 "draft_bytes")

#: the offload leg additionally freezes the host-DRAM residency (the
#: displaced optimizer total, informational — outside the device peak)
OFFLOAD_COMPONENTS = COMPONENTS + ("offload_bytes",)

#: the liveness leg additionally freezes the overlap slack (the legacy
#: sum-of-maxima minus the interval-overlap peak)
LIVENESS_COMPONENTS = COMPONENTS + ("overlap_slack_bytes",)


def canon_serve():
    """The fixed ServeSpec of the decode_paged leg: 16-token blocks at
    0.9 pool utilization, 0.5 prefix-cache hit rate over a 256-token
    shared prefix, and a 25%-prefill request mix."""
    from repro.serve.fleet import RequestMix
    from repro.serve.pool import ServeSpec
    return ServeSpec.make(
        block_size=16, utilization=0.9, prefix_hit_rate=0.5,
        prefix_len=256,
        mix=RequestMix.make(0.25, ((512, 1), (CANON_SEQ, 3))))

#: fixed non-identity profile for the calibrated leg (never fitted — its
#: only job is to exercise the scaled path deterministically)
GOLDEN_PROFILE = CalibrationProfile(
    coefficients={"static": 1.0417, "act_saved": 0.9313,
                  "act_transient": 1.1902, "overhead": 0.8641},
    chip_constant_bytes={"v5e": 134217728, "*": 33554432})


def snapshot(arch: str, engine=None) -> dict:
    """The golden payload for one arch: kind -> raw/calibrated ->
    components (+ the per-module table on the raw leg).  Kinds are the
    three step kinds plus ``decode_paged`` (decode under the fixed
    :func:`canon_serve` serving-fleet knobs), ``train_offload`` (train
    with host-offloaded optimizer states) and ``train_liveness`` (train
    under the interval-overlap liveness assembly)."""
    from repro.core import sweep as SW
    engine = engine or SW.SweepEngine()
    budget = int(PL.chip_hbm(CANON_CHIP) * PL.HEADROOM)
    out: dict = {}
    for kind in KINDS + (SERVE_KIND, OFFLOAD_KIND, LIVENESS_KIND):
        serve = canon_serve() if kind == SERVE_KIND else None
        offload = kind == OFFLOAD_KIND
        liveness = kind == LIVENESS_KIND
        comps = (SERVE_COMPONENTS if kind == SERVE_KIND
                 else OFFLOAD_COMPONENTS if offload
                 else LIVENESS_COMPONENTS if liveness else COMPONENTS)
        shape = ShapeConfig("golden", CANON_SEQ, CANON_BATCH,
                            "decode" if kind == SERVE_KIND
                            else "train" if offload or liveness else kind)
        per: dict = {}
        for variant, profile in (("raw", None),
                                 ("calibrated", GOLDEN_PROFILE)):
            rep = engine.report(arch, shape, dict(CANON_MESH),
                                backend=CANON_BACKEND, budget_bytes=budget,
                                chip=CANON_CHIP, profile=profile,
                                serve=serve, offload_opt=offload,
                                assembly="liveness" if liveness
                                else "legacy")
            comp = {c: int(getattr(rep.prediction, c)) for c in comps}
            if variant == "raw":
                comp["per_module"] = {
                    path: {k: (int(v) if k != "trainable" else bool(v))
                           for k, v in m.items()}
                    for path, m in rep.prediction.per_module.items()}
            per[variant] = comp
        out[kind] = per
    return out


def golden_path(arch: str) -> str:
    return os.path.join(GOLDEN_DIR, f"{arch}.json")


def first_divergence(want: dict, got: dict, prefix: str = "") -> str:
    """Human-readable path of the first differing leaf (or '' if equal);
    walks kinds -> variants -> components in deterministic order."""
    if want == got:
        return ""
    for key in list(want) + [k for k in got if k not in want]:
        w, g = want.get(key), got.get(key)
        here = f"{prefix}/{key}" if prefix else str(key)
        if isinstance(w, dict) and isinstance(g, dict):
            sub = first_divergence(w, g, here)
            if sub:
                return sub
        elif w != g:
            return (f"{here}: golden {w!r} != current {g!r}")
    return f"{prefix}: structural difference"


def main(argv=None) -> int:
    import sys
    from repro.configs import registered_archs
    from repro.core import sweep as SW
    arches = argv if argv else registered_archs()
    arches = [SW.normalize_arch(a) for a in arches]
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    engine = SW.SweepEngine()
    for arch in arches:
        payload = snapshot(arch, engine=engine)
        path = golden_path(arch)
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {os.path.relpath(path)}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    import sys
    raise SystemExit(main(sys.argv[1:] or None))
