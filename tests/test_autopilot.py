"""Memory autopilot: telemetry watch, offload tier, mitigation planning,
the closed-loop guard, and the fault-tolerance fixes that ride this PR.

Covers the ISSUE-7 acceptance properties:

* telemetry defects (missing file, truncated JSON, missing counters,
  zero/negative peaks) classify UNAVAILABLE — never a crash, never a
  bogus SAFE;
* the Eq.1 offload tier is byte-identical between the scalar and
  columnar sweep paths and inert when off;
* every applied mitigation's predicted peak re-validates against
  un-memoized ``planner.check`` for the mutated cell;
* the guarded trainer finishes every synthetic drift scenario with zero
  injected OOMs while the unguarded baseline aborts;
* ``ResilientTrainer`` aborts on CONSECUTIVE failures only (lifetime
  ``restarts`` keeps counting) and stragglers rotate onto a different,
  valid shard.
"""

import json
import os

import pytest

from repro.autopilot import (SCENARIOS, Autopilot, MemoryWatch, Mitigation,
                             MitigationError, MitigationPlanner, WatchState,
                             base_cell, load_dryrun, observed_bytes,
                             run_scenario, scan_dryrun_dir, scenario)
from repro.autopilot.harness import BASE_FRAC
from repro.configs import ShapeConfig
from repro.core import factors as F
from repro.core import planner as PL
from repro.core import sweep as SW
from repro.core.spec import FULL_TRAIN


# -- telemetry ingest: observed_bytes / load_dryrun / scan_dryrun_dir --------

GOOD_MEM = {"argument_bytes": 100, "output_bytes": 40, "temp_bytes": 70,
            "alias_bytes": 10}


def test_observed_bytes_total_wins_and_rebuild():
    assert observed_bytes({"memory": {"total_bytes": 123}}) == 123
    # full record or bare memory dict both accepted
    assert observed_bytes({"memory": GOOD_MEM}) == 200
    assert observed_bytes(GOOD_MEM) == 200
    # serialized total wins over the counters
    assert observed_bytes({**GOOD_MEM, "total_bytes": 7}) == 7


@pytest.mark.parametrize("record", [
    None, 17, "nope", [],                         # not a record at all
    {}, {"memory": None}, {"memory": []},         # no memory dict
    {"memory": {}},                               # no counters at all
    {"memory": {"argument_bytes": 1}},            # missing counters
    {"memory": {**GOOD_MEM, "temp_bytes": None}},
    {"memory": {**GOOD_MEM, "temp_bytes": "NaNish"}},
    {"memory": {"total_bytes": 0}},               # zero-byte peak
    {"memory": {"total_bytes": -5}},
    {"memory": {"total_bytes": "garbage"}},
])
def test_observed_bytes_defects_yield_none(record):
    assert observed_bytes(record) is None


def test_observed_bytes_matches_memory_stats_contract():
    """The watch rebuilds the SAME total core/xla_metrics computes and
    launch/dryrun serializes (arg + temp + out - alias)."""
    from repro.core.xla_metrics import MemoryStats
    ms = MemoryStats(argument_bytes=100, output_bytes=40, temp_bytes=70,
                     alias_bytes=10)
    assert observed_bytes({"memory": GOOD_MEM}) == ms.total_bytes
    # the full dryrun artifact layout (counters + serialized total)
    record = {"arch": "x", "memory": {**GOOD_MEM,
                                      "total_bytes": ms.total_bytes}}
    assert observed_bytes(record) == ms.total_bytes
    # an all-aliased program nets to zero -> unusable, not SAFE
    zero = MemoryStats(argument_bytes=5, output_bytes=5, temp_bytes=0,
                       alias_bytes=10)
    assert zero.total_bytes == 0
    assert observed_bytes({"memory": {
        "argument_bytes": 5, "output_bytes": 5, "temp_bytes": 0,
        "alias_bytes": 10}}) is None


def test_load_dryrun_defects(tmp_path):
    good = tmp_path / "good.json"
    good.write_text(json.dumps({"memory": GOOD_MEM}))
    assert load_dryrun(str(good)) == 200

    truncated = tmp_path / "truncated.json"
    truncated.write_text(json.dumps({"memory": GOOD_MEM})[:25])
    assert load_dryrun(str(truncated)) is None

    empty = tmp_path / "empty.json"
    empty.write_text("")
    assert load_dryrun(str(empty)) is None
    assert load_dryrun(str(tmp_path / "missing.json")) is None

    zero = tmp_path / "zero.json"
    zero.write_text(json.dumps({"memory": {"total_bytes": 0}}))
    assert load_dryrun(str(zero)) is None


def test_scan_dryrun_dir(tmp_path):
    assert scan_dryrun_dir(str(tmp_path / "nope")) == []
    (tmp_path / "a.json").write_text(json.dumps({"memory": GOOD_MEM}))
    (tmp_path / "b.json").write_text("{ not json")
    (tmp_path / "c.txt").write_text("ignored")
    rows = scan_dryrun_dir(str(tmp_path))
    assert rows == [("a.json", 200), ("b.json", None)]


# -- the watch state machine -------------------------------------------------


def _watch(**kw):
    return MemoryWatch(predicted_bytes=1000, budget_bytes=1250, **kw)


def test_watch_safe_then_drift_then_critical():
    w = _watch()
    assert w.observe(0, 1000).state is WatchState.SAFE
    # inside the guard band (0.95 * 1250 = 1187.5) but over it -> DRIFT
    assert w.observe(1, 1200).state is WatchState.DRIFT
    # at/over budget -> CRITICAL, regardless of the EWMA
    assert w.observe(2, 1300).state is WatchState.CRITICAL


def test_watch_ewma_arm_catches_slow_leak():
    """Persistent 10% overshoot never enters the guard band raw, but the
    EWMA ratio crosses drift_tolerance."""
    w = _watch()
    states = [w.observe(i, 1100).state for i in range(12)]
    assert states[0] is WatchState.SAFE        # ewma still ~1.025
    assert WatchState.DRIFT in states
    assert w.ewma_ratio > w.drift_tolerance
    # projection rides the EWMA: still inside the guard band
    assert all(s is not WatchState.CRITICAL for s in states)


@pytest.mark.parametrize("bad", [None, 0, -123,
                                 {"memory": {"total_bytes": 0}},
                                 {"memory": {}}])
def test_watch_unusable_telemetry_is_unavailable_never_safe(bad):
    w = _watch()
    before = w.ewma_ratio
    s = w.observe(0, bad)
    assert s.state is WatchState.UNAVAILABLE
    assert s.observed_bytes is None
    assert w.ewma_ratio == before          # no observation, no EWMA update


def test_watch_repredict_and_guards():
    w = _watch()
    w.observe(0, 1400)
    ratio = w.ewma_ratio
    w.repredict(500, reset_ewma=False)
    assert (w.predicted_bytes, w.ewma_ratio) == (500, ratio)
    w.repredict(500)
    assert w.ewma_ratio == 1.0
    with pytest.raises(ValueError):
        w.repredict(0)
    with pytest.raises(ValueError):
        MemoryWatch(predicted_bytes=0, budget_bytes=1)


# -- the Eq.1 offload tier ---------------------------------------------------


def test_offload_staged_bytes_math():
    assert F.offload_staged_bytes(0) == 0
    assert F.offload_staged_bytes(16) == 2
    assert F.offload_staged_bytes(17) == 4          # ceil to a bucket
    big = 10 ** 9
    assert F.offload_staged_bytes(big) < big        # always a shrink
    assert F.offload_staged_bytes(big) == \
        2 * -(-big // F.OFFLOAD_BUCKETS)


def test_offload_scalar_semantics():
    """Offload swaps the resident optimizer bytes for the staging
    window and surfaces the displaced total as host residency."""
    shape = ShapeConfig("cell", 1024, 8, "train")
    mesh = {"data": 2, "model": 2}
    base = PL.check("smollm-360m", shape, mesh, backend="tpu")
    off = PL.check("smollm-360m", shape, mesh, backend="tpu",
                   offload_opt=True)
    pb, po = base.prediction, off.prediction
    assert po.offload_bytes == pb.opt_bytes          # displaced total
    assert po.opt_bytes == F.offload_staged_bytes(pb.opt_bytes)
    assert po.opt_bytes < pb.opt_bytes
    assert off.peak_bytes < base.peak_bytes
    assert pb.offload_bytes == 0                     # off => inert


@pytest.mark.parametrize("kind", ["prefill", "decode"])
def test_offload_rejected_on_serve_kinds(kind):
    shape = ShapeConfig("cell", 1024, 8, kind)
    with pytest.raises(ValueError, match="offload-optimizer is invalid"):
        PL.check("smollm-360m", shape, {"data": 2}, backend="tpu",
                 offload_opt=True)
    grid = SW.SweepGrid(arch="smollm-360m", chips=8, kind=kind,
                        offload_optimizer=(False, True),
                        global_batches=(8,), seq_lens=(512,))
    for mode in ("cell", "columnar"):
        with pytest.raises(ValueError, match="offload-optimizer"):
            SW.sweep(grid, mode=mode)


def test_sweep_cli_rejects_offload_on_serve(capsys):
    with pytest.raises(SystemExit) as exc:
        SW.main(["--arch", "smollm_360m", "--chips", "8", "--kind",
                 "decode", "--batch", "8", "--seq-len", "512",
                 "--offload-optimizer", "on"])
    assert exc.value.code == 2
    assert "offload-optimizer is invalid" in capsys.readouterr().err


def test_offload_columnar_parity(sweep_engine):
    """Scalar and columnar paths agree byte-for-byte across the offload
    knob, and the off half is bit-equal to a grid without the axis."""
    grid = SW.SweepGrid(
        arch="deepseek-v2-lite-16b", chips=8,
        offload_optimizer=(False, True),
        optimizers=(None, "adafactor"), grad_accums=(1, 2),
        global_batches=(8,), seq_lens=(512,), backend="tpu")
    col = sweep_engine.sweep(grid, mode="columnar")
    cell = sweep_engine.sweep(grid, mode="cell")

    def cols(res):
        return [(r.peak_bytes, r.fits, r.optimizer, r.grad_accum,
                 tuple(sorted(r.mesh_shape.items())), r.offload,
                 r.offload_bytes) for r in res.results]

    assert cols(col) == cols(cell)
    on = [r for r in col.results if r.offload]
    assert on and all(r.offload_bytes > 0 for r in on)
    plain = sweep_engine.sweep(
        SW.SweepGrid(arch="deepseek-v2-lite-16b", chips=8,
                     optimizers=(None, "adafactor"), grad_accums=(1, 2),
                     global_batches=(8,), seq_lens=(512,), backend="tpu"),
        mode="columnar")
    offless = [c for c in cols(col) if not c[-2]]
    assert offless == cols(plain)
    assert all(r.offload_bytes == 0 for r in plain.results)


# -- mitigation planning -----------------------------------------------------


def _harness_headroom(engine, frac=BASE_FRAC):
    """The harness's budget normalization: base cell at ``frac`` of the
    budget (the default v5e budget is far below the harness cell, which
    would force every plan straight to reshard)."""
    base_pred = engine.evaluate(base_cell(), policy=FULL_TRAIN).peak_bytes
    return (base_pred / frac) / PL.chip_hbm("v5e")


def test_planner_ranks_cheapest_safe_first(sweep_engine):
    planner = MitigationPlanner(engine=sweep_engine, policy=FULL_TRAIN,
                                headroom=_harness_headroom(sweep_engine))
    plan = planner.plan(base_cell(), ewma_ratio=1.2)
    assert plan.candidates, "the harness cell must have knob room"
    base_pred = sweep_engine.evaluate(
        base_cell(), policy=FULL_TRAIN).peak_bytes
    for c in plan.candidates:
        assert c.predicted_bytes < base_pred       # real savings only
        assert c.projected_bytes == int(1.2 * c.predicted_bytes)
    ranked = [(not c.safe, c.throughput_cost) for c in plan.candidates]
    assert ranked == sorted(ranked)
    # pp=1 cell: no microbatch move, so grad_accum is the cheapest prior
    assert plan.best.action == "grad_accum"


def test_planner_reshard_is_last_resort(sweep_engine):
    """With an absurd drift ratio nothing on-mesh is safe, so the
    planner escalates to plan_min_chips."""
    planner = MitigationPlanner(engine=sweep_engine, policy=FULL_TRAIN,
                                headroom=_harness_headroom(sweep_engine))
    plan = planner.plan(base_cell(), ewma_ratio=50.0)
    assert not any(c.safe for c in plan.candidates
                   if c.action != "reshard")
    actions = {c.action for c in plan.candidates}
    if "reshard" in actions:                 # found a bigger legal mesh
        rs = next(c for c in plan.candidates if c.action == "reshard")
        assert rs.cell.n_chips > base_cell().n_chips
    modest = planner.plan(base_cell(), ewma_ratio=1.1)
    assert modest.reaches_safety
    assert "reshard" not in {c.action for c in modest.candidates}


def test_applied_mitigation_validates_against_planner_check(sweep_engine):
    hr = _harness_headroom(sweep_engine)
    pilot = Autopilot(cell=base_cell(), engine=sweep_engine, headroom=hr)
    m = pilot.mitigate(step=0, ewma_ratio=1.2)
    assert m is not None and pilot.cell == m.cell
    shape = ShapeConfig("t", m.cell.seq_len, m.cell.global_batch, "train")
    ref = PL.check(m.cell.arch, shape, m.cell.mesh_shape,
                   backend=m.cell.backend, grad_accum=m.cell.grad_accum,
                   remat=m.cell.remat, optimizer=m.cell.optimizer,
                   chip=m.cell.chip, headroom=hr,
                   offload_opt=m.cell.offload)
    assert ref.peak_bytes == m.predicted_bytes


def test_tampered_mitigation_raises(sweep_engine):
    pilot = Autopilot(cell=base_cell(), engine=sweep_engine,
                      headroom=_harness_headroom(sweep_engine))
    good = pilot.planner.plan(base_cell(), ewma_ratio=1.2).best
    bogus = Mitigation(action=good.action, cell=good.cell,
                       predicted_bytes=good.predicted_bytes + 1,
                       projected_bytes=good.projected_bytes,
                       budget_bytes=good.budget_bytes,
                       throughput_cost=good.throughput_cost)
    with pytest.raises(MitigationError):
        pilot._apply(0, bogus)
    assert pilot.cell == base_cell()       # nothing applied


def test_on_restart_revalidates_mesh(sweep_engine):
    # triple the harness budget so the resize itself never re-mitigates
    pilot = Autopilot(cell=base_cell(), engine=sweep_engine,
                      headroom=3 * _harness_headroom(sweep_engine))
    before = pilot.predicted_bytes
    cell = pilot.on_restart(mesh_shape={"data": 4, "model": 1})
    assert cell.mesh_shape == {"data": 4, "model": 1}
    assert pilot.predicted_bytes != before
    # an illegal resize (expert axis on a dense arch) fails loudly
    with pytest.raises(ValueError):
        pilot.on_restart(mesh_shape={"data": 2, "expert": 2})


# -- the closed loop: guarded vs unguarded trainer runs ----------------------


@pytest.mark.parametrize("name", [s.name for s in SCENARIOS])
def test_guarded_run_completes_every_scenario(name, sweep_engine):
    r = run_scenario(scenario(name), guarded=True, engine=sweep_engine)
    assert r.completed and not r.aborted
    assert r.oom_free and r.restarts == 0
    assert r.steps_done == r.n_steps
    assert r.mitigations, "crossing the budget line must cost a knob"
    assert r.final_predicted_bytes < r.base_predicted_bytes


def test_unguarded_run_aborts(sweep_engine):
    r = run_scenario(scenario("underestimate"), guarded=False,
                     engine=sweep_engine)
    assert r.aborted and not r.completed
    assert r.oom_steps and r.restarts > 0
    assert not r.mitigations


def test_scenarios_all_cross_budget():
    for s in SCENARIOS:
        assert s.crosses_budget(), s.name
        assert s.n_steps == len(s.ratios)
    assert abs(1.0 / BASE_FRAC - 1.25) < 1e-9
    with pytest.raises(KeyError):
        scenario("nope")


# -- ResilientTrainer fixes (satellites a + b) -------------------------------


def _trainer(tmp_path, injector, max_restarts=3, pipeline=None):
    from repro.checkpoint import Checkpointer
    from repro.runtime.fault_tolerance import FaultConfig, ResilientTrainer
    return ResilientTrainer(
        train_step=lambda state, batch: (state + 1, {"loss": 0.0}),
        pipeline=pipeline,
        checkpointer=Checkpointer(str(tmp_path)),
        fault_cfg=FaultConfig(ckpt_every=10 ** 6,
                              max_restarts=max_restarts),
        make_batch=lambda step: None,
        failure_injector=injector)


def test_restart_budget_is_consecutive_not_lifetime(tmp_path):
    """Regression: sporadic recovered failures across a long run must
    never exhaust the budget — only a consecutive streak aborts."""
    failed = set()

    def flaky(step):               # fail each even step exactly once
        if step % 2 == 0 and step not in failed:
            failed.add(step)
            return True
        return False

    trainer = _trainer(tmp_path, flaky, max_restarts=3)
    state, history = trainer.run(0, 0, 12)
    assert state == 12
    assert trainer.restarts == 6             # lifetime stat kept counting
    assert trainer.restarts > trainer.fault_cfg.max_restarts
    assert [h["step"] for h in history] == list(range(12))


def test_restart_budget_aborts_on_consecutive_streak(tmp_path):
    trainer = _trainer(tmp_path, lambda step: step == 4, max_restarts=2)
    with pytest.raises(RuntimeError, match="injected failure"):
        # no checkpoint exists, so the same step retries and fails
        trainer.run(0, 0, 10)
    assert trainer.restarts == 3             # max_restarts + the fatal one


def test_consecutive_counter_resets_after_success(tmp_path):
    fails = {3: 2}                           # two back-to-back, then ok

    def injector(step):
        if fails.get(step, 0) > 0:
            fails[step] -= 1
            return True
        return False

    trainer = _trainer(tmp_path, injector, max_restarts=2)
    state, _ = trainer.run(0, 0, 6)
    assert state == 6
    assert trainer.restarts == 2
    assert trainer._consecutive_failures == 0


def test_straggler_rotates_to_next_valid_shard(tmp_path):
    """Regression: the old ``shard_id % max(n_shards - 1, 1)`` rule
    could reassign a shard to itself; rotation never does."""
    class Pipe:
        n_shards = 4
        shard_id = 2
    pipe = Pipe()
    trainer = _trainer(tmp_path, None, pipeline=pipe)
    trainer._ewma = 0.001
    for _ in range(pipe.n_shards + 1):       # full cycle and then some
        old = pipe.shard_id
        trainer._track_stragglers(0, 1.0)    # way past factor * ewma
        trainer._ewma = 0.001
        assert pipe.shard_id != old
        assert 0 <= pipe.shard_id < pipe.n_shards
        assert pipe.shard_id == (old + 1) % pipe.n_shards
    assert len(trainer.straggler_events) == pipe.n_shards + 1


def test_trainer_admission_control_calls_autopilot(tmp_path):
    """The memory hook observes BEFORE each step and on_restart fires on
    every recovered failure."""
    calls = {"observe": [], "restart": []}

    class StubPilot:
        def observe(self, step, obs):
            calls["observe"].append((step, obs))

        def on_restart(self, step=-1, mesh_shape=None):
            calls["restart"].append(step)

    failed = []

    def inj(step):                           # fail step 2 exactly once
        if step == 2 and not failed:
            failed.append(step)
            return True
        return False

    trainer = _trainer(tmp_path, inj, max_restarts=3)
    trainer.autopilot = StubPilot()
    trainer.memory_source = lambda step: 1000 + step
    state, _ = trainer.run(0, 0, 4)
    assert state == 4
    assert calls["observe"][0] == (0, 1000)
    assert len(calls["observe"]) == 5        # 4 steps + the retried one
    assert calls["restart"] == [2]


# -- CLI smokes --------------------------------------------------------------


def test_cli_list(capsys):
    from repro.autopilot.__main__ import main
    assert main(["--list"]) == 0
    out = capsys.readouterr().out
    for s in SCENARIOS:
        assert s.name in out


def test_cli_ingest(tmp_path, capsys):
    from repro.autopilot.__main__ import main
    (tmp_path / "ok.json").write_text(json.dumps({"memory": GOOD_MEM}))
    (tmp_path / "bad.json").write_text("{ nope")
    assert main(["--ingest", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "telemetry unavailable" in out
    assert "2 artifacts, 1 unusable" in out
    assert main(["--ingest", str(tmp_path / "missing")]) == 1


def test_cli_scenario_run(capsys):
    from repro.autopilot.__main__ import main
    assert main(["--scenario", "underestimate"]) == 0
    out = capsys.readouterr().out
    assert "guarded" in out and "ABORTED" in out


def test_cli_rejects_unknown_scenario(capsys):
    from repro.autopilot.__main__ import main
    with pytest.raises(SystemExit) as exc:
        main(["--scenario", "nope"])
    assert exc.value.code == 2
    assert "unknown scenario" in capsys.readouterr().err


# -- continual refit: DRIFT spends a refit before a mitigation ---------------


def test_drift_refit_absorbs_bias_before_mitigation(sweep_engine):
    """A steady 8% under-prediction (pure bias, far from the budget)
    drifts the EWMA past tolerance; once enough samples accumulated the
    autopilot refits the residual model instead of burning a knob move,
    the forecast absorbs the bias, and the run settles back to SAFE
    with ZERO mitigations."""
    pilot = Autopilot(cell=base_cell(), engine=sweep_engine,
                      headroom=3 * _harness_headroom(sweep_engine),
                      refit=True, refit_min_samples=8)
    base_pred = pilot.predicted_bytes
    obs = int(1.08 * base_pred)
    states = [pilot.observe(step, obs).state for step in range(20)]
    assert WatchState.DRIFT in states
    assert pilot.refits == 1
    assert any(kind == "refit" for _, kind, _ in pilot.events)
    assert not pilot.applied               # bias absorbed, no knob spent
    assert pilot.predicted_bytes > base_pred
    assert states[-1] is WatchState.SAFE
    # the refreshed model threads the planner (future plans see it too)
    assert pilot.residual is not None
    assert pilot.planner.residual is pilot.residual
    # every usable observation accumulated as a refit sample ...
    assert len(pilot.store) == 20
    m = pilot.store.measurements[0]
    assert m.arch == pilot.cell.arch
    assert m.source == "autopilot:step0"
    assert (m.microbatches, m.schedule, m.offload_optimizer) == \
        (pilot.cell.microbatches, pilot.cell.schedule, pilot.cell.offload)
    # ... and unusable telemetry never does
    pilot.observe(20, None)
    assert len(pilot.store) == 20


def test_refit_budget_and_sample_gate(sweep_engine):
    pilot = Autopilot(cell=base_cell(), engine=sweep_engine,
                      headroom=3 * _harness_headroom(sweep_engine),
                      refit=True, refit_min_samples=5, max_refits=0)
    obs = int(1.1 * pilot.predicted_bytes)
    for step in range(12):
        pilot.observe(step, obs)
    assert pilot.refits == 0               # max_refits=0: gate never opens
    assert len(pilot.store) == 12          # samples still accumulate


def test_refit_rejects_serve_cell(sweep_engine):
    from dataclasses import replace

    from repro.serve.pool import ServeSpec
    cell = replace(base_cell(), kind="decode",
                   serve=ServeSpec.make(block_size=16))
    with pytest.raises(ValueError, match="serve"):
        Autopilot(cell=cell, engine=sweep_engine,
                  headroom=_harness_headroom(sweep_engine), refit=True)
