"""Columnar batch predictor (core/batch.py): byte-parity with the
per-cell path, vectorized shard resolution, lazy SweepResults.

The contract under test is exact: every verdict, every per-device peak
byte count, and every Pareto-query answer from ``mode="columnar"`` must
equal the per-cell reference (``mode="cell"``, itself verified against
un-memoized ``planner.check``) — including tie-breaking order.
"""

import random

import pytest

np = pytest.importorskip("numpy")

from repro.calibrate.profile import CalibrationProfile
from repro.configs import registered_archs
from repro.core import batch as B
from repro.core import sweep as SW
from repro.core.spec import LLAVA_STAGE1, LLAVA_STAGE2
from repro.mesh_ctx import DEFAULT_RULES, shard_factor

PROFILE = CalibrationProfile(
    coefficients={"static": 1.0312, "act_saved": 0.977,
                  "act_transient": 1.13, "overhead": 0.84},
    chip_constant_bytes={"v5e": 123456789, "*": 7777777})


def both_modes(grid):
    cell = SW.SweepEngine().sweep(grid, mode="cell")
    col = SW.SweepEngine().sweep(grid, mode="columnar")
    assert col.columns is not None, "columnar mode did not engage"
    return cell, col


def assert_identical(cell, col):
    assert len(cell) == len(col)
    for a, b in zip(cell.results, col.results):
        assert a == b, f"\ncell: {a!r}\ncol:  {b!r}"


# ---------------------------------------------------------------------------
# vectorized shard resolution == scalar shard resolution
# ---------------------------------------------------------------------------


def test_batch_shard_factor_matches_scalar_randomized():
    rng = random.Random(7)
    axes_pool = [None, "batch", "seq", "vocab", "heads", "kv_heads", "ffn",
                 "ssm", "layers", "cache_seq", "embed_cols"]
    for _ in range(300):
        rank = rng.randint(1, 5)
        dims = [rng.choice([1, 2, 3, 8, 15, 16, 60, 576, 4096])
                for _ in range(rank)]
        axes = tuple(rng.choice(axes_pool) for _ in range(rank))
        mesh = {a: rng.choice([1, 2, 4, 8, 16])
                for a in rng.sample(["pod", "data", "model"],
                                    rng.randint(1, 3))}
        extra = ("data",) if rng.random() < 0.5 else ()
        want = shard_factor(dims, axes, mesh, dict(DEFAULT_RULES), extra)
        got = B.batch_shard_factor(dims, axes, mesh, dict(DEFAULT_RULES),
                                   extra)
        assert int(got) == want, (dims, axes, mesh, extra)


def test_batch_shard_factor_size1_axis_equals_missing_axis():
    """The columnar path pads heterogeneous mesh lists with size-1 axes;
    a size-1 axis must be indistinguishable from an absent one."""
    rng = random.Random(11)
    for _ in range(200):
        rank = rng.randint(1, 4)
        dims = [rng.choice([2, 3, 15, 16, 64, 576]) for _ in range(rank)]
        axes = tuple(rng.choice([None, "batch", "vocab", "heads", "ffn",
                                 "layers"]) for _ in range(rank))
        mesh = {"data": rng.choice([2, 4]), "model": rng.choice([2, 8])}
        padded = {**mesh, "pod": 1}
        extra = ("data",)
        assert shard_factor(dims, axes, mesh, dict(DEFAULT_RULES), extra) \
            == int(B.batch_shard_factor(dims, axes, padded,
                                        dict(DEFAULT_RULES), extra))


def test_batch_shard_factor_broadcasts_over_meshes_and_cells():
    sizes = {"data": np.array([[1], [2], [4]]),
             "model": np.array([[8], [4], [2]])}
    b = np.array([4, 6, 8, 12])
    got = B.batch_shard_factor((b, 128), ("batch", "vocab"), sizes,
                               dict(DEFAULT_RULES))
    assert got.shape == (3, 4)
    for mi, mesh in enumerate(({"data": 1, "model": 8},
                               {"data": 2, "model": 4},
                               {"data": 4, "model": 2})):
        for ci, bv in enumerate(b.tolist()):
            assert got[mi, ci] == shard_factor(
                (bv, 128), ("batch", "vocab"), mesh, dict(DEFAULT_RULES))


# ---------------------------------------------------------------------------
# columnar == cell, across the zoo
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", registered_archs())
def test_columnar_matches_cell_per_arch(arch):
    grid = SW.SweepGrid(
        arch=arch, chips=8, chip=("v5e", "h200"),
        optimizers=(None, "adafactor"), remats=(None, "none", "dots"),
        grad_accums=(1, 2), global_batches=(8, 12),
        seq_lens=(512,), backend="cpu")
    assert_identical(*both_modes(grid))


@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
@pytest.mark.parametrize("profile", [None, PROFILE],
                         ids=["raw", "calibrated"])
def test_columnar_matches_cell_kinds_and_profile(kind, profile):
    grid = SW.SweepGrid(
        arch="llava15-7b", chips=(4, 8),
        grad_accums=(1, 2) if kind == "train" else (1,),
        global_batches=(4, 8, 12), seq_lens=(256, 1024), kind=kind,
        backend="tpu", profile=profile)
    assert_identical(*both_modes(grid))


@pytest.mark.parametrize("policy", [LLAVA_STAGE1, LLAVA_STAGE2],
                         ids=["stage1", "stage2"])
def test_columnar_matches_cell_frozen_policies(policy):
    grid = SW.SweepGrid(arch="llava15-7b", chips=8, policy=policy,
                        grad_accums=(1, 2), global_batches=(8, 16),
                        seq_lens=(1024,), backend="cpu", profile=PROFILE)
    assert_identical(*both_modes(grid))


def test_columnar_matches_cell_heterogeneous_meshes():
    grid = SW.SweepGrid(
        arch="qwen3-32b",                       # fsdp + seq-parallel
        mesh_shapes=[{"data": 4, "model": 2},
                     {"pod": 2, "data": 2, "model": 2}, {"model": 8}],
        grad_accums=(1, 2), global_batches=(8, 16), seq_lens=(512, 1024),
        backend="tpu", profile=PROFILE)
    assert_identical(*both_modes(grid))


def test_columnar_multi_arch_grid():
    grid = SW.SweepGrid(arch=("smollm-360m", "llama3.2-3b"), chips=4,
                        global_batches=(8, 16), seq_lens=(512,),
                        backend="tpu")
    assert_identical(*both_modes(grid))


def test_columnar_jobs_identical():
    grid = SW.SweepGrid(arch="llava15-7b", chips=(8, 16),
                        remats=("none", "block"), grad_accums=(1, 2),
                        global_batches=(8, 32), seq_lens=(512, 2048),
                        backend="cpu", profile=PROFILE)
    one = SW.SweepEngine().sweep(grid, mode="columnar", jobs=1)
    four = SW.SweepEngine().sweep(grid, mode="columnar", jobs=4)
    assert (one.columns.peak_bytes == four.columns.peak_bytes).all()
    assert (one.columns.fits == four.columns.fits).all()
    assert_identical(one, four)


# ---------------------------------------------------------------------------
# lazy SweepResults: queries on arrays == queries on objects
# ---------------------------------------------------------------------------


def _query_grid():
    return SW.SweepGrid(arch="smollm-360m", chips=(8, 16),
                        grad_accums=(1, 2, 4),
                        global_batches=(32, 64, 128, 256, 512),
                        seq_lens=(1024,), backend="tpu")


def test_lazy_queries_match_cell_mode():
    cell, col = both_modes(_query_grid())
    assert col.fit_count == len(cell.fitting())
    assert col.frontier() == cell.frontier()
    assert col.max_global_batch() == cell.max_global_batch()
    assert col.max_global_batch(n_chips=8) == cell.max_global_batch(
        n_chips=8)
    assert col.max_global_batch(chip="v5e") == cell.max_global_batch(
        chip="v5e")
    assert col.max_global_batch(chip="h200") is None \
        and cell.max_global_batch(chip="h200") is None
    assert col.min_chips() == cell.min_chips()
    assert col.min_chips(global_batch=64) == cell.min_chips(
        global_batch=64)
    assert [r.peak_bytes for r in col.sorted_results()] \
        == [r.peak_bytes for r in cell.sorted_results()]


def test_lazy_reports_match_cell_mode():
    cell, col = both_modes(_query_grid())
    assert col.to_markdown(limit=5) == cell.to_markdown(limit=5)
    assert col.to_markdown() == cell.to_markdown()
    assert col.to_csv() == cell.to_csv()


def test_lazy_queries_do_not_materialize_rows():
    col = SW.SweepEngine().sweep(_query_grid(), mode="columnar")
    col.fit_count, col.frontier(), col.max_global_batch(), col.min_chips()
    col.to_markdown(limit=3)
    assert col._results is None, \
        "Pareto queries must not materialize the full row list"
    n = len(col)
    assert len(col.results) == n          # full materialization on demand
    assert col._results is not None


def test_columnar_single_row_equals_cell_row():
    cell, col = both_modes(_query_grid())
    for i in (0, 7, len(cell) - 1):
        assert col.columns.result(i) == cell.results[i]


# ---------------------------------------------------------------------------
# engine plumbing
# ---------------------------------------------------------------------------


def test_keep_predictions_falls_back_to_cell_path():
    grid = SW.SweepGrid(arch="smollm-360m", chips=4,
                        global_batches=(16,), seq_lens=(256,),
                        keep_predictions=True)
    res = SW.SweepEngine().sweep(grid, mode="columnar")
    assert res.columns is None
    assert all(r.prediction is not None for r in res.results)


def test_unknown_mode_raises():
    grid = SW.SweepGrid(arch="smollm-360m", chips=4,
                        global_batches=(16,), seq_lens=(256,))
    with pytest.raises(ValueError, match="unknown sweep mode"):
        SW.SweepEngine().sweep(grid, mode="vectorised")


def test_empty_grid_returns_empty_results():
    grid = SW.SweepGrid(arch="smollm-360m", chips=4,
                        grad_accums=(2,), global_batches=(3, 9),
                        seq_lens=(256,))
    res = SW.sweep(grid)
    assert len(res) == 0 and res.fitting() == []
    assert grid.size() == 0


def test_grid_size_matches_enumeration():
    for grid in (
            _query_grid(),
            SW.SweepGrid(arch="llava15-7b", chips=(4, 8),
                         optimizers=(None, "adafactor"),
                         remats=("none", "block", "dots"),
                         grad_accums=(1, 2, 3),
                         global_batches=(6, 8, 12), seq_lens=(256, 512)),
    ):
        assert grid.size() == sum(1 for _ in grid.cells())
