"""JAX columnar engine (core/batch_jax.py): every result column must be
byte-identical (exact int64) to the numpy reference engine on the same
grid — train and serve kinds, pipeline schedules, MoE expert/context
axes, the optimizer-offload tier, and calibrated profiles — and the
engine selector must reject the combinations it cannot honor.
"""

import pytest

np = pytest.importorskip("numpy")
pytest.importorskip("jax")

from repro.core import sweep as SW  # noqa: E402

#: every ColumnarResults value column the sweep materializes
COLUMNS = ("peak_bytes", "fits", "budget_bytes", "pool_bytes",
           "draft_bytes", "hit_saved_bytes", "offload_bytes",
           "n_chips", "global_batch")


@pytest.fixture(scope="module")
def eng():
    return SW.SweepEngine()


def assert_engine_parity(eng, grid):
    ref = eng.sweep(grid, engine="numpy")
    got = eng.sweep(grid, engine="jax")
    assert len(got) == len(ref) > 0
    for name in COLUMNS:
        a = getattr(ref.columns, name)
        b = getattr(got.columns, name)
        assert np.array_equal(a, b), f"column {name!r} diverged"
        if np.asarray(b).dtype.kind in "iu":
            assert np.asarray(b).dtype == np.int64
    assert got.fit_count == ref.fit_count
    # reductions see identical bytes -> identical Pareto answers
    gm, rm = got.min_chips(), ref.min_chips()
    assert (gm is None) == (rm is None)
    if gm is not None:
        assert (gm.n_chips, gm.peak_bytes) == (rm.n_chips, rm.peak_bytes)
    assert got.frontier() == ref.frontier()


def test_parity_train_pipeline(eng):
    assert_engine_parity(eng, SW.SweepGrid(
        arch="llama3.2-3b", chips=(4, 8), chip="v5e",
        global_batches=(8, 16), seq_lens=(1024, 2048),
        microbatches=(1, 2, 4), schedules=("1f1b", "gpipe"),
        grad_accums=(1, 2), kind="train"))


def test_parity_moe_expert_context(eng):
    assert_engine_parity(eng, SW.SweepGrid(
        arch="deepseek-v2-lite-16b", chips=(8, 16), chip="v5e",
        global_batches=(8,), seq_lens=(2048,), kind="train",
        mesh_axes=("data", "model", "expert", "context", "pipe")))


def test_parity_multi_arch_optimizers_offload(eng):
    assert_engine_parity(eng, SW.SweepGrid(
        arch=("llama3.2-3b", "smollm-360m"), chips=(4,),
        chip=("v5e", "h100"), optimizers=("adamw", "adafactor"),
        offload_optimizer=(False, True), global_batches=(16,),
        seq_lens=(1024,), kind="train"))


def test_parity_serve_paged_kv(eng):
    assert_engine_parity(eng, SW.SweepGrid(
        arch="llama3.2-3b", chips=(1, 4), chip="v5e",
        global_batches=(16, 64), seq_lens=(2048,), kind="decode",
        block_sizes=(0, 16), utilizations=(1.0, 0.9),
        prefix_hit_rates=(0.0, 0.5), prefix_len=512,
        draft_archs=("", "smollm-360m")))


def test_parity_calibrated_profile(eng):
    from repro.calibrate.profile import CalibrationProfile

    prof = CalibrationProfile(
        coefficients={"static": 1.07, "act_saved": 0.93,
                      "act_transient": 1.21, "overhead": 1.0},
        chip_constant_bytes={"*": 256 * 1024 ** 2})
    assert_engine_parity(eng, SW.SweepGrid(
        arch="llava15-7b", chips=(4, 8), chip="v5e",
        global_batches=(8, 16), seq_lens=(1024,), kind="train",
        profile=prof))


def test_parity_cpu_backend(eng):
    assert_engine_parity(eng, SW.SweepGrid(
        arch="smollm-360m", chips=(2, 4), chip="v5e", backend="cpu",
        global_batches=(8,), seq_lens=(512, 1024), kind="prefill"))


def test_jax_engine_is_deterministic(eng):
    """Two warm runs of the same grid return identical bytes (the jit
    cache replays, it does not drift)."""
    grid = SW.SweepGrid(arch="llama3.2-3b", chips=(4, 8), chip="v5e",
                        global_batches=(8, 16), seq_lens=(2048,))
    a = eng.sweep(grid, engine="jax")
    b = eng.sweep(grid, engine="jax")
    assert np.array_equal(a.columns.peak_bytes, b.columns.peak_bytes)
    assert np.array_equal(a.columns.fits, b.columns.fits)


def test_engine_selector_validation(eng):
    grid = SW.SweepGrid(arch="smollm-360m", chips=(2,),
                        global_batches=(8,), seq_lens=(512,))
    with pytest.raises(ValueError, match="engine"):
        eng.sweep(grid, engine="fortran")
    with pytest.raises(ValueError, match="cell"):
        eng.sweep(grid, mode="cell", engine="jax")
    with pytest.raises(ValueError, match="keep_predictions|breakdown"):
        eng.sweep(SW.SweepGrid(arch="smollm-360m", chips=(2,),
                               global_batches=(8,), seq_lens=(512,),
                               keep_predictions=True), engine="jax")


def test_module_level_sweep_engine_shorthand():
    """sweep(grid, engine="jax") string shorthand drives a fresh
    SweepEngine on the jitted path."""
    grid = SW.SweepGrid(arch="smollm-360m", chips=(2,),
                        global_batches=(8,), seq_lens=(512,))
    a = SW.sweep(grid, engine="jax")
    b = SW.sweep(grid, engine="numpy")
    assert np.array_equal(a.columns.peak_bytes, b.columns.peak_bytes)
