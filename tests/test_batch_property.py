"""Property tests (hypothesis) for the columnar batch predictor.

Randomized small grids across all registered architectures, every step
kind, both oracle backends, with and without a calibration profile: the
columnar path (core/batch.py) must agree with the per-cell reference
byte for byte on every field of every result row.

Split out from tests/test_batch.py so the deterministic parity tests run
even where hypothesis is not installed (same importorskip convention as
tests/test_mesh_ctx.py).  CI installs hypothesis via requirements-dev.txt
and runs under the shared "ci" settings profile registered in
tests/conftest.py (fixed seed, no deadline); strategies cover the
expert-parallel / context-parallel mesh axes alongside pp.
"""

import pytest

np = pytest.importorskip("numpy")
pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis; `pip install hypothesis` "
           "to run them")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.calibrate.profile import CalibrationProfile  # noqa: E402
from repro.configs import get_config, registered_archs  # noqa: E402
from repro.core import sweep as SW  # noqa: E402
from repro.mesh_ctx import DEFAULT_RULES, shard_factor  # noqa: E402

_profiles = st.one_of(
    st.none(),
    st.builds(
        lambda s, sv, tr, ov, k: CalibrationProfile(
            coefficients={"static": s, "act_saved": sv,
                          "act_transient": tr, "overhead": ov},
            chip_constant_bytes={"*": k}),
        *(st.floats(0.5, 1.5) for _ in range(4)),
        st.integers(0, 2 * 1024 ** 3)))


@settings(max_examples=25, deadline=None)
@given(
    arch=st.sampled_from(registered_archs()),
    chips=st.sampled_from([4, 8, 16]),
    kind=st.sampled_from(["train", "prefill", "decode"]),
    backend=st.sampled_from(["tpu", "cpu"]),
    accums=st.lists(st.sampled_from([1, 2, 3, 4]), min_size=1,
                    max_size=2, unique=True),
    batches=st.lists(st.integers(1, 48), min_size=1, max_size=2,
                     unique=True),
    seqs=st.lists(st.sampled_from([128, 384, 512, 1024]), min_size=1,
                  max_size=2, unique=True),
    profile=_profiles)
def test_property_columnar_equals_cell(arch, chips, kind, backend, accums,
                                       batches, seqs, profile):
    grid = SW.SweepGrid(arch=arch, chips=chips, grad_accums=tuple(accums),
                        global_batches=tuple(batches),
                        seq_lens=tuple(seqs), kind=kind, backend=backend,
                        profile=profile)
    cell = SW.SweepEngine().sweep(grid, mode="cell")
    col = SW.SweepEngine().sweep(grid, mode="columnar")
    assert len(cell) == len(col)
    if len(col) and col.columns is None:
        pytest.fail("columnar mode did not engage")
    for a, b in zip(cell.results, col.results):
        assert a == b


@settings(max_examples=100, deadline=None)
@given(
    dims=st.lists(st.sampled_from([1, 2, 3, 8, 15, 16, 60, 576, 4096]),
                  min_size=1, max_size=5),
    data=st.sampled_from([1, 2, 4, 8, 16]),
    model=st.sampled_from([1, 2, 4, 8, 16]),
    pod=st.sampled_from([None, 1, 2]),
    expert=st.sampled_from([None, 1, 2, 4]),
    context=st.sampled_from([None, 1, 2, 4]),
    extra=st.sampled_from([(), ("data",)]),
    axes_seed=st.integers(0, 2 ** 31))
def test_property_batch_shard_factor_equals_scalar(dims, data, model, pod,
                                                   expert, context, extra,
                                                   axes_seed):
    import random

    from repro.core.batch import batch_shard_factor
    rng = random.Random(axes_seed)
    pool = [None, "batch", "seq", "vocab", "heads", "kv_heads", "ffn",
            "ssm", "layers", "cache_seq", "embed_cols", "experts",
            "expert_buf"]
    axes = tuple(rng.choice(pool) for _ in dims)
    mesh = {"data": data, "model": model}
    if pod is not None:
        mesh["pod"] = pod
    if expert is not None:
        mesh["expert"] = expert
    if context is not None:
        mesh["context"] = context
    # half the runs exercise the train/prefill rule where `seq` maps to
    # the context axis (launch.mesh.arch_rules), half the default table
    rules = dict(DEFAULT_RULES)
    if rng.random() < 0.5:
        rules["seq"] = ("context",) + tuple(rules["seq"])
    want = shard_factor(dims, axes, mesh, dict(rules), extra)
    got = batch_shard_factor(dims, axes, mesh, dict(rules), extra)
    assert int(got) == want


_MOE_ARCHS = [a for a in registered_archs()
              if get_config(a).moe is not None]


@settings(max_examples=20, deadline=None)
@given(
    arch=st.sampled_from(_MOE_ARCHS),
    kind=st.sampled_from(["train", "prefill"]),
    ep=st.sampled_from([1, 2, 4]),
    cp=st.sampled_from([1, 2, 4]),
    pp=st.sampled_from([1, 2]),
    sched=st.sampled_from(["1f1b", "gpipe"]),
    mbs=st.lists(st.sampled_from([1, 2, 4, 8]), min_size=1, max_size=2,
                 unique=True),
    batches=st.lists(st.sampled_from([4, 8, 16]), min_size=1, max_size=2,
                     unique=True),
    seq=st.sampled_from([512, 1024, 2048]),
    backend=st.sampled_from(["tpu", "cpu"]),
    profile=_profiles)
def test_property_columnar_equals_cell_epcp(arch, kind, ep, cp, pp, sched,
                                            mbs, batches, seq, backend,
                                            profile):
    """ep x cp x pp meshes (heterogeneous with a plain 2-axis mesh in the
    same grid): columnar must equal the per-cell reference on every row."""
    meshes = [{"data": 2, "model": 1, "expert": ep, "context": cp,
               "pipe": pp}, {"data": 2, "model": 2}]
    grid = SW.SweepGrid(arch=arch, mesh_shapes=meshes, kind=kind,
                        schedules=(sched,), microbatches=tuple(mbs),
                        global_batches=tuple(batches), seq_lens=(seq,),
                        backend=backend, profile=profile)
    cell = SW.SweepEngine().sweep(grid, mode="cell")
    col = SW.SweepEngine().sweep(grid, mode="columnar")
    assert len(cell) == len(col) > 0
    if col.columns is None:
        pytest.fail("columnar mode did not engage")
    for a, b in zip(cell.results, col.results):
        assert a == b
