"""Calibration subsystem (repro/calibrate/): ISSUE-2 checklist.

Profile round-trip + staleness rules, deterministic synthetic oracle,
NNLS recovery of a hidden ground-truth profile, strictly-lower calibrated
MAPE per arch family, identity-profile byte-identity, memoized-sweep vs
cell-by-cell parity WITH a profile applied, dry-run artifact ingest, and
CLI smoke runs.
"""

import json

import numpy as np
import pytest

from repro.calibrate import (FEATURE_NAMES, TERMS, CalibrationProfile,
                             Measurement, MeasurementStore, ResidualModel,
                             apply_residual, decompose, evaluate,
                             fit_profile, fit_residual, generate,
                             leave_one_family_out, nnls, parse_mesh_string,
                             predict_measurement)
from repro.calibrate import synthetic as SYN
from repro.calibrate.paths import dryrun_dir, repo_root
from repro.configs import ShapeConfig
from repro.core import planner, sweep as SW

# one shared engine: measurements decompose through the same caches the
# sweep uses, so the whole module runs in seconds
ENGINE = SW.SweepEngine()

SMALL_ARCHS = ("smollm-360m", "mamba2-1.3b")


def small_store(noise=0.01, **kw):
    return generate(archs=SMALL_ARCHS, engine=ENGINE, noise=noise, **kw)


@pytest.fixture(scope="module")
def fitted():
    store = generate(engine=ENGINE)
    return store, fit_profile(store, engine=ENGINE)


@pytest.fixture(scope="module")
def fitted_liveness(fitted):
    store, _ = fitted
    return store, fit_profile(store, engine=ENGINE, assembly="liveness")


# ---------------------------------------------------------------------------
# profile: round-trip, hashing, staleness rules
# ---------------------------------------------------------------------------


def test_profile_roundtrip(tmp_path):
    p = CalibrationProfile(
        coefficients={"static": 1.05, "act_saved": 1.2,
                      "act_transient": 0.9, "overhead": 1.1},
        chip_constant_bytes={"v5e": 123456789, "*": 1000},
        created="2026-07-30T00:00:00Z", source={"n_measurements": 7})
    path = p.save(tmp_path / "p.json")
    q = CalibrationProfile.load(path)
    assert q == p
    assert q.profile_hash == p.profile_hash


def test_profile_hash_ignores_metadata():
    a = CalibrationProfile(created="2020-01-01", source={"x": 1})
    b = CalibrationProfile(created="2026-07-30", source={"y": 2})
    assert a.profile_hash == b.profile_hash
    c = CalibrationProfile(
        coefficients={"static": 1.01, "act_saved": 1.0,
                      "act_transient": 1.0, "overhead": 1.0})
    assert c.profile_hash != a.profile_hash


def test_profile_rejects_missing_or_negative_terms():
    with pytest.raises(ValueError):
        CalibrationProfile(coefficients={"static": 1.0})
    with pytest.raises(ValueError):
        CalibrationProfile(coefficients={t: -1.0 for t in TERMS})


def test_profile_staleness_rules(tmp_path):
    d = CalibrationProfile().to_dict()
    bad_version = dict(d, schema_version=99)
    with pytest.raises(ValueError, match="schema_version"):
        CalibrationProfile.from_dict(bad_version)
    bad_terms = dict(d, terms=["static", "act_saved"])
    with pytest.raises(ValueError, match="stale"):
        CalibrationProfile.from_dict(bad_terms)
    with pytest.raises(ValueError, match="kind"):
        CalibrationProfile.from_dict(dict(d, kind="other"))


def test_chip_offset_fallback():
    p = CalibrationProfile(chip_constant_bytes={"v5e": 10, "*": 3})
    assert p.chip_offset("v5e") == 10
    assert p.chip_offset("h100") == 3
    assert p.chip_offset(None) == 3
    q = CalibrationProfile(chip_constant_bytes={"v5e": 10})
    assert q.chip_offset("h100") == 0       # unknown chip: never a guess


# ---------------------------------------------------------------------------
# identity: no profile == identity profile, byte for byte
# ---------------------------------------------------------------------------


def test_identity_profile_is_noop():
    ident = CalibrationProfile.identity()
    assert ident.is_identity
    mesh = {"data": 4, "model": 2}
    raw = planner.check("smollm-360m", "train_4k", mesh)
    cal = planner.check("smollm-360m", "train_4k", mesh, profile=ident)
    assert raw.prediction == cal.prediction
    assert raw.peak_bytes == cal.peak_bytes
    assert cal.prediction.calibration_bytes == 0


def test_uncalibrated_prediction_unchanged_by_new_field():
    # the calibration_bytes field defaults to 0 and must not move peaks
    pred = planner.check("smollm-360m", "train_4k",
                         {"data": 4, "model": 2}).prediction
    assert pred.calibration_bytes == 0
    total = (pred.param_bytes + pred.grad_bytes + pred.opt_bytes
             + pred.act_saved_bytes + pred.act_transient_bytes
             + pred.loss_bytes + pred.input_bytes + pred.cache_bytes
             + pred.output_copy_bytes)
    assert pred.peak_bytes == total


# ---------------------------------------------------------------------------
# synthetic oracle: deterministic, distorted by the hidden profile
# ---------------------------------------------------------------------------


def test_synthetic_deterministic():
    a = small_store()
    b = small_store()
    assert [m.to_dict() for m in a] == [m.to_dict() for m in b]
    assert all(m.measured_bytes > 0 for m in a)


def test_synthetic_noise_bounded():
    clean = small_store(noise=0.0)
    noisy = small_store(noise=0.05)
    for c, n in zip(clean, noisy):
        assert c.key == n.key
        assert abs(n.measured_bytes - c.measured_bytes) \
            <= 0.05 * c.measured_bytes + 1


def test_bundled_fixture_matches_generator():
    """The checked-in benchmark fixture IS the generator's output —
    regeneration must reproduce it bit-for-bit."""
    path = repo_root() / "benchmarks" / "fixtures" / \
        "calibration_measurements.json"
    bundled = MeasurementStore.load(path)
    fresh = generate(engine=ENGINE)
    assert bundled.to_dict() == fresh.to_dict()


# ---------------------------------------------------------------------------
# residual decomposition
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("assembly", ["legacy", "liveness"])
def test_decompose_terms_sum_to_raw_peak(assembly):
    store = small_store()
    for row in decompose(store, ENGINE, assembly=assembly):
        assert set(row.terms) == set(TERMS)
        assert sum(row.terms.values()) == row.raw_peak_bytes
        assert row.residual_bytes == \
            row.measurement.measured_bytes - row.raw_peak_bytes


def test_decompose_liveness_peak_le_legacy():
    """The interval-overlap peak can only discard overlap slack — per
    measurement it is bounded above by the sum-of-maxima peak."""
    store = small_store()
    legacy = decompose(store, ENGINE, assembly="legacy")
    live = decompose(store, ENGINE, assembly="liveness")
    assert any(lv.raw_peak_bytes < lg.raw_peak_bytes
               for lg, lv in zip(legacy, live))
    for lg, lv in zip(legacy, live):
        assert lg.measurement.key == lv.measurement.key
        assert lv.raw_peak_bytes <= lg.raw_peak_bytes


# ---------------------------------------------------------------------------
# fitting: NNLS recovers the hidden ground truth
# ---------------------------------------------------------------------------


def test_nnls_nonnegative_exact_recovery():
    rng = np.random.RandomState(0)
    A = rng.rand(40, 4)
    x_true = np.array([1.2, 0.0, 0.7, 2.0])
    x, rnorm = nnls(A, A @ x_true)
    assert np.allclose(x, x_true, atol=1e-8)
    assert rnorm < 1e-8


def test_fit_recovers_true_profile_noiseless():
    # the oracle composes from the liveness decomposition, so the
    # closed loop recovers the hidden skews only when the fit uses the
    # same assembly; the non-affine oracle layers (family skew, seq
    # reservation) are disabled — an exact NNLS inversion is only
    # defined against an exactly-affine truth
    store = generate(engine=ENGINE, noise=0.0, family_skew=None,
                     knob_effects=None)
    prof = fit_profile(store, engine=ENGINE, assembly="liveness")
    for t in TERMS:
        assert prof.coefficients[t] == \
            pytest.approx(SYN.TRUE_PROFILE.coefficients[t], rel=0.02)
    for chip, k in SYN.TRUE_PROFILE.chip_constant_bytes.items():
        assert prof.chip_constant_bytes[chip] == pytest.approx(k, rel=0.05)


def test_fit_with_noise_still_close():
    # coefficient recovery (like the noiseless test above) is only
    # defined against a pure-affine oracle, so the non-affine layers
    # are disabled; the shared fixtures keep them ON for MAPE tests
    store = generate(engine=ENGINE, family_skew=None, knob_effects=None)
    prof = fit_profile(store, engine=ENGINE, assembly="liveness")
    for t in TERMS:
        # the at-peak transient slice is the smallest design column, so
        # measurement noise concentrates in its coefficient
        rel = 0.10 if t == "act_transient" else 0.05
        assert prof.coefficients[t] == \
            pytest.approx(SYN.TRUE_PROFILE.coefficients[t], rel=rel)


def test_legacy_oracle_escape_hatch():
    """generate(assembly="legacy") reproduces the historical oracle:
    a legacy-assembly fit recovers the hidden profile from it."""
    store = generate(archs=SMALL_ARCHS, engine=ENGINE, noise=0.0,
                     assembly="legacy", family_skew=None,
                     knob_effects=None)
    prof = fit_profile(store, engine=ENGINE)
    for t in TERMS:
        assert prof.coefficients[t] == \
            pytest.approx(SYN.TRUE_PROFILE.coefficients[t], rel=0.02)


def test_fit_refuses_empty_store():
    with pytest.raises(ValueError):
        fit_profile(MeasurementStore(), engine=ENGINE)


def test_unsupported_term_stays_identity():
    """A measurement set that exercises no cache/loss/input bytes must
    leave the overhead coefficient at 1.0, not NNLS's zero."""
    from repro.calibrate.fit import fit_rows
    from repro.calibrate.residual import TermRow
    store = small_store()
    rows = []
    for r in decompose(store, ENGINE):
        terms = dict(r.terms, overhead=0)
        rows.append(TermRow(measurement=r.measurement, terms=terms,
                            raw_peak_bytes=sum(terms.values())))
    prof = fit_rows(rows)
    assert prof.coefficients["overhead"] == 1.0
    assert "overhead" in prof.fit_info["inactive_terms"]


# ---------------------------------------------------------------------------
# accuracy: calibrated strictly better than raw, per family AND per arch
# ---------------------------------------------------------------------------


def test_calibrated_mape_strictly_lower_everywhere(fitted):
    store, prof = fitted
    by_family = evaluate(store, prof, by="family", engine=ENGINE)
    assert len(by_family.rows) == 6          # all six arch families
    for row in by_family.rows:
        assert row.mape_calibrated < row.mape_raw, row.group
    assert by_family.mape_calibrated < by_family.mape_raw
    by_arch = evaluate(store, prof, by="arch", engine=ENGINE)
    for row in by_arch.rows:
        assert row.mape_calibrated < row.mape_raw, row.group


def test_liveness_raw_mape_beats_legacy_raw(fitted, fitted_liveness):
    """ISSUE-9 acceptance: on the fixture set the raw liveness peak cuts
    the raw legacy MAPE (~11.2% -> ~10.5% with the ISSUE-10 non-affine
    oracle layers on), and the liveness fit still improves every family
    strictly."""
    store, prof_legacy = fitted
    _, prof_live = fitted_liveness
    legacy = evaluate(store, prof_legacy, by="family", engine=ENGINE,
                      assembly="legacy")
    live = evaluate(store, prof_live, by="family", engine=ENGINE,
                    assembly="liveness")
    assert live.mape_raw < legacy.mape_raw
    assert legacy.mape_raw == pytest.approx(11.2, abs=0.5)
    assert live.mape_raw == pytest.approx(10.5, abs=0.5)
    assert live.all_groups_improved
    for row in live.rows:
        assert row.mape_calibrated < row.mape_raw, row.group


def test_accuracy_report_writers(fitted, tmp_path):
    store, prof = fitted
    rep = evaluate(store, prof, by="family", engine=ENGINE)
    md = rep.to_markdown()
    assert "MAPE raw %" in md and "ALL" in md
    csv = rep.to_csv()
    assert csv.splitlines()[0].startswith("group,cells")
    rep.save_json(tmp_path / "r.json")
    loaded = json.loads((tmp_path / "r.json").read_text())
    assert loaded["n_measurements"] == rep.n
    assert set(loaded["groups"]) == {r.group for r in rep.rows}


# ---------------------------------------------------------------------------
# profile threading: memoized sweep == cell-by-cell check, byte for byte
# ---------------------------------------------------------------------------


def test_sweep_with_profile_matches_check(fitted):
    _, prof = fitted
    grid = SW.SweepGrid(
        arch="smollm-360m", chips=8,
        optimizers=(None, "adafactor"), remats=(None, "none"),
        grad_accums=(1, 2), global_batches=(16, 32), seq_lens=(512,),
        chip=("v5e", "h100"), backend="tpu",
        keep_predictions=True, profile=prof)
    res = SW.sweep(grid)
    assert len(res) > 50
    for r in res:
        shape = ShapeConfig("cell", r.seq_len, r.global_batch, r.kind)
        ref = planner.check(r.arch, shape, r.mesh_shape, backend=r.backend,
                            grad_accum=r.grad_accum, remat=r.remat,
                            optimizer=r.optimizer, chip=r.chip,
                            profile=prof)
        assert ref.peak_bytes == r.peak_bytes
        assert ref.fits == r.fits
        assert ref.prediction == r.prediction


def test_engine_does_not_leak_across_profiles(fitted):
    _, prof = fitted
    engine = SW.SweepEngine()
    cell = next(SW.SweepGrid(arch="smollm-360m", chips=4,
                             global_batches=(16,),
                             seq_lens=(256,)).cells())
    raw = engine.evaluate(cell, keep_prediction=True)
    cal = engine.evaluate(cell, keep_prediction=True, profile=prof)
    raw2 = engine.evaluate(cell, keep_prediction=True)
    assert raw == raw2                       # warm == cold, same profile
    assert cal.peak_bytes != raw.peak_bytes  # profile actually applied
    assert cal.prediction.calibration_bytes == prof.chip_offset(cell.chip)


def test_chip_constant_lands_in_prediction(fitted):
    _, prof = fitted
    mesh = {"data": 2, "model": 2}
    v5e = planner.check("smollm-360m", "train_4k", mesh, chip="v5e",
                        profile=prof)
    h100 = planner.check("smollm-360m", "train_4k", mesh, chip="h100",
                         profile=prof)
    assert v5e.prediction.calibration_bytes == prof.chip_offset("v5e")
    assert h100.prediction.calibration_bytes == prof.chip_offset("h100")
    assert v5e.peak_bytes - v5e.prediction.calibration_bytes == \
        h100.peak_bytes - h100.prediction.calibration_bytes


def test_planner_plan_accepts_profile(fitted):
    _, prof = fitted
    r = planner.plan("smollm-360m", "train_4k", {"data": 4, "model": 2},
                     profile=prof)
    assert r.peak_bytes > 0


# ---------------------------------------------------------------------------
# measurement store: round-trip + dryrun ingest
# ---------------------------------------------------------------------------


def test_store_roundtrip(tmp_path):
    store = small_store()
    path = store.save(tmp_path / "store.json")
    loaded = MeasurementStore.load(path)
    assert loaded.to_dict() == store.to_dict()
    assert loaded.archs() == store.archs()
    assert loaded.chips() == ["h100", "v5e"]


def test_store_rejects_wrong_schema(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"kind": "measurement_store",
                             "schema_version": 42, "measurements": []}))
    with pytest.raises(ValueError):
        MeasurementStore.load(p)


def _fake_dryrun_record(arch="smollm-360m", shape="train_4k",
                        mesh="16x16", total=7 * 1024 ** 3):
    return {"arch": arch, "shape": shape, "mesh": mesh, "kind": "train",
            "compile_seconds": 1.0,
            "memory": {"argument_bytes": 1, "output_bytes": 2,
                       "temp_bytes": 3, "alias_bytes": 0,
                       "total_bytes": total}}


def test_dryrun_ingest(tmp_path):
    (tmp_path / "a.json").write_text(json.dumps(_fake_dryrun_record()))
    (tmp_path / "b.json").write_text(json.dumps(
        _fake_dryrun_record(mesh="2x16x16", total=5 * 1024 ** 3)))
    (tmp_path / "junk.json").write_text("{\"not\": \"an artifact\"}")
    half = dict(_fake_dryrun_record(), memory=None)   # partially written
    (tmp_path / "half.json").write_text(json.dumps(half))
    store = MeasurementStore.ingest_dryrun_dir(tmp_path)
    assert len(store) == 2                  # junk + half skipped, not fatal
    m = store.measurements[0]
    assert m.arch == "smollm-360m"
    assert m.mesh_shape == {"data": 16, "model": 16}
    assert m.backend == "cpu"
    assert m.measured_bytes == 7 * 1024 ** 3
    assert store.measurements[1].mesh_shape == \
        {"pod": 2, "data": 16, "model": 16}
    with pytest.raises((KeyError, TypeError, ValueError)):
        MeasurementStore.ingest_dryrun_dir(tmp_path, strict=True)
    # ingested measurements decompose + predict like any other
    pred = predict_measurement(m, ENGINE)
    assert pred.peak_bytes > 0


def test_dryrun_ingest_explicit_mesh_shape(tmp_path):
    rec = _fake_dryrun_record()
    rec["mesh_shape"] = {"data": 8, "model": 4}    # new-format artifacts
    (tmp_path / "c.json").write_text(json.dumps(rec))
    store = MeasurementStore.ingest_dryrun_dir(tmp_path)
    assert store.measurements[0].mesh_shape == {"data": 8, "model": 4}


def test_dryrun_default_dir_is_shared():
    import repro.launch.dryrun as DR
    assert DR.OUT_DIR == str(dryrun_dir())


# ---------------------------------------------------------------------------
# CLI smoke
# ---------------------------------------------------------------------------


def test_cli_fit_apply_report(tmp_path, capsys):
    from repro.calibrate.__main__ import main
    prof_path = tmp_path / "prof.json"
    rc = main(["fit", "--synthetic", "--out", str(prof_path)])
    assert rc == 0
    assert prof_path.exists()
    rc = main(["apply", "--profile", str(prof_path),
               "--arch", "smollm_360m", "--mesh", "data=4,model=2",
               "--chip", "v5e"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "raw :" in out and "cal :" in out
    rc = main(["report", "--profile", str(prof_path), "--synthetic",
               "--by", "family", "--md", str(tmp_path / "r.md"),
               "--json", str(tmp_path / "r.json")])
    assert rc == 0
    assert (tmp_path / "r.md").exists()
    assert (tmp_path / "r.json").exists()


def test_configs_table_with_profile(fitted, tmp_path, capsys):
    _, prof = fitted
    from repro.configs.__main__ import main as cfg_main
    path = prof.save(tmp_path / "p.json")
    rc = cfg_main(["--profile", str(path), "--chip", "v5e"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "calibrated GiB" in out
    rc = cfg_main([])
    assert rc == 0
    assert "calibrated" not in capsys.readouterr().out


# ---------------------------------------------------------------------------
# measurement ingest: defect matrix, mesh parsing, knob round-trip
# ---------------------------------------------------------------------------


def test_parse_mesh_string():
    assert parse_mesh_string("8x4") == {"data": 8, "model": 4}
    assert parse_mesh_string("2x4x8") == {"pod": 2, "data": 4, "model": 8}
    for bad in ("16", "2x2x2x2", "axb", "8x0", "8x-4", ""):
        with pytest.raises(ValueError):
            parse_mesh_string(bad)


def test_dryrun_ingest_defect_matrix():
    """from_dryrun_record raises a ValueError NAMING the telemetry
    defect — a zero/negative/defective peak must never enter a fit as
    ground truth (it once sailed in as measured_bytes=0 and scored as a
    PERFECT prediction)."""
    cases = [
        ({"argument_bytes": 1}, "missing"),         # counters gone
        ({"total_bytes": "??"}, "non-numeric"),
        ({"argument_bytes": 1, "output_bytes": "x", "temp_bytes": 3,
          "alias_bytes": 0}, "non-numeric"),
        ({"total_bytes": 0}, "non-positive"),
        ({"argument_bytes": 1, "output_bytes": 1, "temp_bytes": 1,
          "alias_bytes": 10}, "non-positive"),
    ]
    for mem, needle in cases:
        rec = dict(_fake_dryrun_record(), memory=mem)
        with pytest.raises(ValueError, match=needle):
            Measurement.from_dryrun_record(rec, source="t.json")


def test_dryrun_ingest_truncated_json(tmp_path):
    (tmp_path / "trunc.json").write_text('{"arch": "smollm-360m", "mem')
    store = MeasurementStore.ingest_dryrun_dir(tmp_path)
    assert len(store) == 0                     # skipped, not fatal
    with pytest.raises(ValueError):            # JSONDecodeError is one
        MeasurementStore.ingest_dryrun_dir(tmp_path, strict=True)


def test_dryrun_ingest_rejects_unnameable_mesh():
    rec = _fake_dryrun_record(mesh="2x2x2x2")
    with pytest.raises(ValueError, match="mesh"):
        Measurement.from_dryrun_record(rec)


def test_measurement_schema_v1_knob_defaults():
    """Stores written before the pipeline/offload knobs load with the
    pre-knob defaults (m=1, 1f1b, no offload) — the exact cells those
    measurements were historically decomposed against."""
    d = {"arch": "smollm-360m", "kind": "train", "seq_len": 512,
         "global_batch": 8, "mesh_shape": {"data": 4},
         "measured_bytes": 123}
    m = Measurement.from_dict(d)
    assert (m.microbatches, m.schedule, m.offload_optimizer) == \
        (1, "1f1b", False)


def test_pipelined_measurement_roundtrip():
    """ISSUE-10 regression: a pp=4 / m=8 measurement must decompose
    against the pp=4 / m=8 cell (stash-bearing activations), not the
    schema-v1 default m=1 cell, and the two cells must never share a
    store key."""
    kw = dict(arch="smollm-360m", kind="train", seq_len=1024,
              global_batch=32, mesh_shape={"data": 2, "pipe": 4},
              measured_bytes=4 * 1024 ** 3, backend="tpu", chip="v5e")
    piped = Measurement(**kw, microbatches=8)
    flat = Measurement(**kw)                   # schema-v1 default m=1
    assert piped.key != flat.key
    pp, pf = (predict_measurement(m, ENGINE) for m in (piped, flat))
    assert pp.peak_bytes != pf.peak_bytes
    # m=8 stashes per-microbatch activations; m=1 holds the whole batch
    assert pp.act_saved_bytes < pf.act_saved_bytes
    for row in decompose(MeasurementStore([piped, flat]), ENGINE):
        assert sum(row.terms.values()) == row.raw_peak_bytes


def test_offload_measurement_roundtrip():
    kw = dict(arch="smollm-360m", kind="train", seq_len=1024,
              global_batch=32, mesh_shape={"data": 8},
              measured_bytes=4 * 1024 ** 3, backend="tpu", chip="v5e",
              optimizer="adamw")
    off = predict_measurement(Measurement(**kw, offload_optimizer=True),
                              ENGINE)
    on_dev = predict_measurement(Measurement(**kw), ENGINE)
    assert off.peak_bytes < on_dev.peak_bytes


def test_ape_nan_for_defective_actual():
    import math

    from repro.core import report as RPT
    bad = RPT.PredictionRecord("x", 100, 0)
    assert math.isnan(bad.ape)
    valid, excluded = RPT.split_valid([bad])
    assert valid == [] and excluded == 1
    assert RPT.mape([bad]) == 0.0              # no valid rows, no average
    good = RPT.PredictionRecord("y", 110, 100)
    assert RPT.grouped_mape({"g": [bad, good]}) == \
        [("g", 1, pytest.approx(10.0))]


def test_zero_actual_excluded_from_evaluate(fitted):
    store, prof = fitted
    poisoned = MeasurementStore(list(store.measurements))
    d = store.measurements[0].to_dict()
    d["measured_bytes"] = 0
    poisoned.add(Measurement.from_dict(d))
    clean = evaluate(store, prof, engine=ENGINE)
    rep = evaluate(poisoned, prof, engine=ENGINE)
    assert clean.n_excluded == 0 and rep.n_excluded == 1
    assert rep.n == clean.n
    assert rep.mape_raw == pytest.approx(clean.mape_raw)
    assert rep.mape_calibrated == pytest.approx(clean.mape_calibrated)
    assert "excluded" in rep.to_markdown()


# ---------------------------------------------------------------------------
# learned residual model: fit guard, inertness, memo keys, staleness, CLI
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fitted_residual(fitted):
    store, prof = fitted
    return store, prof, fit_residual(store, profile=prof, engine=ENGINE)


def test_residual_fit_never_worsens_in_sample(fitted_residual):
    _, _, model = fitted_residual
    info = model.fit_info
    assert info["mape_learned_pct"] <= info["mape_affine_pct"]
    # the fixture oracle has real non-affine structure to learn
    assert info["mape_learned_pct"] < info["mape_affine_pct"]
    assert model.global_weights is not None
    assert not model.is_identity


def test_residual_guard_on_pure_affine_store():
    """On an exactly-affine store there is nothing left to learn; the
    guard keeps any weight vector that cannot strictly improve its own
    rows' MAPE out of the model, so the fit can never worsen it."""
    store = generate(archs=SMALL_ARCHS, engine=ENGINE, noise=0.0,
                     family_skew=None, knob_effects=None,
                     assembly="legacy")
    prof = fit_profile(store, engine=ENGINE)
    model = fit_residual(store, profile=prof, engine=ENGINE)
    info = model.fit_info
    assert info["mape_learned_pct"] <= info["mape_affine_pct"]


def test_residual_fit_refuses_empty_store():
    with pytest.raises(ValueError):
        fit_residual(MeasurementStore(), engine=ENGINE)
    # a store of only defective rows is as empty as an empty one
    d = {"arch": "smollm-360m", "kind": "train", "seq_len": 512,
         "global_batch": 8, "mesh_shape": {"data": 4},
         "measured_bytes": 0}
    with pytest.raises(ValueError):
        fit_residual(MeasurementStore([Measurement.from_dict(d)]),
                     engine=ENGINE)


def test_identity_residual_bit_inert(fitted):
    store, prof = fitted
    m = store.measurements[0]
    base = predict_measurement(m, ENGINE, profile=prof)
    ident = predict_measurement(
        m, ENGINE, profile=prof,
        residual=ResidualModel.identity(prof.profile_hash))
    assert ident is base               # the exact cached base object
    assert ResidualModel.identity().is_identity


def test_sweep_identity_residual_matches_plain(fitted):
    _, prof = fitted
    kw = dict(arch="smollm-360m", chips=8, global_batches=(16,),
              seq_lens=(512,), profile=prof)
    plain = SW.sweep(SW.SweepGrid(**kw))               # columnar path
    ident = SW.sweep(SW.SweepGrid(                     # cell path
        **kw, residual_model=ResidualModel.identity(prof.profile_hash)))
    assert [r.peak_bytes for r in plain.results] == \
        [r.peak_bytes for r in ident.results]
    assert [r.fits for r in plain.results] == \
        [r.fits for r in ident.results]


def test_residual_memo_keys_differ_across_versions(fitted):
    store, prof = fitted
    m = store.measurements[0]
    w1 = [0.0] * len(FEATURE_NAMES)
    w1[0] = 0.25                       # +0.25 GiB constant correction
    w2 = list(w1)
    w2[0] = 0.5
    m1 = ResidualModel(global_weights=tuple(w1),
                       base_profile_hash=prof.profile_hash)
    m2 = ResidualModel(global_weights=tuple(w2),
                       base_profile_hash=prof.profile_hash)
    assert m1.model_hash != m2.model_hash
    base = predict_measurement(m, ENGINE, profile=prof)
    p1 = predict_measurement(m, ENGINE, profile=prof, residual=m1)
    p2 = predict_measurement(m, ENGINE, profile=prof, residual=m2)
    assert p1.peak_bytes == base.peak_bytes + 256 * 1024 ** 2
    assert p2.peak_bytes == base.peak_bytes + 512 * 1024 ** 2
    # same model hash -> the exact cached object; versions never mix
    assert predict_measurement(m, ENGINE, profile=prof,
                               residual=m1) is p1


def test_residual_roundtrip_and_staleness(tmp_path, fitted_residual):
    _, _, model = fitted_residual
    path = model.save(tmp_path / "r.json")
    loaded = ResidualModel.load(path)
    assert loaded.model_hash == model.model_hash
    assert loaded.families == model.families
    assert loaded.global_weights == model.global_weights
    d = model.to_dict()
    with pytest.raises(ValueError):
        ResidualModel.from_dict(dict(d, kind="calibration_profile"))
    with pytest.raises(ValueError):
        ResidualModel.from_dict(dict(d, schema_version=99))
    with pytest.raises(ValueError):
        ResidualModel.from_dict(dict(d, features=["a", "b"]))
    with pytest.raises(ValueError):
        ResidualModel(global_weights=(1.0, 2.0))       # wrong arity


def test_residual_profile_binding(fitted_residual):
    store, prof, model = fitted_residual
    m = store.measurements[0]
    with pytest.raises(ValueError, match="profile"):
        predict_measurement(m, ENGINE, residual=model)   # no profile
    other = CalibrationProfile(
        coefficients={"static": 1.01, "act_saved": 1.0,
                      "act_transient": 1.0, "overhead": 1.0})
    with pytest.raises(ValueError, match="profile"):
        predict_measurement(m, ENGINE, profile=other, residual=model)


def test_residual_evaluate_adds_learned_series(fitted_residual):
    store, prof, model = fitted_residual
    rep = evaluate(store, prof, by="family", engine=ENGINE,
                   residual=model)
    assert rep.mape_learned is not None
    assert rep.mape_learned < rep.mape_calibrated
    assert rep.residual_hash == model.model_hash
    assert "MAPE learned %" in rep.to_markdown()
    assert rep.to_csv().splitlines()[0].endswith("mape_learned_pct")
    assert rep.to_json_dict()["residual_hash"] == model.model_hash


def test_leave_one_family_out_folds(fitted):
    from repro.calibrate.report import _family_of
    store, _ = fitted
    folds = leave_one_family_out(store)
    assert len(folds) == 6             # all six arch families
    for fam, train, test in folds:
        assert len(train) + len(test) == len(store)
        assert {_family_of(m.arch) for m in test} == {fam}
        assert fam not in {_family_of(m.arch) for m in train}


def test_held_out_family_uses_global_fallback(fitted):
    store, prof = fitted
    fam, train, _ = leave_one_family_out(store)[0]
    model = fit_residual(train, profile=prof, engine=ENGINE)
    assert fam not in model.families
    assert model.weights_for(fam) is model.global_weights


def test_jax_engine_rejects_residual(fitted_residual):
    _, prof, model = fitted_residual
    grid = SW.SweepGrid(arch="smollm-360m", chips=4,
                        global_batches=(16,), seq_lens=(256,),
                        profile=prof, residual_model=model)
    with pytest.raises(ValueError, match="residual"):
        ENGINE.sweep(grid, engine="jax")


def test_cli_fit_residual_apply_report(tmp_path, capsys):
    from repro.calibrate.__main__ import main
    prof_path = tmp_path / "prof.json"
    res_path = tmp_path / "res.json"
    assert main(["fit", "--synthetic", "--out", str(prof_path)]) == 0
    rc = main(["fit-residual", "--synthetic", "--profile",
               str(prof_path), "--out", str(res_path)])
    assert rc == 0 and res_path.exists()
    assert "in-sample MAPE" in capsys.readouterr().out
    rc = main(["apply", "--profile", str(prof_path),
               "--residual-model", str(res_path),
               "--arch", "smollm_360m", "--mesh", "data=4,model=2",
               "--chip", "v5e"])
    assert rc == 0
    assert "ResidualModel[" in capsys.readouterr().out
    rc = main(["report", "--profile", str(prof_path),
               "--residual-model", str(res_path), "--synthetic",
               "--by", "family"])
    assert rc == 0
    assert "MAPE learned %" in capsys.readouterr().out


def test_cli_residual_profile_mismatch(tmp_path):
    from repro.calibrate.__main__ import main
    prof_path = tmp_path / "prof.json"
    res_path = tmp_path / "res.json"
    assert main(["fit", "--synthetic", "--out", str(prof_path)]) == 0
    # fitted WITHOUT a profile: bound to the raw prediction
    assert main(["fit-residual", "--synthetic",
                 "--out", str(res_path)]) == 0
    with pytest.raises(SystemExit):
        main(["apply", "--profile", str(prof_path),
              "--residual-model", str(res_path),
              "--arch", "smollm_360m", "--mesh", "data=4,model=2",
              "--chip", "v5e"])


def test_configs_table_with_residual(fitted_residual, tmp_path, capsys):
    _, prof, model = fitted_residual
    from repro.configs.__main__ import main as cfg_main
    pp = prof.save(tmp_path / "p.json")
    rp = model.save(tmp_path / "r.json")
    rc = cfg_main(["--profile", str(pp), "--residual-model", str(rp),
                   "--chip", "v5e"])
    assert rc == 0
    assert "learned GiB" in capsys.readouterr().out
