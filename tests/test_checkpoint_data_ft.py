"""Checkpointing (sync/async/retention/elastic), deterministic data
pipeline, and the fault-tolerant trainer driver."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.conftest import tiny_batch
from repro.checkpoint import (Checkpointer, latest_step, load_checkpoint,
                              save_checkpoint)
from repro.configs import ShapeConfig, get_config
from repro.core.spec import FULL_TRAIN
from repro.data.pipeline import SyntheticPipeline
from repro.models import build_model
from repro.models import param as PM
from repro.runtime import FaultConfig, ResilientTrainer
from repro.train import OptimizerConfig, TrainState, make_train_step
from repro.train.optimizer import init_opt_state


def _state(model):
    params = model.init(jax.random.PRNGKey(0))
    mask = PM.trainable_mask(model.spec, FULL_TRAIN)
    tr, _ = PM.partition_params(params, mask)
    return TrainState(params=params,
                      opt=init_opt_state(tr, OptimizerConfig()),
                      step=jnp.int32(0))


def _trees_equal(a, b):
    fa = jax.tree.leaves(a, is_leaf=lambda x: x is None)
    fb = jax.tree.leaves(b, is_leaf=lambda x: x is None)
    for x, y in zip(fa, fb):
        if x is None:
            assert y is None
            continue
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_roundtrip(tmp_path):
    model = build_model(get_config("smollm-360m").reduced())
    state = _state(model)
    save_checkpoint(str(tmp_path), 7, state)
    assert latest_step(str(tmp_path)) == 7
    restored = load_checkpoint(str(tmp_path), 7, like=state)
    _trees_equal(state, restored)


def test_checkpoint_none_leaves_roundtrip(tmp_path):
    """Trainable/frozen partitions contain None leaves — must survive."""
    model = build_model(get_config("llava-next-mistral-7b").reduced())
    from repro.core.spec import LLAVA_STAGE1
    params = model.init(jax.random.PRNGKey(0))
    mask = PM.trainable_mask(model.spec, LLAVA_STAGE1)
    tr, _ = PM.partition_params(params, mask)
    save_checkpoint(str(tmp_path), 1, tr)
    restored = load_checkpoint(str(tmp_path), 1, like=tr)
    _trees_equal(tr, restored)


def test_async_checkpointer_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(10)}
    for step in (1, 2, 3, 4):
        ck.save_async(step, tree)
    ck.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]
    step, restored = ck.restore_latest(like=tree)
    assert step == 4
    _trees_equal(tree, restored)


def test_pipeline_deterministic_and_restart_safe():
    cfg = get_config("smollm-360m").reduced()
    shape = ShapeConfig("t", 32, 8, "train")
    p1 = SyntheticPipeline(cfg, shape, n_shards=4, shard_id=2)
    a = p1.shard_batch(step=11)
    b = p1.shard_batch(step=11)        # same step -> identical
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = p1.shard_batch(step=12)        # different step -> different
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_pipeline_elastic_repartition():
    """Re-sharding the pipeline reproduces the same global batch."""
    cfg = get_config("smollm-360m").reduced()
    shape = ShapeConfig("t", 32, 8, "train")
    g4 = SyntheticPipeline(cfg, shape, n_shards=4).global_batch(3)
    g2 = SyntheticPipeline(cfg, shape, n_shards=2).global_batch(3)
    # shard boundaries differ, but rows are keyed by absolute row0 ranges:
    # shards of 2 cover rows (0..3)(4..7); shards of 4 cover (0..1)(2..3)...
    # identical global content requires same (step, row0) keying granularity,
    # so compare the 4-shard assembly against itself re-sharded
    g4b = SyntheticPipeline(cfg, shape, n_shards=4).global_batch(3)
    np.testing.assert_array_equal(g4["tokens"], g4b["tokens"])
    assert g2["tokens"].shape == g4["tokens"].shape


def test_resilient_trainer_recovers_from_failure(tmp_path):
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    shape = ShapeConfig("t", 32, 4, "train")
    pipe = SyntheticPipeline(cfg, shape)
    step_fn = jax.jit(make_train_step(model, FULL_TRAIN, OptimizerConfig()))

    def make_batch(step):
        return {k: jnp.asarray(v) for k, v in pipe.global_batch(step).items()}

    fails = {5}
    trainer = ResilientTrainer(
        train_step=step_fn, pipeline=pipe,
        checkpointer=Checkpointer(str(tmp_path), keep=2),
        fault_cfg=FaultConfig(ckpt_every=3, max_restarts=2),
        make_batch=make_batch,
        failure_injector=lambda s: s in fails and not fails.remove(s))

    state, history = trainer.run(_state(model), start_step=0, n_steps=10)
    assert trainer.restarts == 1
    assert int(state.step) >= 10
    assert all(np.isfinite(h["loss"]) for h in history)
    # failure at step 5 rolls back to the step-3 checkpoint and REPLAYS
    # steps 3-4 (deterministic pipeline -> identical batches), then
    # continues through step 9: every step is eventually covered.
    steps = [h["step"] for h in history]
    assert set(steps) == set(range(10))
    replayed = [s for s in set(steps) if steps.count(s) > 1]
    assert replayed, "rollback must replay from the checkpoint"
    # replayed steps produced identical losses (bit-determinism of the
    # pipeline + restored state)
    for s in replayed:
        losses = [h["loss"] for h in history if h["step"] == s]
        assert len(set(losses)) == 1, (s, losses)


def test_resilient_trainer_straggler_detection(tmp_path):
    import time as _time
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    shape = ShapeConfig("t", 16, 2, "train")
    pipe = SyntheticPipeline(cfg, shape, n_shards=2, shard_id=1)
    calls = {"n": 0}

    def slow_step(state, batch):
        calls["n"] += 1
        if calls["n"] == 6:
            _time.sleep(0.75)           # inject one slow step
        return state, {"loss": jnp.float32(1.0)}

    trainer = ResilientTrainer(
        train_step=slow_step, pipeline=pipe,
        checkpointer=Checkpointer(str(tmp_path)),
        fault_cfg=FaultConfig(straggler_factor=3.0, ckpt_every=100),
        make_batch=lambda s: {})
    trainer.run(_state(model), start_step=0, n_steps=8)
    assert len(trainer.straggler_events) >= 1
