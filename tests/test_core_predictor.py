"""Unit tests for the paper's core: parser, factor equations, predictor.

The exactness invariants (param/opt factors equal the bytes the runtime
actually allocates) are what make the framework's Eq.1 trustworthy.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, ShapeConfig, get_config
from repro.core import factors as F
from repro.core.parser import active_params, parse_model, total_params
from repro.core.spec import (FULL_TRAIN, LLAVA_STAGE1, LLAVA_STAGE2,
                             TrainPolicy, dtype_bytes)
from repro.core import predictor as PR
from repro.models import build_model
from repro.models import param as PM
from repro.train.optimizer import OptimizerConfig, init_opt_state


def nbytes_tree(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if x is not None)


# ---------------------------------------------------------------------------
# parser
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,expect_params,tol", [
    ("smollm-360m", 360e6, 0.10),
    ("llama3.2-3b", 3.2e9, 0.15),
    ("minicpm3-4b", 4.0e9, 0.20),
    ("qwen3-32b", 32e9, 0.15),
    ("deepseek-v2-lite-16b", 16e9, 0.20),
    ("arctic-480b", 480e9, 0.10),
    ("mamba2-1.3b", 1.3e9, 0.20),
    ("llava-next-mistral-7b", 7.2e9, 0.15),
    ("zamba2-2.7b", 2.7e9, 0.25),
    ("seamless-m4t-large-v2", 2.3e9, 0.35),
])
def test_param_counts_match_published_size(arch, expect_params, tol,
                                            zoo_rows):
    """The spec tree reproduces each model's published parameter count."""
    _, _, rows = zoo_rows(arch)
    n = total_params(rows)
    assert abs(n - expect_params) / expect_params < tol, \
        f"{arch}: {n/1e9:.2f}B params vs expected {expect_params/1e9:.2f}B"


def test_parser_param_count_matches_allocation(reduced_zoo):
    """Parsed counts == actually allocated leaves (exactness)."""
    _, model, params = reduced_zoo("smollm-360m")
    rows = parse_model(model.spec, FULL_TRAIN)
    assert total_params(rows) == PM.count_params(params)


def test_policy_freezes_modules(reduced_zoo):
    _, model, _ = reduced_zoo("llava-next-mistral-7b")
    rows = parse_model(model.spec, LLAVA_STAGE1)
    frozen = [r for r in rows if not r.trainable]
    trainable = [r for r in rows if r.trainable]
    assert trainable and frozen
    assert all("projector" in r.path for r in trainable)
    rows2 = parse_model(model.spec, LLAVA_STAGE2)
    t2 = {r.path for r in rows2 if r.trainable}
    assert any("language_model" in p for p in t2)
    assert not any("vision" in p for p in t2)


def test_active_params_moe_less_than_total(zoo_rows):
    _, _, rows = zoo_rows("deepseek-v2-lite-16b")
    assert active_params(rows) < 0.35 * total_params(rows)


# ---------------------------------------------------------------------------
# factor equations: exactness vs real allocations
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["smollm-360m", "deepseek-v2-lite-16b",
                                  "mamba2-1.3b", "seamless-m4t-large-v2"])
def test_param_factor_exact_unsharded(arch, reduced_zoo):
    """Sum of param factors on a 1-device mesh == allocated param bytes."""
    _, model, params = reduced_zoo(arch)
    rows = parse_model(model.spec, FULL_TRAIN)
    ctx = F.PredictContext(mesh_shape={}, global_batch=2, seq_len=32)
    predicted = sum(F.param_factor(r, ctx) for r in rows)
    assert predicted == nbytes_tree(params)


@pytest.mark.parametrize("opt", ["adamw", "adamw8bit", "adafactor"])
def test_opt_factor_exact(opt, reduced_zoo):
    """Optimizer-state factor == bytes of the real optimizer state."""
    _, model, params = reduced_zoo("smollm-360m")
    rows = parse_model(model.spec, FULL_TRAIN)
    cfg = OptimizerConfig(name=opt, master_fp32=(opt != "adafactor"))
    ctx = F.PredictContext(mesh_shape={}, optimizer=opt,
                           master_fp32=(opt != "adafactor"),
                           global_batch=2, seq_len=32)
    predicted = sum(F.opt_factor(r, ctx) for r in rows)
    state = init_opt_state(params, cfg)
    assert predicted == nbytes_tree(state)


def test_grad_factor_zero_for_frozen(reduced_zoo):
    _, model, _ = reduced_zoo("llava-next-mistral-7b")
    rows = parse_model(model.spec, LLAVA_STAGE1)
    ctx = F.PredictContext(mesh_shape={}, global_batch=2, seq_len=32)
    for r in rows:
        g = F.grad_factor(r, ctx)
        o = F.opt_factor(r, ctx)
        a = F.act_factor_saved(r, ctx)
        if not r.trainable:
            assert g == 0 and o == 0 and a == 0
        elif r.layer.params:
            assert g > 0 and o > 0


def test_grad_factor_zero_for_serving(reduced_zoo):
    _, model, _ = reduced_zoo("smollm-360m")
    rows = parse_model(model.spec, FULL_TRAIN)
    ctx = F.PredictContext(mesh_shape={}, kind="decode", global_batch=2,
                           seq_len=32)
    assert sum(F.grad_factor(r, ctx) + F.opt_factor(r, ctx)
               for r in rows) == 0


def test_sharding_divides_factors():
    """TP over `model` divides the sharded factors by the mesh size."""
    model = build_model(get_config("llama3.2-3b"))
    rows = parse_model(model.spec, FULL_TRAIN)
    ctx1 = F.PredictContext(mesh_shape={}, global_batch=8, seq_len=128)
    ctx16 = F.PredictContext(mesh_shape={"model": 16},
                             global_batch=8, seq_len=128)
    p1 = sum(F.param_factor(r, ctx1) for r in rows)
    p16 = sum(F.param_factor(r, ctx16) for r in rows)
    # most params shard 16x; norms/embeds partially -> between 2x and 16x
    assert p1 / 16 <= p16 <= p1 / 2


def test_zero_shards_optimizer_over_data(zoo_rows):
    _, _, rows = zoo_rows("llama3.2-3b")
    base = F.PredictContext(mesh_shape={"data": 8}, zero=False, fsdp=False,
                            global_batch=8, seq_len=128)
    zero = F.PredictContext(mesh_shape={"data": 8}, zero=True, fsdp=False,
                            global_batch=8, seq_len=128)
    o_base = sum(F.opt_factor(r, base) for r in rows)
    o_zero = sum(F.opt_factor(r, zero) for r in rows)
    p_base = sum(F.param_factor(r, base) for r in rows)
    p_zero = sum(F.param_factor(r, zero) for r in rows)
    assert o_zero < o_base / 4          # ZeRO shards states ~8x
    assert p_zero == p_base             # but params stay replicated (ZeRO-2)


def test_remat_reduces_saved_activations(zoo_rows):
    _, _, rows = zoo_rows("llama3.2-3b")
    none = F.PredictContext(mesh_shape={}, remat="none", global_batch=4,
                            seq_len=256)
    block = F.PredictContext(mesh_shape={}, remat="block", global_batch=4,
                             seq_len=256)
    a_none = sum(F.act_factor_saved(r, none) for r in rows)
    a_block = sum(F.act_factor_saved(r, block) for r in rows)
    assert a_block < a_none / 4


# ---------------------------------------------------------------------------
# predictor aggregation
# ---------------------------------------------------------------------------


def test_predict_peak_monotone_in_batch(zoo_rows):
    _, model, _ = zoo_rows("smollm-360m")
    peaks = []
    for b in (8, 16, 32):
        ctx = F.PredictContext(mesh_shape={}, global_batch=b, seq_len=512)
        peaks.append(PR.predict(model, FULL_TRAIN, ctx).peak_bytes)
    assert peaks[0] < peaks[1] < peaks[2]


def test_predict_reports_per_module(reduced_zoo):
    # llava15-7b carries the REAL (frozen) vision tower — the paper's case
    _, model, _ = reduced_zoo("llava15-7b")
    ctx = F.PredictContext(mesh_shape={}, global_batch=2, seq_len=64)
    pred = PR.predict(model, LLAVA_STAGE2, ctx)
    mods = pred.per_module
    assert any(not v["trainable"] for v in mods.values())
    assert any(v["trainable"] for v in mods.values())
    frozen_opt = sum(v["opt"] for v in mods.values() if not v["trainable"])
    assert frozen_opt == 0


def test_cache_bytes_decode_scale_with_len(zoo_rows):
    _, model, _ = zoo_rows("llama3.2-3b")
    ctx1 = F.PredictContext(mesh_shape={}, kind="decode", global_batch=4,
                            seq_len=1024, max_len=1024)
    ctx2 = F.PredictContext(mesh_shape={}, kind="decode", global_batch=4,
                            seq_len=2048, max_len=2048)
    c1 = PR.predict(model, FULL_TRAIN, ctx1).cache_bytes
    c2 = PR.predict(model, FULL_TRAIN, ctx2).cache_bytes
    assert c2 == 2 * c1 > 0


def test_mla_cache_much_smaller_than_gqa_equivalent(zoo_rows):
    """MLA's latent cache (the paper-zoo's memory trick) is ~10x smaller."""
    _, mla_model, _ = zoo_rows("deepseek-v2-lite-16b")
    # architectural comparison -> tpu backend (no cpu-oracle fp32 twins)
    ctx = F.PredictContext(mesh_shape={}, kind="decode", global_batch=4,
                           seq_len=4096, max_len=4096, backend="tpu")
    mla_cache = PR.predict(mla_model, FULL_TRAIN, ctx).cache_bytes
    # equivalent naive GQA cache: 2 * L * B * S * H * hd * 2 bytes
    cfg = get_config("deepseek-v2-lite-16b")
    naive = 2 * cfg.n_layers * 4 * 4096 * cfg.n_heads * 128 * 2
    assert mla_cache < naive / 4
