"""Expert-parallel (ep) + context-parallel (cp) memory model (ISSUE-5).

Contracts under test:

* **Inertness** — a mesh with ``expert=1``/``context=1`` (or without the
  axes) is byte-identical to prior main on every registered arch x
  train/prefill/decode (the golden suite freezes the absolute bytes;
  this file asserts the trivial-axis equivalence per arch).
* **Semantics** — ``expert`` divides exactly the MoE weight stacks and
  dispatch buffers (never dense layers); ``context`` divides the seq dim
  of train/prefill activations and adds the ring-attention per-hop KV
  send/recv transient; decode KV caches stay on ``cache_seq``.
* **Parity** — scalar (un-memoized ``planner.check``), memoized cell
  mode, and the columnar engine agree byte-for-byte on ep x cp x pp
  grids, raw and calibrated.
"""

import pytest

from repro.calibrate.profile import CalibrationProfile
from repro.configs import ShapeConfig, get_config, registered_archs
from repro.core import factors as F
from repro.core import planner
from repro.core import sweep as SW
from repro.core.parser import parse_model
from repro.core.spec import FULL_TRAIN
from repro.mesh_ctx import DEFAULT_RULES, shard_factor
from repro.models import build_model

ARCHS = registered_archs()
MOE_ARCHS = [a for a in ARCHS if get_config(a).moe is not None]

PROFILE = CalibrationProfile(
    coefficients={"static": 1.0271, "act_saved": 0.9582,
                  "act_transient": 1.1514, "overhead": 0.8899},
    chip_constant_bytes={"v5e": 98765432, "*": 11111111})

#: ep x cp x pp crossed, as the acceptance grid demands
EPCP_PP_MESHES = [
    {"data": 2, "model": 1, "expert": e, "context": c, "pipe": p}
    for e in (1, 2, 4) for c in (1, 2, 4) for p in (1, 2, 4)]


# ---------------------------------------------------------------------------
# inertness: trivial axes reproduce prior main on every arch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCHS)
def test_trivial_ep_cp_axes_byte_identical_per_arch(arch, sweep_engine):
    """expert=1 x context=1 x pipe=1 == the axis-free mesh, for every
    component of every kind (with the golden suite pinning the axis-free
    bytes to prior main, this closes the ep=1/cp=1 no-drift argument)."""
    budget = int(planner.chip_hbm("v5e") * planner.HEADROOM)
    for kind in ("train", "prefill", "decode"):
        shape = ShapeConfig("cell", 1024, 8, kind)
        base = sweep_engine.report(arch, shape, {"data": 2, "model": 2},
                                   backend="tpu", budget_bytes=budget)
        triv = sweep_engine.report(
            arch, shape,
            {"data": 2, "model": 2, "expert": 1, "context": 1, "pipe": 1},
            backend="tpu", budget_bytes=budget)
        assert triv.peak_bytes == base.peak_bytes, (arch, kind)
        for f in ("param_bytes", "grad_bytes", "opt_bytes",
                  "act_saved_bytes", "act_transient_bytes", "loss_bytes",
                  "input_bytes", "cache_bytes", "output_copy_bytes"):
            assert getattr(triv.prediction, f) \
                == getattr(base.prediction, f), (arch, kind, f)


# ---------------------------------------------------------------------------
# semantics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", MOE_ARCHS)
def test_ep_divides_only_moe_terms(arch):
    """The expert axis shrinks MoE params (E-dim weight stacks) and the
    dispatch-buffer activations; every dense module's bytes are
    untouched."""
    shape = ShapeConfig("cell", 1024, 8, "train")
    base = planner.check(arch, shape, {"data": 2, "model": 1})
    ep = planner.check(arch, shape, {"data": 2, "model": 1, "expert": 4})
    assert ep.prediction.param_bytes < base.prediction.param_bytes
    shrunk = []
    for path, m in base.prediction.per_module.items():
        e = ep.prediction.per_module[path]
        rows = (m["param"], m["grad"], m["opt"], m["act"])
        erows = (e["param"], e["grad"], e["opt"], e["act"])
        if "blocks" in path:            # the MoE stacks live here
            shrunk.append(erows < rows)
        else:                           # embed / head / norms: untouched
            assert erows == rows, path
    assert any(shrunk)


def test_ep_shard_factor_on_expert_dims_only():
    """Rule-table check: `expert` divides `experts`/`expert_buf` dims
    and nothing else (heads, ffn, vocab, batch stay put)."""
    mesh = {"data": 2, "model": 2, "expert": 4}
    rules = dict(DEFAULT_RULES)
    # experts rule is (expert, model): E=64 takes expert x4, then model
    # x2 on what stays divisible -> 8-way; expert_buf is EP-only -> 4
    assert shard_factor((64, 2048, 1408), ("experts", "embed", None),
                        mesh, rules) == 8
    assert shard_factor((64,), ("experts",), mesh, rules) == 8
    assert shard_factor((15360,), ("expert_buf",), mesh, rules) == 4
    for ax in ("heads", "ffn", "vocab", "batch"):
        with_ep = shard_factor((64, 4096), (ax, None), mesh, rules)
        without = shard_factor((64, 4096), (ax, None),
                               {"data": 2, "model": 2}, rules)
        assert with_ep == without, ax


def test_cp_divides_seq_activations_and_adds_ring_transient():
    shape = ShapeConfig("cell", 2048, 8, "train")
    base = planner.check("llama3.2-3b", shape, {"data": 2, "model": 1})
    cp = planner.check("llama3.2-3b", shape,
                       {"data": 2, "model": 1, "context": 4})
    # saved seq activations divide by cp
    assert cp.prediction.act_saved_bytes * 4 \
        == base.prediction.act_saved_bytes
    # the ring KV send/recv buffers exist only under cp
    cfg = get_config("llama3.2-3b")
    rows = parse_model(build_model(cfg).spec, FULL_TRAIN)
    attn = next(r for r in rows if r.layer.kind == "attention")
    spec = F.ring_kv_spec(attn)
    assert spec is not None and spec.nbytes == 2 and spec.mult == 4
    ctx = planner.make_context(cfg, {"data": 2, "model": 1, "context": 4},
                               kind="train", global_batch=8, seq_len=2048)
    assert F._ring_bytes(attn, ctx) > 0
    ctx1 = planner.make_context(cfg, {"data": 2, "model": 1},
                                kind="train", global_batch=8, seq_len=2048)
    assert F._ring_bytes(attn, ctx1) == 0


def test_cp_shards_prefill_cache_but_not_decode():
    """Under ring-attention prefill each cp rank holds only its sequence
    block's KV, so the prefill `cache_seq` rule names `context` (ahead
    of `model`) and prefill cache bytes divide by cp; decode never does
    (cp is rejected there, and its `cache_seq` stays model-only)."""
    from repro.launch.mesh import arch_rules
    cfg = get_config("llama3.2-3b")
    assert "context" in arch_rules(cfg, "train")["seq"]
    assert "context" in arch_rules(cfg, "prefill")["seq"]
    assert arch_rules(cfg, "prefill")["cache_seq"][0] == "context"
    assert "context" not in arch_rules(cfg, "decode").get("cache_seq", ())
    assert "context" not in arch_rules(cfg, "decode").get("seq", ())
    shape = ShapeConfig("cell", 2048, 8, "prefill")
    base = planner.check("llama3.1-8b", shape, {"data": 1, "model": 1})
    cp4 = planner.check("llama3.1-8b", shape,
                        {"data": 1, "model": 1, "context": 4})
    assert cp4.prediction.cache_bytes * 4 == base.prediction.cache_bytes


def test_plan_min_chips_filters_illegal_enumerations():
    """plan_min_chips is a search: enumerated meshes check_parallel
    would reject are filtered, not fatal — non-divisible cp degrees
    drop out, a dense arch with allow_ep keeps its expert=1 slice."""
    shape = ShapeConfig("cell", 1002, 8, "train")      # 1002 % 4 != 0
    r = planner.plan_min_chips("deepseek-v2-lite-16b", shape,
                               chips=(32, 64), allow_cp=True, max_cp=4)
    assert r is not None and r.cp in (1, 2)
    r2 = planner.plan_min_chips(
        "smollm-360m", ShapeConfig("cell", 1024, 8, "train"),
        chips=(8,), allow_ep=True)
    assert r2 is not None and r2.ep == 1
    # decode + allow_cp: every cp>1 mesh filtered, cp=1 slice searched
    r3 = planner.plan_min_chips(
        "smollm-360m", ShapeConfig("cell", 512, 4, "decode"),
        chips=(8,), allow_cp=True, allow_pp=False)
    assert r3 is None or r3.cp == 1


def test_ring_spec_shapes_gqa_vs_mla():
    gqa_rows = parse_model(build_model(get_config("llama3.1-8b")).spec,
                           FULL_TRAIN)
    mla_rows = parse_model(
        build_model(get_config("deepseek-v2-lite-16b")).spec, FULL_TRAIN)
    gqa = next(r for r in gqa_rows if r.layer.kind == "attention")
    mla = next(r for r in mla_rows if r.layer.kind == "attention"
               and r.layer.meta.get("attn_kind") == "mla")
    sg = F.ring_kv_spec(gqa)
    assert sg.mult == 4                      # (k + v) x (send + recv)
    sm = F.ring_kv_spec(mla)
    assert sm.mult == 2                      # one latent x (send + recv)
    mcfg = get_config("deepseek-v2-lite-16b").mla
    assert mcfg.kv_lora_rank + mcfg.qk_rope_head_dim in sm.dims
    # non-attention rows have no ring
    ssm_rows = parse_model(build_model(get_config("mamba2-1.3b")).spec,
                           FULL_TRAIN)
    assert all(F.ring_kv_spec(r) is None for r in ssm_rows
               if r.layer.kind != "attention")


def test_predict_context_ep_cp_properties():
    """ep/cp derive from the mesh (unlike pp, which make_context sets
    from the pipe axis explicitly)."""
    ctx = F.PredictContext(mesh_shape={"data": 2, "expert": 4,
                                       "context": 2})
    assert (ctx.ep, ctx.cp) == (4, 2)
    assert F.PredictContext(mesh_shape={}).ep == 1
    assert F.PredictContext(mesh_shape={}).cp == 1
    cfg = get_config("deepseek-v2-lite-16b")
    mctx = planner.make_context(
        cfg, {"data": 2, "expert": 4, "context": 2, "pipe": 2},
        kind="train", global_batch=8, seq_len=1024)
    assert (mctx.ep, mctx.cp, mctx.pp) == (4, 2, 2)


# ---------------------------------------------------------------------------
# parity: check == cell == columnar on ep x cp x pp grids
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["train", "prefill"])
def test_columnar_matches_cell_epcp_pp_grid(kind):
    pytest.importorskip("numpy")
    grid = SW.SweepGrid(
        arch="deepseek-v2-lite-16b", mesh_shapes=EPCP_PP_MESHES,
        kind=kind, schedules=("1f1b", "gpipe"), microbatches=(1, 4),
        grad_accums=(1, 2) if kind == "train" else (1,),
        global_batches=(8,), seq_lens=(1024,), backend="cpu")
    cell = SW.SweepEngine().sweep(grid, mode="cell")
    col = SW.SweepEngine().sweep(grid, mode="columnar")
    assert col.columns is not None
    assert len(cell) == len(col) > 0
    for a, b in zip(cell.results, col.results):
        assert a == b, f"\ncell: {a!r}\ncol:  {b!r}"


def test_columnar_matches_cell_epcp_calibrated():
    pytest.importorskip("numpy")
    grid = SW.SweepGrid(
        arch="deepseek-v2-lite-16b",
        mesh_shapes=[m for m in EPCP_PP_MESHES if m["pipe"] < 4],
        schedules=("1f1b",), microbatches=(1, 8),
        global_batches=(8,), seq_lens=(1024,), backend="tpu",
        profile=PROFILE)
    cell = SW.SweepEngine().sweep(grid, mode="cell")
    col = SW.SweepEngine().sweep(grid, mode="columnar")
    for a, b in zip(cell.results, col.results):
        assert a == b


def test_columnar_matches_cell_cp_dense_arch():
    """cp on a dense (non-MoE) arch: legal, and still byte-par."""
    pytest.importorskip("numpy")
    grid = SW.SweepGrid(
        arch="llava15-7b",
        mesh_shapes=[{"data": 2, "context": 2},
                     {"data": 1, "context": 4, "pipe": 2},
                     {"model": 2, "context": 2}],
        schedules=("1f1b",), microbatches=(1, 4),
        global_batches=(8, 16), seq_lens=(1024,), backend="cpu")
    cell = SW.SweepEngine().sweep(grid, mode="cell")
    col = SW.SweepEngine().sweep(grid, mode="columnar")
    for a, b in zip(cell.results, col.results):
        assert a == b


def test_cell_path_matches_unmemoized_check_epcp():
    grid = SW.SweepGrid(
        arch="deepseek-v2-lite-16b",
        mesh_shapes=[{"data": 1, "model": 1, "expert": 4, "context": 2,
                      "pipe": 2}],
        schedules=("1f1b", "gpipe"), microbatches=(1, 4),
        global_batches=(8,), seq_lens=(1024,), backend="cpu")
    res = SW.SweepEngine().sweep(grid, mode="cell")
    assert len(res) > 0
    for r in res.results:
        shape = ShapeConfig("cell", r.seq_len, r.global_batch, r.kind)
        ref = planner.check(r.arch, shape, r.mesh_shape,
                            backend=r.backend, grad_accum=r.grad_accum,
                            remat=r.remat, optimizer=r.optimizer,
                            chip=r.chip, microbatches=r.microbatches,
                            schedule=r.schedule)
        assert ref.peak_bytes == r.peak_bytes, r


def test_sweep_result_exposes_ep_cp():
    grid = SW.SweepGrid(
        arch="deepseek-v2-lite-16b",
        mesh_shapes=[{"data": 2, "expert": 2, "context": 2}],
        global_batches=(8,), seq_lens=(1024,), backend="tpu")
    r = SW.sweep(grid).results[0]
    assert (r.ep, r.cp, r.pp) == (2, 2, 1)


def test_enumerate_meshes_expert_context_axes():
    from repro.launch.mesh import cp_degree, enumerate_meshes, ep_degree
    meshes = enumerate_meshes(16, ("data", "expert", "context"),
                              {"expert": 4, "context": 2})
    assert all(m["data"] * m["expert"] * m["context"] == 16
               for m in meshes)
    assert {ep_degree(m) for m in meshes} == {1, 2, 4}
    assert {cp_degree(m) for m in meshes} == {1, 2}
