"""Golden-snapshot regression suite: every Eq.1 component byte-frozen.

For each registered architecture x train/prefill/decode at the canonical
cell (see tests/regen_golden.py), the full per-component breakdown —
raw and under a fixed calibration profile, plus the per-module table —
must equal the committed snapshot in tests/golden/<arch>.json exactly.

On any divergence the failure names the FIRST differing component
(e.g. ``train/calibrated/act_transient_bytes: golden 123 != current
456``) so a refactor that drifts bytes is caught at the component, not
just the total.  If the change is intentional, regenerate with::

    PYTHONPATH=src python -m tests.regen_golden

and commit the JSON diff for review.
"""

import json
import os

import pytest

from repro.configs import registered_archs
from tests.regen_golden import (GOLDEN_DIR, KINDS, LIVENESS_KIND,
                                OFFLOAD_KIND, SERVE_KIND, first_divergence,
                                golden_path, snapshot)

REGEN_HINT = ("regenerate with `PYTHONPATH=src python -m "
              "tests.regen_golden` and commit the diff if this byte "
              "change is intentional")


@pytest.mark.parametrize("arch", registered_archs())
def test_golden_component_breakdown(arch, sweep_engine):
    path = golden_path(arch)
    assert os.path.exists(path), \
        f"missing golden snapshot {path}; {REGEN_HINT}"
    with open(path) as f:
        want = json.load(f)
    got = snapshot(arch, engine=sweep_engine)
    if want != got:
        pytest.fail(f"golden drift for {arch} at "
                    f"{first_divergence(want, got)}; {REGEN_HINT}")


def test_golden_covers_all_arches_and_kinds():
    """The committed snapshot set is complete: 12 arches x (3 kinds +
    the paged-serve, optimizer-offload and liveness-assembly legs) x
    raw+calibrated, and no stale files for unregistered arches."""
    arches = registered_archs()
    files = {f[:-5] for f in os.listdir(GOLDEN_DIR) if f.endswith(".json")}
    assert files == set(arches), \
        f"golden dir out of sync: extra {files - set(arches)}, " \
        f"missing {set(arches) - files}; {REGEN_HINT}"
    extra_kinds = {SERVE_KIND, OFFLOAD_KIND, LIVENESS_KIND}
    for arch in arches:
        with open(golden_path(arch)) as f:
            payload = json.load(f)
        assert set(payload) == set(KINDS) | extra_kinds, arch
        for kind in (*KINDS, *extra_kinds):
            assert set(payload[kind]) == {"raw", "calibrated"}, (arch, kind)


def test_golden_liveness_leg_bounded_by_legacy_train():
    """The frozen liveness peak nets exactly the frozen overlap slack
    off the frozen legacy train peak, raw and calibrated."""
    for arch in registered_archs():
        with open(golden_path(arch)) as f:
            payload = json.load(f)
        for variant in ("raw", "calibrated"):
            legacy = payload["train"][variant]
            live = payload[LIVENESS_KIND][variant]
            assert live["overlap_slack_bytes"] >= 0, (arch, variant)
            assert live["peak_bytes"] <= legacy["peak_bytes"], \
                (arch, variant)
            assert live["peak_bytes"] + live["overlap_slack_bytes"] == \
                legacy["peak_bytes"], (arch, variant)


def test_first_divergence_names_component():
    want = {"train": {"raw": {"param_bytes": 10, "opt_bytes": 4}}}
    got = {"train": {"raw": {"param_bytes": 10, "opt_bytes": 5}}}
    msg = first_divergence(want, got)
    assert msg == "train/raw/opt_bytes: golden 4 != current 5"
    assert first_divergence(want, want) == ""
