"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (B, Sq, Skv, H, Hkv, D, Dv, causal, block)
    (2, 256, 256, 4, 2, 64, 64, True, 128),
    (1, 200, 200, 6, 3, 32, 32, True, 128),     # ragged seq -> padding
    (2, 1, 384, 4, 4, 64, 64, False, 128),      # decode-shaped
    (1, 256, 256, 8, 1, 128, 64, True, 128),    # MQA + Dq != Dv (MLA-like)
    (1, 130, 130, 2, 2, 64, 64, True, 128),     # off-by-two padding
    (2, 128, 256, 4, 2, 64, 64, True, 128),     # q continuation (offset)
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_fwd(case, dtype):
    B, Sq, Skv, H, Hkv, D, Dv, causal, block = case
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (B, Sq, H, D), dtype)
    k = jax.random.normal(k2, (B, Skv, Hkv, D), dtype)
    v = jax.random.normal(k3, (B, Skv, Hkv, Dv), dtype)
    qoff = Skv - Sq if causal else 0
    out = ops.flash_attention(q, k, v, causal, block, qoff, True)
    expect, _ = ref.attention_ref(q, k, v, causal, qoff)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("case", FLASH_CASES[:4])
def test_flash_attention_grads(case):
    B, Sq, Skv, H, Hkv, D, Dv, causal, block = case
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (B, Sq, H, D), jnp.float32)
    k = jax.random.normal(k2, (B, Skv, Hkv, D), jnp.float32)
    v = jax.random.normal(k3, (B, Skv, Hkv, Dv), jnp.float32)
    qoff = Skv - Sq if causal else 0

    def f_kernel(q, k, v):
        return (ops.flash_attention(q, k, v, causal, block, qoff, True)
                * jnp.cos(jnp.arange(Dv))).sum()

    def f_ref(q, k, v):
        return (ref.attention_ref(q, k, v, causal, qoff)[0]
                * jnp.cos(jnp.arange(Dv))).sum()

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4)


def test_flash_matches_model_attention_path():
    """Kernel vs the model code's pure-lax flash (one definition)."""
    from repro.models.attention import flash_attention as lax_flash
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (2, 192, 6, 64), jnp.float32)
    k = jax.random.normal(k2, (2, 192, 2, 64), jnp.float32)
    v = jax.random.normal(k3, (2, 192, 2, 64), jnp.float32)
    a = ops.flash_attention(q, k, v, True, 128, 0, True)
    b = lax_flash(q, k, v, True, 128, 0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(64, 128), (3, 50, 96), (2, 7, 33, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_fwd(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    s = jax.random.normal(jax.random.PRNGKey(5), shape[-1:], dtype)
    out = ops.rmsnorm(x, s, 1e-5, True)
    expect = ref.rmsnorm_ref(x, s)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_rmsnorm_grads():
    x = jax.random.normal(KEY, (40, 96), jnp.float32)
    s = jax.random.normal(jax.random.PRNGKey(5), (96,), jnp.float32)
    gk = jax.grad(lambda x, s: (ops.rmsnorm(x, s, 1e-5, True) ** 2).sum(),
                  argnums=(0, 1))(x, s)
    gr = jax.grad(lambda x, s: (ref.rmsnorm_ref(x, s) ** 2).sum(),
                  argnums=(0, 1))(x, s)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", [
    # (b, S, H, P, N, chunk)
    (2, 128, 4, 16, 32, 32),
    (1, 96, 2, 32, 16, 32),      # padded final chunk
    (1, 64, 1, 64, 64, 64),
])
def test_ssd_kernel(case):
    b, S, H, P, N, chunk = case
    x = jax.random.normal(KEY, (b, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (H,)) * 0.3)
    B_ = jax.random.normal(jax.random.PRNGKey(3), (b, S, N)) * 0.5
    C_ = jax.random.normal(jax.random.PRNGKey(4), (b, S, N)) * 0.5
    y, st = ops.ssd_scan(x, dt, A, B_, C_, chunk=chunk, interpret=True)
    yr, str_ = ref.ssd_ref(x, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_),
                               atol=1e-4, rtol=1e-4)


def test_ssd_kernel_matches_model_path():
    """Kernel vs models.mamba.ssd_chunked (the training path)."""
    from repro.models.mamba import ssd_chunked
    b, S, H, P, N = 1, 128, 2, 16, 32
    x = jax.random.normal(KEY, (b, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (H,)) * 0.3)
    B_ = jax.random.normal(jax.random.PRNGKey(3), (b, S, 1, N)) * 0.5
    C_ = jax.random.normal(jax.random.PRNGKey(4), (b, S, 1, N)) * 0.5
    yk, stk = ops.ssd_scan(x, dt, A, B_[:, :, 0], C_[:, :, 0],
                           chunk=32, interpret=True)
    ym, stm = ssd_chunked(x, dt, A, B_, C_, chunk=32)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(ym),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(stk), np.asarray(stm),
                               atol=1e-4, rtol=1e-4)
