"""Property tests (hypothesis) for the liveness event-program assembly.

Three invariant families the interval-overlap peak must satisfy for ANY
component byte values and ANY real sweep grid:

* bounds — the liveness peak is at least the largest single component
  (everything live at some event dominates each member) and at most the
  legacy sum-of-maxima peak (overlap can only discard slack, never add);
* ledger conservation — every within-step alloc has a matching free:
  persistent components net +1, every other component nets 0, no running
  prefix ever goes negative, and the program ends holding exactly the
  persistent set;
* grid parity — on randomized SweepGrids the columnar liveness peak is
  bounded by the columnar legacy peak cell-for-cell, and the reported
  overlap slack never pushes the liveness peak above it.

Same importorskip convention as tests/test_batch_property.py; CI runs
under the shared "ci" settings profile registered in tests/conftest.py.
"""

import pytest

np = pytest.importorskip("numpy")
pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis; `pip install hypothesis` "
           "to run them")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import registered_archs  # noqa: E402
from repro.core import liveness as LV  # noqa: E402
from repro.core import sweep as SW  # noqa: E402

_GIB = 1024 ** 3

# component byte values spanning zero, byte-scale and multi-GiB scale
_values = st.fixed_dictionaries(
    {c: st.integers(0, 64 * _GIB) for c in LV.COMPONENTS})


@settings(max_examples=200, deadline=None)
@given(kind=st.sampled_from(["train", "decode"]), values=_values)
def test_property_peak_bounds(kind, values):
    """liveness peak in [max single component, legacy sum-of-maxima]."""
    program = LV.compile_program(kind)
    rep = LV.replay(program, values)
    live = {c for ev in program.events for c, _ in ev.deltas}
    assert rep.peak >= max(values[c] for c in live)
    assert rep.peak <= sum(values[c] for c in live)
    assert rep.peak == max(rep.prefixes)
    # ties keep the earliest event
    assert rep.prefixes.index(rep.peak) == rep.event_index
    # the at-peak group decomposition reassembles the peak exactly
    assert sum(rep.group_at_peak.values()) == rep.peak


@settings(max_examples=200, deadline=None)
@given(kind=st.sampled_from(["train", "decode"]), values=_values)
def test_property_ledger_conservation(kind, values):
    """Every alloc has a matching free; the step ends holding exactly
    the persistent components and no prefix ever dips below them."""
    program = LV.compile_program(kind)
    net = program.net_deltas()
    for comp, n in net.items():
        assert n == (1 if comp in LV._PERSISTENT else 0), comp
    rep = LV.replay(program, values)
    persistent = sum(values[c] for c in LV._PERSISTENT)
    assert rep.final == persistent
    assert rep.prefixes[-1] == persistent
    assert all(p >= 0 for p in rep.prefixes)
    # delta_matrix is the same ledger in contraction form
    cols = np.array(program.delta_matrix()).sum(axis=0)
    for i, comp in enumerate(LV.COMPONENTS):
        assert cols[i] == net[comp], comp


@settings(max_examples=15, deadline=None)
@given(
    arch=st.sampled_from(registered_archs()),
    kind=st.sampled_from(["train", "prefill", "decode"]),
    chips=st.sampled_from([4, 8]),
    batches=st.lists(st.sampled_from([4, 8, 16]), min_size=1, max_size=2,
                     unique=True),
    seq=st.sampled_from([256, 512, 1024]),
    backend=st.sampled_from(["tpu", "cpu"]))
def test_property_grid_liveness_le_legacy(arch, kind, chips, batches, seq,
                                          backend):
    mk = lambda asm: SW.SweepGrid(arch=arch, chips=chips, kind=kind,
                                  global_batches=tuple(batches),
                                  seq_lens=(seq,), backend=backend,
                                  assembly=asm)
    legacy = SW.SweepEngine().sweep(mk("legacy"))
    live = SW.SweepEngine().sweep(mk("liveness"))
    assert len(legacy) == len(live) > 0
    for lg, lv in zip(legacy.results, live.results):
        assert lv.peak_bytes <= lg.peak_bytes
        assert lv.overlap_slack_bytes >= 0
        # slack is taken against the liveness-winning stage's legacy
        # peak, which is itself bounded by the overall legacy peak
        assert lv.peak_bytes + lv.overlap_slack_bytes <= lg.peak_bytes
        assert lg.overlap_slack_bytes == 0
