"""Property tests (hypothesis) for the sharding-resolution core.

The system's central invariant: ``shard_factor`` (used by the memory
predictor) and ``resolve_pspec`` (used by the runtime) are arithmetic twins
— they may never disagree, or predictions drift from execution.
"""

import math

import jax
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis; `pip install hypothesis` "
           "(see requirements.txt) to run them")
from hypothesis import given, settings, strategies as st

from repro.mesh_ctx import (DEFAULT_RULES, assign_axes, resolve_pspec,
                            shard_factor)

AXES = [None, "batch", "seq", "vocab", "heads", "kv_heads", "ffn",
        "experts", "layers", "embed"]

dims = st.integers(min_value=1, max_value=4096)
axis_names = st.sampled_from(AXES)
mesh_sizes = st.fixed_dictionaries({
    "pod": st.sampled_from([1, 2]),
    "data": st.sampled_from([1, 2, 4, 8, 16]),
    "model": st.sampled_from([1, 2, 4, 8, 16]),
})


@st.composite
def shaped(draw):
    rank = draw(st.integers(min_value=1, max_value=4))
    shape = tuple(draw(dims) for _ in range(rank))
    axes = tuple(draw(axis_names) for _ in range(rank))
    return shape, axes


@given(shaped(), mesh_sizes)
@settings(max_examples=200, deadline=None)
def test_shard_factor_divides_size(sa, sizes):
    shape, axes = sa
    f = shard_factor(shape, axes, sizes)
    total = math.prod(shape)
    assert f >= 1
    assert total % f == 0, "shard factor must divide the element count"


@given(shaped(), mesh_sizes)
@settings(max_examples=200, deadline=None)
def test_per_dim_divisibility(sa, sizes):
    shape, axes = sa
    per_dim = assign_axes(shape, axes, sizes, dict(DEFAULT_RULES))
    for dim, assigned in zip(shape, per_dim):
        k = math.prod(sizes[a] for a in assigned)
        assert dim % k == 0
    flat = [a for d in per_dim for a in d]
    assert len(flat) == len(set(flat)), "a mesh axis may appear only once"


@given(shaped(), mesh_sizes)
@settings(max_examples=200, deadline=None)
def test_fsdp_extra_never_on_layers(sa, sizes):
    shape, axes = sa
    per_dim = assign_axes(shape, axes, sizes, dict(DEFAULT_RULES),
                          extra=("data",))
    for ax, assigned in zip(axes, per_dim):
        if ax == "layers":
            assert "data" not in assigned


@given(shaped(), mesh_sizes)
@settings(max_examples=100, deadline=None)
def test_factor_bounded_by_mesh(sa, sizes):
    shape, axes = sa
    f = shard_factor(shape, axes, sizes)
    assert f <= math.prod(sizes.values())


@given(shaped())
@settings(max_examples=50, deadline=None)
def test_empty_mesh_means_replicated(sa):
    shape, axes = sa
    assert shard_factor(shape, axes, {}) == 1


def test_twin_consistency_on_live_mesh():
    """resolve_pspec sharding == shard_factor arithmetic on a real mesh."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sizes = {"data": 1, "model": 1}
    for shape, axes in [((16, 64), ("batch", "embed")),
                        ((4, 128, 30), ("batch", "seq", "heads"))]:
        spec = resolve_pspec(shape, axes, mesh)
        f = shard_factor(shape, axes, sizes)
        sharded = math.prod(
            sizes[a] for entry in spec
            for a in ((entry,) if isinstance(entry, str) else entry or ()))
        assert sharded == f


def test_known_cases():
    sizes = {"data": 16, "model": 16}
    # batch 4 not divisible by data=16 -> replicated; merged heads 960 shard
    assert shard_factor((4, 128, 960), ("batch", "seq", "heads"),
                        sizes) == 16
    # batch divisible -> both axes engage
    assert shard_factor((64, 128, 960), ("batch", "seq", "heads"),
                        sizes) == 256
    # smollm's 4-D head layout: 15 heads do NOT divide model=16 -> replicate
    assert shard_factor((64, 128, 15, 64),
                        ("batch", "seq", "heads", None), sizes) == 16
    # sequence parallelism rule override
    rules = dict(DEFAULT_RULES, seq=("model",))
    assert shard_factor((64, 4096, 1024), ("batch", "seq", "embed"),
                        sizes, rules) == 256
