"""Per-arch smoke tests (reduced configs) + model math consistency.

Every assigned architecture: one train step (finite loss, shapes) and one
prefill->decode serve step on CPU.  Plus decode-vs-forward consistency —
the KV/latent/SSM cache path must reproduce full-context logits.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.conftest import tiny_batch
from repro.configs import ARCH_NAMES, ShapeConfig, get_config
from repro.core.spec import FULL_TRAIN
from repro.models import build_model
from repro.models import param as PM
from repro.train import OptimizerConfig, TrainState, make_train_step
from repro.train.optimizer import init_opt_state


def make_state(model, params=None, policy=FULL_TRAIN, opt="adamw"):
    """TrainState from (optionally pre-initialized, session-cached)
    params — the jitted steps never donate in tests, so shared params
    are never invalidated."""
    if params is None:
        params = model.init(jax.random.PRNGKey(0))
    mask = PM.trainable_mask(model.spec, policy)
    trainable, _ = PM.partition_params(params, mask)
    opt_state = init_opt_state(trainable, OptimizerConfig(name=opt))
    return TrainState(params=params, opt=opt_state, step=jnp.int32(0))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_smoke(arch, reduced_zoo):
    cfg, model, params = reduced_zoo(arch)
    state = make_state(model, params)
    shape = ShapeConfig("t", 64, 2, "train")
    batch = tiny_batch(model, shape)
    step = jax.jit(make_train_step(model, FULL_TRAIN,
                                   OptimizerConfig(name="adamw")))
    state2, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert float(metrics["loss"]) > 0
    assert int(state2.step) == 1
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: None if a is None else float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))),
        state.params, state2.params, is_leaf=lambda x: x is None)
    assert max(x for x in jax.tree.leaves(moved) if x is not None) > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_smoke(arch, reduced_zoo):
    cfg, model, params = reduced_zoo(arch)
    shape = ShapeConfig("p", 32, 2, "prefill")
    batch = tiny_batch(model, shape)
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab
    assert bool(jnp.isfinite(logits).all())
    if cfg.family == "encdec":
        cache = model.init_cache(2, 32, enc_len=32)
    tok = jnp.ones((2, 1), jnp.int32)
    logits2, cache2 = jax.jit(model.decode_step)(params, tok, cache)
    assert logits2.shape == (2, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", ["smollm-360m", "qwen3-32b",
                                  "deepseek-v2-lite-16b", "mamba2-1.3b",
                                  "zamba2-2.7b"])
def test_decode_matches_forward(arch, reduced_zoo):
    """Teacher-forced decode over a short sequence must reproduce the
    full-context forward logits (cache correctness, incl. MLA + SSM)."""
    cfg, model, params = reduced_zoo(arch)
    S = 16
    tokens = jax.random.randint(jax.random.PRNGKey(3), (1, S), 0, cfg.vocab)

    # full-context prefill logits at the last position
    logits_full, _ = jax.jit(model.prefill)(params, {"tokens": tokens})

    # token-by-token decode
    cache = model.init_cache(1, S)
    decode = jax.jit(model.decode_step)
    logits_step = None
    for t in range(S):
        logits_step, cache = decode(params, tokens[:, t:t + 1], cache)

    # MoE: bf16 rounding differences between the full-seq and per-token
    # paths can flip borderline top-k routing -> slightly looser bound.
    tol = 8e-2 if cfg.moe else 2e-2
    np.testing.assert_allclose(
        np.asarray(logits_full[:, -1], np.float32).ravel(),
        np.asarray(logits_step[:, 0], np.float32).ravel(),
        atol=tol, rtol=tol)


def test_vlm_frozen_vision_stage1(reduced_zoo):
    """LLaVA stage-1: only the projector trains; vision/LM stay frozen."""
    from repro.core.spec import LLAVA_STAGE1
    cfg, model, params = reduced_zoo("llava-next-mistral-7b")
    state = make_state(model, params, LLAVA_STAGE1)
    shape = ShapeConfig("t", 64, 2, "train")
    batch = tiny_batch(model, shape)
    step = jax.jit(make_train_step(model, LLAVA_STAGE1,
                                   OptimizerConfig(name="adamw")))
    state2, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])

    mask = PM.trainable_mask(model.spec, LLAVA_STAGE1)
    flat0, _ = jax.tree_util.tree_flatten_with_path(state.params)
    flat1, _ = jax.tree_util.tree_flatten_with_path(state2.params)
    flatm = jax.tree.leaves(mask)
    for (p0, a), (p1, b), m in zip(flat0, flat1, flatm):
        same = bool(jnp.all(a == b))
        if m:
            assert not same, f"trainable leaf did not move: {p0}"
        else:
            assert same, f"frozen leaf moved: {p0}"


def test_loss_decreases_under_training(reduced_zoo):
    cfg, model, params = reduced_zoo("smollm-360m")
    state = make_state(model, params)
    shape = ShapeConfig("t", 64, 4, "train")
    batch = tiny_batch(model, shape)  # overfit one fixed batch
    step = jax.jit(make_train_step(model, FULL_TRAIN,
                                   OptimizerConfig(name="adamw", lr=1e-3)))
    losses = []
    for _ in range(30):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::6]


def test_grad_accum_equivalence(reduced_zoo):
    """grad_accum=2 must match a single full-batch step (same update)."""
    cfg, model, params = reduced_zoo("smollm-360m")
    shape = ShapeConfig("t", 32, 4, "train")
    batch = tiny_batch(model, shape)

    s1 = make_state(model, params)
    s2 = make_state(model, params)
    step1 = jax.jit(make_train_step(model, FULL_TRAIN,
                                    OptimizerConfig(name="adamw")))
    step2 = jax.jit(make_train_step(model, FULL_TRAIN,
                                    OptimizerConfig(name="adamw"),
                                    grad_accum=2))
    s1, m1 = step1(s1, batch)
    s2, m2 = step2(s2, batch)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        s1.params, s2.params)
    worst = max(jax.tree.leaves(d))
    assert worst < 5e-2, f"accum diverges from full batch by {worst}"
    # losses match (mean over microbatches == full-batch mean for equal sizes)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2


@pytest.mark.parametrize("remat", ["none", "block", "dots"])
def test_remat_policies_same_loss(remat, reduced_zoo):
    cfg, model, params = reduced_zoo("smollm-360m")
    shape = ShapeConfig("t", 32, 2, "train")
    batch = tiny_batch(model, shape)
    loss, _ = jax.jit(lambda p, b: model.loss(p, b, remat=remat))(params,
                                                                  batch)
    loss_ref, _ = jax.jit(lambda p, b: model.loss(p, b,
                                                  remat="none"))(params,
                                                                 batch)
    np.testing.assert_allclose(float(loss), float(loss_ref), rtol=1e-3)
