"""Property tests (hypothesis) for the monotone structure core/search.py
prunes with.  Each invariant is CI-load-bearing: if a new knob or term
breaks one, the branch-and-bound searches could silently mis-prune, so
these run under the shared fixed-seed "ci" profile (tests/conftest.py)
and a violation fails CI before the pruner can return a wrong answer.

Invariants (each also has deterministic anchor cases in
tests/test_search.py so local runs without hypothesis keep coverage):

* aligned-floor lemma — ``peak(gb) >= peak(L * (gb // L))`` where L is
  the mesh's non-pipe axis product: rounding gb DOWN to the ladder
  never increases the peak;
* ladder monotonicity — along multiples of L the peak is non-decreasing
  in global batch (the bracket monotone_max binary-searches);
* seq monotonicity — peak non-decreasing in sequence length at a fixed
  mesh and aligned batch;
* data-axis monotonicity — doubling the ``data`` axis at batches
  aligned to the doubled mesh leaves every batch-bearing
  PredictedMemory component non-increasing (and the peak, on archs
  whose params don't reshard with data);
* statics floor — ``floor // n_chips <= peak`` for every cell of a
  random grid (the min_chips/frontier pruning bound);
* pruned == exhaustive — min_chips_search and frontier_search in
  oracle mode on randomized grids (the oracle raises on divergence).

The helpers below are plain functions so the deterministic twins and
local debugging can call them directly.
"""

import pytest

np = pytest.importorskip("numpy")
pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis; `pip install hypothesis` "
           "to run them")
from hypothesis import assume, given, settings, strategies as st  # noqa: E402

from repro.configs import ShapeConfig, get_config  # noqa: E402
from repro.core import planner as PL  # noqa: E402
from repro.core import search as SR  # noqa: E402
from repro.core import sweep as SW  # noqa: E402
from repro.core.spec import FULL_TRAIN  # noqa: E402

ENG = SW.SweepEngine()          # memoized across examples on purpose
BUDGET = int(PL.chip_hbm("v5e") * PL.HEADROOM)

#: small-static archs: scalar report() probes stay cheap, and the span
#: still crosses dense / MoE-free / ssm / hybrid / multimodal families
ARCHS = ("smollm-360m", "llama3.2-3b", "mamba2-1.3b", "zamba2-2.7b",
         "minicpm3-4b")
KINDS = ("train", "prefill", "decode")

#: batch-bearing PredictedMemory components: the ``data`` axis reaches
#: them only through gb-derived dims, so at aligned batches doubling it
#: can only grow their shard denominators
BATCH_COMPONENTS = ("act_saved_bytes", "act_transient_bytes",
                    "loss_bytes", "input_bytes", "cache_bytes")


def report(arch, seq, gb, kind, mesh):
    return ENG.report(arch, ShapeConfig("prop", seq, gb, kind),
                      dict(mesh), budget_bytes=BUDGET, chip="v5e")


def peak(arch, seq, gb, kind, mesh):
    return report(arch, seq, gb, kind, mesh).peak_bytes


# ---------------------------------------------------------------------------
# batch / seq monotonicity (the plan_max_concurrency bound)
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(arch=st.sampled_from(ARCHS), kind=st.sampled_from(KINDS),
       data=st.sampled_from([1, 2, 4]), model=st.sampled_from([1, 2]),
       gb=st.integers(1, 192), seq=st.sampled_from([512, 1024]))
def test_aligned_floor_lemma(arch, kind, data, model, gb, seq):
    mesh = {"data": data, "model": model}
    L = SR.batch_align(mesh)
    assume(gb >= L)
    assert peak(arch, seq, gb, kind, mesh) \
        >= peak(arch, seq, L * (gb // L), kind, mesh)


@settings(max_examples=40, deadline=None)
@given(arch=st.sampled_from(ARCHS), kind=st.sampled_from(KINDS),
       data=st.sampled_from([1, 2, 4]), model=st.sampled_from([1, 2]),
       k1=st.integers(1, 48), k2=st.integers(1, 48),
       seq=st.sampled_from([512, 1024]))
def test_ladder_monotone_in_batch(arch, kind, data, model, k1, k2, seq):
    assume(k1 < k2)
    mesh = {"data": data, "model": model}
    L = SR.batch_align(mesh)
    assert peak(arch, seq, k1 * L, kind, mesh) \
        <= peak(arch, seq, k2 * L, kind, mesh)


@settings(max_examples=30, deadline=None)
@given(arch=st.sampled_from(ARCHS), kind=st.sampled_from(KINDS),
       data=st.sampled_from([1, 2]), model=st.sampled_from([1, 2]),
       k=st.integers(1, 8), seq=st.sampled_from([256, 512, 1024]))
def test_monotone_in_seq(arch, kind, data, model, k, seq):
    mesh = {"data": data, "model": model}
    gb = k * SR.batch_align(mesh)
    assert peak(arch, seq, gb, kind, mesh) \
        <= peak(arch, 2 * seq, gb, kind, mesh)


@settings(max_examples=30, deadline=None)
@given(arch=st.sampled_from(ARCHS), kind=st.sampled_from(KINDS),
       data=st.sampled_from([1, 2, 4]), k=st.integers(1, 16),
       seq=st.sampled_from([512, 1024]))
def test_data_axis_components_non_increasing(arch, kind, data, k, seq):
    """Doubling data at a batch aligned to the DOUBLED mesh: every
    batch-bearing component is non-increasing, and on archs whose
    params don't reshard with data (no FSDP) so is the peak."""
    gb = k * 2 * data
    a = report(arch, seq, gb, kind, {"data": data, "model": 1}).prediction
    b = report(arch, seq, gb, kind,
               {"data": 2 * data, "model": 1}).prediction
    for comp in BATCH_COMPONENTS:
        assert getattr(b, comp) <= getattr(a, comp), comp
    if not get_config(SW.normalize_arch(arch)).fsdp:
        assert b.peak_bytes <= a.peak_bytes


# ---------------------------------------------------------------------------
# statics floor + pruned-vs-exhaustive on randomized grids
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(arch=st.sampled_from(ARCHS), kind=st.sampled_from(KINDS),
       chips=st.sampled_from([(4,), (8,), (4, 8)]),
       opt=st.sampled_from([None, "adamw", "adafactor", "adamw8bit"]),
       offload=st.booleans(),
       gbs=st.lists(st.integers(1, 64), min_size=1, max_size=2,
                    unique=True),
       seq=st.sampled_from([512, 1024]))
def test_statics_floor_bounds_every_cell(arch, kind, chips, opt,
                                         offload, gbs, seq):
    grid = SW.SweepGrid(arch=arch, chips=chips, chip="v5e",
                        optimizers=(opt,),
                        offload_optimizer=(False, True) if offload
                        and kind == "train" else (False,),
                        global_batches=tuple(gbs), seq_lens=(seq,),
                        kind=kind)
    floor = SR._floor_for(grid)
    res = ENG.sweep(grid)
    assume(len(res))
    bound = floor // res.columns.n_chips
    assert int((res.columns.peak_bytes < bound).sum()) == 0


@settings(max_examples=20, deadline=None)
@given(arch=st.sampled_from(ARCHS),
       chips=st.sampled_from([(2, 4, 8), (4, 16), (8, 16, 32)]),
       gb=st.sampled_from([8, 16, 64]),
       seq=st.sampled_from([512, 2048]),
       mbs=st.sampled_from([(1,), (1, 2, 4)]),
       allow_pp=st.booleans())
def test_pruned_searches_equal_exhaustive(arch, chips, gb, seq, mbs,
                                          allow_pp):
    shape = ShapeConfig("prop", seq, gb, "train")
    grid = PL._search_grid(arch, shape, chips, "v5e", FULL_TRAIN, "tpu",
                           PL.HEADROOM, allow_pp, 8, False, 8, False, 8,
                           mbs, ("1f1b",), None)
    assume(grid is not None)
    SR.min_chips_search(grid, engine=ENG, oracle=True)  # raises on drift
    fgrid = PL._search_grid(arch, shape, chips, "v5e", FULL_TRAIN, "tpu",
                            PL.HEADROOM, allow_pp, 8, False, 8, False, 8,
                            mbs, ("1f1b",), None,
                            global_batches=(gb, gb // 2 or 1, 1))
    SR.frontier_search(fgrid, engine=ENG, oracle=True)
