"""Optimizer unit tests: math vs reference, chunked-update equivalence,
8-bit state quantization error bounds, state-byte accounting exactness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.factors import opt_bytes_for
from repro.core.spec import ParamSpec
from repro.train.optimizer import (OptimizerConfig, apply_updates,
                                   init_opt_state, _leaf_update)


def _tree(key, shapes):
    ks = jax.random.split(key, len(shapes))
    return {f"w{i}": jax.random.normal(k, s, jnp.float32) * 0.1
            for i, (k, s) in enumerate(zip(ks, shapes))}


def test_adamw_matches_reference():
    cfg = OptimizerConfig(name="adamw", lr=1e-2, weight_decay=0.0)
    p = _tree(jax.random.PRNGKey(0), [(8, 16)])
    g = _tree(jax.random.PRNGKey(1), [(8, 16)])
    st = init_opt_state(p, cfg)
    newp, newst = apply_updates(p, g, st, jnp.float32(1), cfg)

    # textbook Adam, step 1
    m = 0.1 * np.asarray(g["w0"])
    v = 0.05 * np.asarray(g["w0"]) ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.95)
    expect = np.asarray(p["w0"]) - 1e-2 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(newp["w0"]), expect, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(newst["w0"]["m"]), m, rtol=1e-6)


def test_chunked_update_matches_monolithic():
    """The depth-chunked update (arctic memory fix) is bit-compatible."""
    for name in ("adamw", "adafactor"):
        cfg = OptimizerConfig(name=name,
                              master_fp32=(name == "adamw"))
        p = _tree(jax.random.PRNGKey(0), [(6, 16, 32)])
        g = _tree(jax.random.PRNGKey(1), [(6, 16, 32)])
        st = init_opt_state(p, cfg)
        p1, s1 = apply_updates(p, g, st, jnp.float32(3), cfg, chunked=True)
        p2, s2 = apply_updates(p, g, st, jnp.float32(3), cfg, chunked=False)
        if name == "adamw":   # elementwise -> equal up to fusion rounding
            np.testing.assert_allclose(np.asarray(p1["w0"]),
                                       np.asarray(p2["w0"]),
                                       atol=1e-7, rtol=1e-6)
        else:                 # adafactor RMS clip is per-slice (documented)
            np.testing.assert_allclose(np.asarray(p1["w0"]),
                                       np.asarray(p2["w0"]), atol=1e-3)


def test_adamw8bit_tracks_fp32_adam():
    cfg8 = OptimizerConfig(name="adamw8bit", lr=1e-2, weight_decay=0.0)
    cfg32 = OptimizerConfig(name="adamw", lr=1e-2, weight_decay=0.0)
    p = _tree(jax.random.PRNGKey(0), [(32, 64)])
    st8, st32 = init_opt_state(p, cfg8), init_opt_state(p, cfg32)
    p8, p32 = p, p
    for step in range(1, 6):
        g = _tree(jax.random.PRNGKey(step), [(32, 64)])
        p8, st8 = apply_updates(p8, g, st8, jnp.float32(step), cfg8)
        p32, st32 = apply_updates(p32, g, st32, jnp.float32(step), cfg32)
    err = float(jnp.max(jnp.abs(p8["w0"] - p32["w0"])))
    rng = float(jnp.max(jnp.abs(p32["w0"] - p["w0"])))
    assert err < 0.15 * rng, (err, rng)


def test_adafactor_second_moment_factored():
    cfg = OptimizerConfig(name="adafactor", master_fp32=False)
    p = _tree(jax.random.PRNGKey(0), [(16, 32)])
    st = init_opt_state(p, cfg)
    assert st["w0"]["v_row"].shape == (16,)
    assert st["w0"]["v_col"].shape == (32,)
    assert "master" not in st["w0"]


@pytest.mark.parametrize("opt,master", [("adamw", True), ("adamw", False),
                                        ("adamw8bit", True),
                                        ("adafactor", False)])
@pytest.mark.parametrize("shape", [(8,), (16, 32), (4, 16, 32)])
def test_opt_bytes_accounting_exact(opt, master, shape):
    """core.factors.opt_bytes_for mirrors the real state bytes exactly."""
    cfg = OptimizerConfig(name=opt, master_fp32=master)
    p = {"w": jnp.zeros(shape, jnp.bfloat16)}
    st = init_opt_state(p, cfg)
    actual = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(st))
    spec = ParamSpec(shape, "bfloat16")
    predicted = opt_bytes_for(spec, shape, opt, master)
    assert predicted == actual, (predicted, actual)
